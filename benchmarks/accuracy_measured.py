"""§IV-C reproduction with *measured* accuracy: trains reduced CNNs on the
synthetic classification task, then evaluates real partitioned fake-quant
inference per cut (weights at each platform's bit width, link activations
quantized) and optional QAT recovery.

Validates: (a) later cuts (more layers on the 16-bit platform) give higher
top-1 — Fig. 2(c)/(f) trend; (b) QAT recovers accuracy lost to aggressive
quantization."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, timed
from repro.core import QuantSpec
from repro.utils.atomicio import atomic_write_json
from repro.data.synthetic import SyntheticImages, batch_iterator
from repro.models.cnn.zoo import reduced_cnn
from repro.optim.optimizers import adamw
from repro.optim.schedules import warmup_cosine
from repro.quantize.evaluate import (cnn_measured_accuracy, qat_finetune,
                                     quantized_eval)
from repro.training.train_lib import (evaluate_classifier,
                                      make_classifier_train_step)

TRAIN_STEPS = 400


def train_cnn(name: str, steps: int = TRAIN_STEPS):
    m = reduced_cnn(name)
    p, s = m.init(jax.random.PRNGKey(0))
    ds = SyntheticImages(noise=0.2)
    opt = adamw(warmup_cosine(2e-3, steps // 10, steps))
    os_ = opt.init(p)
    step = jax.jit(make_classifier_train_step(m, opt))
    for i in range(steps):
        x, y = ds.batch(64, i)
        p, os_, s, _ = step(p, os_, s, jnp.asarray(x), jnp.asarray(y))
    return m, p, s, ds


def run(out_dir: str = "experiments", models=("resnet50", "efficientnet_b0"),
        steps: int = TRAIN_STEPS):
    os.makedirs(out_dir, exist_ok=True)
    rows, out = [], {}
    for name in models:
        (m, p, s, ds), dt_train = timed(train_cnn, name, steps)
        vx, vy = ds.eval_set(512)
        acc_fp = evaluate_classifier(m, p, s, jnp.asarray(vx), jnp.asarray(vy))

        graph = m.to_graph()
        sched = graph.topo_sort()
        cuts = graph.clean_cuts(sched)
        # thin out cuts for speed: ~8 evenly spaced
        cuts_used = cuts[:: max(1, len(cuts) // 8)]
        specs = [QuantSpec(bits=16), QuantSpec(bits=4)]  # A precise, B coarse
        acc_fn = cnn_measured_accuracy(m, p, s, sched, vx, vy, specs)
        curve = [{"cut": c, "layer": sched[c].name,
                  "accuracy": acc_fn((c,))} for c in cuts_used]
        accs = [pt["accuracy"] for pt in curve]
        # trend: later cut => more layers on the 16-bit platform => higher acc
        trend_ok = accs[-1] >= accs[0]
        # QAT recovery at the most aggressive setting (all on 4-bit B)
        acc_all_b = acc_fn((-1,))
        it = batch_iterator(ds, 64, start_seed=9000)
        (p_qat, s_qat), dt_qat = timed(
            qat_finetune, m, p, s, QuantSpec(bits=4), adamw(5e-4), it, 60)
        acc_qat = quantized_eval(m, p_qat, s_qat, vx, vy, QuantSpec(bits=4))
        out[name] = {"acc_fp32": acc_fp, "curve": curve,
                     "acc_all_on_B_4bit": acc_all_b, "acc_after_qat": acc_qat,
                     "later_cut_higher_acc": bool(trend_ok),
                     "train_s": round(dt_train, 1),
                     "qat_s": round(dt_qat, 1)}
        rows.append(csv_row(
            f"acc_measured_{name}", (dt_train + dt_qat) * 1e6,
            f"fp={acc_fp:.3f};first_cut={accs[0]:.3f};"
            f"last_cut={accs[-1]:.3f};allB4={acc_all_b:.3f};"
            f"qat={acc_qat:.3f}"))
    atomic_write_json(os.path.join(out_dir, "accuracy_measured.json"), out)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
