"""Shared benchmark plumbing: the paper's evaluation system (§V-A), both as
live ``SystemConfig`` objects and as declarative ``SystemSpec``s for the
``repro.explore`` campaign API."""

from __future__ import annotations

import dataclasses
import time

from repro.core import SystemConfig
from repro.core.hwmodel import EYERISS_LIKE, SIMBA_LIKE
from repro.core.hwmodel.arch import register_arch
from repro.explore import PlatformSpec, SystemSpec

PAPER_CNNS = ["vgg16", "resnet50", "squeezenet11", "googlenet",
              "regnetx_400mf", "efficientnet_b0"]

# leakage-dominated energy-table variants (Fig. 2 sensitivity ablation, see
# paper_system_spec) registered under their own arch names so declarative
# specs can reference them — distinct names keep cost-table caches separate
EYR_LEAKY = dataclasses.replace(
    EYERISS_LIKE, name="EYR-leaky",
    energy=dataclasses.replace(EYERISS_LIKE.energy, leakage_w=0.05))
SMB_LEAKY = dataclasses.replace(
    SIMBA_LIKE, name="SMB-leaky",
    energy=dataclasses.replace(SIMBA_LIKE.energy, leakage_w=0.08))
register_arch(EYR_LEAKY, "eyr_leaky")
register_arch(SMB_LEAKY, "smb_leaky")


def paper_system_spec(variant: str = "efficient") -> SystemSpec:
    """Platform A: 16-bit Eyeriss-like; B: Simba-like; GigE link (§V-A).

    Energy-table variants (Fig. 2 sensitivity ablation, EXPERIMENTS
    §Paper-validation): 'efficient' = int8 SMB with low static power (our
    default Accelergy-class constants); 'leaky' = both platforms
    leakage-dominated (50/80 mW) — under which the paper's dual
    latency+energy win for VGG/SqueezeNet reproduces, because the slow SMB
    pays static energy for its longer runtime."""
    suffix = "_leaky" if variant == "leaky" else ""
    return SystemSpec(
        platforms=(PlatformSpec("A", f"eyr{suffix}", bits=16),
                   PlatformSpec("B", f"smb{suffix}", bits=8)),
        links=("gige",),
        name=f"EYR+SMB{suffix}")


def chain_system_spec(n_eyr: int = 2, n_smb: int = 2) -> SystemSpec:
    """§V-C: chain of 2×EYR then 2×SMB over GigE."""
    plats = tuple([PlatformSpec(f"EYR{i}", "eyr", bits=16)
                   for i in range(n_eyr)] +
                  [PlatformSpec(f"SMB{i}", "smb", bits=8)
                   for i in range(n_smb)])
    return SystemSpec(platforms=plats, links=("gige",) * (len(plats) - 1),
                      name=f"{n_eyr}xEYR+{n_smb}xSMB")


def paper_system(variant: str = "efficient") -> SystemConfig:
    """Live-object form of :func:`paper_system_spec`."""
    return paper_system_spec(variant).build()


def chain_system(n_eyr: int = 2, n_smb: int = 2) -> SystemConfig:
    """Live-object form of :func:`chain_system_spec`."""
    return chain_system_spec(n_eyr, n_smb).build()


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
