"""Shared benchmark plumbing: the paper's evaluation system (§V-A)."""

from __future__ import annotations

import time
from typing import List, Optional

from repro.core import (Constraints, Explorer, Platform, QuantSpec,
                        SystemConfig, get_link)
from repro.core.hwmodel import EYERISS_LIKE, SIMBA_LIKE

PAPER_CNNS = ["vgg16", "resnet50", "squeezenet11", "googlenet",
              "regnetx_400mf", "efficientnet_b0"]


def paper_system(variant: str = "efficient") -> SystemConfig:
    """Platform A: 16-bit Eyeriss-like; B: Simba-like; GigE link (§V-A).

    Energy-table variants (Fig. 2 sensitivity ablation, EXPERIMENTS
    §Paper-validation): 'efficient' = int8 SMB with low static power (our
    default Accelergy-class constants); 'leaky' = both platforms
    leakage-dominated (50/80 mW) — under which the paper's dual
    latency+energy win for VGG/SqueezeNet reproduces, because the slow SMB
    pays static energy for its longer runtime."""
    import dataclasses
    eyr, smb = EYERISS_LIKE, SIMBA_LIKE
    if variant == "leaky":
        eyr = dataclasses.replace(
            eyr, energy=dataclasses.replace(eyr.energy, leakage_w=0.05))
        smb = dataclasses.replace(
            smb, energy=dataclasses.replace(smb.energy, leakage_w=0.08))
    return SystemConfig(
        [Platform("A", eyr, QuantSpec(bits=16)),
         Platform("B", smb, QuantSpec(bits=8))],
        [get_link("gige")])


def chain_system(n_eyr: int = 2, n_smb: int = 2) -> SystemConfig:
    """§V-C: chain of 2×EYR then 2×SMB over GigE."""
    plats = ([Platform(f"EYR{i}", EYERISS_LIKE, QuantSpec(bits=16))
              for i in range(n_eyr)] +
             [Platform(f"SMB{i}", SIMBA_LIKE, QuantSpec(bits=8))
              for i in range(n_smb)])
    return SystemConfig(plats, [get_link("gige")] * (len(plats) - 1))


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
