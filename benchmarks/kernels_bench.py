"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle on CPU.

On CPU these numbers measure the *correctness harness*, not TPU speed —
the derived column therefore reports the arithmetic intensity and the
projected v5e roofline time for each kernel invocation, which is the
number that matters for the §Roofline analysis."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.kernels import ref

V5E_FLOPS = 197e12
V5E_BW = 819e9


def _bench(fn, *args, repeat=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat


def run(out_dir: str = "experiments"):
    rows = []
    key = jax.random.PRNGKey(0)

    # quant_matmul 512x512x512
    m = k = n = 512
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.05
    w_scale = jnp.abs(w).max(axis=0) / 127.0
    w_q = jnp.clip(jnp.round(w / w_scale), -128, 127).astype(jnp.int8)
    x_scale = jnp.abs(x).max() / 127.0
    dt = _bench(jax.jit(ref.quant_matmul), x, w_q, w_scale, x_scale)
    flops = 2 * m * k * n
    bytes_ = (m * k + k * n + m * n) * 4
    roof = max(flops / V5E_FLOPS, bytes_ / V5E_BW)
    rows.append(csv_row("quant_matmul_512", dt * 1e6,
                        f"AI={flops/bytes_:.1f};v5e_roofline_us={roof*1e6:.1f}"))

    # ssd_scan b2 t512 h4 p64 n64
    b, t, h, p, nst, chunk = 2, 512, 4, 64, 64, 128
    ks = jax.random.split(key, 5)
    xs = jax.random.normal(ks[0], (b, t, h, p))
    dts = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, t, nst)) * 0.5
    C = jax.random.normal(ks[4], (b, t, nst)) * 0.5
    fn = jax.jit(lambda *a: ref.ssd_scan(*a, chunk))
    dt1 = _bench(fn, xs, dts, A, B, C)
    # SSD flops: intra-chunk (c*c*n + c*c*p) + states per chunk
    nc = t // chunk
    flops = 2 * b * nc * (chunk * chunk * nst + h * chunk * chunk * p
                          + 2 * h * chunk * p * nst)
    bytes_ = (xs.size + dts.size + B.size + C.size) * 4 * 2
    roof = max(flops / V5E_FLOPS, bytes_ / V5E_BW)
    rows.append(csv_row("ssd_scan_512", dt1 * 1e6,
                        f"AI={flops/bytes_:.1f};v5e_roofline_us={roof*1e6:.2f}"))

    # window attention t1024 w256
    t2, w2, h2, hd = 1024, 256, 8, 64
    q = jax.random.normal(ks[0], (1, t2, h2, hd))
    kk = jax.random.normal(ks[1], (1, t2, h2, hd))
    v = jax.random.normal(ks[2], (1, t2, h2, hd))
    fn2 = jax.jit(lambda *a: ref.window_attn(*a, w2))
    dt2 = _bench(fn2, q, kk, v)
    flops = 2 * 2 * t2 * w2 * h2 * hd
    bytes_ = (q.size * 3 + q.size) * 4
    roof = max(flops / V5E_FLOPS, bytes_ / V5E_BW)
    rows.append(csv_row("window_attn_1k_w256", dt2 * 1e6,
                        f"AI={flops/bytes_:.1f};v5e_roofline_us={roof*1e6:.1f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
