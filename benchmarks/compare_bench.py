"""Perf-regression gate over ``BENCH_explorer.json`` artifacts.

Diffs the search-path throughput keys of the current benchmark run against a
baseline (the previous successful CI run's uploaded artifact, falling back
to the committed ``benchmarks/baseline_explorer.json``) and exits non-zero
when any tracked metric regressed by more than ``--max-regression``
(default 20%) — the ROADMAP "perf trajectory" gate.

Tracked keys:

* higher is better: ``batch_evals_per_s``, ``nsga_evals_per_s``,
  ``jit_nsga_evals_per_s``, ``jit_nsga_scale_evals_per_s``,
  ``serve_tokens_per_s``, ``requests_recovered``
* lower is better:  ``campaign_wall_s``, ``fleet_sweep_wall_s``,
  ``recovery_ms``, ``serve_obs_overhead_pct``

Baselines are only comparable when both their ``bench_schema`` *and* their
``mode`` (quick vs full) match the current run's: key semantics change
across schema bumps (e.g. schema 2 moved ``nsga_evals_per_s`` to pop 2048)
and quick/full runs measure different workload sizes under the same keys,
so diffing across either boundary gates on incomparable numbers.
Mismatching baselines are skipped with a warning.  The committed fallback
baseline is an intentionally conservative floor (CI runners are slower
than dev machines), not a fresh measurement.

CI runs the gate twice: tight (20%) against the deterministic committed
floor, and looser (``--max-regression 0.5``) against the previous run's
artifact — absolute evals/s vary across heterogeneous hosted runners, so a
tight threshold there would flag runner lottery, not code.

``--trend BENCH_trend.json`` additionally fits a least-squares slope over
the last ``--trend-window`` comparable runs of each tracked metric (same
``bench_schema`` and ``mode`` as the current run): per-run noise averages
out over the window, so a sustained drift each individual ±20%/±50% gate
waves through — e.g. −4% per run for eight runs — is caught here.  The
fitted end-to-end drift (slope × window span, as a fraction of the window
mean) failing ``--max-trend-regression`` (default 0.15) exits non-zero;
fewer than 3 comparable points skips the check.

  python benchmarks/compare_bench.py --current BENCH_explorer.json \
      --baseline prev/BENCH_explorer.json \
      --baseline benchmarks/baseline_explorer.json \
      --trend BENCH_trend.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional, Tuple

HIGHER_BETTER = ("batch_evals_per_s", "nsga_evals_per_s",
                 "jit_nsga_evals_per_s", "jit_nsga_scale_evals_per_s",
                 "serve_tokens_per_s", "repartition_warm_speedup",
                 "requests_recovered")
LOWER_BETTER = ("campaign_wall_s", "fleet_sweep_wall_s", "repartition_ms",
                "recovery_ms", "serve_obs_overhead_pct")


def load(path: str) -> Optional[dict]:
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"note: unreadable baseline {path}: {e}")
        return None


def pick_baseline(paths, schema, mode) -> Tuple[Optional[dict], Optional[str]]:
    """First baseline that exists and is comparable: same ``bench_schema``
    AND same ``mode`` — a full-mode artifact diffed against a quick run (or
    a pre-schema-bump artifact against a current one) would flag workload
    differences as regressions, so those are skipped with a warning."""
    for p in paths:
        d = load(p)
        if d is None:
            continue
        if d.get("bench_schema") != schema:
            print(f"WARNING: skipping incomparable baseline {p} "
                  f"(bench_schema={d.get('bench_schema')!r} != {schema!r})")
            continue
        if d.get("mode") != mode:
            print(f"WARNING: skipping incomparable baseline {p} "
                  f"(mode={d.get('mode')!r} != {mode!r})")
            continue
        return d, p
    return None, None


def diff(base: dict, cur: dict, max_regression: float) -> int:
    """Print the per-key comparison; return the number of regressions."""
    failures = 0
    rows = [(k, +1) for k in HIGHER_BETTER] + [(k, -1) for k in LOWER_BETTER]
    print(f"{'metric':26s} {'baseline':>12s} {'current':>12s} "
          f"{'change':>8s}  verdict")
    for key, sign in rows:
        b, c = base.get(key), cur.get(key)
        if b is None or c is None:
            print(f"{key:26s} {'-':>12s} {'-':>12s} {'-':>8s}  skipped "
                  f"(missing in {'baseline' if b is None else 'current'})")
            continue
        if not b:
            print(f"{key:26s} {b:12.1f} {'-':>12s} {'-':>8s}  skipped "
                  f"(baseline value 0 — unusable)")
            continue
        change = (c - b) / b                      # >0 = value went up
        regression = -change * sign               # >0 = got worse
        verdict = "ok"
        if regression > max_regression:
            verdict = f"REGRESSION (>{max_regression:.0%})"
            failures += 1
        print(f"{key:26s} {b:12.1f} {c:12.1f} {change:+8.1%}  {verdict}")
    return failures


def trend_series(trend: dict, key: str, schema, mode, window: int) -> list:
    """The last ``window`` comparable values of one metric, oldest first."""
    runs = [r for r in trend.get("runs", [])
            if r.get("bench_schema") == schema and r.get("mode") == mode
            and isinstance(r.get("metrics", {}).get(key), (int, float))]
    return [r["metrics"][key] for r in runs[-window:]]


def fit_drift(series: list) -> float:
    """Fractional end-to-end drift of the least-squares fit line: slope ×
    span, normalized by the series mean.  The fit (not last-vs-first)
    keeps one noisy endpoint from dominating the verdict."""
    n = len(series)
    xs = range(n)
    mean_x = (n - 1) / 2.0
    mean_y = sum(series) / n
    sxx = sum((x - mean_x) ** 2 for x in xs)
    sxy = sum((x - mean_x) * (y - mean_y) for x, y in zip(xs, series))
    slope = sxy / sxx if sxx else 0.0
    return (slope * (n - 1)) / mean_y if mean_y else 0.0


def check_trend(trend: dict, cur: dict, window: int,
                max_trend_regression: float) -> int:
    """Print the per-key sustained-drift table; return regression count."""
    schema, mode = cur.get("bench_schema"), cur.get("mode")
    failures = 0
    rows = [(k, +1) for k in HIGHER_BETTER] + [(k, -1) for k in LOWER_BETTER]
    print(f"\ntrend over last {window} comparable run(s) "
          f"(bench_schema={schema}, mode={mode}):")
    print(f"{'metric':26s} {'runs':>5s} {'fit drift':>10s}  verdict")
    for key, sign in rows:
        series = trend_series(trend, key, schema, mode, window)
        if len(series) < 3:
            print(f"{key:26s} {len(series):5d} {'-':>10s}  skipped "
                  "(<3 comparable points)")
            continue
        drift = fit_drift(series)
        regression = -drift * sign                # >0 = sustained worsening
        verdict = "ok"
        if regression > max_trend_regression:
            verdict = (f"SUSTAINED REGRESSION "
                       f"(>{max_trend_regression:.0%} over window)")
            failures += 1
        print(f"{key:26s} {len(series):5d} {drift:+10.1%}  {verdict}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_explorer.json")
    ap.add_argument("--baseline", action="append", default=[],
                    help="candidate baseline paths, tried in order "
                         "(first existing, schema-matching one wins)")
    ap.add_argument("--max-regression", type=float, default=0.20,
                    help="fail when a metric regresses by more than this "
                         "fraction (default 0.20)")
    ap.add_argument("--trend", default=None, metavar="FILE",
                    help="BENCH_trend.json run history; enables the "
                         "sustained-drift check")
    ap.add_argument("--trend-window", type=int, default=8,
                    help="number of most recent comparable runs the drift "
                         "is fitted over (default 8)")
    ap.add_argument("--max-trend-regression", type=float, default=0.15,
                    help="fail when the fitted drift over the window "
                         "regresses by more than this fraction "
                         "(default 0.15)")
    args = ap.parse_args()

    cur = load(args.current)
    if cur is None:
        print(f"FAIL: current benchmark {args.current} not found",
              file=sys.stderr)
        return 1
    paths = args.baseline or ["benchmarks/baseline_explorer.json"]
    base, used = pick_baseline(paths, cur.get("bench_schema"),
                               cur.get("mode"))
    if base is None:
        print("note: no usable baseline — skipping the regression gate "
              f"(tried: {', '.join(paths)})")
        failures = 0
    else:
        print(f"baseline: {used} (mode={base.get('mode')}) vs "
              f"current: {args.current} (mode={cur.get('mode')})")
        failures = diff(base, cur, args.max_regression)

    trend_failures = 0
    if args.trend:
        trend = load(args.trend)
        if trend is None:
            print(f"note: trend file {args.trend} not found/unreadable — "
                  "skipping the sustained-drift check")
        else:
            trend_failures = check_trend(trend, cur, args.trend_window,
                                         args.max_trend_regression)

    if failures:
        print(f"FAIL: {failures} metric(s) regressed more than "
              f"{args.max_regression:.0%}", file=sys.stderr)
    if trend_failures:
        print(f"FAIL: {trend_failures} metric(s) show a sustained trend "
              f"regression beyond {args.max_trend_regression:.0%} over "
              f"the last {args.trend_window} run(s)", file=sys.stderr)
    if failures or trend_failures:
        return 1
    print("perf gate: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
