"""Link-bandwidth sensitivity (the paper's central co-design knob): how the
optimal cut and its value move as the inter-platform link changes.

Sweeps the EYR+SMB system over 100 Mb Ethernet / GigE / PCIe-class links
for EfficientNet-B0 and ResNet-50.  Expected physics: slower links push the
optimum toward the endpoints (single-platform), faster links unlock more
cuts and bigger pipelined-throughput wins — quantifying the paper's claim
that the link model is essential for partitioning decisions."""

from __future__ import annotations

import dataclasses
import json
import os

from benchmarks.common import csv_row, paper_system, timed
from repro.core import Explorer
from repro.core.link import LinkModel, gigabit_ethernet, pcie_gen4_x4
from repro.models.cnn.zoo import build_cnn


def links():
    gige = gigabit_ethernet()
    return {
        "eth_100m": dataclasses.replace(gige, name="eth100m", rate_bps=1e8),
        "gige": gige,
        "tengig": dataclasses.replace(gige, name="10gige", rate_bps=1e10,
                                      t_setup_s=20e-6),
        "pcie": pcie_gen4_x4(),
    }


def run(out_dir: str = "experiments"):
    os.makedirs(out_dir, exist_ok=True)
    rows, out = [], {}
    for model_name in ("efficientnet_b0", "resnet50"):
        graph = build_cnn(model_name).to_graph()
        out[model_name] = {}
        for link_name, link in links().items():
            system = paper_system()
            system = dataclasses.replace(system, links=[link])

            def explore():
                ex = Explorer(graph, system,
                              objectives=("latency", "energy", "throughput"))
                return ex.run(seed=0)

            res, dt = timed(explore)
            base_th = max(b.throughput for b in res.baselines)
            best = max(res.all_evals, key=lambda e: e.throughput,
                       default=None)
            gain = (best.throughput / base_th - 1) * 100 if best else 0.0
            n_useful = sum(1 for e in res.all_evals
                           if e.throughput > base_th)
            out[model_name][link_name] = {
                "best_cut": best.cuts[0] if best else None,
                "best_layer": (res.schedule[best.cuts[0]].name
                               if best and best.cuts[0] >= 0 else "-"),
                "throughput_gain_pct": round(gain, 1),
                "cuts_beating_single": n_useful,
                "pareto_size": len(res.pareto),
            }
            rows.append(csv_row(
                f"link_{model_name}_{link_name}", dt * 1e6,
                f"th_gain={gain:.1f}%;useful_cuts={n_useful}"))
    with open(os.path.join(out_dir, "link_sensitivity.json"), "w") as f:
        json.dump(out, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
