"""Link-bandwidth sensitivity (the paper's central co-design knob): how the
optimal cut and its value move as the inter-platform link changes.

Sweeps the EYR+SMB system over 100 Mb Ethernet / GigE / PCIe-class links
for EfficientNet-B0 and ResNet-50, as one ``Campaign`` fanning each model
across the four link variants (per-model cost tables are built once and
reused for every link).  Expected physics: slower links push the optimum
toward the endpoints (single-platform), faster links unlock more cuts and
bigger pipelined-throughput wins — quantifying the paper's claim that the
link model is essential for partitioning decisions."""

from __future__ import annotations

import os

from benchmarks.common import csv_row
from repro.utils.atomicio import atomic_write_json
from repro.explore import (Campaign, ExplorationSpec, LinkSpec, ModelRef,
                           PlatformSpec, SystemSpec)

LINK_VARIANTS = {
    "eth_100m": LinkSpec(base="gige", name="eth100m", rate_bps=1e8),
    "gige": LinkSpec(base="gige"),
    "tengig": LinkSpec(base="gige", name="10gige", rate_bps=1e10,
                       t_setup_s=20e-6),
    "pcie": LinkSpec(base="pcie4x4"),
}

PLATFORMS = (PlatformSpec("A", "eyr", bits=16),
             PlatformSpec("B", "smb", bits=8))


def run(out_dir: str = "experiments"):
    os.makedirs(out_dir, exist_ok=True)
    systems = [SystemSpec(platforms=PLATFORMS, links=(link,), name=lname)
               for lname, link in LINK_VARIANTS.items()]
    spec = ExplorationSpec(
        model=ModelRef("cnn", "efficientnet_b0"),
        system=systems[0],
        objectives=("latency", "energy", "throughput"))
    camp = Campaign(spec,
                    models=[ModelRef("cnn", n)
                            for n in ("efficientnet_b0", "resnet50")],
                    systems=systems).run()

    rows, out = [], {}
    for entry in camp.entries:
        res, model_name, link_name = entry.result, entry.model, entry.system
        base_th = max(b.throughput for b in res.baselines)
        best = max(res.all_evals, key=lambda e: e.throughput, default=None)
        gain = (best.throughput / base_th - 1) * 100 if best else 0.0
        n_useful = sum(1 for e in res.all_evals if e.throughput > base_th)
        out.setdefault(model_name, {})[link_name] = {
            "best_cut": best.cuts[0] if best else None,
            "best_layer": (res.layer_name(best.cuts[0])
                           if best and best.cuts[0] >= 0 else "-"),
            "throughput_gain_pct": round(gain, 1),
            "cuts_beating_single": n_useful,
            "pareto_size": len(res.pareto),
        }
        rows.append(csv_row(
            f"link_{model_name}_{link_name}", entry.wall_s * 1e6,
            f"th_gain={gain:.1f}%;useful_cuts={n_useful}"))
    atomic_write_json(os.path.join(out_dir, "link_sensitivity.json"), out)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
