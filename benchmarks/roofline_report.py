"""§Roofline report: formats the dry-run JSON (single-pod 10×4 sweep) into
the per-(arch × shape) table — three terms, dominant bottleneck, MODEL_FLOPS
ratio, one-line recommendation."""

from __future__ import annotations

import json
import os
from typing import List

from benchmarks.common import csv_row
from repro.utils.atomicio import atomic_write_text

RECOMMEND = {
    "compute": "increase per-chip work (bigger microbatch) or cut redundant"
               " recompute (remat policy)",
    "memory": "fuse/bf16-ify residual traffic, tighten dispatch buffers,"
              " shard the KV cache further",
    "collective": "reshard to cut all-gathers (2D weight sharding along the"
                  " contracted dim), overlap collectives with compute,"
                  " or shrink the model axis",
}


def load_rows(path: str = "experiments/dryrun_single_pod.json") -> List[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def format_table(rows: List[dict]) -> str:
    out = ["| arch | shape | compute_s | memory_s | collective_s | bound | "
           "useful | args GiB/dev | temp GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped ({r['reason'][:40]}...) | — | — | — |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"ERROR | — | — | — |")
            continue
        mem = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{mem['argument_bytes']/2**30:.2f} | "
            f"{mem['temp_bytes']/2**30:.2f} |")
    return "\n".join(out)


def run(out_dir: str = "experiments"):
    rows = load_rows()
    csv = []
    if not rows:
        return [csv_row("roofline_report", 0.0,
                        "missing=experiments/dryrun_single_pod.json —"
                        " run python -m repro.launch.dryrun --all --out ...")]
    ok = [r for r in rows if "error" not in r and not r.get("skipped")]
    table = format_table(rows)
    atomic_write_text(os.path.join(out_dir, "roofline_table.md"),
                      table + "\n")
    by_dom = {}
    for r in ok:
        by_dom.setdefault(r["dominant"], []).append(r)
    for dom, rs in sorted(by_dom.items()):
        worst = max(rs, key=lambda r: r["bound_s"])
        csv.append(csv_row(
            f"roofline_{dom}_bound", 0.0,
            f"n={len(rs)};worst={worst['arch']}x{worst['shape']}"
            f"@{worst['bound_s']:.2f}s;fix={RECOMMEND[dom][:40]}"))
    csv.append(csv_row("roofline_total", 0.0,
                       f"ok={len(ok)};skipped={sum(1 for r in rows if r.get('skipped'))};"
                       f"errors={sum(1 for r in rows if 'error' in r)}"))
    return csv


if __name__ == "__main__":
    for r in run():
        print(r)
