"""Fig. 2 reproduction: per-cut latency / energy / throughput / accuracy for
the six CNNs on the EYR+SMB+GigE system; validates the paper's headline
claims (+47.5 % EfficientNet-B0 and +29 % ResNet-50 throughput; dual
latency+energy wins for VGG-16 / SqueezeNet; accuracy rises with later
cuts).  Runs as one ``Campaign`` over the CNN zoo."""

from __future__ import annotations

import os
from typing import Dict

from benchmarks.common import PAPER_CNNS, csv_row, paper_system_spec
from repro.explore import Campaign, ExplorationSpec, ModelRef
from repro.utils.atomicio import atomic_write_json

OBJECTIVES = ("latency", "energy", "throughput", "accuracy")


def cnn_campaign(models, variant: str = "efficient",
                 objectives=OBJECTIVES):
    spec = ExplorationSpec(
        model=ModelRef("cnn", models[0]),
        system=paper_system_spec(variant),
        objectives=objectives)
    return Campaign(spec, models=[ModelRef("cnn", n) for n in models]).run()


def run(out_dir: str = "experiments") -> Dict[str, str]:
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    results = {}
    # energy-balance ablation on the dual-win claim (see paper_system_spec)
    leaky = cnn_campaign(("vgg16", "squeezenet11"), "leaky")
    for entry in leaky.entries:
        res = entry.result
        smb = res.baselines[-1]
        dual = any(e.latency_s < smb.latency_s and e.energy_j < smb.energy_j
                   for e in res.all_evals)
        rows.append(csv_row(f"fig2_{entry.model}_leaky_variant",
                            entry.wall_s * 1e6, f"dual_win_vs_B={dual}"))

    camp = cnn_campaign(PAPER_CNNS)
    for entry in camp.entries:
        res, name, dt = entry.result, entry.model, entry.wall_s
        base_th = max(b.throughput for b in res.baselines)
        best_th = max((e.throughput for e in res.all_evals), default=0.0)
        th_gain = (best_th / base_th - 1.0) * 100 if base_th else 0.0
        # the paper's dual win (Fig. 2a/2d): some cut beats running the
        # WHOLE network on platform B (SMB) in both latency and energy
        smb = res.baselines[-1]
        dual = any(e.latency_s < smb.latency_s and e.energy_j < smb.energy_j
                   for e in res.all_evals)
        # stronger: beats the best-of-both-platforms on both metrics
        base_lat = min(b.latency_s for b in res.baselines)
        base_en = min(b.energy_j for b in res.baselines)
        dual_strict = any(e.latency_s < base_lat and e.energy_j < base_en
                          for e in res.all_evals)
        # accuracy trend: later cut (more layers on 16-bit A) -> higher acc
        accs = sorted((e.cuts[0], e.accuracy) for e in res.all_evals)
        monotone_frac = 0.0
        if len(accs) > 1:
            ups = sum(1 for (p1, a1), (p2, a2) in zip(accs, accs[1:])
                      if a2 >= a1 - 1e-9)
            monotone_frac = ups / (len(accs) - 1)
        sel = res.selected
        results[name] = {
            "n_cuts_evaluated": len(res.all_evals),
            "best_throughput_gain_pct": round(th_gain, 1),
            "dual_latency_energy_win_vs_B": bool(dual),
            "dual_win_vs_best_single": bool(dual_strict),
            "accuracy_monotone_frac": round(monotone_frac, 3),
            "selected_cut": sel.cuts if sel else None,
            "selected_layer": (res.layer_name(sel.cuts[0])
                               if sel and 0 <= sel.cuts[0] < len(res.schedule)
                               else "single-platform"),
            "pareto_size": len(res.pareto),
            "explore_s": round(dt, 2),
            "points": [
                {"cut": e.cuts[0],
                 "layer": res.layer_name(e.cuts[0]),
                 "latency_ms": e.latency_s * 1e3,
                 "energy_mJ": e.energy_j * 1e3,
                 "throughput": e.throughput,
                 "accuracy": e.accuracy,
                 "link_kB": e.link_bytes / 1e3} for e in res.all_evals],
            "baselines": [
                {"platform": i, "latency_ms": b.latency_s * 1e3,
                 "energy_mJ": b.energy_j * 1e3, "throughput": b.throughput,
                 "accuracy": b.accuracy}
                for i, b in enumerate(res.baselines)],
        }
        rows.append(csv_row(
            f"fig2_{name}", dt * 1e6,
            f"th_gain={th_gain:.1f}%;dual_win={dual};"
            f"acc_monotone={monotone_frac:.2f}"))
    atomic_write_json(os.path.join(out_dir, "fig2_pareto.json"), results)
    # the serializable fleet report, straight from the campaign
    camp.report.save(os.path.join(out_dir, "fig2_campaign_report.json"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
