"""CI observability smoke for ``repro.obs`` (obs-smoke job).

A traffic burst is served through a two-replica router with a live
``Obs`` handle while a :class:`~repro.serve.faults.FaultPlan` degrades a
link mid-stream and then kills one replica.  The exported Chrome trace
must tell the whole failover story, and tracing must stay cheap.  Fails
loudly (non-zero exit) unless:

* **tracing is near-free**: best-of-3 async throughput with a live
  tracer is >= ``MIN_TPS_RATIO`` (0.95x) of the untraced run on the same
  compiled runner (the <5% budget ``serve_bench.py --max-obs-overhead``
  gates on the explorer chain);
* the trace-event JSON **validates** (:func:`validate_chrome_trace`) and
  every stage/request span **nests** inside the survivor's driver span;
* the **failover is visible**: the crashed replica's tracks end before
  the survivor's, a ``replica_crash`` instant marks the death, salvaged
  requests keep their spans on the crashed replica's ``requests`` track,
  and every failed-over rid re-appears on the survivor's;
* the **per-request breakdown reconciles**: each ``cat='request'`` span's
  latency/TTFT matches the merged :class:`~repro.serve.request.ServeReport`
  record, the nearest-rank p50/p95 footer matches ``report.summary()``,
  and ``python -m repro.obs`` renders the trace with exit code 0.

  PYTHONPATH=src python benchmarks/obs_smoke.py
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import Any, Dict, List, Tuple

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

from repro.core.link import LinkModel
from repro.models.registry import build_model, get_config
from repro.obs import (NOOP_OBS, Obs, load_chrome_trace,
                       validate_chrome_trace, write_chrome_trace)
from repro.obs.cli import main as obs_cli_main
from repro.obs.cli import request_rows
from repro.obs.stats import latency_summary
from repro.serve import (FaultPlan, LinkDegrade, PipelineServeEngine,
                         ReplicaCrash, ReplicaRouter, Request, ServeLink,
                         poisson_traffic, stream_of)
from repro.serving.pipeline import PartitionedLMRunner

N_REQUESTS = 12
MAX_NEW = 8
PROMPT_LEN = 8
DEGRADE = 8.0          # injected link slow-down factor
DEGRADE_AT = 4         # ... from the link's 4th transfer (mid-stream)
CRASH_STEP = 14        # replica dies after 14 decode steps: the first
#                        admission wave has finished (-> salvage), later
#                        waves must fail over (the whole burst would
#                        need ~24 steps)
MIN_TPS_RATIO = 0.95   # traced async throughput vs untraced, best-of-3
LAT_TOL_MS = 0.05      # trace-vs-report reconciliation tolerance


def track_names(events: List[Dict[str, Any]]) -> Dict[Tuple[int, int], str]:
    """(pid, tid) -> "process/thread" from the trace's metadata events
    (the naming scheme ``repro.obs.chrome`` documents)."""
    procs: Dict[int, str] = {}
    out: Dict[Tuple[int, int], str] = {}
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "process_name":
            procs[ev["pid"]] = ev["args"]["name"]
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            out[(ev["pid"], ev["tid"])] = (
                f"{procs.get(ev['pid'], ev['pid'])}/{ev['args']['name']}")
    return out


def async_tokens_per_s(runner, burst, obs) -> float:
    """One clean async run on the shared compiled runner -> tokens/s."""
    eng = PipelineServeEngine(runner, n_slots=4, eos=None, mode="async",
                              capacity=32, obs=obs)
    eng.warmup(prompt_len=PROMPT_LEN)
    rep = eng.run(stream_of([Request(r.rid, r.prompt, r.max_new, 0.0)
                             for r in burst]))
    return rep.summary()["tokens_per_s"]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="obs_trace.json", metavar="FILE",
                    help="where to export the failover Chrome trace")
    args = ap.parse_args()

    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    runner = PartitionedLMRunner(model, params, cuts=[0])

    reqs = poisson_traffic(N_REQUESTS, rate_rps=2000.0, vocab=cfg.vocab,
                           prompt_len=PROMPT_LEN, max_new=MAX_NEW, seed=7)
    burst = [Request(r.rid, r.prompt, r.max_new, 0.0) for r in reqs]

    fails: List[str] = []

    # 1. tracing overhead: same runner (one compile), fresh engine per
    # run.  One discarded run shakes out cache warmth; then interleaved
    # order-alternating pairs, best-of-N per arm — per-run noise on a
    # shared CI core is heavy-tailed (whole runs randomly lose 30%), so
    # the max approximates the noise-free capability of each arm.  One
    # escalation round before failing keeps a single unlucky window from
    # gating the job.
    async_tokens_per_s(runner, burst, NOOP_OBS)
    off_runs: List[float] = []
    on_runs: List[float] = []

    def ratio_round(n_pairs: int) -> float:
        for i in range(n_pairs):
            arms = [(off_runs, NOOP_OBS), (on_runs, Obs.on())]
            for sink, obs_arm in (arms if i % 2 == 0 else arms[::-1]):
                sink.append(async_tokens_per_s(runner, burst, obs_arm))
        return max(on_runs) / max(off_runs)

    ratio = ratio_round(3)
    if ratio < MIN_TPS_RATIO:
        ratio = ratio_round(3)
    print(f"[obs-smoke] async tokens/s untraced={max(off_runs):.0f} "
          f"traced={max(on_runs):.0f} ratio={ratio:.3f} "
          f"({len(off_runs)} run(s)/arm)")
    if ratio < MIN_TPS_RATIO:
        fails.append(f"traced throughput ratio {ratio:.3f} < "
                     f"{MIN_TPS_RATIO} — tracing is not near-free")

    # 2. traced fault-injected routed run: degraded link, then one death
    obs = Obs.on()
    plan = FaultPlan(events=(
        LinkDegrade(0, DEGRADE, at_transfer=DEGRADE_AT),
        ReplicaCrash(at_step=CRASH_STEP)))
    links = [ServeLink(model=LinkModel(name="slow", rate_bps=1e9,
                                       t_setup_s=0.02))
             for _ in range(runner.n_stages - 1)]
    crashy = PipelineServeEngine(runner, n_slots=4, eos=None, mode="async",
                                 capacity=32, name="crashy", links=links,
                                 faults=plan, obs=obs)
    survivor = PipelineServeEngine(runner, n_slots=4, eos=None,
                                   mode="async", capacity=32,
                                   name="survivor", obs=obs)
    crashy.warmup(prompt_len=PROMPT_LEN)
    survivor.warmup(prompt_len=PROMPT_LEN)
    rep = ReplicaRouter([crashy, survivor], obs=obs).serve(
        list(burst), realtime=False)

    if rep.n_done != N_REQUESTS or rep.n_failed != 0:
        fails.append(f"routed run lost requests: {rep.n_done} done, "
                     f"{rep.n_failed} failed")

    write_chrome_trace(args.trace, obs.tracer)
    trace = load_chrome_trace(args.trace)
    print(f"[obs-smoke] exported {len(trace['traceEvents'])} events "
          f"to {args.trace}")

    # 3. structural validity
    errors = validate_chrome_trace(trace)
    if errors:
        fails.append(f"trace failed validation: {errors[:3]}")
    if obs.tracer.dropped:
        fails.append(f"{obs.tracer.dropped} span(s) dropped — ring "
                     "capacity too small for the smoke workload")

    events = trace["traceEvents"]
    tracks = track_names(events)

    def on_track(prefix: str, ph: str = "X") -> List[Dict[str, Any]]:
        return [ev for ev in events if ev.get("ph") == ph
                and tracks.get((ev.get("pid"), ev.get("tid")),
                               "").startswith(prefix)]

    # 4. nesting: every survivor stage/request span lies inside the
    # survivor's single driver span (the crashed replica never completes
    # its driver span — its death is the replica_crash instant instead)
    drivers = [ev for ev in on_track("survivor/driver")
               if ev.get("cat") == "driver"]
    if len(drivers) != 1:
        fails.append(f"expected 1 survivor driver span, got {len(drivers)}")
    else:
        d0 = drivers[0]["ts"]
        d1 = d0 + drivers[0]["dur"]
        eps = 1e3                                # 1 ms slack, in us
        inner = [ev for ev in on_track("survivor/")
                 if ev.get("cat") in ("stage", "request")]
        bad = [ev for ev in inner
               if ev["ts"] < d0 - eps or ev["ts"] + ev["dur"] > d1 + eps]
        if not inner:
            fails.append("no stage/request spans on the survivor")
        if bad:
            fails.append(f"{len(bad)} survivor span(s) fall outside the "
                         f"driver span (e.g. {bad[0]['name']})")

    # 5. the failover story: crash instant, crashy's tracks end first,
    # salvage kept on crashy, every failed-over rid lands on the survivor
    crash_marks = on_track("crashy/driver", ph="i")
    if not any(ev["name"] == "replica_crash" for ev in crash_marks):
        fails.append("no replica_crash instant on crashy/driver")
    crashy_end = max((ev["ts"] + ev.get("dur", 0.0)
                      for ev in on_track("crashy/")), default=0.0)
    surv_end = max((ev["ts"] + ev.get("dur", 0.0)
                    for ev in on_track("survivor/")), default=0.0)
    if not crashy_end < surv_end:
        fails.append("crashed replica's tracks do not end before the "
                     f"survivor's ({crashy_end:.0f} !< {surv_end:.0f} us)")

    router_marks = on_track("router/", ph="i")
    by_name: Dict[str, List[Dict[str, Any]]] = {}
    for ev in router_marks:
        by_name.setdefault(ev["name"], []).append(ev)
    salvaged = {ev["args"]["rid"] for ev in by_name.get("salvage", [])}
    failed_over = {ev["args"]["rid"] for ev in by_name.get("failover", [])}
    if not by_name.get("replica_failed"):
        fails.append("no replica_failed instant on the router track")
    if not salvaged:
        fails.append("no request salvaged before the crash "
                     f"(CRASH_STEP={CRASH_STEP} fired too early)")
    if not failed_over:
        fails.append("no request failed over to the survivor")
    crashy_rids = {ev["args"]["rid"] for ev in on_track("crashy/requests")
                   if ev.get("cat") == "request"}
    surv_rids = {ev["args"]["rid"] for ev in on_track("survivor/requests")
                 if ev.get("cat") == "request"}
    if not salvaged <= crashy_rids:
        fails.append(f"salvaged rids {sorted(salvaged - crashy_rids)} "
                     "missing from crashy's requests track")
    if not failed_over <= surv_rids:
        fails.append(f"failed-over rids {sorted(failed_over - surv_rids)} "
                     "missing from the survivor's requests track")

    # 6. per-request reconciliation: the trace's breakdown is the report
    rows = request_rows(trace)
    recs = {r.rid: r for r in rep.records}
    if sorted(r["rid"] for r in rows) != sorted(recs):
        fails.append(f"trace has {len(rows)} request span(s) for "
                     f"{len(recs)} report record(s)")
    else:
        for row in rows:
            rec = recs[row["rid"]]
            if abs(row["latency_ms"] - rec.latency_s * 1e3) > LAT_TOL_MS:
                fails.append(f"rid {row['rid']} latency: trace "
                             f"{row['latency_ms']:.3f} ms != report "
                             f"{rec.latency_s * 1e3:.3f} ms")
            if rec.ttft_s is not None and abs(
                    row["ttft_ms"] - rec.ttft_s * 1e3) > LAT_TOL_MS:
                fails.append(f"rid {row['rid']} TTFT: trace "
                             f"{row['ttft_ms']:.3f} ms != report "
                             f"{rec.ttft_s * 1e3:.3f} ms")
        summ = rep.summary()
        lat = latency_summary([r["latency_ms"] for r in rows])
        ttft = latency_summary([r["ttft_ms"] for r in rows
                                if r["ttft_ms"] is not None])
        for key, got in (("latency_p50_ms", lat["p50"]),
                         ("latency_p95_ms", lat["p95"]),
                         ("ttft_p50_ms", ttft["p50"]),
                         ("ttft_p95_ms", ttft["p95"])):
            if abs(got - summ[key]) > LAT_TOL_MS:
                fails.append(f"{key}: trace footer {got:.3f} != "
                             f"report {summ[key]:.3f}")

    # 7. the CLI renders the same file (its output is the CI log's copy
    # of the breakdown; exit 2 would mean it rejected its own export)
    rc = obs_cli_main([args.trace, "--top", "5"])
    if rc != 0:
        fails.append(f"python -m repro.obs exited {rc} on the trace")

    snap = obs.metrics.snapshot()
    # failed-over requests are routed twice (initial + re-admission)
    want_routed = N_REQUESTS + len(failed_over)
    if snap.get("router_requests_routed") != want_routed:
        fails.append(f"router_requests_routed = "
                     f"{snap.get('router_requests_routed')}, expected "
                     f"{want_routed}")
    if snap.get("serve_replica_crashes") != 1:
        fails.append(f"serve_replica_crashes = "
                     f"{snap.get('serve_replica_crashes')}, expected 1")

    for msg in fails:
        print(f"FAIL: {msg}", file=sys.stderr)
    if fails:
        return 1
    print(f"[obs-smoke] OK: ratio={ratio:.3f}, {len(events)} events, "
          f"{len(salvaged)} salvaged + {len(failed_over)} failed over, "
          f"breakdown reconciles with ServeReport")
    return 0


if __name__ == "__main__":
    sys.exit(main())
