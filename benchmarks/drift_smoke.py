"""CI smoke test for the online re-partitioning loop (drift-smoke job).

A miniature degradation schedule — three same-shape perturbations of the
paper chain (two link degradations, one node dropout) — is replayed twice
through fresh :class:`~repro.explore.online.OnlineRepartitioner` instances.
Asserts, loudly and with a non-zero exit on failure:

* decisions are **deterministic** — both replays emit identical cut
  sequences (seeded search, seeded warm-start jitter, no wall-clock in the
  decision path);
* ``repartition_ms`` is recorded (> 0) on every decision;
* the second replay performs **zero recompilation** — the shared compiled-
  runner cache holds exactly one entry from start to finish, because every
  perturbed system is same-shape and table values ride in as runtime args;
* the node-dropout decision routes every layer off the dead platform.

  PYTHONPATH=src python benchmarks/drift_smoke.py
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import chain_system_spec
from repro.explore import (ExplorationSpec, ModelRef, OnlineRepartitioner,
                           SearchSettings, clear_jit_runner_cache,
                           degrade_link, drop_node, jit_runner_cache_size)

N_EVENTS = 3


def smoke_spec() -> ExplorationSpec:
    return ExplorationSpec(
        model=ModelRef("cnn", "squeezenet11", {"in_hw": 64}),
        system=chain_system_spec(),
        objectives=("latency", "energy", "throughput"),
        search=SearchSettings(strategy="jit_nsga2", seed=0,
                              pop_size=96, n_gen=10))


def run_loop(spec: ExplorationSpec):
    base = spec.system
    events = [degrade_link(base, 0, 8.0),
              degrade_link(base, 2, 64.0),
              drop_node(base, 1)]
    rp = OnlineRepartitioner(spec)
    decisions = [rp.update(base)]
    decisions += list(rp.watch(events))
    return decisions


def main() -> int:
    spec = smoke_spec()
    clear_jit_runner_cache()
    first = run_loop(spec)
    cache_after_first = jit_runner_cache_size()
    second = run_loop(spec)
    cache_after_second = jit_runner_cache_size()

    fails = []
    cuts_a = [d.cuts for d in first]
    cuts_b = [d.cuts for d in second]
    for d in first:
        print(f"[drift-smoke] step {d.step} {d.label}: cuts={d.cuts} "
              f"changed={d.changed} feasible={d.feasible} "
              f"repartition_ms={d.repartition_ms:.1f}")
    if cuts_a != cuts_b:
        fails.append(f"decisions not deterministic: {cuts_a} != {cuts_b}")
    if not all(d.repartition_ms > 0 for d in first + second):
        fails.append("repartition_ms missing on a decision")
    if cache_after_first != 1 or cache_after_second != 1:
        fails.append(
            f"expected exactly one compiled runner for {2 * (N_EVENTS + 1)} "
            f"same-shape re-searches, cache went "
            f"{cache_after_first} -> {cache_after_second}")
    dropped = first[-1]
    if dropped.cuts is not None:
        # platform 1 is dead: stage 1 (bounds[1]..bounds[2]) must be empty,
        # i.e. the first two cut genes coincide (or the earlier is -1 ==
        # "platform skipped")
        b = [-1] + list(dropped.cuts)
        if b[2] > b[1]:
            fails.append(f"dropout decision still uses dead platform 1: "
                         f"cuts={dropped.cuts}")
    if not all(d.strategy_used == "jit_nsga2" for d in first + second):
        fails.append("a decision did not come from the jit_nsga2 strategy")

    for msg in fails:
        print(f"FAIL: {msg}", file=sys.stderr)
    if not fails:
        print(f"[drift-smoke] OK: {len(first)} deterministic decisions, "
              f"1 compiled runner, median warm "
              f"{sorted(d.repartition_ms for d in first[1:])[1]:.1f} ms")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
