"""CI fault-tolerance smoke for the serve runtime (fault-smoke job).

One traffic burst is served against two replicas of a 2-stage partitioned
reduced LM while a :class:`~repro.serve.faults.FaultPlan` injects a
mid-stream link degradation and then kills one replica outright.  A
:class:`~repro.serve.health.DivergenceMonitor` watches the crashing
replica's :class:`~repro.serve.health.HealthMonitor` live.  Fails loudly
(non-zero exit) unless:

* the injected faults were actually applied (the replica's fault trace
  records the degradation and the crash);
* **zero requests are lost** — every submitted rid comes back finished
  (``n_failed == 0``), the crashed replica's requests failed over to the
  survivor, and ``recovery_ms`` is reported;
* the recovered requests' greedy tokens are **byte-identical** to a
  no-fault single-replica run;
* the link divergence alarm fired from *measurement* (hysteresis held:
  ``min_breach`` consecutive observations over the enter threshold), and
  the warm re-partition it triggers records ``trigger='measured'``.

With ``--json`` the recovery metrics are merged into the explorer bench
artifact (schema 8): ``recovery_ms``, ``requests_recovered``, and
``repartition_trigger``.

  PYTHONPATH=src python benchmarks/fault_smoke.py
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

from benchmarks.common import chain_system_spec
from repro.core.link import LinkModel
from repro.explore import (ExplorationSpec, ModelRef, OnlineRepartitioner,
                           SearchSettings)
from repro.models.registry import build_model, get_config
from repro.serve import (DivergenceMonitor, FaultPlan, HealthMonitor,
                         LinkDegrade, PipelineServeEngine, ReplicaCrash,
                         ReplicaRouter, Request, ServeLink, poisson_traffic,
                         stream_of)
from repro.serving.pipeline import PartitionedLMRunner
from repro.utils.atomicio import atomic_write_json

BENCH_SCHEMA = 8
N_REQUESTS = 12
MAX_NEW = 8
PROMPT_LEN = 8
DEGRADE = 8.0          # injected link slow-down factor
DEGRADE_AT = 4         # ... from the link's 4th transfer (mid-stream)
CRASH_STEP = 5         # replica dies after 5 decode steps: before any
#                        completion (MAX_NEW needs 7), so every routed
#                        request must fail over


def slow_links(n: int):
    """Per-gap links slow enough that wire time is measurable on CI."""
    return [ServeLink(model=LinkModel(name="slow", rate_bps=1e9,
                                      t_setup_s=0.02)) for _ in range(n)]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="merge recovery metrics into this bench artifact")
    args = ap.parse_args()

    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    runner = PartitionedLMRunner(model, params, cuts=[0])
    system = chain_system_spec()

    reqs = poisson_traffic(N_REQUESTS, rate_rps=2000.0, vocab=cfg.vocab,
                           prompt_len=PROMPT_LEN, max_new=MAX_NEW, seed=7)
    burst = [Request(r.rid, r.prompt, r.max_new, 0.0) for r in reqs]

    # 1. no-fault reference: the byte-identity target
    ref_eng = PipelineServeEngine(runner, n_slots=4, eos=None, mode="async",
                                  capacity=32, name="ref")
    ref_eng.warmup(prompt_len=PROMPT_LEN)
    ref = ref_eng.run(stream_of(list(burst)))
    ref_toks = {r.rid: list(r.tokens) for r in ref.records}

    # 2. faulted fleet: crashing replica (degraded link, then death) +
    # clean survivor; the health monitor is sized to the deployed system's
    # links (serve link i maps to system link i)
    plan = FaultPlan(events=(
        LinkDegrade(0, DEGRADE, at_transfer=DEGRADE_AT),
        ReplicaCrash(at_step=CRASH_STEP)))
    health = HealthMonitor(runner.n_stages, len(system.links))
    crashy = PipelineServeEngine(runner, n_slots=4, eos=None, mode="async",
                                 capacity=32, name="crashy",
                                 links=slow_links(runner.n_stages - 1),
                                 faults=plan, health=health)
    survivor = PipelineServeEngine(runner, n_slots=4, eos=None, mode="async",
                                   capacity=32, name="survivor")
    crashy.warmup(prompt_len=PROMPT_LEN)
    survivor.warmup(prompt_len=PROMPT_LEN)

    dm = DivergenceMonitor(system, enter=3.0, exit=1.5, min_breach=3,
                           cooldown_s=2.0, min_samples=4)
    stop = threading.Event()

    def observer():
        while not stop.is_set():
            dm.observe(health)
            time.sleep(0.02)

    th = threading.Thread(target=observer, daemon=True)
    th.start()
    rep = ReplicaRouter([crashy, survivor]).serve(list(burst),
                                                  realtime=False)
    stop.set()
    th.join(timeout=2.0)
    dm.observe(health)               # catch a fire pending at drain time

    fails = []
    trace_kinds = {e[0] for e in crashy.fault_trace.canonical()}
    if "link_degrade" not in trace_kinds:
        fails.append("link degradation was never applied")
    if "replica_crash" not in trace_kinds:
        fails.append("replica crash was never injected")
    if rep.extra.get("n_replica_failures") != 1:
        fails.append(f"expected exactly 1 replica failure, got "
                     f"{rep.extra.get('n_replica_failures')}")
    if rep.n_failed != 0:
        fails.append(f"{rep.n_failed} request(s) lost/shed — zero-loss "
                     "failover violated")
    if rep.n_done != N_REQUESTS:
        fails.append(f"only {rep.n_done}/{N_REQUESTS} requests finished")
    if rep.extra.get("requests_recovered", 0) < 1:
        fails.append("no request was recovered from the dead replica")
    if "recovery_ms" not in rep.extra:
        fails.append("recovery_ms missing from the merged report")
    got = {r.rid: list(r.tokens) for r in rep.records}
    if got != ref_toks:
        bad = [rid for rid in ref_toks if got.get(rid) != ref_toks[rid]]
        fails.append(f"recovered tokens diverge from the no-fault run "
                     f"(rids {bad})")

    if not dm.signals:
        fails.append(f"divergence alarm never fired (link0 divergence "
                     f"{health.link_divergence(0):.2f}x, "
                     f"{health.link_samples(0)} samples)")
        decision = None
    else:
        sig = dm.signals[0]
        print(f"[fault-smoke] measured {sig.divergence:.1f}x divergence on "
              f"link {sig.link} (injected {DEGRADE:g}x)")
        # 3. the measured-trigger warm re-partition (same search setup as
        # drift_smoke: one cold compile, then the measured update)
        spec = ExplorationSpec(
            model=ModelRef("cnn", "squeezenet11", {"in_hw": 64}),
            system=system,
            objectives=("latency", "energy", "throughput"),
            search=SearchSettings(strategy="jit_nsga2", seed=0,
                                  pop_size=96, n_gen=10))
        rp = OnlineRepartitioner(spec)
        rp.update(system)                              # cold baseline
        decision = rp.update(dm.drifted_system(),
                             label=f"measured~link{sig.link}",
                             trigger="measured")
        if decision.trigger != "measured":
            fails.append(f"re-partition trigger is {decision.trigger!r}, "
                         "not 'measured'")
        if not decision.repartition_ms > 0:
            fails.append("measured re-partition recorded no wall time")
        print(f"[fault-smoke] warm re-partition {decision.repartition_ms:.1f}"
              f" ms, trigger={decision.trigger}, cuts={decision.cuts}")

    for msg in fails:
        print(f"FAIL: {msg}", file=sys.stderr)
    if fails:
        return 1

    print(f"[fault-smoke] OK: {rep.n_done}/{N_REQUESTS} served, "
          f"{rep.extra['requests_recovered']} recovered in "
          f"{rep.extra['recovery_ms']:.1f} ms, 0 lost, tokens identical, "
          f"measured-trigger re-partition fired")

    if args.json:
        out = {}
        if os.path.exists(args.json):
            with open(args.json) as f:
                out = json.load(f)
        out.setdefault("mode", "quick")
        out["bench_schema"] = BENCH_SCHEMA
        out.update({
            "recovery_ms": rep.extra["recovery_ms"],
            "requests_recovered": rep.extra["requests_recovered"],
            "repartition_trigger": decision.trigger,
            "fault_divergence": round(dm.signals[0].divergence, 2),
        })
        atomic_write_json(args.json, out)
        print(f"merged recovery metrics into {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
