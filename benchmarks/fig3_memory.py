"""Fig. 3 reproduction: per-cut memory on both platforms for
EfficientNet-B0 (two 16-bit platforms).  The paper's observation: unlike
the other CNNs (front-heavy memory), EfficientNet-B0's platform-A memory
*grows* with later cuts, so memory-efficient cuts are early (before
Conv_56) or late (after Conv_79)."""

from __future__ import annotations

import os

from benchmarks.common import csv_row, timed
from repro.utils.atomicio import atomic_write_json
from repro.explore import (ExplorationSpec, ModelRef, PlatformSpec,
                           SystemSpec, run_spec)


def run(out_dir: str = "experiments"):
    os.makedirs(out_dir, exist_ok=True)
    spec = ExplorationSpec(
        model=ModelRef("cnn", "efficientnet_b0"),
        system=SystemSpec(
            platforms=(PlatformSpec("A", "eyr", bits=16),
                       PlatformSpec("B", "eyr", bits=16)),
            links=("gige",)),
        objectives=("latency", "memory"))

    res, dt = timed(run_spec, spec)
    points = []
    for e in res.all_evals:
        points.append({"cut": e.cuts[0],
                       "layer": res.layer_name(e.cuts[0]),
                       "mem_A_MiB": e.memory_bytes[0] / 2 ** 20,
                       "mem_B_MiB": e.memory_bytes[1] / 2 ** 20,
                       "sum_MiB": sum(e.memory_bytes) / 2 ** 20})
    # find the memory valley: best cuts by total memory
    points_sorted = sorted(points, key=lambda p: p["sum_MiB"])
    best = points_sorted[:5]
    worst = points_sorted[-5:]
    out = {"points": points, "best5": best, "worst5": worst,
           "explore_s": round(dt, 2)}
    atomic_write_json(os.path.join(out_dir, "fig3_memory.json"), out)
    best_names = ",".join(p["layer"] for p in best[:3])
    return [csv_row("fig3_efficientnet_memory", dt * 1e6,
                    f"best_cuts={best_names};"
                    f"min_sum={best[0]['sum_MiB']:.1f}MiB;"
                    f"max_sum={worst[-1]['sum_MiB']:.1f}MiB")]


if __name__ == "__main__":
    for r in run():
        print(r)
