"""Bench trend history (ROADMAP open item): accumulate per-run
``BENCH_explorer.json`` artifacts into one queryable ``BENCH_trend.json``.

Each CI bench run appends its metrics — keyed by commit SHA, stamped with
the run date, mode and ``bench_schema`` — to the trend file downloaded from
the previous successful run's artifact, and re-uploads the result.  The
outcome is a single JSON whose ``runs`` list is the perf trajectory across
PRs (one dashboard file instead of one artifact per commit).

  python benchmarks/trend.py --current BENCH_explorer.json \
      --trend BENCH_trend.json [--prev prev/BENCH_trend.json] [--sha SHA]

Re-running a commit (e.g. a re-triggered CI job) replaces that SHA's entry
instead of duplicating it; runs are kept in append order.  Trend files that
already contain same-SHA duplicates (accumulated by pre-dedupe versions or
hand-merged artifacts) are cleaned on load — the latest entry per SHA wins.
Render the result into a markdown sparkline table with
``benchmarks/render_trend.py``.
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys

# CI invokes this without PYTHONPATH=src; the atomic-write helper lives in
# the repro package, so bootstrap the path relative to this file
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.utils.atomicio import atomic_write_json  # noqa: E402

TREND_SCHEMA = 1


def git_sha() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def dedupe_runs(runs: list) -> list:
    """Collapse same-SHA reruns, keeping the *latest* entry per SHA at the
    position of its last occurrence (append order preserved).  Runs without
    a real SHA (missing key, or the ``git_sha()`` "unknown" fallback) are
    distinct runs, not reruns — they are never collapsed."""
    def key(r, i):
        sha = r.get("sha")
        return (sha, -1) if sha and sha != "unknown" else (None, i)
    latest = {key(r, i): i for i, r in enumerate(runs)}
    return [r for i, r in enumerate(runs) if latest[key(r, i)] == i]


def load_trend(path: str) -> dict:
    if path and os.path.exists(path):
        try:
            with open(path) as f:
                d = json.load(f)
            if isinstance(d, dict) and isinstance(d.get("runs"), list):
                d["runs"] = dedupe_runs(d["runs"])
                return d
            print(f"note: ignoring malformed trend file {path}")
        except (OSError, json.JSONDecodeError) as e:
            print(f"note: ignoring unreadable trend file {path}: {e}")
    return {"trend_schema": TREND_SCHEMA, "runs": []}


def append_run(trend: dict, bench: dict, sha: str, date: str) -> dict:
    entry = {
        "sha": sha,
        "date": date,
        "mode": bench.get("mode"),
        "bench_schema": bench.get("bench_schema"),
        "metrics": {k: v for k, v in bench.items()
                    if isinstance(v, (int, float)) and k != "bench_schema"},
    }
    runs = dedupe_runs(trend["runs"])
    if sha and sha != "unknown":      # a real SHA replaces its old entry
        runs = [r for r in runs if r.get("sha") != sha]
    runs.append(entry)
    return {"trend_schema": TREND_SCHEMA, "runs": runs}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_explorer.json",
                    help="this run's benchmark artifact")
    ap.add_argument("--trend", default="BENCH_trend.json",
                    help="trend file to write")
    ap.add_argument("--prev", default=None,
                    help="previous trend file to extend (e.g. the last "
                         "successful CI run's downloaded artifact)")
    ap.add_argument("--sha", default=None,
                    help="commit SHA for this run (default: git HEAD)")
    ap.add_argument("--date", default=None,
                    help="ISO date for this run (default: now, UTC)")
    args = ap.parse_args()

    if not os.path.exists(args.current):
        print(f"FAIL: current benchmark {args.current} not found",
              file=sys.stderr)
        return 1
    with open(args.current) as f:
        bench = json.load(f)

    # seed from --prev when given, else extend the output file in place
    trend = load_trend(args.prev if args.prev else args.trend)
    sha = args.sha or git_sha()
    date = args.date or (datetime.datetime.now(datetime.timezone.utc)
                         .strftime("%Y-%m-%dT%H:%M:%SZ"))
    # atomic publish: a CI job killed mid-write must not leave a truncated
    # BENCH_trend.json for the next run to extend
    trend = append_run(trend, bench, sha, date)
    atomic_write_json(args.trend, trend)
    print(f"wrote {args.trend}: {len(trend['runs'])} run(s), "
          f"latest {sha[:12]} ({bench.get('mode')})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
