"""Partitioned-serving throughput benchmark: the Def.-4 pipelining claim,
served for real.

The explorer picks cuts for a chain of embedded platforms joined by
10-Mbit/s Ethernet (``eth10``), the cuts are mapped onto the reduced LM's
block boundaries (``repro.explore.lm_block_cuts``), and the same traffic
burst is served twice through ``repro.serve.PipelineServeEngine``:

* ``serial`` — lockstep stage handoff (the pre-``repro.serve`` executor
  behavior): every step pays ``sum(stage) + sum(link)``;
* ``async``  — thread-per-stage workers with emulated wire time slept in
  shuttle threads, so link transfers overlap compute and each other.

Two configurations are measured:

* the **explorer-chosen chain** (4 platforms -> up to 4 stages).  This is
  the gated configuration: with several links in flight the async runtime
  hides most wire time and sustains well over the ``--min-speedup`` 1.5x
  bar, landing within ``--max-def4-gap`` (30 %) of the Def.-4 prediction.
* a **2-stage reference** (single cut).  Its Def.-4 ratio is gated too;
  its speedup is recorded ungated: this bench host serializes all stage
  compute on one CPU core (JAX CPU executions do not overlap across
  threads), so with a single link the async ceiling is
  ``(C + L) / max(C + driver, L)`` — about 1.4x here — and only deeper
  chains can amortize further.  On a genuinely distributed deployment the
  2-stage bound is the full ``1/max(stage, link)``.

Def.-4 inputs are each resource's *measured per-item occupancy* (stage
wall, link wall including emulated wire sleep), which is what the paper's
formula consumes; the pure modeled wire time is reported alongside
(``link_model_s`` in the engine stats).

The ``repro.obs`` tracing overhead is measured on the explorer chain too:
the same compiled runner serves the async burst untraced and traced
(fresh engine per run, interleaved, best-of-N per arm), and
``serve_obs_overhead_pct`` reports how much throughput a live ``Obs``
handle costs — gated below ``--max-obs-overhead`` (CI: 5%).

Merges ``serve_*`` keys into ``BENCH_explorer.json`` (schema 8) so
``compare_bench.py`` gates ``serve_tokens_per_s`` and the trend dashboard
plots it.

  PYTHONPATH=src python benchmarks/serve_bench.py              # full
  PYTHONPATH=src python benchmarks/serve_bench.py --quick      # CI mode
  ... --min-speedup 1.5      # gate: async/serial on the explorer chain
  ... --max-def4-gap 0.3     # gate: |1 - measured/Def.4| on both configs
  ... --max-obs-overhead 5   # gate: tracing throughput cost, percent
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time


sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import csv_row
from repro.core import Platform, QuantSpec, SystemConfig, get_link
from repro.core.hwmodel import EYERISS_LIKE, SIMBA_LIKE
from repro.explore import SearchSettings, explore_graph, lm_block_cuts
from repro.models.registry import build_model, get_config
from repro.obs import NOOP_OBS, Obs
from repro.serve import (PipelineServeEngine, Request, ServeLink,
                         poisson_traffic, stream_of)
from repro.serving.pipeline import PartitionedLMRunner
from repro.utils.atomicio import atomic_write_json

BENCH_SCHEMA = 8
SERVE_LINK = "eth10"


def build_lm(n_layers: int = 4):
    cfg = dataclasses.replace(get_config("smollm-360m").reduced(),
                              n_layers=n_layers)
    model = build_model(cfg)
    import jax
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def explorer_cuts(cfg, model, prompt_len: int) -> list:
    """Let the explorer place the reduced LM onto a 4-platform embedded
    chain, then snap the schedule cuts onto decoder-block boundaries."""
    graph = model.to_graph(prompt_len)
    system = SystemConfig(
        [Platform("EYR0", EYERISS_LIKE, QuantSpec(bits=16)),
         Platform("EYR1", EYERISS_LIKE, QuantSpec(bits=16)),
         Platform("SMB0", SIMBA_LIKE, QuantSpec(bits=8)),
         Platform("SMB1", SIMBA_LIKE, QuantSpec(bits=8))],
        [get_link(SERVE_LINK)] * 3)
    er = explore_graph(graph, system,
                       objectives=("latency", "energy", "throughput"),
                       search=SearchSettings(seed=0))
    sel = er.selected.cuts if er.selected is not None else (1, 3, 5)
    return lm_block_cuts(sel, cfg.n_layers)


def serve_pair(runner, cuts, *, n_requests, max_new, prompt_len,
               n_slots=16, n_groups=8, vocab=512, tag="chain"):
    """Serve one burst through serial then async; -> (stats dict, ok).

    ``runner`` is built by the caller so the obs-overhead probe can reuse
    the same compiled stages (a fresh runner would pay XLA again)."""
    links = [ServeLink(model=get_link(SERVE_LINK))
             for _ in range(runner.n_stages - 1)]
    reqs = poisson_traffic(n_requests, rate_rps=2000.0, vocab=vocab,
                           prompt_len=prompt_len, max_new=max_new, seed=3)
    burst = [Request(r.rid, r.prompt, r.max_new, 0.0) for r in reqs]

    results = {}
    for mode in ("serial", "async"):
        eng = PipelineServeEngine(runner, n_slots=n_slots, n_groups=n_groups,
                                  eos=None, mode=mode, capacity=64,
                                  links=links)
        eng.warmup(prompt_len=prompt_len)
        t0 = time.perf_counter()
        rep = eng.run(stream_of(list(burst)), max_wall_s=300.0)
        results[mode] = rep
        s = rep.summary()
        print(csv_row(f"serve_{tag}_{len(cuts) + 1}stage_{mode}",
                      (time.perf_counter() - t0) * 1e6,
                      f"tok_per_s={s['tokens_per_s']:.0f};"
                      f"meas={s['measured_steps_per_s']:.0f};"
                      f"def4={s['def4_steps_per_s']:.0f}"))

    ser, asy = results["serial"], results["async"]
    dropped = 2 * len(burst) - ser.n_done - asy.n_done
    identical = ({r.rid: r.tokens for r in ser.records}
                 == {r.rid: r.tokens for r in asy.records})
    s_sum, a_sum = ser.summary(), asy.summary()
    def4 = a_sum["def4_steps_per_s"]
    ratio = a_sum["measured_steps_per_s"] / def4 if def4 else 0.0
    stats = {
        "tokens_per_s": a_sum["tokens_per_s"],
        "serial_tokens_per_s": s_sum["tokens_per_s"],
        "speedup": round(a_sum["tokens_per_s"]
                         / max(s_sum["tokens_per_s"], 1e-9), 2),
        "def4_ratio": round(ratio, 3),
        "def4_steps_per_s": def4,
        "measured_steps_per_s": a_sum["measured_steps_per_s"],
        "p95_ttft_ms": a_sum.get("ttft_p95_ms", 0.0),
        "n_stages": runner.n_stages,
        "cuts": list(cuts),
    }
    return stats, dropped, identical


def measure_obs_overhead(runner, *, n_requests, max_new, prompt_len,
                         n_slots=16, n_groups=8, vocab=512,
                         escalate_below=5.0):
    """Async tokens/s untraced vs traced on the shared compiled runner;
    -> (overhead_pct, untraced_tps, traced_tps).

    Fresh engine per run (the engine is cheap, the runner holds the
    compile), arms interleaved in alternating order, best-of-N per arm:
    per-run noise on a shared CI core is heavy-tailed (whole runs
    randomly lose 30%), so the max approximates each arm's noise-free
    capability.  While the measurement sits above ``escalate_below``
    (the gate threshold), up to two more rounds of pairs are added —
    the true per-span cost is far below the gate, so a persistent gap
    means a regression, not an unlucky window."""
    links = [ServeLink(model=get_link(SERVE_LINK))
             for _ in range(runner.n_stages - 1)]
    reqs = poisson_traffic(n_requests, rate_rps=2000.0, vocab=vocab,
                           prompt_len=prompt_len, max_new=max_new, seed=3)
    burst = [Request(r.rid, r.prompt, r.max_new, 0.0) for r in reqs]

    def one_run(obs) -> float:
        eng = PipelineServeEngine(runner, n_slots=n_slots,
                                  n_groups=n_groups, eos=None, mode="async",
                                  capacity=64, links=links, obs=obs)
        eng.warmup(prompt_len=prompt_len)
        rep = eng.run(stream_of(list(burst)), max_wall_s=300.0)
        return rep.summary()["tokens_per_s"]

    off, on = [], []

    def overhead_after(n_pairs: int) -> float:
        for i in range(n_pairs):
            arms = [(off, NOOP_OBS), (on, Obs.on())]
            for sink, obs in (arms if i % 2 == 0 else arms[::-1]):
                sink.append(one_run(obs))
        return (max(off) - max(on)) / max(off) * 100.0

    pct = overhead_after(2)
    for _ in range(2):
        if pct <= escalate_below:
            break
        pct = overhead_after(2)
    return round(pct, 2), max(off), max(on)


def merge_bench_json(path: str, serve_keys: dict, *, mode: str) -> None:
    """Fold serve_* keys into the explorer bench artifact (creating a
    minimal one when explorer_bench hasn't run), bumping the schema.

    An existing artifact keeps its own mode (CI: explorer_bench wrote it);
    only a fresh standalone file gets this run's mode."""
    out = {}
    if os.path.exists(path):
        with open(path) as f:
            out = json.load(f)
    out.setdefault("mode", mode)
    out["bench_schema"] = BENCH_SCHEMA
    out.update(serve_keys)
    atomic_write_json(path, out)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller traffic burst for CI")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="fail when async/serial on the explorer chain "
                         "drops below this")
    ap.add_argument("--max-def4-gap", type=float, default=None,
                    help="fail when |1 - measured/Def.4| exceeds this on "
                         "either config")
    ap.add_argument("--max-obs-overhead", type=float, default=None,
                    help="fail when live tracing costs more than this "
                         "percent of async tokens/s on the explorer chain")
    ap.add_argument("--json", default="BENCH_explorer.json",
                    help="artifact to merge serve_* keys into")
    args = ap.parse_args()

    n_req, max_new = (24, 16) if args.quick else (32, 24)
    plen = 8
    cfg, model, params = build_lm(n_layers=4)

    cuts = explorer_cuts(cfg, model, plen)
    print(csv_row("serve_explorer_cuts", 0.0, f"blocks={cuts}"))

    deep_runner = PartitionedLMRunner(model, params, cuts=cuts)
    ref_runner = PartitionedLMRunner(model, params,
                                     cuts=[cfg.n_layers // 2 - 1])
    deep, deep_drop, deep_ident = serve_pair(
        deep_runner, cuts, n_requests=n_req, max_new=max_new,
        prompt_len=plen, vocab=cfg.vocab)
    ref, ref_drop, ref_ident = serve_pair(
        ref_runner, [cfg.n_layers // 2 - 1], n_requests=n_req,
        max_new=max_new, prompt_len=plen, vocab=cfg.vocab, tag="ref")

    obs_pct, tps_off, tps_on = measure_obs_overhead(
        deep_runner, n_requests=n_req, max_new=max_new, prompt_len=plen,
        vocab=cfg.vocab,
        escalate_below=(args.max_obs_overhead
                        if args.max_obs_overhead is not None else 5.0))
    print(csv_row("serve_obs_overhead", 0.0,
                  f"untraced={tps_off:.0f};traced={tps_on:.0f};"
                  f"overhead_pct={obs_pct}"))

    serve_keys = {
        "serve_tokens_per_s": deep["tokens_per_s"],
        "serve_serial_tokens_per_s": deep["serial_tokens_per_s"],
        "serve_speedup": deep["speedup"],
        "serve_def4_ratio": deep["def4_ratio"],
        "serve_def4_steps_per_s": deep["def4_steps_per_s"],
        "serve_measured_steps_per_s": deep["measured_steps_per_s"],
        "serve_p95_ttft_ms": deep["p95_ttft_ms"],
        "serve_stages": deep["n_stages"],
        "serve_cuts": deep["cuts"],
        "serve_2stage_tokens_per_s": ref["tokens_per_s"],
        "serve_2stage_speedup": ref["speedup"],
        "serve_2stage_def4_ratio": ref["def4_ratio"],
        "serve_obs_overhead_pct": obs_pct,
        "serve_traced_tokens_per_s": round(tps_on, 1),
        "serve_link": SERVE_LINK,
        "serve_requests": n_req,
        "serve_max_new": max_new,
    }
    merge_bench_json(args.json, serve_keys,
                     mode="quick" if args.quick else "full")
    print(f"merged serve_* into {args.json}")
    print(csv_row("serve_summary", 0.0,
                  f"speedup=x{deep['speedup']};ratio={deep['def4_ratio']};"
                  f"2stage=x{ref['speedup']}/{ref['def4_ratio']}"))

    fail = []
    if deep_drop or ref_drop:
        fail.append(f"dropped requests: deep={deep_drop} ref={ref_drop}")
    if not (deep_ident and ref_ident):
        fail.append("async/serial greedy tokens diverged "
                    f"(deep={deep_ident}, ref={ref_ident})")
    if args.min_speedup is not None and deep["speedup"] < args.min_speedup:
        fail.append(f"explorer-chain speedup x{deep['speedup']} < "
                    f"required x{args.min_speedup}")
    if args.max_def4_gap is not None:
        for tag, r in (("chain", deep["def4_ratio"]),
                       ("2stage", ref["def4_ratio"])):
            if abs(1.0 - r) > args.max_def4_gap:
                fail.append(f"{tag} Def.-4 gap |1-{r}| > {args.max_def4_gap}")
    if args.max_obs_overhead is not None and obs_pct > args.max_obs_overhead:
        fail.append(f"tracing overhead {obs_pct}% > allowed "
                    f"{args.max_obs_overhead}% of async tokens/s")
    for msg in fail:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if fail else 0


if __name__ == "__main__":
    sys.exit(main())
