"""Fleet fault-tolerance smoke (CI ``fleet-smoke`` job): run a 2-worker
local sweep, SIGKILL the workers mid-run, resume from the manifest, and
assert the merged report is report-identical to the serial baseline with no
done cell recomputed.

This exercises the whole crash path end-to-end: atomic claims survive the
kill, ``reclaim_stale`` frees the dead workers' claims, the resumed run
executes only pending cells (verified via shard mtimes), and the merge is
fingerprint-equal to ``Campaign.run``.

  PYTHONPATH=src python benchmarks/fleet_smoke.py [--models 3] [--workers 2]
"""

from __future__ import annotations

import argparse
import os
import signal
import sys
import time

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.explore import (Campaign, ExplorationSpec, LinkSpec, ModelRef,
                           PlatformSpec, SearchSettings, SystemSpec)
from repro.fleet import (Manifest, merge_manifest, report_fingerprint,
                         run_fleet, start_workers)

MODELS = ("squeezenet11", "vgg16", "regnetx_400mf")

AB = SystemSpec(platforms=(PlatformSpec("A", "eyr", bits=16),
                           PlatformSpec("B", "smb", bits=8)),
                links=("gige",), name="AB")
AB_SLOW = SystemSpec(platforms=(PlatformSpec("A", "eyr", bits=16),
                                PlatformSpec("B", "smb", bits=8)),
                     links=(LinkSpec(base="gige", rate_bps=1e8),),
                     name="AB-slow")


def build_campaign(n_models: int) -> Campaign:
    spec = ExplorationSpec(
        model=ModelRef("cnn", MODELS[0], {"in_hw": 64}),
        system=AB,
        objectives=("latency", "energy"),
        search=SearchSettings(strategy="nsga2", seed=0, pop_size=48,
                              n_gen=8))
    return Campaign(spec,
                    models=[ModelRef("cnn", n, {"in_hw": 64})
                            for n in MODELS[:n_models]],
                    systems=[AB, AB_SLOW])


def wait_for_shards(manifest: Manifest, n: int, timeout_s: float) -> int:
    t0 = time.time()
    while time.time() - t0 < timeout_s:
        done = len(manifest.cells_in_state("done"))
        if done >= n:
            return done
        time.sleep(0.1)
    return len(manifest.cells_in_state("done"))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", type=int, default=3)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--manifest", default=None,
                    help="manifest dir (default: a temp dir)")
    ap.add_argument("--timeout", type=float, default=300.0)
    args = ap.parse_args()

    camp = build_campaign(args.models)
    print(f"[smoke] serial baseline: {args.models} models x 2 systems ...")
    t0 = time.time()
    serial = camp.run().report
    print(f"[smoke] serial done in {time.time() - t0:.1f}s")

    import tempfile
    mdir = args.manifest or tempfile.mkdtemp(prefix="fleet-smoke-")
    manifest = camp.to_manifest(mdir)
    n_cells = len(manifest.cells)
    print(f"[smoke] manifest {mdir}: {n_cells} cells")

    # phase 1: start workers, SIGKILL them all mid-run (after >=1 shard,
    # before the sweep finishes) — simulating a host crash
    procs = start_workers(mdir, args.workers)
    done_before_kill = wait_for_shards(manifest, 1, args.timeout)
    for p in procs:
        if p.poll() is None:
            os.kill(p.pid, signal.SIGKILL)
    for p in procs:
        p.wait()
    st = manifest.status()
    print(f"[smoke] killed {args.workers} worker(s): {st['done']} done, "
          f"{st['running']} orphaned claim(s), {st['pending']} pending")
    if st["done"] >= n_cells:
        print("[smoke] WARNING: sweep finished before the kill landed — "
              "crash path not exercised (sweep too small/fast)")
    pre_shards = {c.id: os.stat(manifest._shard_path(c.id)).st_mtime_ns
                  for c in manifest.cells_in_state("done")}

    # phase 2: resume — same command a user would run; stale-claim reclaim
    # plus completing only pending cells
    t0 = time.time()
    merged = run_fleet(mdir, workers=args.workers, verbose=True)
    print(f"[smoke] resume completed in {time.time() - t0:.1f}s")

    failures = []
    manifest = Manifest.load(mdir)
    for cid, mtime in pre_shards.items():
        if os.stat(manifest._shard_path(cid)).st_mtime_ns != mtime:
            failures.append(f"done cell {cid} was recomputed after resume")
    if report_fingerprint(merged) != report_fingerprint(serial):
        failures.append("merged fleet report != serial baseline")
    if report_fingerprint(merge_manifest(mdir)) != \
            report_fingerprint(serial):
        failures.append("re-merge from manifest != serial baseline")
    if len(merged.entries) != n_cells:
        failures.append(f"merged {len(merged.entries)} entries, "
                        f"expected {n_cells}")

    if failures:
        for f in failures:
            print(f"[smoke] FAIL: {f}", file=sys.stderr)
        return 1
    print(f"[smoke] OK: {done_before_kill} pre-kill shard(s) preserved, "
          f"{n_cells - done_before_kill} cell(s) resumed, merged report "
          f"identical to serial")
    return 0


if __name__ == "__main__":
    sys.exit(main())
