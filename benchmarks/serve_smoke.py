"""CI correctness smoke for the ``repro.serve`` runtime.

A short traffic burst is served against a 2-stage partitioned reduced LM
three ways — async pipeline, serial-handoff baseline, and the monolithic
``GenerationEngine`` — and the run fails unless:

* zero requests are dropped (every submitted rid comes back finished);
* greedy tokens are byte-identical across all three executors, including
  the EOS-eviction path (the EOS id is taken from a real greedy
  continuation so some sequences stop early and their slots backfill);
* async throughput >= 0.9x the serial-handoff baseline (noise headroom;
  the strict >=1.5x speedup gate lives in ``serve_bench``).

  PYTHONPATH=src python benchmarks/serve_smoke.py
"""

from __future__ import annotations

import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from repro.core import get_link
from repro.models.registry import build_model, get_config
from repro.serve import (PipelineServeEngine, Request, ServeLink,
                        poisson_traffic, stream_of)
from repro.serving.engine import GenerationEngine
from repro.serving.pipeline import PartitionedLMRunner

N_REQUESTS = 12
MAX_NEW = 8
PROMPT_LEN = 8


def main() -> int:
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    runner = PartitionedLMRunner(model, params, cuts=[0])

    reqs = poisson_traffic(N_REQUESTS, rate_rps=2000.0, vocab=cfg.vocab,
                           prompt_len=PROMPT_LEN, max_new=MAX_NEW, seed=7)
    burst = [Request(r.rid, r.prompt, r.max_new, 0.0) for r in reqs]

    # EOS from a real greedy continuation so eviction/backfill paths run
    engine = GenerationEngine(model, params,
                              max_seq=PROMPT_LEN + MAX_NEW + 8,
                              cache_dtype=jnp.float32)
    prompts = np.stack([r.prompt for r in reqs])
    probe = engine.generate(prompts, max_new=MAX_NEW)
    eos = int(probe.tokens[0, 2])
    ref = engine.generate(prompts, max_new=MAX_NEW, eos=eos)

    reports = {}
    for mode in ("serial", "async"):
        eng = PipelineServeEngine(runner, n_slots=8, n_groups=4, eos=eos,
                                  mode=mode, capacity=32,
                                  links=[ServeLink(model=get_link("eth10"))])
        eng.warmup(prompt_len=PROMPT_LEN)
        reports[mode] = eng.run(stream_of(list(burst)), max_wall_s=120.0)

    fail = []
    for mode, rep in reports.items():
        if rep.n_done != N_REQUESTS:
            fail.append(f"{mode}: dropped {N_REQUESTS - rep.n_done} "
                        f"of {N_REQUESTS} request(s)")

    tokens = {mode: {r.rid: r.tokens for r in rep.records}
              for mode, rep in reports.items()}
    if tokens["serial"] != tokens["async"]:
        bad = [rid for rid in tokens["serial"]
               if tokens["serial"][rid] != tokens["async"].get(rid)]
        fail.append(f"async vs serial token mismatch for rids {bad}")
    for i, r in enumerate(reqs):
        row = list(ref.tokens[i])
        if eos in row:
            row = row[:row.index(eos) + 1]
        if tokens["async"].get(r.rid) != row:
            fail.append(f"rid {r.rid}: async diverged from "
                        f"GenerationEngine greedy reference")

    ser = reports["serial"].summary()["tokens_per_s"]
    asy = reports["async"].summary()["tokens_per_s"]
    print(f"serve_smoke: serial={ser:.0f} tok/s, async={asy:.0f} tok/s "
          f"(x{asy / max(ser, 1e-9):.2f}), eos={eos}, "
          f"{N_REQUESTS} requests, 0 dropped" if not fail else
          f"serve_smoke: serial={ser:.0f} async={asy:.0f}")
    # correctness smoke, not a perf gate: on this 2-stage chain only link
    # time overlaps, so allow noise headroom on a shared runner — the
    # strict >=1.5x speedup check lives in serve_bench's deeper chain
    if asy < 0.9 * ser:
        fail.append(f"async throughput {asy:.0f} tok/s below 0.9x serial "
                    f"baseline {ser:.0f} tok/s")

    for msg in fail:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if fail else 0


if __name__ == "__main__":
    sys.exit(main())
