"""The paper's technique applied to every assigned architecture: pipeline
stage boundaries across 2 and 4 TPU pods over inter-pod DCI, chosen by the
explorer from each model's layer graph (at train_4k's sequence length).

Outputs, per arch: the selected cuts, stage balance, pipelined-throughput
gain over a single pod, and whether the explorer kept all stages (Table-II
effect on pods: transmission overhead can make fewer stages optimal)."""

from __future__ import annotations

import dataclasses
import json
import os

from benchmarks.common import csv_row, timed
from repro.core import (Explorer, Platform, QuantSpec, SystemConfig,
                        get_link)
from repro.core.hwmodel.arch import TPU_V5E
from repro.models.registry import ARCH_IDS, build_model, get_config

SEQ = 4096


def run(out_dir: str = "experiments"):
    os.makedirs(out_dir, exist_ok=True)
    pod = Platform("pod", dataclasses.replace(TPU_V5E,
                                              mem_bytes=256 * 16 * 2 ** 30),
                   QuantSpec(bits=16))
    rows, out = [], {}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        graph = model.to_graph(SEQ)
        shared = (model.shared_groups()
                  if hasattr(model, "shared_groups") else None)
        out[arch] = {}
        for n_pods in (2, 4):
            system = SystemConfig([pod] * n_pods,
                                  [get_link("dci")] * (n_pods - 1))

            def explore():
                ex = Explorer(graph, system,
                              objectives=("latency", "throughput"),
                              shared_groups=shared)
                return ex.run(seed=0)

            res, dt = timed(explore)
            s = res.selected
            gain = (s.throughput / res.baselines[0].throughput
                    if res.baselines[0].throughput else 0.0)
            out[arch][f"{n_pods}pods"] = {
                "cuts": list(s.cuts),
                "stages_used": s.n_partitions,
                "stage_latency_ms": [round(t * 1e3, 2)
                                     for t in s.stage_latency_s],
                "throughput_gain_x": round(gain, 2),
            }
            rows.append(csv_row(
                f"pods_{arch}_{n_pods}", dt * 1e6,
                f"stages={s.n_partitions}/{n_pods};th_gain={gain:.2f}x"))
    with open(os.path.join(out_dir, "llm_pod_partition.json"), "w") as f:
        json.dump(out, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
