"""The paper's technique applied to every assigned architecture: pipeline
stage boundaries across 2 and 4 TPU pods over inter-pod DCI, chosen from
each model's layer graph (at train_4k's sequence length) by a single
``Campaign`` fanning the whole registry across both pod counts.

Outputs, per arch: the selected cuts, stage balance, pipelined-throughput
gain over a single pod, and whether the search kept all stages (Table-II
effect on pods: transmission overhead can make fewer stages optimal)."""

from __future__ import annotations

import os

from benchmarks.common import csv_row
from repro.utils.atomicio import atomic_write_json
from repro.explore import (Campaign, ExplorationSpec, ModelRef, PlatformSpec,
                           SystemSpec)
from repro.models.registry import ARCH_IDS

SEQ = 4096

POD = PlatformSpec("pod", "tpu_v5e", bits=16,
                   mem_capacity=256 * 16 * 2 ** 30)


def run(out_dir: str = "experiments"):
    os.makedirs(out_dir, exist_ok=True)
    systems = [SystemSpec(platforms=(POD,) * n, links=("dci",) * (n - 1),
                          name=f"{n}pods") for n in (2, 4)]
    spec = ExplorationSpec(
        model=ModelRef("registry", ARCH_IDS[0], {"seq": SEQ}),
        system=systems[0],
        objectives=("latency", "throughput"))
    camp = Campaign(spec,
                    models=[ModelRef("registry", a, {"seq": SEQ})
                            for a in ARCH_IDS],
                    systems=systems).run()

    rows, out = [], {}
    for entry in camp.entries:
        res, arch = entry.result, entry.model
        s = res.selected
        gain = (s.throughput / res.baselines[0].throughput
                if s and res.baselines[0].throughput else 0.0)
        out.setdefault(arch, {})[entry.system] = {
            "cuts": list(s.cuts) if s else None,
            "stages_used": s.n_partitions if s else 0,
            "stage_latency_ms": ([round(t * 1e3, 2)
                                  for t in s.stage_latency_s] if s else []),
            "throughput_gain_x": round(gain, 2),
        }
        rows.append(csv_row(
            f"pods_{arch}_{entry.system}", entry.wall_s * 1e6,
            f"stages={s.n_partitions if s else 0}/{len(res.baselines)};"
            f"th_gain={gain:.2f}x"))
    camp.report.save(os.path.join(out_dir, "llm_pod_campaign_report.json"))
    atomic_write_json(os.path.join(out_dir, "llm_pod_partition.json"), out)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
