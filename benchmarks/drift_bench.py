"""Online re-partitioning benchmark: warm re-search vs cold compile+search.

The online drift story (``repro.explore.online``) claims a re-partition
after a link degradation or node dropout costs *milliseconds*, not the
seconds a cold search pays for XLA compilation.  This bench measures both
ends on the paper's 4-platform chain (2×EYR + 2×SMB over GigE) with
EfficientNet-B0:

* **cold** — a fresh :class:`OnlineRepartitioner` with an empty compiled-
  runner cache: model resolution, candidate filtering, XLA trace+compile
  of the whole NSGA-II program and the first search.  This is what every
  perturbed system used to cost before table values became runtime
  arguments.
* **warm** — a stream of same-shape perturbations (degraded links, one
  node dropout) through the same repartitioner: the compiled runner is
  reused (cache size must stay 1 — asserted) and each search warm-starts
  from the previous front.  ``repartition_ms`` is the median decision
  wall.

``repartition_warm_speedup = cold_ms / repartition_ms`` is merged into
``BENCH_explorer.json`` (schema 8) so ``compare_bench.py`` gates it against
the committed floor and the trend dashboard plots ``repartition_ms``;
``--min-warm-speedup`` makes this run itself the hard ≥ 20× gate in CI.

  PYTHONPATH=src python benchmarks/drift_bench.py              # full
  PYTHONPATH=src python benchmarks/drift_bench.py --quick      # CI mode
  ... --min-warm-speedup 20    # gate: cold/warm wall ratio
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import chain_system_spec, csv_row
from repro.explore import (ExplorationSpec, ModelRef, OnlineRepartitioner,
                           SearchSettings, clear_jit_runner_cache,
                           degrade_link, drop_node, jit_runner_cache_size)
from repro.utils.atomicio import atomic_write_json

BENCH_SCHEMA = 8
DRIFT_MODEL = "efficientnet_b0"


def drift_spec(pop: int, n_gen: int) -> ExplorationSpec:
    """EfficientNet-B0 on the §V-C 4-platform chain, jit_nsga2 search."""
    return ExplorationSpec(
        model=ModelRef("cnn", DRIFT_MODEL, {"in_hw": 64}),
        system=chain_system_spec(),
        objectives=("latency", "energy", "throughput"),
        search=SearchSettings(strategy="jit_nsga2", seed=0,
                              pop_size=pop, n_gen=n_gen))


def drift_stream(base, n_events: int):
    """Deterministic perturbation schedule: progressive link degradation
    round-robin over the chain's links, with one node dropout mixed in."""
    events = []
    for i in range(n_events):
        if i == n_events // 2:
            events.append(drop_node(base, len(base.platforms) - 2))
        else:
            link = i % len(base.links)
            events.append(degrade_link(base, link, 2.0 ** (1 + i // 2)))
    return events


def bench_drift(pop: int, n_gen: int, n_events: int) -> dict:
    spec = drift_spec(pop, n_gen)

    clear_jit_runner_cache()
    t0 = time.perf_counter()
    rp = OnlineRepartitioner(spec)
    first = rp.update(spec.system)
    cold_s = time.perf_counter() - t0
    assert jit_runner_cache_size() == 1, "cold search must compile once"
    print(csv_row("drift_cold", cold_s * 1e6,
                  f"cuts={first.cuts};pareto={first.pareto_size}"))

    warm_ms = []
    n_changed = 0
    for event in drift_stream(spec.system, n_events):
        d = rp.update(event)
        warm_ms.append(d.repartition_ms)
        n_changed += int(d.changed)
        print(csv_row("drift_warm", d.repartition_ms * 1e3,
                      f"label={d.label};cuts={d.cuts};changed={d.changed};"
                      f"feasible={d.feasible}"))
    assert jit_runner_cache_size() == 1, (
        f"warm re-searches recompiled: cache={jit_runner_cache_size()}")

    med_ms = statistics.median(warm_ms)
    speedup = (cold_s * 1e3) / med_ms
    print(csv_row("drift_summary", 0.0,
                  f"cold_ms={cold_s * 1e3:.0f};warm_ms={med_ms:.1f};"
                  f"speedup=x{speedup:.0f};changed={n_changed}/{n_events}"))
    return {
        "repartition_warm_speedup": round(speedup, 1),
        "repartition_ms": round(med_ms, 2),
        "repartition_cold_ms": round(cold_s * 1e3, 1),
        "repartition_events": n_events,
        "repartition_changed": n_changed,
        "repartition_model": DRIFT_MODEL,
    }


def merge_bench_json(path: str, keys: dict, *, mode: str) -> None:
    """Fold repartition_* keys into the explorer bench artifact (creating a
    minimal one when explorer_bench hasn't run).  An existing artifact
    keeps its own mode; only a fresh standalone file gets this run's."""
    out = {}
    if os.path.exists(path):
        with open(path) as f:
            out = json.load(f)
    out.setdefault("mode", mode)
    out["bench_schema"] = BENCH_SCHEMA
    out.update(keys)
    atomic_write_json(path, out)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller search budget / fewer events for CI")
    ap.add_argument("--min-warm-speedup", type=float, default=None,
                    help="fail when cold/warm wall ratio drops below this")
    ap.add_argument("--json", default="BENCH_explorer.json",
                    help="artifact to merge repartition_* keys into")
    args = ap.parse_args()

    pop, n_gen, n_events = (128, 12, 4) if args.quick else (256, 16, 8)
    keys = bench_drift(pop, n_gen, n_events)
    merge_bench_json(args.json, keys,
                     mode="quick" if args.quick else "full")
    print(f"merged repartition_* into {args.json}")

    if (args.min_warm_speedup is not None
            and keys["repartition_warm_speedup"] < args.min_warm_speedup):
        print(f"FAIL: repartition_warm_speedup "
              f"x{keys['repartition_warm_speedup']} < required "
              f"x{args.min_warm_speedup}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
