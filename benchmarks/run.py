"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run             # everything
  PYTHONPATH=src python -m benchmarks.run --only fig2
"""

from __future__ import annotations

import argparse
import sys
import traceback

BENCHES = [
    ("fig2", "benchmarks.fig2_pareto"),            # Fig. 2: six-CNN pareto
    ("fig3", "benchmarks.fig3_memory"),            # Fig. 3: memory vs cut
    ("table2", "benchmarks.table2_multipartition"),  # Table II: 4-platform
    ("accuracy", "benchmarks.accuracy_measured"),  # §IV-C measured + QAT
    ("link", "benchmarks.link_sensitivity"),       # link co-design sweep
    ("pods", "benchmarks.llm_pod_partition"),      # technique on 10 archs
    ("kernels", "benchmarks.kernels_bench"),       # Pallas kernel micro
    ("roofline", "benchmarks.roofline_report"),    # §Roofline table
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: " +
                         ",".join(k for k, _ in BENCHES))
    args = ap.parse_args()
    subset = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for key, module in BENCHES:
        if subset and key not in subset:
            continue
        try:
            import importlib
            mod = importlib.import_module(module)
            for row in mod.run():
                print(row, flush=True)
        except Exception:
            failures += 1
            print(f"{key},0.0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
