"""Render ``BENCH_trend.json`` into a markdown sparkline table (the ROADMAP
"Trend dashboard" item): one row per tracked metric with a unicode
sparkline over the run history, first/last values and the net drift — the
slow-drift view the per-run ±20% gate cannot see.

  python benchmarks/render_trend.py --trend BENCH_trend.json \
      --out BENCH_trend.md [--last 30]

CI commits the output to the benchmark artifact next to the JSON, so every
run carries a human-readable perf trajectory.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# CI invokes this without PYTHONPATH=src; the atomic-write helper lives in
# the repro package, so bootstrap the path relative to this file
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.utils.atomicio import atomic_write_text  # noqa: E402

# the gate's tracked-metric split is the single source of truth: a metric
# added to compare_bench.py shows up here automatically
try:
    from compare_bench import HIGHER_BETTER, LOWER_BETTER
except ImportError:    # invoked as a module (python -m benchmarks.render_trend)
    from benchmarks.compare_bench import HIGHER_BETTER, LOWER_BETTER

SPARK_BARS = "▁▂▃▄▅▆▇█"


def sparkline(values: list) -> str:
    """Unicode sparkline; constant series render mid-bar, not flat-bottom."""
    if not values:
        return ""
    lo, hi = min(values), max(values)
    if hi - lo <= 0:
        return SPARK_BARS[3] * len(values)
    span = hi - lo
    return "".join(
        SPARK_BARS[min(int((v - lo) / span * len(SPARK_BARS)),
                       len(SPARK_BARS) - 1)]
        for v in values)


def fmt(v: float) -> str:
    if abs(v) >= 1000:
        return f"{v:,.0f}"
    return f"{v:.3g}"


def render(trend: dict, last: int = 0) -> str:
    all_runs = trend.get("runs", [])
    if not all_runs:
        return "# Benchmark trend\n\n_No runs recorded yet._\n"
    # only runs comparable to the latest one: same bench_schema AND mode —
    # the same incomparability rule the compare_bench.py gate applies (key
    # semantics change across schema bumps; quick/full measure different
    # workloads under the same keys)
    schema = all_runs[-1].get("bench_schema")
    mode = all_runs[-1].get("mode")
    runs = [r for r in all_runs if r.get("bench_schema") == schema
            and r.get("mode") == mode]
    excluded = len(all_runs) - len(runs)
    if last > 0:
        runs = runs[-last:]

    lines = [
        "# Benchmark trend",
        "",
        f"{len(runs)} run(s)"
        + (f" ({excluded} older run(s) hidden: different bench_schema/mode)"
           if excluded else "") + ", "
        f"{runs[0].get('sha', '?')[:9]} → {runs[-1].get('sha', '?')[:9]} "
        f"({runs[0].get('date', '?')[:10]} → {runs[-1].get('date', '?')[:10]}"
        f", mode={mode}, bench_schema={schema})",
        "",
        "| metric | trend | first | last | drift | |",
        "|---|---|---:|---:|---:|---|",
    ]
    for key, sign in ([(k, +1) for k in HIGHER_BETTER]
                      + [(k, -1) for k in LOWER_BETTER]):
        series = [(r["metrics"][key]) for r in runs
                  if isinstance(r.get("metrics", {}).get(key), (int, float))]
        if not series:
            continue
        first, latest = series[0], series[-1]
        drift = (latest - first) / first if first else 0.0
        better = drift * sign
        verdict = ("improved" if better > 0.02
                   else "regressed" if better < -0.02 else "flat")
        lines.append(
            f"| `{key}` | `{sparkline(series)}` | {fmt(first)} "
            f"| {fmt(latest)} | {drift:+.1%} | {verdict} |")
    lines += [
        "",
        "_Sparklines are min–max scaled per metric over the shown window; "
        "`drift` is last vs first. Gate thresholds live in "
        "`benchmarks/compare_bench.py`._",
        "",
    ]
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trend", default="BENCH_trend.json")
    ap.add_argument("--out", default="BENCH_trend.md")
    ap.add_argument("--last", type=int, default=0,
                    help="only render the last N runs (0 = all)")
    args = ap.parse_args()

    if not os.path.exists(args.trend):
        print(f"FAIL: trend file {args.trend} not found", file=sys.stderr)
        return 1
    with open(args.trend) as f:
        trend = json.load(f)
    md = render(trend, last=args.last)
    atomic_write_text(args.out, md)
    print(f"wrote {args.out} ({len(trend.get('runs', []))} run(s))")
    print(md)
    return 0


if __name__ == "__main__":
    sys.exit(main())
