"""Table II reproduction: four-platform chain (2×EYR → 2×SMB over GigE),
Pareto-optimal schedules w.r.t. latency / energy / bandwidth; count how
many partitions (active platforms) near-optimal schedules use.

With the batched evaluator the k-cut space of this chain is exhaustively
enumerable, so the counts use the exact ``MultiCutScan`` strategy instead
of a sampled NSGA-II front.

Paper finding: small CNNs (SqueezeNet, VGG) rarely profit from 4
partitions; large ones (RegNetX, EfficientNet-B0) do."""

from __future__ import annotations

import os
from collections import Counter

from benchmarks.common import PAPER_CNNS, chain_system_spec, csv_row
from repro.utils.atomicio import atomic_write_json
from repro.explore import (Campaign, ExplorationSpec, ModelRef,
                           SearchSettings)

OBJECTIVE_SETS = {
    # the paper's §V-C wording ("latency, energy consumption and link
    # bandwidth") — but its discussion of the results is throughput-driven
    # ("significantly higher throughput" for RegNetX/EfficientNet), so we
    # report both the literal and the throughput-extended objective sets.
    "faithful": ("latency", "energy", "bandwidth"),
    "with_throughput": ("latency", "energy", "bandwidth", "throughput"),
}


def run(out_dir: str = "experiments"):
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    table = {name: {} for name in PAPER_CNNS}
    for oname, objectives in OBJECTIVE_SETS.items():
        spec = ExplorationSpec(
            model=ModelRef("cnn", PAPER_CNNS[0]),
            system=chain_system_spec(),
            objectives=objectives,
            search=SearchSettings(strategy="multicut"))
        camp = Campaign(spec, models=[ModelRef("cnn", n)
                                      for n in PAPER_CNNS]).run()
        for entry in camp.entries:
            res, name, dt = entry.result, entry.model, entry.wall_s
            counts = Counter(e.n_partitions for e in res.pareto)
            table[name][oname] = {str(k): counts.get(k, 0)
                                  for k in (1, 2, 3, 4)}
            table[name][oname]["pareto_size"] = len(res.pareto)
            table[name][oname]["explore_s"] = round(dt, 2)
            rows.append(csv_row(
                f"table2_{name}_{oname}", dt * 1e6,
                "partitions=" + "/".join(str(counts.get(k, 0))
                                         for k in (1, 2, 3, 4))))
    atomic_write_json(os.path.join(out_dir, "table2_multipartition.json"),
                      table)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
