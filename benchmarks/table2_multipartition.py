"""Table II reproduction: four-platform chain (2×EYR → 2×SMB over GigE),
Pareto-optimal schedules w.r.t. latency / energy / bandwidth; count how
many partitions (active platforms) near-optimal schedules use.

Paper finding: small CNNs (SqueezeNet, VGG) rarely profit from 4
partitions; large ones (RegNetX, EfficientNet-B0) do."""

from __future__ import annotations

import json
import os
from collections import Counter

from benchmarks.common import PAPER_CNNS, chain_system, csv_row, timed
from repro.core import Explorer
from repro.models.cnn.zoo import build_cnn


OBJECTIVE_SETS = {
    # the paper's §V-C wording ("latency, energy consumption and link
    # bandwidth") — but its discussion of the results is throughput-driven
    # ("significantly higher throughput" for RegNetX/EfficientNet), so we
    # report both the literal and the throughput-extended objective sets.
    "faithful": ("latency", "energy", "bandwidth"),
    "with_throughput": ("latency", "energy", "bandwidth", "throughput"),
}


def run(out_dir: str = "experiments"):
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    table = {}
    for name in PAPER_CNNS:
        graph = build_cnn(name).to_graph()
        table[name] = {}
        for oname, objectives in OBJECTIVE_SETS.items():
            def explore():
                ex = Explorer(graph, chain_system(), objectives=objectives)
                return ex.run(seed=0, pop_size=48, n_gen=40)

            res, dt = timed(explore)
            counts = Counter(e.n_partitions for e in res.pareto)
            table[name][oname] = {str(k): counts.get(k, 0)
                                  for k in (1, 2, 3, 4)}
            table[name][oname]["pareto_size"] = len(res.pareto)
            table[name][oname]["explore_s"] = round(dt, 2)
            rows.append(csv_row(
                f"table2_{name}_{oname}", dt * 1e6,
                "partitions=" + "/".join(str(counts.get(k, 0))
                                         for k in (1, 2, 3, 4))))
    with open(os.path.join(out_dir, "table2_multipartition.json"), "w") as f:
        json.dump(table, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
