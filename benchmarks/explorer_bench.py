"""Search-path throughput benchmark: candidate evaluations/second through
the scalar ``PartitionEvaluator.evaluate`` loop vs the vectorized
``evaluate_batch`` path, NSGA-II-scale runs through both the NumPy and the
``jax.jit``-compiled strategy at pop ≥ 2048, and a multi-model ``Campaign``
fan-out — the whole Fig.-1 hot path at fleet scale.

This is the hot path of the whole framework (§IV, Table I): search quality
scales with how many placements we can afford to score, so regressions here
silently shrink the reachable population/generation budget.

Emits a machine-readable ``BENCH_explorer.json`` (evals/s, campaign
wall-clock, JIT compile time reported separately from steady-state rate) so
CI can track the perf trajectory across PRs and gate regressions with
``benchmarks/compare_bench.py``.

  PYTHONPATH=src python benchmarks/explorer_bench.py            # full
  PYTHONPATH=src python benchmarks/explorer_bench.py --quick    # CI mode
  ... --min-speedup 5        # exit non-zero below this batch/scalar ratio
  ... --min-jit-speedup 3    # exit non-zero below this jit/numpy NSGA ratio
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import chain_system, chain_system_spec, csv_row
from repro.utils.atomicio import atomic_write_json
from repro.core.accuracy import ProxyAccuracy
from repro.core.graph import linearize
from repro.core.partition import Constraints, PartitionEvaluator
from repro.explore import (Campaign, ExplorationSpec, ModelRef,
                           SearchSettings, explore_graph)
from repro.models.cnn.zoo import build_cnn


def random_cut_matrix(rng, n: int, n_cuts: int, length: int) -> np.ndarray:
    return np.sort(rng.integers(-1, length, size=(n, n_cuts)), axis=1)


def make_evaluator(model: str = "squeezenet11"):
    graph = build_cnn(model, in_hw=64).to_graph()
    system = chain_system()                       # 4 platforms -> n_cuts = 3
    schedule = linearize(graph, "min_memory")
    return PartitionEvaluator(graph, schedule, system,
                              accuracy_fn=ProxyAccuracy(schedule, system))


def bench_eval_paths(out: dict, model: str = "squeezenet11",
                     n_candidates: int = 2048, scalar_cap: int = 256):
    """Score the same random candidate matrix through both paths."""
    evaluator = make_evaluator(model)
    cons = Constraints(max_link_bytes=10_000_000)
    rng = np.random.default_rng(0)
    cuts = random_cut_matrix(rng, n_candidates, evaluator.system.n_cuts,
                             len(evaluator.schedule))

    n_scalar = min(scalar_cap, n_candidates)
    t0 = time.perf_counter()
    for row in cuts[:n_scalar]:
        evaluator.evaluate(row, cons)
    scalar_dt = time.perf_counter() - t0
    scalar_rate = n_scalar / scalar_dt

    evaluator.evaluate_batch(cuts[:8], cons)      # warm lazy tables
    t0 = time.perf_counter()
    evaluator.evaluate_batch(cuts, cons)
    batch_dt = time.perf_counter() - t0
    batch_rate = n_candidates / batch_dt

    speedup = batch_rate / scalar_rate
    out["scalar_evals_per_s"] = round(scalar_rate, 1)
    out["batch_evals_per_s"] = round(batch_rate, 1)
    out["batch_speedup"] = round(speedup, 1)
    print(csv_row("explorer_scalar_evals_per_s", 1e6 / scalar_rate,
                  f"rate={scalar_rate:.0f}/s"))
    print(csv_row("explorer_batch_evals_per_s", 1e6 / batch_rate,
                  f"rate={batch_rate:.0f}/s"))
    print(csv_row("explorer_batch_speedup", 0.0, f"x{speedup:.1f}"))
    return speedup


def bench_nsga_run(out: dict, model: str = "squeezenet11",
                   pop_size: int = 2048, n_gen: int = 3):
    """End-to-end exploration through the NumPy NSGA-II strategy at the
    population scale the JIT comparison is specified at (pop >= 2048)."""
    graph = build_cnn(model, in_hw=64).to_graph()
    t0 = time.perf_counter()
    res = explore_graph(graph, chain_system(),
                        search=SearchSettings(strategy="nsga2", seed=0,
                                              pop_size=pop_size,
                                              n_gen=n_gen))
    dt = time.perf_counter() - t0
    evals = pop_size * (n_gen + 1)
    out["nsga_pop"] = pop_size
    out["nsga_run_s"] = round(dt, 3)
    out["nsga_evals_per_s"] = round(evals / dt, 1)
    print(csv_row("explorer_nsga_run", dt * 1e6,
                  f"pop={pop_size};gens={n_gen};"
                  f"evals_per_s={evals / dt:.0f};"
                  f"pareto={len(res.pareto)}"))
    return evals / dt


def bench_jit_nsga_run(out: dict, model: str = "squeezenet11",
                       pop_size: int = 2048, n_gen: int = 8):
    """The ``jax.jit``-compiled NSGA-II strategy at the same scale.

    Two identical searches over one evaluator: the first pays XLA
    compilation (the strategy caches the compiled runner on the evaluator),
    the second is steady state.  ``jit_nsga_evals_per_s`` is the
    steady-state rate; compilation is reported separately as
    ``jit_compile_s`` so the regression gate tracks throughput, not
    compiler wall-clock.
    """
    evaluator = make_evaluator(model)
    settings = SearchSettings(strategy="jit_nsga2", seed=0,
                              pop_size=pop_size, n_gen=n_gen)
    from repro.explore import run_search
    t0 = time.perf_counter()
    run_search(evaluator, settings=settings)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = run_search(evaluator, settings=settings)
    dt = time.perf_counter() - t0
    evals = pop_size * (n_gen + 1)
    out["jit_nsga_pop"] = pop_size
    out["jit_nsga_run_s"] = round(dt, 3)
    out["jit_nsga_evals_per_s"] = round(evals / dt, 1)
    out["jit_compile_s"] = round(max(cold - dt, 0.0), 3)
    print(csv_row("explorer_jit_nsga_run", dt * 1e6,
                  f"pop={pop_size};gens={n_gen};"
                  f"evals_per_s={evals / dt:.0f};"
                  f"compile={max(cold - dt, 0):.1f}s;"
                  f"pareto={len(res.pareto)}"))
    return evals / dt


def bench_jit_scale(out: dict, model: str = "squeezenet11",
                    pop_size: int = 32768, n_gen: int = 1):
    """The tiled-ranking scale point (ROADMAP open item 1): a full
    ``jit_nsga2`` generation loop at a population the dense (pop, pop)
    ranking path cannot hold in memory — the blocked
    ``kernels.pareto_rank`` primitive keeps the ranking working set at
    O(pop · rank_block).

    Records ``jit_nsga_pop_max`` (the population this bench proves out)
    and the steady-state ``jit_nsga_scale_evals_per_s``; like the pop-2048
    bench, the first run pays XLA compilation (reported separately as
    ``jit_scale_compile_s``) and the second run is the gated rate.
    """
    evaluator = make_evaluator(model)
    settings = SearchSettings(strategy="jit_nsga2", seed=0,
                              pop_size=pop_size, n_gen=n_gen)
    from repro.explore import run_search
    t0 = time.perf_counter()
    run_search(evaluator, settings=settings)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = run_search(evaluator, settings=settings)
    dt = time.perf_counter() - t0
    evals = pop_size * (n_gen + 1)
    out["jit_nsga_pop_max"] = pop_size
    out["jit_nsga_scale_run_s"] = round(dt, 3)
    out["jit_nsga_scale_evals_per_s"] = round(evals / dt, 1)
    out["jit_scale_compile_s"] = round(max(cold - dt, 0.0), 3)
    print(csv_row("explorer_jit_nsga_scale", dt * 1e6,
                  f"pop={pop_size};gens={n_gen};"
                  f"evals_per_s={evals / dt:.0f};"
                  f"compile={max(cold - dt, 0):.1f}s;"
                  f"pareto={len(res.pareto)}"))
    return evals / dt


def _bench_campaign_spec(models, in_hw: int) -> ExplorationSpec:
    return ExplorationSpec(
        model=ModelRef("cnn", models[0], {"in_hw": in_hw}),
        system=chain_system_spec(),
        objectives=("latency", "energy", "throughput"),
        search=SearchSettings(strategy="nsga2"))


def bench_campaign(out: dict, models=("squeezenet11", "regnetx_400mf",
                                      "efficientnet_b0"),
                   in_hw: int = 64):
    """Multi-model fan-out through the Campaign runner (shared cost
    tables), the ROADMAP's fleet-level-study shape."""
    spec = _bench_campaign_spec(models, in_hw)
    t0 = time.perf_counter()
    camp = Campaign(spec, models=[ModelRef("cnn", n, {"in_hw": in_hw})
                                  for n in models]).run()
    dt = time.perf_counter() - t0
    out["campaign_wall_s"] = round(dt, 3)
    out["campaign_models"] = len(models)
    out["campaign_pareto_sizes"] = [len(e.result.pareto)
                                    for e in camp.entries]
    print(csv_row("explorer_campaign", dt * 1e6,
                  f"models={len(models)};wall={dt:.2f}s"))
    return dt


def bench_fleet(out: dict, models=("squeezenet11", "regnetx_400mf",
                                   "efficientnet_b0"),
                in_hw: int = 64, workers: int = 2):
    """The same campaign through the ``repro.fleet`` runtime with local
    worker processes: manifest init + claim/shard orchestration + merge.

    ``fleet_sweep_wall_s`` is the end-to-end sweep wall-clock (gated,
    lower-better): it prices the whole distribution overhead — per-worker
    interpreter start-up and cost-table builds included — against the
    serial ``campaign_wall_s`` above, so a regression in the claim/merge
    path (or an orchestration stall) fails CI even when the search
    strategies themselves are healthy.
    """
    import shutil
    import tempfile

    from repro.fleet import report_fingerprint, run_fleet

    spec = _bench_campaign_spec(models, in_hw)
    camp = Campaign(spec, models=[ModelRef("cnn", n, {"in_hw": in_hw})
                                  for n in models])
    d = tempfile.mkdtemp(prefix="bench-fleet-")
    try:
        t0 = time.perf_counter()
        camp.to_manifest(d)
        report = run_fleet(d, workers=workers)
        dt = time.perf_counter() - t0
        # the merged report must be the serial report (fingerprint parity
        # is tested in tier-1; here we just guard the bench itself)
        assert len(report_fingerprint(report)["entries"]) == len(models)
    finally:
        shutil.rmtree(d, ignore_errors=True)
    out["fleet_sweep_wall_s"] = round(dt, 3)
    out["fleet_workers"] = workers
    print(csv_row("explorer_fleet_sweep", dt * 1e6,
                  f"workers={workers};models={len(models)};wall={dt:.2f}s"))
    return dt


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workload for CI")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="fail when batch/scalar speedup drops below this")
    ap.add_argument("--min-jit-speedup", type=float, default=None,
                    help="fail when the jit/numpy NSGA-II evals/s ratio "
                         "drops below this")
    ap.add_argument("--json", default="BENCH_explorer.json",
                    help="machine-readable output path")
    ap.add_argument("--scale-pop", type=int, default=32768,
                    help="population for the tiled-ranking scale bench "
                         "(0 skips it)")
    args = ap.parse_args()

    # bench_schema guards cross-PR artifact diffs: compare_bench.py refuses
    # to diff files whose schemas (and so key semantics) don't match
    # (schema 3 added the pop-32768 jit_nsga_scale_* keys; schema 4 the
    # 2-worker fleet_sweep_wall_s; schema 5 the serve_* keys merged in by
    # serve_bench.py; schema 6 the repartition_* keys merged in by
    # drift_bench.py; schema 7 the fault-recovery keys — recovery_ms,
    # requests_recovered, repartition_trigger — merged in by
    # fault_smoke.py --json; schema 8 the repro.obs tracing-overhead keys
    # — serve_obs_overhead_pct, serve_traced_tokens_per_s — merged in by
    # serve_bench.py)
    out = {"mode": "quick" if args.quick else "full", "bench_schema": 8}
    if args.quick:
        speedup = bench_eval_paths(out, n_candidates=1024, scalar_cap=128)
        np_rate = bench_nsga_run(out, pop_size=2048, n_gen=3)
        jit_rate = bench_jit_nsga_run(out, pop_size=2048, n_gen=8)
        if args.scale_pop:
            bench_jit_scale(out, pop_size=args.scale_pop, n_gen=1)
        bench_campaign(out)
        bench_fleet(out)
    else:
        speedup = bench_eval_paths(out, n_candidates=8192, scalar_cap=512)
        np_rate = bench_nsga_run(out, pop_size=2048, n_gen=8)
        jit_rate = bench_jit_nsga_run(out, pop_size=2048, n_gen=30)
        if args.scale_pop:
            bench_jit_scale(out, pop_size=args.scale_pop, n_gen=2)
        bench_campaign(out)
        bench_fleet(out)
    out["jit_nsga_speedup"] = round(jit_rate / np_rate, 1)
    print(csv_row("explorer_jit_nsga_speedup", 0.0,
                  f"x{jit_rate / np_rate:.1f}"))

    atomic_write_json(args.json, out)
    print(f"wrote {args.json}")

    if args.min_speedup is not None and speedup < args.min_speedup:
        print(f"FAIL: batch speedup x{speedup:.1f} < "
              f"required x{args.min_speedup:.1f}", file=sys.stderr)
        return 1
    if (args.min_jit_speedup is not None
            and jit_rate / np_rate < args.min_jit_speedup):
        print(f"FAIL: jit NSGA-II speedup x{jit_rate / np_rate:.1f} < "
              f"required x{args.min_jit_speedup:.1f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
