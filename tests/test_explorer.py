"""End-to-end explorer: Fig. 1 pipeline on toy + real CNN graphs."""

import numpy as np
import pytest

from repro.core import (Constraints, Explorer, Platform, QuantSpec,
                        SystemConfig, get_link)
from repro.core.hwmodel import EYERISS_LIKE, SIMBA_LIKE
from repro.core.nsga2 import dominates
from repro.models.cnn.zoo import build_cnn


def small_system(**kw):
    return SystemConfig(
        [Platform("A", EYERISS_LIKE, QuantSpec(bits=16)),
         Platform("B", SIMBA_LIKE, QuantSpec(bits=8))],
        [get_link("gige")])


@pytest.fixture(scope="module")
def squeezenet_result():
    g = build_cnn("squeezenet11", in_hw=64).to_graph()
    ex = Explorer(g, small_system(),
                  objectives=("latency", "energy", "throughput", "accuracy"))
    return ex.run(seed=0)


def test_explorer_finds_candidates(squeezenet_result):
    assert len(squeezenet_result.candidates) > 5
    assert len(squeezenet_result.pareto) >= 1


def test_pareto_mutually_nondominating(squeezenet_result):
    res = squeezenet_result
    F = np.array([ev.as_objectives(res.objectives) for ev in res.pareto])
    for i in range(len(F)):
        for j in range(len(F)):
            assert not dominates(F[i], F[j])


def test_selected_is_feasible_and_on_front(squeezenet_result):
    res = squeezenet_result
    assert res.selected.violation <= 0
    assert any(res.selected.cuts == ev.cuts for ev in res.pareto)


def test_memory_filter_respected():
    g = build_cnn("squeezenet11", in_hw=64).to_graph()
    # platform A with absurdly small memory -> few or no feasible prefixes
    sys_small = SystemConfig(
        [Platform("A", EYERISS_LIKE, QuantSpec(16), mem_capacity=40_000),
         Platform("B", SIMBA_LIKE, QuantSpec(8))],
        [get_link("gige")])
    ex = Explorer(g, sys_small)
    cands_small = ex.candidate_cuts()
    ex_big = Explorer(g, small_system())
    assert len(cands_small) < len(ex_big.candidate_cuts())
    # every surviving candidate's prefix memory actually fits
    for p in cands_small:
        ev = ex.evaluator.evaluate([p])
        assert ev.memory_bytes[0] <= 40_000


def test_link_filter():
    g = build_cnn("squeezenet11", in_hw=64).to_graph()
    ex = Explorer(g, small_system(),
                  constraints=Constraints(max_link_bytes=20_000))
    for p in ex.candidate_cuts():
        ev = ex.evaluator.evaluate([p])
        assert ev.link_bytes <= 20_000


def test_multi_cut_explorer_runs():
    g = build_cnn("squeezenet11", in_hw=64).to_graph()
    sys4 = SystemConfig(
        [Platform("A0", EYERISS_LIKE, QuantSpec(16)),
         Platform("A1", EYERISS_LIKE, QuantSpec(16)),
         Platform("B0", SIMBA_LIKE, QuantSpec(8)),
         Platform("B1", SIMBA_LIKE, QuantSpec(8))],
        [get_link("gige")] * 3)
    ex = Explorer(g, sys4, objectives=("latency", "energy", "bandwidth"))
    res = ex.run(seed=0, pop_size=16, n_gen=8)
    assert res.nsga is not None
    assert len(res.pareto) >= 1
    assert res.selected.violation <= 0
