"""Timeloop-lite mapper sanity: roofline lower bounds, arch ordering,
segment additivity, energy positivity."""

import pytest

from repro.core import layers as L
from repro.core.hwmodel import (EYERISS_LIKE, SIMBA_LIKE, TPU_V5E,
                                evaluate_layer, evaluate_segment)
from repro.core.hwmodel.mapper import decompose


def big_conv():
    return L.conv_layer("c", 64, 128, (56, 56), 3)


def test_latency_at_least_roofline():
    for arch in (EYERISS_LIKE, SIMBA_LIKE):
        layer = big_conv()
        cost = evaluate_layer(layer, arch)
        lb = layer.macs / arch.peak_macs_per_s
        assert cost.latency_s >= lb * 0.99


def test_eyr_faster_smb_more_efficient():
    """The §V-A platform trade-off: EYR (384 16-bit MACs) is faster, SMB
    (128 int8 MACs) burns less energy per inference."""
    layer = big_conv()
    c_eyr = evaluate_layer(layer, EYERISS_LIKE)
    c_smb = evaluate_layer(layer, SIMBA_LIKE)
    assert c_eyr.latency_s < c_smb.latency_s
    assert c_smb.energy_j < c_eyr.energy_j


def test_tpu_much_faster():
    layer = big_conv()
    t = evaluate_layer(layer, TPU_V5E).latency_s
    assert t < evaluate_layer(layer, SIMBA_LIKE).latency_s / 50


def test_segment_additive():
    layers = [big_conv(),
              L.elementwise_layer("r", L.RELU, (128, 56, 56)),
              L.gemm_layer("g", 128, 10)]
    seg = evaluate_segment(layers, EYERISS_LIKE)
    parts = [evaluate_layer(l, EYERISS_LIKE) for l in layers]
    assert seg.latency_s == pytest.approx(sum(p.latency_s for p in parts))
    assert seg.energy_j == pytest.approx(sum(p.energy_j for p in parts))


def test_energy_positive_and_scales_with_work():
    small = L.conv_layer("s", 8, 8, (8, 8), 3)
    big = big_conv()
    e_s = evaluate_layer(small, SIMBA_LIKE).energy_j
    e_b = evaluate_layer(big, SIMBA_LIKE).energy_j
    assert 0 < e_s < e_b


def test_decompose_macs_match():
    for layer in [big_conv(), L.gemm_layer("g", 256, 512),
                  L.mlp_layer("m", 128, 512, 64),
                  L.attention_layer("a", 128, 4, 2, 64),
                  L.moe_layer("moe", 128, 64, 32, 8, 2, 1),
                  L.ssm_layer("s", 128, 16, 64)]:
        atoms, _ = decompose(layer)
        atom_macs = sum(a.macs for a in atoms)
        assert atom_macs == pytest.approx(layer.macs, rel=0.35), layer.name


def test_batch_scales_latency():
    layer = big_conv()
    t1 = evaluate_layer(layer, SIMBA_LIKE, batch=1).latency_s
    t4 = evaluate_layer(layer, SIMBA_LIKE, batch=4).latency_s
    assert 3.0 * t1 < t4 < 5.0 * t1
