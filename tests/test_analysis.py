"""repro.analysis rule suite: every rule fires on a seeded-violation
fixture AND stays silent on a clean twin (the zero-false-positive
contract), plus the baseline workflow, CLI exit codes, and the gate the
CI job enforces — the repo's own src/ + benchmarks/ are clean under the
committed baseline."""

import json
import os
import subprocess
import sys

import pytest

from repro.analysis import (BaselineError, all_rules, analyze_paths,
                            apply_baseline, load_baseline, write_baseline)
from repro.analysis.cli import main as cli_main

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_rules(tmp_path, source, select, relpath="mod.py"):
    p = tmp_path / relpath
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(source)
    findings, n = analyze_paths([str(tmp_path)], root=str(tmp_path),
                                select=[select])
    return findings


def rule_ids(findings):
    return sorted({f.rule for f in findings})


# -- registry -----------------------------------------------------------------

def test_rule_catalog():
    rules = all_rules()
    ids = [r.id for r in rules]
    assert len(ids) == len(set(ids))
    for family in ("RPR1", "RPR2", "RPR3", "RPR4"):
        assert any(i.startswith(family) for i in ids), family
    assert ids == sorted(ids)


def test_select_unknown_prefix_raises():
    with pytest.raises(ValueError, match="matches no rule"):
        analyze_paths([REPO_ROOT + "/src/repro/analysis"], select=["RPR9"])


# -- RPR101: python control flow on tracers -----------------------------------

RPR101_BAD = """
import jax

@jax.jit
def f(x, y):
    if x > 0:
        y = y + 1
    while y > 0:
        y = y - 1
    z = x if x > 0 else -x
    return y + z

def outer(n, x):
    return jax.lax.fori_loop(0, n, lambda i, c: c + 1 if c > 0 else c, x)
"""

RPR101_CLEAN = """
import jax
import jax.numpy as jnp

@jax.jit
def f(x, y=None):
    if y is None:                      # identity check: trace-safe
        y = jnp.zeros_like(x)
    if x.ndim == 2:                    # shape attr: static at trace time
        x = x[None]
    for i in range(len(y)):            # len(): static
        x = x + y[i]
    return jnp.where(x > 0, x, -x)     # the traced branch, done right

@jax.jit
def g(flag: bool, x):
    # params can be python config too; only *uses* that branch are flagged
    n, m = x.shape
    for j in range(m):
        x = x + j
    return x
"""


def test_rpr101_fires(tmp_path):
    findings = run_rules(tmp_path, RPR101_BAD, "RPR101")
    assert rule_ids(findings) == ["RPR101"]
    msgs = " ".join(f.message for f in findings)
    assert "`if`" in msgs and "`while`" in msgs
    assert "conditional expression" in msgs
    assert any("fori_loop" in f.message for f in findings)


def test_rpr101_clean_twin_silent(tmp_path):
    assert run_rules(tmp_path, RPR101_CLEAN, "RPR101") == []


# -- RPR102: host syncs -------------------------------------------------------

RPR102_BAD = """
import jax
import numpy as np

@jax.jit
def f(x):
    a = np.asarray(x)        # device->host
    b = float(x)             # concretizes the tracer
    c = x.item()
    return a, b, c
"""

RPR102_CLEAN = """
import jax
import jax.numpy as jnp
import numpy as np

TABLE = np.arange(16)        # module-level host data: fine

@jax.jit
def f(x):
    t = jnp.asarray(TABLE)   # host constant closed over, not synced
    n = float(x.shape[0])    # shape is static
    return x * n + t[0]

def host_side(x):
    return np.asarray(x)     # not a jit region at all
"""


def test_rpr102_fires(tmp_path):
    findings = run_rules(tmp_path, RPR102_BAD, "RPR102")
    assert rule_ids(findings) == ["RPR102"]
    msgs = " ".join(f.message for f in findings)
    assert "numpy.asarray" in msgs and "float()" in msgs \
        and ".item()" in msgs


def test_rpr102_clean_twin_silent(tmp_path):
    assert run_rules(tmp_path, RPR102_CLEAN, "RPR102") == []


# -- RPR103: jit-in-loop ------------------------------------------------------

RPR103_BAD = """
import jax

def run_all(fns, x):
    outs = []
    for fn in fns:
        outs.append(jax.jit(fn)(x))    # recompiles every iteration
    return outs
"""

RPR103_CLEAN = """
import jax

def run_all(fns, x):
    jitted = [jax.jit(fn) for fn in fns]   # hoisted: compiled once each
    step = jax.jit(lambda y: y + 1)
    out = x
    for fn in jitted:
        out = fn(out)                       # *calling* in a loop is fine
    return step(out)
"""


def test_rpr103_fires(tmp_path):
    findings = run_rules(tmp_path, RPR103_BAD, "RPR103")
    assert rule_ids(findings) == ["RPR103"]


def test_rpr103_clean_twin_silent(tmp_path):
    assert run_rules(tmp_path, RPR103_CLEAN, "RPR103") == []


# -- RPR104: missing donation -------------------------------------------------

RPR104_BAD = """
import jax

def make_runner(step):
    def run(key, X0, n_gen):
        return step(key, X0, n_gen)
    return jax.jit(run)                 # X0 not donated

@jax.jit
def advance(state, dt):
    return state + dt
"""

RPR104_CLEAN = """
import functools

import jax

def make_runner(step):
    def run(key, X0, n_gen):
        return step(key, X0, n_gen)
    return jax.jit(run, donate_argnums=(1,))

@functools.partial(jax.jit, donate_argnames=("state",))
def advance(state, dt):
    return state + dt

@jax.jit
def small(x, y):                        # no large-buffer param names
    return x + y
"""


def test_rpr104_fires(tmp_path):
    findings = run_rules(tmp_path, RPR104_BAD, "RPR104")
    assert rule_ids(findings) == ["RPR104"]
    assert len(findings) == 2           # jit() call form + decorator form


def test_rpr104_clean_twin_silent(tmp_path):
    assert run_rules(tmp_path, RPR104_CLEAN, "RPR104") == []


# -- RPR201: block/shape divisibility -----------------------------------------

RPR201_BAD = """
import jax
from jax.experimental import pallas as pl

def k(kernel, x):
    return pl.pallas_call(
        kernel,
        grid=(2,),
        out_shape=jax.ShapeDtypeStruct((100, 64), x.dtype),
        out_specs=pl.BlockSpec((48, 64), lambda i: (i, 0)),
    )(x)
"""

RPR201_CLEAN = """
import jax
from jax.experimental import pallas as pl

def k(kernel, x, bm):
    return pl.pallas_call(
        kernel,
        grid=(2,),
        out_shape=jax.ShapeDtypeStruct((100, 64), x.dtype),
        out_specs=pl.BlockSpec((50, 64), lambda i: (i, 0)),
    )(x)

def k_dynamic(kernel, x, bm):
    # dynamic block sizes: nothing statically checkable, stays silent
    return pl.pallas_call(
        kernel,
        grid=(2,),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        out_specs=pl.BlockSpec((bm, 64), lambda i: (i, 0)),
    )(x)
"""


def test_rpr201_fires(tmp_path):
    findings = run_rules(tmp_path, RPR201_BAD, "RPR201")
    assert rule_ids(findings) == ["RPR201"]
    assert "does not divide" in findings[0].message


def test_rpr201_clean_twin_silent(tmp_path):
    assert run_rules(tmp_path, RPR201_CLEAN, "RPR201") == []


# -- RPR202: index_map arity --------------------------------------------------

RPR202_BAD = """
from jax.experimental import pallas as pl

def k(kernel, x, bm, bn):
    grid = (4, 4)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
    )(x)
"""

RPR202_CLEAN = """
from jax.experimental import pallas as pl

def k(kernel, x, bm, bn, m, n):
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
    )(x)
"""


def test_rpr202_fires(tmp_path):
    findings = run_rules(tmp_path, RPR202_BAD, "RPR202")
    assert rule_ids(findings) == ["RPR202"]
    assert len(findings) == 1           # only the 1-arg lambda
    assert "rank 2" in findings[0].message


def test_rpr202_clean_twin_silent(tmp_path):
    assert run_rules(tmp_path, RPR202_CLEAN, "RPR202") == []


# -- RPR203: hardcoded interpret= ---------------------------------------------

RPR203_BAD = """
from repro.kernels.pareto_rank import packed_domination as k

def rows(Fr, cvr, Fq, cvq):
    return k(Fr, cvr, Fq, cvq, bp=32, bq=256, interpret=True)
"""

RPR203_CLEAN = """
import jax

from repro.kernels.pareto_rank import packed_domination as k

def _interpret() -> bool:
    return jax.default_backend() != "tpu"

def rows(Fr, cvr, Fq, cvq, interp):
    return k(Fr, cvr, Fq, cvq, bp=32, bq=256, interpret=_interpret())

def rows2(Fr, cvr, Fq, cvq, interp):
    return k(Fr, cvr, Fq, cvq, bp=32, bq=256, interpret=interp)
"""


def test_rpr203_fires(tmp_path):
    findings = run_rules(tmp_path, RPR203_BAD, "RPR203")
    assert rule_ids(findings) == ["RPR203"]
    assert "interpret=True is hardcoded" in findings[0].message


def test_rpr203_clean_twin_silent(tmp_path):
    assert run_rules(tmp_path, RPR203_CLEAN, "RPR203") == []


# -- RPR204: pallas_call outside kernels/ -------------------------------------

PALLAS_CALL_SRC = """
from jax.experimental import pallas as pl

def op(kernel, x):
    return pl.pallas_call(kernel, grid=(1,))(x)
"""


def test_rpr204_fires_outside_kernels(tmp_path):
    findings = run_rules(tmp_path, PALLAS_CALL_SRC, "RPR204",
                         relpath="src/repro/explore/fast.py")
    assert rule_ids(findings) == ["RPR204"]
    assert "outside repro/kernels/" in findings[0].message


def test_rpr204_silent_inside_kernels(tmp_path):
    assert run_rules(tmp_path, PALLAS_CALL_SRC, "RPR204",
                     relpath="src/repro/kernels/fast.py") == []


# -- RPR301: raw truncating writes --------------------------------------------

RPR301_BAD = """
import json
from pathlib import Path

def save(path, obj):
    with open(path, "w") as f:
        json.dump(obj, f)

def save_text(path, text):
    Path(path).write_text(text)
"""

RPR301_CLEAN = """
import os

def publish(path, text):
    # an atomic publisher: the tmp write IS the implementation
    tmp = f"{path}.tmp"
    try:
        with open(tmp, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        raise

def read(path):
    with open(path) as f:           # reads are never flagged
        return f.read()

def append_log(path, line):
    with open(path, "a") as f:      # appends are not truncating
        f.write(line)
"""


def test_rpr301_fires(tmp_path):
    findings = run_rules(tmp_path, RPR301_BAD, "RPR301")
    assert rule_ids(findings) == ["RPR301"]
    assert len(findings) == 2
    msgs = " ".join(f.message for f in findings)
    assert "atomic_write" in msgs


def test_rpr301_clean_twin_silent(tmp_path):
    assert run_rules(tmp_path, RPR301_CLEAN, "RPR301") == []


# -- RPR302: /tmp tempfile feeding os.replace ---------------------------------

RPR302_BAD = """
import os
import tempfile

def publish(path, data):
    fd, tmp = tempfile.mkstemp()            # defaults to /tmp
    with os.fdopen(fd, "w") as f:
        f.write(data)
    os.replace(tmp, path)                   # may cross filesystems
"""

RPR302_CLEAN = """
import os
import tempfile

def publish(path, data):
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
    with os.fdopen(fd, "w") as f:
        f.write(data)
    os.replace(tmp, path)

def scratch():
    return tempfile.mkstemp()               # no replace in scope: fine
"""


def test_rpr302_fires(tmp_path):
    findings = run_rules(tmp_path, RPR302_BAD, "RPR302")
    assert rule_ids(findings) == ["RPR302"]
    assert "dir=" in findings[0].message


def test_rpr302_clean_twin_silent(tmp_path):
    assert run_rules(tmp_path, RPR302_CLEAN, "RPR302") == []


# -- RPR303: claims without O_EXCL --------------------------------------------

RPR303_BAD = """
def claim(shard_dir, shard_id, worker):
    path = f"{shard_dir}/{shard_id}.claim"
    with open(path, "w") as f:              # both racers think they won
        f.write(worker)
    return True
"""

RPR303_CLEAN = """
import json
import os

def claim(shard_dir, shard_id, worker):
    cpath = f"{shard_dir}/{shard_id}.claim"
    tmp = f"{cpath}.{worker}.tmp"
    with open(tmp, "w") as f:
        json.dump({"worker": worker}, f)
    try:
        os.link(tmp, cpath)                 # atomic-exclusive create
        return True
    except FileExistsError:
        return False
    finally:
        os.unlink(tmp)

def claim_x(shard_dir, shard_id, worker):
    with open(f"{shard_dir}/{shard_id}.claim", "x") as f:
        f.write(worker)
"""


def test_rpr303_fires(tmp_path):
    findings = run_rules(tmp_path, RPR303_BAD, "RPR303")
    assert rule_ids(findings) == ["RPR303"]
    assert "O_CREAT|O_EXCL" in findings[0].message


def test_rpr303_clean_twin_silent(tmp_path):
    assert run_rules(tmp_path, RPR303_CLEAN, "RPR303") == []


# -- RPR401/402: wall clocks measuring durations ------------------------------

RPR401_BAD = """
import time

def stage_wall():
    t0 = time.time()
    work()
    return time.time() - t0                 # direct operand

def lease_age(started):
    now = time.time()
    return now - started                    # via the assigned name
"""

RPR401_CLEAN = """
import time

def stage_wall():
    t0 = time.perf_counter()
    work()
    return time.perf_counter() - t0

def heartbeat_gap(last):
    return time.monotonic() - last

def shard_stamp():
    return {"time": time.time()}            # a timestamp, not a duration

def other_scope_untainted():
    t0 = time.time()                        # assigned here ...
    return t0

def uses_local(t0):
    return t0 - 1.0                         # ... not this t0: different scope
"""

RPR402_BAD = """
from datetime import datetime

def request_latency(started):
    return datetime.now() - started

def age():
    t0 = datetime.utcnow()
    work()
    return datetime.utcnow() - t0
"""

RPR402_CLEAN = """
import time
from datetime import datetime

def report_stamp():
    return datetime.now().isoformat()       # formatting a moment is fine

def latency():
    t0 = time.perf_counter()
    work()
    return time.perf_counter() - t0
"""


def test_rpr401_fires(tmp_path):
    findings = run_rules(tmp_path, RPR401_BAD, "RPR401",
                         relpath="src/repro/serve/mod.py")
    assert rule_ids(findings) == ["RPR401"]
    assert len(findings) == 2
    msgs = " ".join(f.message for f in findings)
    assert "time.time()" in msgs and "perf_counter" in msgs
    assert "assigned from time.time" in msgs


def test_rpr401_clean_twin_silent(tmp_path):
    assert run_rules(tmp_path, RPR401_CLEAN, "RPR401",
                     relpath="src/repro/fleet/mod.py") == []


def test_rpr401_out_of_scope_silent(tmp_path):
    # the same violating source outside serve/fleet/obs is not flagged —
    # launch scripts legitimately print wall-clock stamps
    assert run_rules(tmp_path, RPR401_BAD, "RPR401",
                     relpath="src/repro/launch/mod.py") == []


def test_rpr402_fires(tmp_path):
    findings = run_rules(tmp_path, RPR402_BAD, "RPR402",
                         relpath="src/repro/obs/mod.py")
    assert rule_ids(findings) == ["RPR402"]
    assert len(findings) == 2


def test_rpr402_clean_twin_silent(tmp_path):
    assert run_rules(tmp_path, RPR402_CLEAN, "RPR402",
                     relpath="src/repro/obs/mod.py") == []


# -- syntax errors ------------------------------------------------------------

def test_syntax_error_becomes_rpr000(tmp_path):
    (tmp_path / "broken.py").write_text("def f(:\n")
    findings, n = analyze_paths([str(tmp_path)], root=str(tmp_path))
    assert n == 1
    assert rule_ids(findings) == ["RPR000"]


# -- baseline workflow --------------------------------------------------------

def test_baseline_roundtrip_suppresses(tmp_path):
    (tmp_path / "bad.py").write_text(RPR301_BAD)
    findings, _ = analyze_paths([str(tmp_path)], root=str(tmp_path),
                                select=["RPR301"])
    assert findings
    bpath = str(tmp_path / "baseline.json")
    n = write_baseline(bpath, findings)
    assert n == 2
    bl = load_baseline(bpath)
    kept, suppressed, stale = apply_baseline(findings, bl)
    assert kept == [] and len(suppressed) == 2 and stale == []


def test_baseline_stale_entries_reported(tmp_path):
    bpath = tmp_path / "baseline.json"
    bpath.write_text(json.dumps({
        "baseline_schema": 1,
        "entries": [{"rule": "RPR301", "file": "gone.py",
                     "context": "f", "reason": "was fixed"}]}))
    bl = load_baseline(str(bpath))
    kept, suppressed, stale = apply_baseline([], bl)
    assert stale == [("RPR301", "gone.py", "f")]


def test_baseline_empty_reason_rejected(tmp_path):
    bpath = tmp_path / "baseline.json"
    bpath.write_text(json.dumps({
        "baseline_schema": 1,
        "entries": [{"rule": "RPR301", "file": "x.py",
                     "context": "f", "reason": "  "}]}))
    with pytest.raises(BaselineError, match="empty reason"):
        load_baseline(str(bpath))


def test_baseline_bad_schema_rejected(tmp_path):
    bpath = tmp_path / "baseline.json"
    bpath.write_text(json.dumps({"baseline_schema": 99, "entries": []}))
    with pytest.raises(BaselineError, match="baseline_schema"):
        load_baseline(str(bpath))


# -- CLI ----------------------------------------------------------------------

def test_cli_exit_codes(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(RPR301_BAD)
    clean = tmp_path / "clean.py"
    clean.write_text(RPR301_CLEAN)

    assert cli_main([str(clean), "--no-baseline",
                     "--root", str(tmp_path)]) == 0
    assert cli_main([str(bad), "--no-baseline",
                     "--root", str(tmp_path)]) == 1
    assert cli_main([str(bad), "--select", "NOPE",
                     "--root", str(tmp_path)]) == 2

    mal = tmp_path / "mal.json"
    mal.write_text("{not json")
    assert cli_main([str(bad), "--baseline", str(mal),
                     "--root", str(tmp_path)]) == 2
    capsys.readouterr()


def test_cli_write_baseline_then_clean(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(RPR301_BAD)
    bpath = str(tmp_path / "bl.json")
    assert cli_main([str(bad), "--write-baseline", bpath,
                     "--root", str(tmp_path)]) == 0
    # TODO reasons are accepted (non-empty) and suppress the findings
    assert cli_main([str(bad), "--baseline", bpath,
                     "--root", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "suppressed by baseline" in out


def test_cli_json_format(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(RPR301_BAD)
    assert cli_main([str(bad), "--no-baseline", "--format", "json",
                     "--root", str(tmp_path)]) == 1
    report = json.loads(capsys.readouterr().out)
    assert report["files"] == 1
    assert {f["rule"] for f in report["findings"]} == {"RPR301"}


def test_cli_module_entrypoint():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--list-rules"],
        capture_output=True, text=True, env=env, cwd=REPO_ROOT, timeout=60)
    assert r.returncode == 0
    assert "RPR101" in r.stdout and "RPR303" in r.stdout


# -- the repo gate ------------------------------------------------------------

def test_repo_is_clean_under_committed_baseline():
    """The CI gate: src/ + benchmarks/ produce zero unsuppressed findings,
    and every committed baseline entry carries a real justification."""
    findings, n_files = analyze_paths(
        [os.path.join(REPO_ROOT, "src"),
         os.path.join(REPO_ROOT, "benchmarks")], root=REPO_ROOT)
    assert n_files > 50
    bl = load_baseline(os.path.join(REPO_ROOT, ".analysis-baseline.json"))
    for e in bl.entries:
        assert len(e["reason"]) > 20, f"flimsy justification: {e}"
        assert "TODO" not in e["reason"], f"unfilled justification: {e}"
    kept, suppressed, stale = apply_baseline(findings, bl)
    assert kept == [], "new findings:\n" + "\n".join(
        f.render() for f in kept)
    assert stale == [], f"stale baseline entries: {stale}"
