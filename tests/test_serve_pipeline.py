"""repro.serve runtime: Def.-4 helper, step-wise stage interface,
SlotDecoder isolation, async-vs-serial token equality, replica routing."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.link import LinkModel
from repro.explore import lm_block_cuts
from repro.models.registry import build_model, get_config
from repro.serve import (PipelineServeEngine, ReplicaRouter, Request,
                         RequestStream, ServeLink, poisson_traffic,
                         stream_of)
from repro.serving.engine import GenerationEngine, SlotDecoder
from repro.serving.pipeline import PartitionedLMRunner, def4_throughput


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def runner(lm):
    cfg, model, params = lm
    return PartitionedLMRunner(model, params, cuts=[0])


def test_def4_throughput_helper():
    assert def4_throughput([2.0]) == pytest.approx(0.5)
    assert def4_throughput([0.5, 0.2], [0.1]) == pytest.approx(2.0)
    assert def4_throughput([]) == 0.0
    assert def4_throughput([0.0, 0.0]) == 0.0      # zeros are "not measured"


def test_lm_block_cuts_mapping():
    # schedule: Embed(0), Attn_0(1), FFN_0(2), Attn_1(3), FFN_1(4), ...
    assert lm_block_cuts([2], n_layers=4) == [0]   # cut after FFN_0
    assert lm_block_cuts([3], n_layers=4) == [1]   # mid-block snaps down
    assert lm_block_cuts([-1], n_layers=4) == [1]  # no cut -> middle
    assert lm_block_cuts([99], n_layers=4) == [2]  # clamped: last stage
    assert lm_block_cuts([2, 4], n_layers=4) == [0, 1]


def test_stage_stepwise_matches_decode_step(runner, lm):
    """Driving the stages one step at a time reproduces the monolithic
    decode_step bit-for-bit (prefill + decode)."""
    cfg, model, params = lm
    b, tp = 2, 6
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, size=(b, tp)).astype(np.int32)
    caches = model.init_caches(b, 32, jnp.float32)
    ref, caches = model.decode_step(params, caches,
                                    {"tokens": jnp.asarray(prompts)})
    nxt = np.asarray(ref[:, -1].argmax(-1)).astype(np.int32)
    ref2, caches = model.decode_step(params, caches,
                                     {"tokens": jnp.asarray(nxt)[:, None]})

    sc = [runner.init_stage_caches(si, b, 32)
          for si in range(runner.n_stages)]
    fns = [runner.stage_step_fn(si) for si in range(runner.n_stages)]
    ws = [runner.stage_weights(si) for si in range(runner.n_stages)]
    x = jnp.asarray(prompts)
    for si in range(runner.n_stages):
        x, sc[si] = fns[si](ws[si], sc[si], x)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(ref))
    x = jnp.asarray(nxt)[:, None]
    for si in range(runner.n_stages):
        x, sc[si] = fns[si](ws[si], sc[si], x)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(ref2))


def test_stage_step_fn_rejects_empty_stage(lm):
    cfg, model, params = lm
    r = PartitionedLMRunner(model, params, cuts=[cfg.n_layers - 1])
    with pytest.raises(AssertionError):
        r.stage_step_fn(r.n_stages - 1)


def test_slot_decoder_no_cross_request_bleed(lm):
    """Admitting a request into slot 1 mid-flight must not change what
    slot 0 decodes — per-slot cache lanes are fully independent."""
    cfg, model, params = lm
    rng = np.random.default_rng(1)
    pa = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, size=6).astype(np.int32)

    def roll(interleave):
        sd = SlotDecoder(model, params, n_slots=2, max_seq=32,
                         cache_dtype=jnp.float32)
        tok = int(np.argmax(sd.prefill(0, pa)))
        seq = [tok]
        for step in range(5):
            if interleave and step == 2:
                sd.prefill(1, pb)          # admission into the other slot
            logits = sd.decode(np.array([seq[-1], 0], np.int32))
            seq.append(int(np.argmax(logits[0])))
        return seq

    assert roll(interleave=False) == roll(interleave=True)


def _burst(reqs):
    return [Request(r.rid, r.prompt, r.max_new, 0.0) for r in reqs]


def test_async_serial_and_engine_tokens_identical(runner, lm):
    """The tentpole invariant: continuous-batching async pipeline, the
    lockstep serial baseline, and the monolithic GenerationEngine all
    produce byte-identical greedy tokens."""
    cfg, model, params = lm
    reqs = poisson_traffic(6, rate_rps=1000.0, vocab=cfg.vocab,
                           prompt_len=6, max_new=6, seed=2)
    # EOS chosen from a real greedy continuation so eviction paths run
    eng = GenerationEngine(model, params, max_seq=32,
                           cache_dtype=jnp.float32)
    prompts = np.stack([r.prompt for r in reqs])
    probe = eng.generate(prompts, max_new=6)
    eos = int(probe.tokens[0, 2])

    outs = {}
    for mode in ("serial", "async"):
        e = PipelineServeEngine(runner, n_slots=4, eos=eos, mode=mode,
                                capacity=32)
        e.warmup(prompt_len=6)
        rep = e.run(stream_of(_burst(reqs)), max_wall_s=120.0)
        assert rep.n_done == len(reqs)                   # nothing dropped
        assert rep.extra["decode_steps"] > 0
        outs[mode] = {r.rid: r.tokens for r in rep.records}
    assert outs["serial"] == outs["async"]

    ref = eng.generate(prompts, max_new=6, eos=eos)
    for i, r in enumerate(reqs):
        row = list(ref.tokens[i])
        if eos in row:
            row = row[:row.index(eos) + 1]
        assert outs["async"][r.rid] == row, f"rid {r.rid} diverged"


def test_streaming_arrival_tokens_identical(runner, lm):
    """Requests arriving while a decode wave is already in flight
    (router-style streaming pushes, not a pre-closed burst) must not pick
    up a spurious first token from the stale wave's logits: every
    request's token stream still equals the monolithic greedy reference."""
    cfg, model, params = lm
    rng = np.random.default_rng(5)
    prompts = rng.integers(0, cfg.vocab, size=(4, 6)).astype(np.int32)
    eng = GenerationEngine(model, params, max_seq=32,
                           cache_dtype=jnp.float32)
    ref = eng.generate(prompts, max_new=6)

    # the slow link keeps each decode wave "on the wire" ~50 ms, so the
    # pushes below almost surely land while a wave is in flight
    slow = LinkModel(name="slow", rate_bps=1e9, t_setup_s=0.05)
    for mode in ("serial", "async"):
        # 2 lanes, 1 wave: request 0 decodes with a free lane in its wave,
        # so later arrivals land mid-flight in that wave's free lane
        e = PipelineServeEngine(runner, n_slots=2, n_groups=1, eos=None,
                                mode=mode, capacity=32,
                                links=[ServeLink(model=slow)])
        e.warmup(prompt_len=6)
        stream = RequestStream()
        stream.push(Request(0, prompts[0], 6, 0.0))
        out = {}
        t = threading.Thread(
            target=lambda: out.update(rep=e.run(stream, max_wall_s=120.0)))
        t.start()
        for rid in range(1, 4):
            time.sleep(0.06)               # land mid-wave, unaligned
            stream.push(Request(rid, prompts[rid], 6, 0.0))
        stream.close()
        t.join(timeout=120.0)
        rep = out["rep"]
        assert rep.n_done == 4
        toks = {r.rid: r.tokens for r in rep.records}
        for rid in range(4):
            assert toks[rid] == list(ref.tokens[rid]), (mode, rid)


def test_n_slots_must_divide_into_groups(runner):
    with pytest.raises(ValueError, match="multiple of"):
        PipelineServeEngine(runner, n_slots=8, n_groups=3)
    with pytest.raises(ValueError, match="multiple of"):
        PipelineServeEngine(runner, n_slots=2, n_groups=4)


def test_router_surfaces_replica_failure(runner):
    """A dying replica's root-cause error must come back from serve() —
    not a masking ValueError from pushing to its closed stream."""
    class Boom(PipelineServeEngine):
        def run(self, stream, max_wall_s=120.0):
            raise RuntimeError("replica exploded")

    reqs = [Request(i, np.zeros(4, np.int32), 2, float(i) * 0.01)
            for i in range(6)]
    bad = Boom(runner, n_slots=2, n_groups=1, mode="serial", capacity=32)
    with pytest.raises(RuntimeError, match="replica failed") as ei:
        ReplicaRouter([bad]).serve(reqs, realtime=True, max_wall_s=5.0)
    assert "replica exploded" in str(ei.value.__cause__)


def test_router_least_outstanding(runner, lm):
    cfg, _, _ = lm
    reqs = poisson_traffic(6, rate_rps=1000.0, vocab=cfg.vocab,
                           prompt_len=6, max_new=4, seed=4)
    replicas = [PipelineServeEngine(runner, n_slots=2, n_groups=1, eos=None,
                                    mode="serial", capacity=32,
                                    name=f"replica{i}") for i in range(2)]
    for r in replicas:
        r.warmup(prompt_len=6)
    rep = ReplicaRouter(replicas).serve(_burst(reqs), realtime=False,
                                        max_wall_s=120.0)
    assert rep.n_done == len(reqs)
    assert sorted(r.rid for r in rep.records) == [r.rid for r in reqs]
    routed = rep.extra["routed_per_replica"]
    assert sum(routed) == len(reqs)
    assert max(routed) - min(routed) <= 2      # least-outstanding balances
    for r in rep.records:
        assert r.replica in ("replica0", "replica1")
        assert len(r.tokens) == 4
