"""Quantization: error bounds, STE gradients, calibration, observers."""

import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core.quant import (ActObserver, QuantSpec, fake_quant,
    quantization_error, quantize_pytree, quantize_tensor)


def test_roundtrip_error_bound():
    spec = QuantSpec(bits=8)
    x = jnp.linspace(-1.0, 1.0, 1001)
    xq = quantize_tensor(x, spec)
    step = 2.0 / 254  # symmetric range/qmax steps
    assert float(jnp.abs(xq - x).max()) <= step / 2 + 1e-6


def test_more_bits_less_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (512,))
    errs = [quantization_error(x, QuantSpec(bits=b)) for b in (4, 8, 16)]
    assert errs[0] > errs[1] > errs[2]


def test_ste_gradient_identity_in_range():
    spec = QuantSpec(bits=8)
    scale, zp = jnp.asarray(0.01), jnp.asarray(0.0)
    g = jax.grad(lambda x: fake_quant(x, scale, zp, spec).sum())(jnp.asarray(0.5))
    assert float(g) == 1.0


def test_per_channel_beats_per_tensor():
    key = jax.random.PRNGKey(1)
    # channels with very different ranges
    w = jax.random.normal(key, (8, 64)) * jnp.logspace(-2, 1, 8)[:, None]
    e_pt = quantization_error(w, QuantSpec(bits=8))
    e_pc = quantization_error(w, QuantSpec(bits=8, per_channel=True,
                                           channel_axis=0))
    assert e_pc < e_pt


def test_observer_accumulates():
    spec = QuantSpec(bits=8)
    obs = ActObserver(spec)
    obs.update(jnp.asarray([-1.0, 1.0]))
    obs.update(jnp.asarray([-3.0, 0.5]))
    assert float(obs.lo) == -3.0 and float(obs.hi) == 1.0
    q = obs.quantizer()
    y = q(jnp.asarray([2.9]))
    assert abs(float(y[0]) - 2.9) < 0.05


def test_quantize_pytree_skips_1d():
    params = {"w": jnp.linspace(-1, 1, 16).reshape(4, 4) * 0.77,
              "b": jnp.linspace(-1, 1, 4) * 0.77}
    out = quantize_pytree(params, QuantSpec(bits=4))
    assert float(jnp.abs(out["b"] - params["b"]).max()) == 0.0
    assert float(jnp.abs(out["w"] - params["w"]).max()) > 0.0


@given(st.integers(4, 16), st.floats(0.1, 100.0))
@settings(max_examples=30, deadline=None)
def test_error_bounded_by_step(bits, scale_mag):
    spec = QuantSpec(bits=bits)
    x = jnp.linspace(-scale_mag, scale_mag, 257)
    xq = quantize_tensor(x, spec)
    step = 2 * scale_mag / (2 ** (bits - 1) - 1)
    assert float(jnp.abs(xq - x).max()) <= step / 2 + 1e-5 * scale_mag
