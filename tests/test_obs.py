"""repro.obs: tracer thread-safety and ring bounds, the metrics registry,
nearest-rank statistics (property-tested against NumPy's inverted_cdf),
Chrome-trace export/validation round trips, the `python -m repro.obs`
CLI, and the traced serve-engine integration (request spans reconcile
with the engine's own RequestRecords)."""

import json
import threading

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.registry import build_model, get_config
from repro.obs import (NOOP_OBS, Counter, Gauge, Histogram, MetricsRegistry,
                       NullTracer, Obs, Tracer, latency_summary,
                       load_chrome_trace, mean_tail, percentile,
                       to_chrome_trace, validate_chrome_trace,
                       write_chrome_trace)
from repro.obs.cli import main as obs_cli, request_rows, slowest_spans
from repro.serve import (PipelineServeEngine, ReplicaRouter, Request,
                         stream_of)
from repro.serving.pipeline import PartitionedLMRunner


@pytest.fixture(scope="module")
def runner():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return PartitionedLMRunner(model, params, cuts=[0])


# -- stats --------------------------------------------------------------------

def test_percentile_nearest_rank_basics():
    vals = [10.0, 20.0, 30.0, 40.0]
    assert percentile(vals, 0) == 10.0
    assert percentile(vals, 50) == 20.0          # rank ceil(0.5*4)=2
    assert percentile(vals, 75) == 30.0
    assert percentile(vals, 100) == 40.0
    assert percentile([7.0], 95) == 7.0
    with pytest.raises(ValueError, match="empty"):
        percentile([], 50)
    with pytest.raises(ValueError, match="in \\[0, 100\\]"):
        percentile([1.0], 101)


@settings(max_examples=100, deadline=None)
@given(st.lists(st.floats(min_value=-1e6, max_value=1e6,
                          allow_nan=False), min_size=1, max_size=64),
       st.integers(min_value=0, max_value=100))
def test_percentile_matches_numpy_inverted_cdf(vals, q):
    """The single nearest-rank definition is exactly NumPy's
    method='inverted_cdf' for every sample set and integer q."""
    expect = float(np.percentile(np.asarray(vals, np.float64), q,
                                 method="inverted_cdf"))
    assert percentile(vals, q) == pytest.approx(expect)


def test_latency_summary_and_mean_tail():
    s = latency_summary([0.010, 0.020, 0.030], unit=1e3)
    assert s["p50"] == pytest.approx(20.0)
    assert s["max"] == pytest.approx(30.0)
    assert s["mean"] == pytest.approx(20.0)
    assert latency_summary([]) == {}
    assert mean_tail([10.0, 1.0, 1.0], skip=1) == pytest.approx(1.0)
    assert mean_tail([10.0], skip=5) == pytest.approx(10.0)  # short: use all
    assert mean_tail([], skip=2) == 0.0


# -- tracer -------------------------------------------------------------------

def test_tracer_span_kinds_and_order():
    tr = Tracer()
    with tr.span("outer", cat="test", track="p/t"):
        tr.instant("mark", cat="test", track="p/t")
    t0 = tr.epoch + 0.5
    tr.complete("pre", cat="test", track="p/t", start=t0, dur=0.25)
    spans = tr.spans()
    assert [s.name for s in spans] == ["outer", "mark", "pre"]
    outer, mark, pre = spans
    assert outer.ph == "X" and mark.ph == "i"
    assert outer.ts <= mark.ts <= outer.end      # the instant nests inside
    assert pre.ts == pytest.approx(0.5)
    assert pre.dur == pytest.approx(0.25)
    assert pre.end == pytest.approx(0.75)
    assert tr.dropped == 0


def test_tracer_thread_safety_and_ring_bound():
    """Concurrent writers never lose each other's spans below capacity,
    and a full per-thread ring drops oldest while counting the drops."""
    tr = Tracer(capacity_per_thread=100)
    n_threads, n_spans = 4, 150                  # 50 drops per thread

    def work(tid):
        for i in range(n_spans):
            tr.instant(f"t{tid}.{i}", track=f"p/{tid}")

    threads = [threading.Thread(target=work, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = tr.spans()
    assert len(spans) == n_threads * 100         # capacity kept per thread
    assert tr.dropped == n_threads * 50
    # the *newest* spans survive drop-oldest
    names = {s.name for s in spans}
    for t in range(n_threads):
        assert f"t{t}.{n_spans - 1}" in names
        assert f"t{t}.0" not in names


def test_null_tracer_and_noop_obs():
    nt = NullTracer()
    with nt.span("x"):
        nt.instant("y")
    nt.complete("z", start=0.0, dur=1.0)
    assert nt.spans() == [] and nt.dropped == 0 and not nt.enabled
    assert not NOOP_OBS.enabled
    NOOP_OBS.metrics.counter("anything").inc()
    NOOP_OBS.metrics.histogram("h").observe(1.0)
    assert NOOP_OBS.metrics.snapshot() == {}
    on = Obs.on()
    assert on.enabled and on.tracer.enabled


# -- metrics ------------------------------------------------------------------

def test_metrics_registry_instruments():
    reg = MetricsRegistry()
    reg.counter("req").inc()
    reg.counter("req").inc(4)
    reg.gauge("depth").set(3.5)
    h = reg.histogram("lat_ms")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["req"] == 5
    assert snap["depth"] == 3.5
    assert snap["lat_ms.count"] == 4
    assert snap["lat_ms.mean"] == pytest.approx(2.5)
    assert snap["lat_ms.p50"] == pytest.approx(2.0)   # nearest rank
    assert snap["lat_ms.min"] == 1.0 and snap["lat_ms.max"] == 4.0
    assert h.quantile(100) == 4.0
    reg.reset()
    assert reg.snapshot() == {}


def test_metrics_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError, match="already registered as Counter"):
        reg.gauge("x")
    with pytest.raises(TypeError, match="not Histogram"):
        reg.histogram("x")


def test_histogram_reservoir_bounds_memory():
    h = Histogram("h", keep=8)
    for v in range(100):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100                     # exact over the stream
    assert s["min"] == 0.0 and s["max"] == 99.0  # exact extremes
    assert s["p50"] >= 92.0                      # quantiles: recent window


def test_metrics_snapshot_atomic_write(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").inc(2)
    path = str(tmp_path / "metrics.json")
    reg.write_snapshot(path)
    with open(path) as f:
        assert json.load(f) == {"c": 2}


# -- chrome export ------------------------------------------------------------

def _sample_tracer():
    tr = Tracer()
    e = tr.epoch
    tr.complete("serve", cat="driver", track="replica0/driver",
                start=e, dur=1.0)
    tr.complete("decode", cat="stage", track="replica0/stage0",
                start=e + 0.1, dur=0.2, args={"group": 0})
    tr.complete("req0", cat="request", track="replica0/requests",
                start=e + 0.05, dur=0.5,
                args={"rid": 0, "ttft_ms": 100.0, "tokens": 4,
                      "finish": "length"})
    tr.instant("admit", cat="sched", track="replica0/sched",
               ts=e + 0.04, args={"rid": 0, "slot": 1})
    return tr


def test_chrome_trace_round_trip(tmp_path):
    tr = _sample_tracer()
    trace = to_chrome_trace(tr.spans(), dropped=tr.dropped)
    assert validate_chrome_trace(trace) == []
    path = str(tmp_path / "trace.json")
    write_chrome_trace(path, tr)
    loaded = load_chrome_trace(path)
    assert validate_chrome_trace(loaded) == []
    evs = loaded["traceEvents"]
    # one process metadata entry per "process", one thread per track
    procs = {e["args"]["name"] for e in evs
             if e.get("ph") == "M" and e["name"] == "process_name"}
    assert procs == {"replica0"}
    threads = {e["args"]["name"] for e in evs
               if e.get("ph") == "M" and e["name"] == "thread_name"}
    assert threads == {"driver", "stage0", "requests", "sched"}
    xs = [e for e in evs if e.get("ph") == "X"]
    assert {e["name"] for e in xs} == {"serve", "decode", "req0"}
    drv = next(e for e in xs if e["name"] == "serve")
    assert drv["dur"] == pytest.approx(1e6)      # seconds -> microseconds
    assert loaded["otherData"]["dropped_spans"] == 0


def test_validate_chrome_trace_catches_malformed():
    assert validate_chrome_trace({"nope": 1})
    bad = {"traceEvents": [{"ph": "X", "name": "a", "ts": 0.0,
                            "pid": 1, "tid": 1, "dur": -5.0}]}
    errs = validate_chrome_trace(bad)
    assert any("dur" in e for e in errs)
    # pid/tid without naming metadata is flagged (Perfetto shows bare ints)
    anon = {"traceEvents": [{"ph": "X", "name": "a", "ts": 0.0,
                             "pid": 7, "tid": 7, "dur": 1.0}]}
    assert any("metadata" in e for e in validate_chrome_trace(anon))


def test_cli_renders_tables(tmp_path, capsys):
    tr = _sample_tracer()
    path = str(tmp_path / "trace.json")
    write_chrome_trace(path, tr)
    assert obs_cli([path, "--top", "5"]) == 0
    out = capsys.readouterr().out
    assert "per-request breakdown" in out
    assert "slowest spans" in out
    assert "latency_ms p50=" in out
    trace = load_chrome_trace(path)
    rows = request_rows(trace)
    assert [r["rid"] for r in rows] == [0]
    assert rows[0]["replica"] == "replica0"
    assert rows[0]["latency_ms"] == pytest.approx(500.0)
    slow = slowest_spans(trace, top=2)
    assert slow[0]["name"] == "serve"            # longest non-request span


def test_cli_rejects_invalid_trace(tmp_path, capsys):
    path = str(tmp_path / "bad.json")
    with open(path, "w") as f:
        json.dump({"traceEvents": [{"ph": "X"}]}, f)
    assert obs_cli([path]) == 2
    assert "INVALID" in capsys.readouterr().err


# -- serve-engine integration -------------------------------------------------

def test_traced_engine_run_reconciles_with_report(runner):
    """A traced async run produces stage/link/driver/request spans whose
    request rows match the engine's own RequestRecords exactly, and the
    scheduler's lifecycle instants land on the sched track."""
    obs = Obs.on()
    eng = PipelineServeEngine(runner, n_slots=2, n_groups=1, eos=None,
                              mode="async", capacity=32, obs=obs)
    eng.warmup(prompt_len=6)
    prompts = np.random.default_rng(1).integers(
        0, 100, size=(3, 6)).astype(np.int32)
    reqs = [Request(i, prompts[i], max_new=3, arrival_s=0.0)
            for i in range(3)]
    rep = eng.run(stream_of(reqs), max_wall_s=120.0)
    assert rep.n_done == 3

    spans = obs.tracer.spans()
    cats = {s.cat for s in spans}
    assert {"driver", "stage", "request", "sched"} <= cats
    driver = [s for s in spans if s.cat == "driver"]
    assert len(driver) == 1
    # every stage span nests inside the driver span's interval
    for s in spans:
        if s.cat == "stage":
            assert s.ts >= driver[0].ts - 1e-6
            assert s.end <= driver[0].end + 1e-6
    # request spans mirror the records byte-for-byte
    req_spans = {s.args["rid"]: s for s in spans if s.cat == "request"}
    assert set(req_spans) == {0, 1, 2}
    for rid, rec in rep_records(rep).items():
        s = req_spans[rid]
        assert s.dur == pytest.approx(rec.latency_s)
        assert s.args["tokens"] == len(rec.tokens)
        assert s.args["ttft_ms"] == pytest.approx(rec.ttft_s * 1e3,
                                                  abs=1e-3)
    # the scheduler's lifecycle instants
    sched = [s.name for s in spans if s.cat == "sched"]
    assert sched.count("submit") == 3
    assert sched.count("admit") == 3
    assert sched.count("evict") == 3
    # counters followed along
    snap = obs.metrics.snapshot()
    assert snap["serve_requests_submitted"] == 3
    assert snap["serve_requests_finished"] == 3
    assert snap["serve_ttft_ms.count"] == 3

    # the exported trace validates and the CLI sees the same rows
    trace = to_chrome_trace(spans, dropped=obs.tracer.dropped)
    assert validate_chrome_trace(trace) == []
    rows = request_rows(trace)
    assert [r["rid"] for r in rows] == [0, 1, 2]


def rep_records(rep):
    return {rec.rid: rec for rec in rep.records}


def test_untraced_engine_records_nothing(runner):
    eng = PipelineServeEngine(runner, n_slots=2, n_groups=1, eos=None,
                              mode="serial", capacity=32)
    eng.warmup(prompt_len=6)
    reqs = [Request(0, np.zeros(6, np.int32), max_new=2, arrival_s=0.0)]
    rep = eng.run(stream_of(reqs), max_wall_s=120.0)
    assert rep.n_done == 1
    assert eng.obs is NOOP_OBS
    assert eng.obs.tracer.spans() == []


def test_router_route_and_serve_spans(runner):
    obs = Obs.on()
    replicas = [PipelineServeEngine(runner, n_slots=2, n_groups=1, eos=None,
                                    mode="serial", capacity=32,
                                    name=f"replica{i}", obs=obs)
                for i in range(2)]
    for r in replicas:
        r.warmup(prompt_len=6)
    prompts = np.random.default_rng(2).integers(
        0, 100, size=(4, 6)).astype(np.int32)
    reqs = [Request(i, prompts[i], max_new=2, arrival_s=0.0)
            for i in range(4)]
    rep = ReplicaRouter(replicas, obs=obs).serve(reqs, realtime=False,
                                                 max_wall_s=120.0)
    assert rep.n_done == 4
    spans = obs.tracer.spans()
    routes = [s for s in spans if s.track == "router/route" and s.ph == "i"]
    assert len(routes) == 4
    assert {s.args["replica"] for s in routes} <= {"replica0", "replica1"}
    serve_span = [s for s in spans
                  if s.track == "router/route" and s.ph == "X"]
    assert len(serve_span) == 1
    assert obs.metrics.counter("router_requests_routed").value == 4
