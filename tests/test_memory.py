"""Definition 3 memory model + filters."""

from _hypothesis_compat import given, settings, st

from repro.core import layers as L
from repro.core.memory import (MemoryModel, prefix_feasible_limit,
                               segment_memory, split_memory)


def mk_layers(params, acts):
    return [L.LayerInfo(f"l{i}", L.GEMM, (a,), (a,), params=p, macs=p)
            for i, (p, a) in enumerate(zip(params, acts))]


def test_definition3_exact():
    # m = (sum params + max(a_j)) * b ; a_j = f_in + f_out = 2a
    layers = mk_layers([10, 20, 30], [4, 8, 2])
    m = segment_memory(layers, MemoryModel(bytes_per_param=2.0))
    assert m == (60 + 16) * 2


def test_shared_groups_counted_once():
    layers = mk_layers([10, 10, 10], [1, 1, 1])
    groups = {"l0": "g", "l2": "g"}
    m = segment_memory(layers, MemoryModel(1.0), shared_groups=groups)
    assert m == (10 + 10) + 2     # l0/l2 share; l1 own; act 2


def test_split_memory_partitions():
    layers = mk_layers([10, 20, 30, 40], [1, 2, 3, 4])
    mm = [MemoryModel(1.0), MemoryModel(2.0)]
    a, b = split_memory(layers, [1], mm)
    assert a == (30 + 4) * 1
    assert b == (70 + 8) * 2


def test_prefix_feasible_limit_monotone():
    layers = mk_layers([10] * 6, [1] * 6)
    mm = MemoryModel(1.0)
    lim = prefix_feasible_limit(layers, mm, capacity_bytes=35)
    assert lim == 2            # 10+2, 20+2, 30+2 fit; 40+2 > 35
    assert prefix_feasible_limit(layers, mm, 5) == -1


@given(st.lists(st.tuples(st.integers(0, 1000), st.integers(1, 1000)),
                min_size=1, max_size=20))
@settings(max_examples=50, deadline=None)
def test_memory_monotone_in_prefix(spec):
    layers = mk_layers([p for p, _ in spec], [a for _, a in spec])
    mm = MemoryModel(2.0)
    prev = 0
    for i in range(1, len(layers) + 1):
        cur = segment_memory(layers[:i], mm)
        assert cur >= prev
        prev = cur


@given(st.lists(st.tuples(st.integers(0, 100), st.integers(1, 50)),
                min_size=2, max_size=12),
       st.integers(0, 10))
@settings(max_examples=50, deadline=None)
def test_split_sums_to_at_least_segments(spec, cut_raw):
    layers = mk_layers([p for p, _ in spec], [a for _, a in spec])
    cut = min(cut_raw, len(layers) - 2)
    mm = [MemoryModel(1.0), MemoryModel(1.0)]
    mems = split_memory(layers, [cut], mm)
    total_params = sum(p for p, _ in spec)
    # params split exactly; activations peak per segment
    assert sum(mems) >= total_params
