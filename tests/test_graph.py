"""Graph IR: topo sort, clean cuts, live sets, branch regions."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import layers as L
from repro.core.graph import GraphError, LayerGraph, linearize


def chain_graph(n=5):
    g = LayerGraph(name="chain")
    layers = [L.elementwise_layer(f"l{i}", L.RELU, (4, 8, 8)) for i in range(n)]
    g.chain(layers)
    return g


def diamond_graph():
    g = LayerGraph(name="diamond")
    g.add(L.conv_layer("a", 3, 8, (8, 8), 3))
    g.add(L.conv_layer("b1", 8, 8, (8, 8), 3), after=["a"])
    g.add(L.conv_layer("b2", 8, 16, (8, 8), 3), after=["a"])
    g.add(L.concat_layer("c", [(8, 8, 8), (16, 8, 8)]), after=["b1", "b2"])
    g.add(L.elementwise_layer("d", L.RELU, (24, 8, 8)), after=["c"])
    return g


def test_topo_sort_chain():
    g = chain_graph()
    order = [l.name for l in g.topo_sort()]
    assert order == [f"l{i}" for i in range(5)]


def test_topo_sort_detects_cycle():
    g = chain_graph(3)
    g.edges.append(("l2", "l0"))
    with pytest.raises(GraphError):
        g.topo_sort()


def test_clean_cuts_chain():
    g = chain_graph(5)
    sched = g.topo_sort()
    assert g.clean_cuts(sched) == [0, 1, 2, 3]


def test_clean_cuts_diamond():
    g = diamond_graph()
    sched = g.topo_sort()
    cuts = g.clean_cuts(sched)
    names = {sched[p].name for p in cuts}
    # inside the parallel branches there is no single-tensor cut
    assert names == {"a", "c"}
    # multi-tensor cuts exist inside the diamond
    all_cuts = dict(g.all_cuts(sched))
    assert any(len(v) == 2 for v in all_cuts.values())


def test_live_set_and_cut_bytes():
    g = diamond_graph()
    sched = g.topo_sort()
    pos_a = [i for i, l in enumerate(sched) if l.name == "a"][0]
    assert g.live_set(sched, pos_a) == ["a"]
    nbytes = g.cut_bytes(sched, pos_a, bytes_per_elem=2)
    assert nbytes == 8 * 8 * 8 * 2


def test_min_memory_policy_valid():
    g = diamond_graph()
    sched = linearize(g, "min_memory")
    assert g.validate_schedule(sched)


def test_random_policy_valid_and_seeded():
    g = diamond_graph()
    s1 = linearize(g, "random", seed=3)
    s2 = linearize(g, "random", seed=3)
    assert [l.name for l in s1] == [l.name for l in s2]
    assert g.validate_schedule(s1)


# -- property tests ------------------------------------------------------------

@st.composite
def random_dag(draw):
    n = draw(st.integers(3, 12))
    g = LayerGraph(name="rand")
    for i in range(n):
        preds = []
        if i > 0:
            k = draw(st.integers(1, min(3, i)))
            preds = sorted({draw(st.integers(0, i - 1)) for _ in range(k)})
        g.add(L.elementwise_layer(f"n{i}", L.RELU, (2, 4, 4)),
              after=[f"n{p}" for p in preds] or None)
    return g


@given(random_dag(), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_topo_sort_respects_edges(g, seed):
    sched = g.topo_sort(seed=seed)
    assert g.validate_schedule(sched)


@given(random_dag())
@settings(max_examples=40, deadline=None)
def test_clean_cut_live_sets_are_singletons(g):
    sched = g.topo_sort()
    for p in g.clean_cuts(sched):
        live = g.live_set(sched, p)
        assert live == [sched[p].name]


@given(random_dag())
@settings(max_examples=30, deadline=None)
def test_cut_bytes_nonnegative_and_zero_only_at_sinks(g):
    sched = g.topo_sort()
    for p in range(len(sched) - 1):
        assert g.cut_bytes(sched, p, 1.0) >= 0
