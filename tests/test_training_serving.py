"""Training loop, QAT, serving engine, LM pipeline runner, checkpointing."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.core.quant import QuantSpec
from repro.data.synthetic import (SyntheticImages, SyntheticTokens,
                                  batch_iterator, make_batch_for)
from repro.models.cnn.zoo import reduced_cnn
from repro.models.registry import build_model, get_config
from repro.optim.optimizers import adamw, adafactor
from repro.quantize.evaluate import qat_finetune, quantized_eval
from repro.serving.engine import GenerationEngine
from repro.serving.pipeline import PartitionedLMRunner
from repro.training.train_lib import (make_classifier_train_step,
                                      make_train_step, evaluate_classifier)


def test_lm_loss_decreases():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params, state = model.init(jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, cfg, opt))
    batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg, 4, 32).items()}
    losses = []
    for _ in range(8):
        params, opt_state, state, m = step(params, opt_state, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7


def test_adafactor_trains():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params, state = model.init(jax.random.PRNGKey(0))
    opt = adafactor(1e-2)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, cfg, opt))
    batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg, 4, 32).items()}
    l0 = None
    for _ in range(8):
        params, opt_state, state, m = step(params, opt_state, state, batch)
        l0 = l0 or float(m["loss"])
    assert float(m["loss"]) < l0


def _train_small_cnn(steps=300):
    from repro.optim.schedules import warmup_cosine
    m = reduced_cnn("squeezenet11")
    p, s = m.init(jax.random.PRNGKey(0))
    ds = SyntheticImages(noise=0.15)
    opt = adamw(warmup_cosine(2e-3, 30, steps))
    os_ = opt.init(p)
    step = jax.jit(make_classifier_train_step(m, opt))
    for i in range(steps):
        x, y = ds.batch(64, i)
        p, os_, s, _ = step(p, os_, s, jnp.asarray(x), jnp.asarray(y))
    return m, p, s, ds


@pytest.fixture(scope="module")
def trained_cnn():
    return _train_small_cnn()


def test_cnn_learns(trained_cnn):
    m, p, s, ds = trained_cnn
    vx, vy = ds.eval_set(256)
    acc = evaluate_classifier(m, p, s, jnp.asarray(vx), jnp.asarray(vy))
    assert acc > 0.30    # chance = 0.10


def test_quantization_hurts_and_qat_recovers(trained_cnn):
    m, p, s, ds = trained_cnn
    vx, vy = ds.eval_set(256)
    acc_fp = evaluate_classifier(m, p, s, jnp.asarray(vx), jnp.asarray(vy))
    spec = QuantSpec(bits=4)    # aggressive quantization
    acc_q = quantized_eval(m, p, s, vx, vy, spec)
    assert acc_q <= acc_fp + 0.02
    # QAT restores some accuracy (paper §IV-C)
    it = batch_iterator(ds, 64, start_seed=500)
    p2, s2 = qat_finetune(m, p, s, spec, adamw(5e-4), it, steps=40)
    acc_qat = quantized_eval(m, p2, s2, vx, vy, spec)
    assert acc_qat >= acc_q - 0.02
    assert acc_qat >= acc_q * 0.9


def test_generation_engine():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    prompts = SyntheticTokens(cfg.vocab).batch(3, 8, seed=0)[:, :-1]
    eng = GenerationEngine(model, params, max_seq=40, cache_dtype=jnp.float32)
    res = eng.generate(prompts, max_new=5)
    assert res.tokens.shape == (3, 5)
    assert (res.tokens >= 0).all() and (res.tokens < cfg.vocab).all()


def test_lm_pipeline_runner_equivalence():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params, state = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(
        SyntheticTokens(cfg.vocab).batch(2, 16, seed=1)[:, :-1])}
    mono, _ = model.apply(params, state, batch, train=False)
    runner = PartitionedLMRunner(model, params, cuts=[0])
    piped, report = runner.forward(batch)
    np.testing.assert_allclose(np.asarray(piped), np.asarray(mono),
                               rtol=1e-5, atol=1e-5)
    assert len(report.latency_s) == 2


def test_checkpoint_roundtrip_with_opt_state():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    with tempfile.TemporaryDirectory() as d:
        save(d, {"params": params, "opt": opt_state}, step=7)
        assert latest_step(d) == 7
        back = restore(d, {"params": params, "opt": opt_state})
        for a, b in zip(jax.tree_util.tree_leaves(back),
                        jax.tree_util.tree_leaves(
                            {"params": params, "opt": opt_state})):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
