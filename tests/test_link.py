"""Link models: latency/energy monotonicity and GigE sanity."""

from _hypothesis_compat import given, settings, st

from repro.core.link import LINKS, get_link, gigabit_ethernet


def test_gige_sanity():
    link = gigabit_ethernet()
    # 1 MB at ~1 Gbit/s with framing: between 8 and 12 ms
    d = link.latency_s(1_000_000)
    assert 0.008 < d < 0.012
    assert link.energy_j(1_000_000) > 0
    assert link.latency_s(0) == 0.0


def test_effective_bw_below_line_rate():
    link = gigabit_ethernet()
    assert link.effective_bw(10_000_000) < link.rate_bps / 8


def test_link_ordering():
    # faster links first: ici (50 GB/s) < pcie4x4 (8 GB/s) < dci (6.25) < gige
    n = 50_000_000
    lat = {name: get_link(name).latency_s(n) for name in LINKS}
    assert lat["ici"] < lat["pcie4x4"] < lat["dci"] < lat["gige"]


@given(st.integers(1, 10 ** 9), st.integers(1, 10 ** 9))
@settings(max_examples=50, deadline=None)
def test_latency_monotone(a, b):
    link = gigabit_ethernet()
    lo, hi = min(a, b), max(a, b)
    assert link.latency_s(lo) <= link.latency_s(hi)


@given(st.sampled_from(sorted(LINKS)), st.integers(1, 10 ** 8))
@settings(max_examples=40, deadline=None)
def test_energy_nonnegative(name, nbytes):
    link = get_link(name)
    assert link.energy_j(nbytes) >= 0.0
