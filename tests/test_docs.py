"""Executable documentation: every fenced ``python`` block in docs/*.md
(and the top-level README) must actually run.

Blocks are executed **per file, in order, in one shared namespace**, so a
walkthrough can build state across snippets exactly as a reader would.
Blocks fenced as ```` ```python no-run ```` are display-only (long
compiles, fleet runs, pseudo-APIs) and are only checked to *compile*.

Also gates the generated artifacts: ``docs/api.md`` must be in sync with
the live docstrings, and the PUBLIC_API docstring coverage must be
clean — the same checks CI's ``python -m repro.docs --check`` step runs.
"""

import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = sorted(p.relative_to(ROOT).as_posix()
                   for p in (ROOT / "docs").glob("*.md")) + ["README.md"]

FENCE = re.compile(r"^```python([^\n`]*)\n(.*?)^```\s*$",
                   re.MULTILINE | re.DOTALL)


def snippets(relpath):
    """-> [(lineno, info, code)] for each fenced python block."""
    text = (ROOT / relpath).read_text()
    out = []
    for m in FENCE.finditer(text):
        lineno = text[:m.start()].count("\n") + 1
        out.append((lineno, m.group(1).strip(), m.group(2)))
    return out


def test_docs_exist():
    assert "docs/architecture.md" in DOC_FILES
    assert "docs/search.md" in DOC_FILES
    assert "docs/serving.md" in DOC_FILES
    assert "docs/drift.md" in DOC_FILES
    assert "docs/observability.md" in DOC_FILES


@pytest.mark.parametrize("relpath", DOC_FILES)
def test_doc_snippets_run(relpath):
    blocks = snippets(relpath)
    ns = {"__name__": f"doctest_{relpath}"}
    ran = 0
    for lineno, info, code in blocks:
        compiled = compile(code, f"{relpath}:{lineno}", "exec")
        if "no-run" in info:
            continue                     # display-only: syntax checked
        exec(compiled, ns)
        ran += 1
    # index and generated pages are prose/reference; walkthroughs must
    # actually execute something
    if relpath not in ("docs/README.md", "docs/api.md"):
        assert ran > 0, f"{relpath} has no executed python snippet"


def test_api_md_in_sync():
    """docs/api.md matches the live docstrings (regen if this fails:
    PYTHONPATH=src python -m repro.docs)."""
    from repro.docs import render_api_md
    on_disk = (ROOT / "docs" / "api.md").read_text()
    assert on_disk == render_api_md(), (
        "docs/api.md is stale — regenerate with "
        "`PYTHONPATH=src python -m repro.docs`")


def test_docstring_coverage_clean():
    from repro.docs import missing_docstrings
    assert missing_docstrings() == []


def test_doc_cross_links_resolve():
    """Relative markdown links between doc pages point at real files."""
    link = re.compile(r"\]\((?!http)([^)#]+)\)")
    for relpath in DOC_FILES:
        base = (ROOT / relpath).parent
        for target in link.findall((ROOT / relpath).read_text()):
            assert (base / target).exists(), f"{relpath} -> {target}"
