"""The fleet orchestration runtime (`repro.fleet`): manifest state machine
and atomic claims, worker loop + bounded retries, deterministic shard merge
(edge cases: empty shard set, duplicate-cell conflicts, failed-cell
placeholders), resume-without-recompute, and merged-vs-serial report
identity on a multi-model × multi-system sweep.  Plus the declarative
accuracy satellite (`AccuracySpec` / measured-oracle registry)."""

import dataclasses
import json
import os

import pytest

from repro.core.accuracy import (MeasuredAccuracy, ProxyAccuracy,
                                 register_accuracy_measure)
from repro.explore import (AccuracySpec, Campaign, ExplorationSpec, LinkSpec,
                           ModelRef, PlatformSpec, SearchSettings, SweepSpec,
                           SystemSpec, run_spec)
from repro.fleet import (Manifest, ManifestError, ReportMergeError,
                         merge_manifest, merge_shards, report_fingerprint)
from repro.fleet.worker import run_cell, run_worker

TWO_PLATFORM = SystemSpec(
    platforms=(PlatformSpec("A", "eyr", bits=16),
               PlatformSpec("B", "smb", bits=8)),
    links=("gige",), name="AB")

SLOW_LINK = SystemSpec(
    platforms=(PlatformSpec("A", "eyr", bits=16),
               PlatformSpec("B", "smb", bits=8)),
    links=(LinkSpec(base="gige", rate_bps=1e8),), name="AB-slow")

SPEC = ExplorationSpec(
    model=ModelRef("cnn", "squeezenet11", {"in_hw": 64}),
    system=TWO_PLATFORM,
    objectives=("latency", "energy"),
    search=SearchSettings(strategy="nsga2", seed=0, pop_size=32, n_gen=6))


def make_campaign(n_models=2, systems=(TWO_PLATFORM,)):
    names = ("squeezenet11", "vgg16", "regnetx_400mf")[:n_models]
    return Campaign(SPEC,
                    models=[ModelRef("cnn", n, {"in_hw": 64})
                            for n in names],
                    systems=list(systems))


# -- SweepSpec ----------------------------------------------------------------

def test_sweep_spec_roundtrip_and_hash():
    sweep = make_campaign(2).to_sweep()
    s2 = SweepSpec.from_json(sweep.to_json())
    assert s2 == sweep
    assert s2.spec_hash() == sweep.spec_hash()
    assert sweep.cells() == (("squeezenet11", "AB"), ("vgg16", "AB"))
    # a different seed is a different sweep
    other = SweepSpec(template=dataclasses.replace(
        SPEC, search=dataclasses.replace(SPEC.search, seed=7)),
        models=sweep.models, systems=sweep.systems)
    assert other.spec_hash() != sweep.spec_hash()


def test_sweep_defaults_to_template_model_system():
    sweep = SweepSpec(template=SPEC)
    assert sweep.models == (SPEC.model,)
    assert sweep.systems == (SPEC.system,)
    assert sweep.cells() == (("squeezenet11", "AB"),)


# -- manifest state machine ---------------------------------------------------

def test_manifest_create_load_and_claims(tmp_path):
    d = str(tmp_path / "m")
    m = make_campaign(2).to_manifest(d)
    assert len(m.cells) == 2
    assert all(m.cell_state(c.id) == "pending" for c in m.cells)

    cid = m.cells[0].id
    assert m.claim(cid, "w1")
    assert not m.claim(cid, "w2")          # exclusive
    assert m.cell_state(cid) == "running"
    m.release(cid)
    assert m.cell_state(cid) == "pending"

    # idempotent reopen; different sweep refuses
    m2 = make_campaign(2).to_manifest(d)
    assert m2.spec_hash == m.spec_hash
    with pytest.raises(ManifestError, match="different sweep"):
        make_campaign(1).to_manifest(d)
    assert Manifest.load(d).status()["cells"] == 2


def test_manifest_retry_budget_and_terminal_failure(tmp_path):
    m = make_campaign(1).to_manifest(str(tmp_path / "m"), max_retries=1)
    cid = m.cells[0].id
    assert m.record_failure(cid, "w", "boom 1") == 1
    assert m.cell_state(cid) == "pending"      # one retry left
    assert m.record_failure(cid, "w", "boom 2") == 2
    assert m.cell_state(cid) == "failed"       # budget spent
    assert m.pending_cells() == []
    assert m.complete()
    errs = m.failure_records(cid)
    assert len(errs) == 2 and "boom 2" in errs[-1]["error"]


def _backdate(path, by_s=60.0):
    """Age a claim file past the reclaim grace period."""
    t = os.stat(path).st_mtime - by_s
    os.utime(path, (t, t))


def test_reclaim_stale_only_dead_pids(tmp_path):
    m = make_campaign(2).to_manifest(str(tmp_path / "m"))
    a, b = m.cells[0].id, m.cells[1].id
    m.claim(a, "live")                          # our own (live) pid
    m.claim(b, "dead")
    # rewrite b's claim with a dead pid
    with open(m._claim_path(b), "w") as f:
        json.dump({"worker": "dead", "pid": 2 ** 22 + 12345,
                   "host": __import__("socket").gethostname(),
                   "time": 0}, f)
    # claims inside the grace window are never touched, even with force
    assert m.reclaim_stale() == []
    assert m.reclaim_stale(force=True) == []
    _backdate(m._claim_path(a))
    _backdate(m._claim_path(b))
    assert m.reclaim_stale() == [b]
    assert m.cell_state(a) == "running"
    assert m.cell_state(b) == "pending"
    assert m.reclaim_stale(force=True) == [a]


def test_lease_ttl_reclaims_hung_worker(tmp_path):
    """A claim held by a *live* pid whose lease expired (hung worker) is
    reclaimed with ``lease_ttl_s``; a refreshed lease survives."""
    m = make_campaign(2).to_manifest(str(tmp_path / "m"))
    a, b = m.cells[0].id, m.cells[1].id
    m.claim(a, "hung")                 # our own pid: provably alive
    m.claim(b, "slow-but-live")
    _backdate(m._claim_path(a), by_s=60.0)
    _backdate(m._claim_path(b), by_s=60.0)
    # pid probing alone never touches live-pid claims, however old
    assert m.reclaim_stale() == []
    # b's worker heartbeats; a's lease stays expired
    assert m.refresh_claim(b)
    assert m.reclaim_stale(lease_ttl_s=30.0) == [a]
    assert m.cell_state(a) == "pending"
    assert m.cell_state(b) == "running"
    # the reclaimed claim is gone, so a further refresh reports it
    assert not m.refresh_claim(a)
    with pytest.raises(ValueError, match="lease_ttl_s"):
        m.reclaim_stale(lease_ttl_s=0.0)


def test_lease_heartbeat_refreshes_until_claim_released(tmp_path):
    """The worker's heartbeat thread keeps bumping the claim's mtime and
    exits on its own once the claim disappears."""
    import threading
    import time as _time

    import repro.fleet.worker as W
    m = make_campaign(1).to_manifest(str(tmp_path / "m"))
    cid = m.cells[0].id
    m.claim(cid, "w")
    _backdate(m._claim_path(cid), by_s=60.0)
    before = os.stat(m._claim_path(cid)).st_mtime
    stop = threading.Event()
    th = threading.Thread(target=W._lease_heartbeat,
                          args=(m, cid, 0.3, stop), daemon=True)
    th.start()
    _time.sleep(0.4)                   # >= one heartbeat period (lease/3)
    assert os.stat(m._claim_path(cid)).st_mtime > before
    m.release(cid)                     # claim vanishes mid-heartbeat
    th.join(timeout=3.0)
    assert not th.is_alive()
    stop.set()


def test_run_worker_validates_lease(tmp_path):
    d = str(tmp_path / "m")
    make_campaign(1).to_manifest(d)
    with pytest.raises(ValueError, match="lease_s"):
        run_worker(d, lease_s=0.0)


# -- merge edge cases ---------------------------------------------------------

def test_merge_empty_shard_set_raises(tmp_path):
    m = make_campaign(2).to_manifest(str(tmp_path / "m"))
    with pytest.raises(ReportMergeError, match="without a shard"):
        merge_manifest(m)


def test_merge_empty_sweep_yields_empty_report():
    rep = merge_shards({"t": 1}, [], [])
    assert rep.entries == [] and rep.wall_s == 0.0


def test_merge_duplicate_cell_conflict():
    cells = [("c0", "m", "s")]
    e1 = {"model": "m", "system": "s", "wall_s": 1.0, "pareto": [1]}
    e2 = {"model": "m", "system": "s", "wall_s": 2.0, "pareto": [1]}
    e3 = {"model": "m", "system": "s", "wall_s": 1.0, "pareto": [2]}
    # identical payloads (timing-stripped) dedupe silently
    rep = merge_shards({}, cells, [("c0", e1), ("c0", e2)])
    assert len(rep.entries) == 1
    # diverging payloads are a hard conflict
    with pytest.raises(ReportMergeError, match="conflicting shards"):
        merge_shards({}, cells, [("c0", e1), ("c0", e3)])
    # shard for a cell outside the sweep is rejected
    with pytest.raises(ReportMergeError, match="unknown cell"):
        merge_shards({}, cells, [("cX", e1)])


def test_merge_failed_cell_placeholder(tmp_path):
    m = make_campaign(2).to_manifest(str(tmp_path / "m"), max_retries=0)
    good, bad = m.cells
    m.write_shard(good.id, run_cell(m, good), "w")
    m.record_failure(bad.id, "w", "ValueError: kaput")
    # without allow_failed the merge refuses to pose as complete
    with pytest.raises(ReportMergeError, match="without a shard"):
        merge_manifest(m)
    rep = merge_manifest(m, allow_failed=True)
    assert len(rep.entries) == 2
    ph = rep.entries[1]
    assert ph["failed"] and "kaput" in ph["error"]
    assert ph["model"] == bad.model and ph["system"] == bad.system
    assert ph["pareto"] == [] and ph["selected"] is None
    # placeholder still JSON-serializable through CampaignReport
    assert json.loads(rep.to_json())["entries"][1]["failed"]


# -- merged == serial ---------------------------------------------------------

def test_fleet_merge_equals_serial_3x2():
    """3 models × 2 systems: in-process worker sweep merges to a report
    fingerprint-identical to the serial Campaign.run (same seeds)."""
    camp = make_campaign(3, systems=(TWO_PLATFORM, SLOW_LINK))
    serial = camp.run().report
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        m = camp.to_manifest(d)
        assert len(m.cells) == 6
        stats = run_worker(d)
        assert stats == {"done": 6, "failed": 0}
        merged = merge_manifest(d)
    assert report_fingerprint(merged) == report_fingerprint(serial)
    # order is serial (model-major), not shard-arrival
    assert [(e["model"], e["system"]) for e in merged.entries] == \
           [(e["model"], e["system"]) for e in serial.entries]


def test_resume_does_not_recompute_done_cells(tmp_path):
    """Kill-and-resume semantics: cells finished before a crash keep their
    shards byte-identical; only pending work runs again."""
    d = str(tmp_path / "m")
    camp = make_campaign(2)
    m = camp.to_manifest(d)
    first, second = m.cells
    m.write_shard(first.id, run_cell(m, first), "w0")   # "pre-crash" work
    before = open(m._shard_path(first.id)).read()
    mtime = os.stat(m._shard_path(first.id)).st_mtime_ns
    # crashed worker left a claim on the second cell with a dead pid
    m.claim(second.id, "dead")
    with open(m._claim_path(second.id), "w") as f:
        json.dump({"worker": "dead", "pid": 2 ** 22 + 999,
                   "host": __import__("socket").gethostname(), "time": 0}, f)
    _backdate(m._claim_path(second.id))
    # resume: reclaim + one worker finishes only the pending cell
    assert m.reclaim_stale() == [second.id]
    stats = run_worker(d)
    assert stats == {"done": 1, "failed": 0}
    assert open(m._shard_path(first.id)).read() == before
    assert os.stat(m._shard_path(first.id)).st_mtime_ns == mtime
    merged = merge_manifest(d)
    assert report_fingerprint(merged) == \
           report_fingerprint(camp.run().report)


def test_worker_retries_transient_failure(tmp_path, monkeypatch):
    """A cell that fails once and then succeeds ends done, within budget."""
    d = str(tmp_path / "m")
    make_campaign(1).to_manifest(d, max_retries=2)
    import repro.fleet.worker as W
    real = W.run_cell
    calls = {"n": 0}

    def flaky(manifest, cell, caches=None):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient")
        return real(manifest, cell, caches)

    monkeypatch.setattr(W, "run_cell", flaky)
    stats = W.run_worker(d)
    assert stats == {"done": 1, "failed": 1}
    m = Manifest.load(d)
    assert m.cell_state(m.cells[0].id) == "done"
    assert m.attempts(m.cells[0].id) == 1


# -- declarative accuracy (satellite) -----------------------------------------

def test_accuracy_spec_proxy_knobs_roundtrip():
    spec = dataclasses.replace(
        SPEC, objectives=("latency", "accuracy"),
        accuracy=AccuracySpec(kind="proxy", base_accuracy=0.9,
                              noise_scale=2.0))
    s2 = ExplorationSpec.from_json(spec.to_json())
    assert s2 == spec
    res = run_spec(spec)
    assert res.selected is not None
    # knobs actually reach the oracle: accuracy capped by base_accuracy
    assert all(e.accuracy <= 0.9 + 1e-9 for e in res.pareto)


def test_accuracy_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        AccuracySpec(kind="magic")
    with pytest.raises(ValueError, match="measure"):
        AccuracySpec(kind="measured")
    # a measure name with the default/typo'd proxy kind would silently run
    # the wrong oracle — rejected instead
    with pytest.raises(ValueError, match="mean kind='measured'"):
        AccuracySpec(kind="proxy", measure="cnn_fakequant")
    with pytest.raises(ValueError, match="unknown accuracy measure"):
        AccuracySpec(kind="measured", measure="no-such").build(
            None, [], None)


def test_measured_accuracy_declarative_path():
    """A registered measured oracle drives the NumPy strategies through the
    spec; per-cut caching comes from MeasuredAccuracy."""
    calls = []

    def factory(graph=None, schedule=None, system=None, *, bonus=0.0):
        assert schedule is not None and system is not None

        def measure(cuts):
            calls.append(tuple(cuts))
            return 0.5 + bonus

        return measure

    register_accuracy_measure("test_const", factory, override=True)
    spec = dataclasses.replace(
        SPEC, objectives=("latency", "accuracy"),
        search=SearchSettings(strategy="exhaustive"),
        accuracy=AccuracySpec(kind="measured", measure="test_const",
                              options={"bonus": 0.25}))
    res = run_spec(spec)
    assert calls, "measured oracle was never invoked"
    assert all(abs(e.accuracy - 0.75) < 1e-9 for e in res.pareto)
    # built oracle is the caching wrapper
    built = spec.accuracy.build(None, [], TWO_PLATFORM.build())
    assert isinstance(built, MeasuredAccuracy)


def test_measured_table_oracle_builtin():
    acc = AccuracySpec(kind="measured", measure="table",
                       options={"table": {"3": 0.91, "-1": 0.4},
                                "default": 0.1})
    fn = acc.build(None, [], TWO_PLATFORM.build())
    assert fn((3,)) == 0.91 and fn((-1,)) == 0.4 and fn((7,)) == 0.1


def test_jit_path_falls_back_on_measured_accuracy():
    """jit_nsga2 + measured oracle + accuracy objective: documented
    fallback to the NumPy strategy, not a crash or silent drop."""
    register_accuracy_measure(
        "test_half", lambda graph=None, schedule=None, system=None:
        (lambda cuts: 0.5), override=True)
    spec = dataclasses.replace(
        SPEC, objectives=("latency", "accuracy"),
        search=SearchSettings(strategy="jit_nsga2", seed=0, pop_size=16,
                              n_gen=2),
        accuracy=AccuracySpec(kind="measured", measure="test_half"))
    with pytest.warns(UserWarning, match="falling back"):
        res = run_spec(spec)
    assert res.selected is not None
    assert all(abs(e.accuracy - 0.5) < 1e-9 for e in res.pareto)


def test_default_accuracy_unchanged():
    """No accuracy field -> the default ProxyAccuracy oracle (seed parity
    with pre-AccuracySpec reports)."""
    res_default = run_spec(SPEC)
    res_explicit = run_spec(dataclasses.replace(
        SPEC, accuracy=AccuracySpec(kind="proxy")))
    assert [e.cuts for e in res_default.pareto] == \
           [e.cuts for e in res_explicit.pareto]
    assert isinstance(ProxyAccuracy([], TWO_PLATFORM.build()), ProxyAccuracy)
