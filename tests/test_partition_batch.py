"""Vectorized search path: ``evaluate_batch`` ≡ scalar ``evaluate`` on
randomized cut matrices, batched memory/accuracy/link building blocks, the
heterogeneous link-filter fix, sub-byte link traffic, and the
``pipeline_report`` zero-latency guard."""

import numpy as np
import pytest

from repro.core import layers as L
from repro.core.accuracy import ProxyAccuracy
from repro.core.graph import LayerGraph
from repro.core.hwmodel import EYERISS_LIKE, SIMBA_LIKE
from repro.core.link import LINKS, get_link, gigabit_ethernet
from repro.core.memory import MemoryModel, SegmentMemoryTable, segment_memory
from repro.core.partition import (Constraints, PartitionEvaluator, Platform,
                                  SystemConfig)
from repro.core.quant import QuantSpec
from repro.serving.pipeline import link_transfer_bytes, pipeline_report

TIGHT_CONSTRAINTS = Constraints(max_link_bytes=300_000, min_accuracy=0.9,
                                max_latency_s=0.05, max_energy_j=0.05,
                                min_throughput=20.0)


def chain_graph(n_layers=10, c=32, hw=28):
    g = LayerGraph(name="chain")
    g.chain([L.conv_layer(f"conv{i}", c, c, (hw, hw), 3)
             for i in range(n_layers)])
    return g


def make_evaluator(n_layers=10, n_platforms=2, batch=1, shared_groups=None,
                   bits=(16, 8, 16, 8)):
    g = chain_graph(n_layers)
    sched = g.topo_sort()
    plats = [Platform(f"p{i}", EYERISS_LIKE if i % 2 == 0 else SIMBA_LIKE,
                      QuantSpec(bits=bits[i % len(bits)]))
             for i in range(n_platforms)]
    system = SystemConfig(plats, [gigabit_ethernet()] * (n_platforms - 1))
    acc = ProxyAccuracy(sched, system)
    return PartitionEvaluator(g, sched, system, accuracy_fn=acc, batch=batch,
                              shared_groups=shared_groups)


def random_cuts(evaluator, n, seed=0):
    rng = np.random.default_rng(seed)
    return np.sort(rng.integers(-1, len(evaluator.schedule),
                                size=(n, evaluator.system.n_cuts)), axis=1)


def assert_rows_match(evaluator, cuts, constraints):
    be = evaluator.evaluate_batch(cuts, constraints)
    assert len(be) == len(cuts)
    for i, row in enumerate(cuts):
        ref = evaluator.evaluate(row, constraints)
        got = be.row(i)
        assert got.cuts == ref.cuts
        assert got.latency_s == pytest.approx(ref.latency_s, rel=1e-9)
        assert got.energy_j == pytest.approx(ref.energy_j, rel=1e-9)
        assert got.throughput == pytest.approx(ref.throughput, rel=1e-9)
        assert got.accuracy == pytest.approx(ref.accuracy, rel=1e-9,
                                             abs=1e-12)
        assert got.violation == pytest.approx(ref.violation, rel=1e-9,
                                              abs=1e-12)
        assert got.link_bytes == ref.link_bytes
        assert got.memory_bytes == ref.memory_bytes
        assert got.stage_latency_s == pytest.approx(ref.stage_latency_s)
        assert got.link_latency_s == pytest.approx(ref.link_latency_s)


@pytest.mark.parametrize("n_platforms", [2, 4])
@pytest.mark.parametrize("constraints", [None, TIGHT_CONSTRAINTS])
def test_batch_matches_scalar(n_platforms, constraints):
    evaluator = make_evaluator(n_platforms=n_platforms)
    assert_rows_match(evaluator, random_cuts(evaluator, 100), constraints)


def test_batch_matches_scalar_sub_byte_platforms():
    # 4-bit producers: the cost model must ceil fractional link bytes, in
    # agreement with the serving-side link_transfer_bytes accounting
    evaluator = make_evaluator(n_platforms=3, bits=(4, 4, 8))
    cuts = random_cuts(evaluator, 80, seed=4)
    assert_rows_match(evaluator, cuts, TIGHT_CONSTRAINTS)
    be = evaluator.evaluate_batch(cuts)
    rows_active = be.link_latency_s.max(axis=1) > 0
    assert np.all(be.link_bytes[rows_active] > 0)


def test_batch_matches_scalar_shared_groups_and_batchsize():
    groups = {"conv1": "gA", "conv5": "gA", "conv2": "gB", "conv7": "gB"}
    evaluator = make_evaluator(n_platforms=4, batch=4, shared_groups=groups)
    assert_rows_match(evaluator, random_cuts(evaluator, 100, seed=1),
                      TIGHT_CONSTRAINTS)


def test_batch_objectives_match_scalar():
    evaluator = make_evaluator(n_platforms=4)
    keys = ("latency", "energy", "throughput", "bandwidth", "memory",
            "accuracy")
    cuts = random_cuts(evaluator, 50, seed=2)
    F = evaluator.evaluate_batch(cuts).as_objectives(keys)
    assert F.shape == (50, len(keys))
    for i, row in enumerate(cuts):
        ref = evaluator.evaluate(row).as_objectives(keys)
        assert F[i] == pytest.approx(ref, rel=1e-9)


def test_batch_rejects_malformed_input():
    evaluator = make_evaluator()
    with pytest.raises(ValueError):
        evaluator.evaluate_batch(np.array([3]))          # 1-D
    with pytest.raises(AssertionError):
        evaluator.evaluate_batch(np.array([[999]]))      # beyond schedule


def test_segment_memory_table_matches_scalar():
    layers = [L.LayerInfo(f"l{i}", L.GEMM, (8,), (8,), params=100 * (i + 1),
                          macs=1) for i in range(12)]
    groups = {"l2": "g", "l9": "g", "l5": "h", "l6": "h"}
    model = MemoryModel(bytes_per_param=1.5, bytes_per_act=0.5)
    table = SegmentMemoryTable(layers, groups)
    a, b = np.meshgrid(np.arange(12), np.arange(12), indexing="ij")
    a, b = a.ravel(), b.ravel()
    got = table.batched(a, b, model, batch=3)
    for ai, bi, gi in zip(a, b, got):
        ref = segment_memory(layers[ai: bi + 1], model, groups, batch=3)
        assert gi == ref, (ai, bi)


def test_proxy_accuracy_batch_matches_scalar():
    evaluator = make_evaluator(n_platforms=4)
    acc = evaluator.accuracy_fn
    cuts = random_cuts(evaluator, 64, seed=3)
    batch = acc.evaluate_batch(cuts)
    for i, row in enumerate(cuts):
        assert batch[i] == pytest.approx(acc(tuple(row)), rel=1e-9)


def test_link_vec_matches_scalar():
    sizes = np.array([0, 1, 100, 1459, 1460, 1461, 10_000, 5_000_000])
    for name in LINKS:
        link = get_link(name)
        lat = link.latency_s_vec(sizes)
        en = link.energy_j_vec(sizes)
        for i, n in enumerate(sizes):
            assert lat[i] == pytest.approx(link.latency_s(int(n)), rel=1e-12)
            assert en[i] == pytest.approx(link.energy_j(int(n)), rel=1e-12)


# -- satellite regressions ----------------------------------------------------

def test_link_filter_uses_producer_bits():
    """A cut feasible at the 8-bit producer's width must survive the filter
    even when another platform in the system runs at 16 bits."""
    from repro.core.explorer import Explorer
    g = chain_graph()
    system = SystemConfig(
        [Platform("A", SIMBA_LIKE, QuantSpec(bits=8)),
         Platform("B", EYERISS_LIKE, QuantSpec(bits=16))],
        [gigabit_ethernet()])
    ex_free = Explorer(g, system)
    all_cands = ex_free.candidate_cuts()
    assert all_cands
    # budget that fits every cut at 1 byte/elem (producer A) but none at 2
    elems = [g.cut_bytes(ex_free.schedule, p, 1.0) for p in all_cands]
    cap = max(elems)
    ex = Explorer(g, system, constraints=Constraints(max_link_bytes=cap))
    kept = ex.candidate_cuts()
    assert kept == all_cands
    # and the kept candidates really are feasible when evaluated
    for p in kept:
        assert ex.evaluator.evaluate([p]).link_bytes <= cap


def test_link_filter_single_platform_system():
    from repro.core.explorer import Explorer
    g = chain_graph()
    system = SystemConfig([Platform("A", SIMBA_LIKE, QuantSpec(bits=8))], [])
    ex = Explorer(g, system, constraints=Constraints(max_link_bytes=1))
    assert ex.candidate_cuts() == ex._memory_filter(
        g.clean_cuts(ex.schedule))   # no links -> nothing to filter


def test_pipeline_report_guards_zero_latencies():
    assert pipeline_report([], [])["throughput"] == 0.0
    assert pipeline_report([0.0, 0.0], [0.0])["throughput"] == 0.0
    rep = pipeline_report([0.1, 0.0], [0.05])
    assert rep["throughput"] == pytest.approx(1.0 / 0.1)
    assert rep["latency_s"] == pytest.approx(0.15)


def test_sub_byte_link_traffic_nonzero():
    # 4-bit link: 1000 elements -> 500 bytes (was 0 with bits // 8)
    assert link_transfer_bytes(1000, QuantSpec(bits=4)) == 500
    assert link_transfer_bytes(1001, QuantSpec(bits=4)) == 501  # ceil
    assert link_transfer_bytes(1000, QuantSpec(bits=8)) == 1000
    assert link_transfer_bytes(1000, None) == 4000              # float32


def test_cnn_runner_reports_sub_byte_link_bytes():
    jax = pytest.importorskip("jax")
    from repro.models.cnn.zoo import reduced_cnn
    from repro.serving.pipeline import PartitionedCNNRunner
    m = reduced_cnn("squeezenet11")
    p, s = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 32, 32))
    runner = PartitionedCNNRunner(m, p, s, [4],
                                  [QuantSpec(bits=4), QuantSpec(bits=8)])
    _, report = runner.run(x)
    assert len(report.link_bytes) == 1
    assert report.link_bytes[0] > 0
