"""The declarative exploration API (`repro.explore`): spec/report JSON
round-trips, strategy equivalence (exhaustive ≡ legacy shim; MultiCutScan ⊇
NSGA-II), campaign fan-out with shared cost tables, the per-(link, position)
feasibility filter, and the deprecation shim."""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import layers as L
from repro.core.graph import LayerGraph
from repro.core.nsga2 import dominates
from repro.core.partition import Constraints
from repro.explore import (Campaign, CampaignReport, ExplorationResult,
                           ExplorationSpec, LinkSpec, ModelRef, PlatformSpec,
                           SearchSettings, SystemSpec, eval_from_dict,
                           eval_to_dict, explore_graph, link_feasibility,
                           feasible_cut_rows, run_spec, scaled_nsga_defaults)

TWO_PLATFORM = SystemSpec(
    platforms=(PlatformSpec("A", "eyr", bits=16),
               PlatformSpec("B", "smb", bits=8)),
    links=("gige",))

FOUR_PLATFORM = SystemSpec(
    platforms=(PlatformSpec("A0", "eyr", bits=16),
               PlatformSpec("A1", "eyr", bits=16),
               PlatformSpec("B0", "smb", bits=8),
               PlatformSpec("B1", "smb", bits=8)),
    links=("gige", "gige", "gige"))

SQUEEZE = ModelRef("cnn", "squeezenet11", {"in_hw": 64})


def make_spec(**kw):
    defaults = dict(model=SQUEEZE, system=TWO_PLATFORM,
                    objectives=("latency", "energy"))
    defaults.update(kw)
    return ExplorationSpec(**defaults)


# -- spec / report serialization ----------------------------------------------

def test_spec_json_roundtrip():
    spec = make_spec(
        system=SystemSpec(
            platforms=(PlatformSpec("A", "eyr", bits=16,
                                    mem_capacity=123456),
                       PlatformSpec("B", "smb", bits=8)),
            links=(LinkSpec(base="gige", name="slow", rate_bps=1e8),),
            name="ab"),
        objectives=("latency", "energy", "throughput"),
        weights=(2.0, 1.0, 1.0),
        constraints=Constraints(max_link_bytes=2_000_000, min_accuracy=0.5),
        search=SearchSettings(strategy="multicut", seed=3, max_scan=5000),
        batch=4)
    s = spec.to_json()
    spec2 = ExplorationSpec.from_json(s)
    assert spec2 == spec
    # stable through a second trip, and valid JSON throughout
    assert json.loads(spec2.to_json()) == json.loads(s)
    # resolvable to live objects
    system = spec2.system.build()
    assert system.platforms[0].capacity == 123456
    assert system.links[0].rate_bps == 1e8


def test_spec_rejects_bad_fields():
    with pytest.raises(ValueError):
        make_spec(objectives=("latency", "speed"))
    with pytest.raises(ValueError):
        make_spec(search=SearchSettings(strategy="magic"))
    with pytest.raises(ValueError):
        SystemSpec(platforms=(PlatformSpec("A", "eyr"),), links=("gige",))


def test_eval_dict_roundtrip():
    res = run_spec(make_spec())
    for ev in res.pareto + res.baselines:
        d = json.loads(json.dumps(eval_to_dict(ev)))
        assert eval_from_dict(d) == ev


# -- strategy equivalence -----------------------------------------------------

@pytest.fixture(scope="module")
def squeezenet_objects():
    graph, _ = SQUEEZE.build()
    return graph, TWO_PLATFORM.build()


def test_exhaustive_matches_legacy_explorer(squeezenet_objects):
    """The new ExhaustiveSearch reproduces the legacy Explorer.run output
    exactly (same candidates, same scan points, same front, same pick)."""
    graph, system = squeezenet_objects
    objectives = ("latency", "energy", "throughput")
    with pytest.warns(DeprecationWarning):
        from repro.core import Explorer
        legacy = Explorer(graph, system, objectives=objectives)
    res_old = legacy.run(seed=0, use_nsga=False)
    res_new = explore_graph(
        graph, system, objectives=objectives,
        search=SearchSettings(strategy="exhaustive"))
    assert res_new.candidates == res_old.candidates
    assert [e.cuts for e in res_new.all_evals] == \
           [e.cuts for e in res_old.all_evals]
    assert [e.cuts for e in res_new.pareto] == \
           [e.cuts for e in res_old.pareto]
    for a, b in zip(res_new.pareto, res_old.pareto):
        assert a == b
    assert res_new.selected == res_old.selected


def test_multicut_front_contains_nsga_front(squeezenet_objects):
    """MultiCutScan is exhaustive ground truth over the encoded cut space;
    no NSGA-II front point may dominate it, on the same spec with only the
    strategy swapped (drop-in interchangeability)."""
    graph, _ = squeezenet_objects
    base = make_spec(system=FOUR_PLATFORM,
                     objectives=("latency", "energy", "bandwidth"))
    spec_scan = dataclasses.replace(
        base, search=SearchSettings(strategy="multicut"))
    spec_ga = dataclasses.replace(
        base, search=SearchSettings(strategy="nsga2", seed=1,
                                    pop_size=32, n_gen=12))
    res_scan = run_spec(spec_scan)
    res_ga = run_spec(spec_ga)
    assert res_scan.strategy == "multicut"
    assert res_ga.nsga is not None
    F_scan = [np.array(e.as_objectives(base.objectives))
              for e in res_scan.pareto]
    for ev in res_ga.pareto:
        f = np.array(ev.as_objectives(base.objectives))
        assert not any(dominates(f, g) for g in F_scan), \
            f"NSGA point {ev.cuts} dominates the exhaustive front"


def test_multicut_includes_fewer_partition_schedules():
    """The scan covers the skip/end sentinels, so Table-II-style
    fewer-partition schedules appear in the evaluated pool."""
    g = LayerGraph(name="chain")
    g.chain([L.conv_layer(f"conv{i}", 16, 16, (16, 16), 3)
             for i in range(8)])
    spec = ExplorationSpec(
        model=SQUEEZE, system=FOUR_PLATFORM,
        objectives=("latency", "energy"),
        search=SearchSettings(strategy="multicut"))
    res = explore_graph(g, spec.system.build(),
                        objectives=spec.objectives, search=spec.search)
    n_parts = {e.n_partitions for e in res.all_evals}
    assert 1 in n_parts and 2 in n_parts


def test_multicut_scan_cap():
    spec = make_spec(system=FOUR_PLATFORM,
                     search=SearchSettings(strategy="multicut", max_scan=10))
    with pytest.raises(ValueError, match="max_scan"):
        run_spec(spec)


def test_scaled_nsga_defaults_grow_with_problem():
    p1, g1 = scaled_nsga_defaults(10, 1, 20)
    p2, g2 = scaled_nsga_defaults(200, 3, 200)
    assert p2 > p1 and g2 > g1
    assert p2 <= 512 and g2 <= 120


# -- per-(link, position) feasibility -----------------------------------------

def test_link_feasibility_matrix_heterogeneous():
    """A 16-bit producer link can be infeasible where the 8-bit one is
    fine; the matrix prices each link at its own producer width."""
    g = LayerGraph(name="chain")
    couts = [4, 4, 32, 4, 4, 4]          # conv2's output tensor is the fat one
    cin = 4
    chain = []
    for i, co in enumerate(couts):
        chain.append(L.conv_layer(f"conv{i}", cin, co, (16, 16), 3))
        cin = co
    g.chain(chain)
    system = SystemSpec(
        platforms=(PlatformSpec("A", "smb", bits=8),
                   PlatformSpec("B", "eyr", bits=16),
                   PlatformSpec("C", "smb", bits=8)),
        links=("gige", "gige")).build()
    from repro.core.partition import PartitionEvaluator
    sched = g.topo_sort()
    ev = PartitionEvaluator(g, sched, system)
    elems = ev.cut_elements()
    cap = int(np.ceil(elems.max() * 1.0))       # fits at 1 B/elem, not 2
    feas = link_feasibility(ev, cap)
    assert feas.shape == (2, len(sched) - 1)
    p = int(np.argmax(elems))
    assert feas[0, p] and not feas[1, p]
    # exact row pruning: the fat cut is allowed on link 0, not on link 1
    C = np.array([[p, len(sched) - 1],          # p feeds link 0 -> keep
                  [0, p]])                      # p feeds link 1 -> drop
    keep = feasible_cut_rows(C, ev, feas)
    assert keep.tolist() == [True, False]
    # and pruning is exact: the dropped row really violates the budget
    bad = ev.evaluate(C[1], Constraints(max_link_bytes=cap))
    assert bad.link_bytes > cap


def test_multicut_pruning_matches_bruteforce():
    """Scan with the feasibility pre-filter finds the same front as brute
    force evaluation of every combination under the constraint."""
    g = LayerGraph(name="chain")
    g.chain([L.conv_layer(f"conv{i}", 8, 8, (12, 12), 3) for i in range(7)])
    system = SystemSpec(
        platforms=(PlatformSpec("A", "smb", bits=8),
                   PlatformSpec("B", "eyr", bits=16),
                   PlatformSpec("C", "smb", bits=8)),
        links=("gige", "gige")).build()
    from repro.core.partition import PartitionEvaluator
    sched = g.topo_sort()
    ev = PartitionEvaluator(g, sched, system)
    cap = int(ev.cut_elements().max())          # tight heterogeneous budget
    cons = Constraints(max_link_bytes=cap)
    res = explore_graph(g, system, objectives=("latency", "energy"),
                        constraints=cons,
                        search=SearchSettings(strategy="multicut"))
    for e in res.pareto:
        assert e.violation <= 0
        assert e.link_bytes <= cap


# -- campaign -----------------------------------------------------------------

@pytest.fixture(scope="module")
def campaign_result():
    spec = ExplorationSpec(
        model=SQUEEZE, system=TWO_PLATFORM,
        objectives=("latency", "energy", "throughput"))
    models = [ModelRef("cnn", n, {"in_hw": 64})
              for n in ("squeezenet11", "vgg16", "resnet50")]
    return Campaign(spec, models=models).run()


def test_campaign_scores_three_models(campaign_result):
    cr = campaign_result
    assert len(cr.entries) == 3
    for e in cr.entries:
        assert len(e.result.pareto) >= 1
        assert e.result.selected is not None
        assert e.result.selected.violation <= 0
    assert {e.model for e in cr.entries} == \
           {"squeezenet11", "vgg16", "resnet50"}
    # entries retrievable by model label
    assert cr.get("vgg16").selected is not None


def test_campaign_report_json_roundtrip(campaign_result):
    rep = campaign_result.report
    rep2 = CampaignReport.from_json(rep.to_json())
    assert rep2.to_dict() == rep.to_dict()
    assert len(rep2.entries) == 3
    for e in rep2.entries:
        assert e["selected"] is not None
        assert eval_from_dict(e["selected"]).cuts == \
               tuple(e["selected"]["cuts"])
    # the template itself round-trips back into a runnable spec
    assert ExplorationSpec.from_dict(rep2.template) is not None
    assert rep.summary()


def test_campaign_shares_cost_tables(monkeypatch):
    """Two systems over the same archs must profile each arch once per
    model, not once per (model, system)."""
    import repro.core.partition as P
    calls = []
    real = P.layer_cost_table

    def counting(schedule, arch, batch):
        calls.append(arch.name)
        return real(schedule, arch, batch)

    monkeypatch.setattr(P, "layer_cost_table", counting)
    spec = ExplorationSpec(model=SQUEEZE, system=TWO_PLATFORM,
                           objectives=("latency", "energy"))
    sys_b = SystemSpec(
        platforms=(PlatformSpec("A2", "eyr", bits=16),
                   PlatformSpec("B2", "smb", bits=8)),
        links=(LinkSpec(base="gige", rate_bps=1e8),), name="slow")
    Campaign(spec, systems=[TWO_PLATFORM, sys_b]).run()
    # one EYR + one SMB profile total, despite two systems
    assert sorted(calls) == ["EYR", "SMB"]


# -- result robustness (satellite: empty fronts / sentinel cuts) --------------

def test_summary_handles_empty_front_and_sentinel_cuts():
    res = run_spec(make_spec())
    empty = ExplorationResult(
        schedule=res.schedule, candidates=[], all_evals=[], pareto=[],
        selected=None, baselines=res.baselines, objectives=res.objectives)
    text = empty.summary()
    assert "no feasible partitioning" in text
    rep = empty.to_report()
    assert rep["selected"] is None and rep["pareto"] == []
    # sentinel / out-of-range cut indices must not raise
    weird = dataclasses.replace(
        res.baselines[0], cuts=(-1, 10 ** 6)[:len(res.baselines[0].cuts)])
    patched = ExplorationResult(
        schedule=res.schedule, candidates=res.candidates, all_evals=[],
        pareto=[weird], selected=weird, baselines=res.baselines,
        objectives=res.objectives)
    assert "-" in patched.summary()
    assert patched.to_report()["selected_layers"] == ["-"] * len(weird.cuts)


def test_infeasible_everything_still_returns_result():
    """Absurd constraints: no feasible cut, baselines infeasible — the
    result must still materialize (pool falls back to baselines)."""
    g = LayerGraph(name="chain")
    g.chain([L.conv_layer(f"conv{i}", 8, 8, (8, 8), 3) for i in range(5)])
    system = SystemSpec(
        platforms=(PlatformSpec("A", "eyr", bits=16, mem_capacity=10),
                   PlatformSpec("B", "smb", bits=8, mem_capacity=10)),
        links=("gige",)).build()
    res = explore_graph(g, system, objectives=("latency", "energy"),
                        constraints=Constraints(max_link_bytes=1))
    assert res.candidates == []
    assert res.summary()          # must not raise
    assert res.selected is not None   # least-bad baseline still picked


# -- deprecation shim ---------------------------------------------------------

def test_explorer_shim_warns_and_delegates(squeezenet_objects):
    graph, system = squeezenet_objects
    from repro.core import Explorer
    with pytest.warns(DeprecationWarning, match="repro.explore"):
        ex = Explorer(graph, system, objectives=("latency", "energy"))
    res = ex.run(seed=0)
    assert isinstance(res, ExplorationResult)
    assert ex.candidate_cuts() == res.candidates
    # shim filters agree with the new filter pipeline
    assert ex._memory_filter(list(range(len(ex.schedule) - 1)))
