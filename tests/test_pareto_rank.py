"""Tiled Pareto-ranking primitives (``repro.kernels.pareto_rank`` /
``kernels.ops``) vs the dense ``nsga2_jax.domination_matrix`` oracle, the
blocked ``nondominated_rank`` path vs the dense peel (bit-exact, incl. caps,
ragged sizes, all-infeasible rows, duplicated objective vectors), the
vmapped multi-restart runner vs per-seed sequential runs, and the
``shard_map``-sharded tile grid on a forced multi-device host."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import nsga2_jax  # noqa: E402
from repro.core.nsga2 import pareto_indices  # noqa: E402
from repro.kernels import ops  # noqa: E402

IMPLS = ("ref", "pallas")
# deliberately ragged vs the 32/64-row tiles used below
SIZES = (33, 97, 130)


def population(n, m=3, infeas=0.3, dup=False, seed=0):
    rng = np.random.default_rng(seed)
    F = rng.random((n, m)).astype(np.float32)
    if dup:                      # duplicated objective vectors share fronts
        F[n // 2:] = F[rng.integers(0, n // 2, n - n // 2)]
    CV = np.where(rng.random(n) < infeas, (rng.random(n) * 3).round(1),
                  0.0).astype(np.float32)
    return jnp.asarray(F), jnp.asarray(CV)


def dense_packed(F, CV):
    return np.asarray(nsga2_jax._pack_bits(
        nsga2_jax.domination_matrix(F, CV)))


# -- packed words / counts vs the dense oracle --------------------------------

@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("n", SIZES)
def test_packed_domination_matches_dense(impl, n):
    F, CV = population(n, dup=True, seed=n)
    want = dense_packed(F, CV)
    got = np.asarray(ops.packed_domination(F, CV, block=32, impl=impl))
    assert got.shape == want.shape
    assert (got == want).all()


@pytest.mark.parametrize("impl", IMPLS)
def test_packed_domination_all_infeasible(impl):
    rng = np.random.default_rng(9)
    CV = jnp.asarray((rng.random(97) * 2 + 0.1).round(1), jnp.float32)
    F = jnp.asarray(rng.random((97, 2)), jnp.float32)
    want = dense_packed(F, CV)
    got = np.asarray(ops.packed_domination(F, CV, block=64, impl=impl))
    assert (got == want).all()


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("n", SIZES)
def test_domination_counts_match_dense(impl, n):
    F, CV = population(n, seed=n + 1)
    D = np.asarray(nsga2_jax.domination_matrix(F, CV))
    got = np.asarray(ops.domination_counts(F, CV, block=32, impl=impl))
    assert (got == D.sum(axis=0)).all()
    alive = jnp.asarray(np.random.default_rng(n).random(n) < 0.5)
    got_alive = np.asarray(
        ops.domination_counts(F, CV, alive, block=32, impl=impl))
    assert (got_alive == D[np.asarray(alive)].sum(axis=0)).all()


# -- blocked rank vs the dense peel -------------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("infeas", (0.0, 0.5, 1.0))
def test_blocked_rank_bit_exact(impl, n, infeas):
    F, CV = population(n, infeas=infeas, dup=True, seed=n)
    for cap in (None, n // 3, n):
        want = np.asarray(nsga2_jax.nondominated_rank(F, CV, cap=cap))
        got = np.asarray(nsga2_jax.nondominated_rank(
            F, CV, cap=cap, rank_block=64, rank_impl=impl))
        assert (got == want).all(), (impl, n, infeas, cap)


def test_blocked_rank_duplicate_cv_groups():
    """Equal-CV infeasible individuals must land in one shared front (the
    closed-form group ranking), exactly as the dense peel assigns them."""
    F = jnp.asarray(np.random.default_rng(0).random((40, 2)), jnp.float32)
    CV = jnp.asarray(np.tile([0.0, 0.5, 0.5, 1.5], 10), jnp.float32)
    want = np.asarray(nsga2_jax.nondominated_rank(F, CV))
    got = np.asarray(nsga2_jax.nondominated_rank(F, CV, rank_block=32))
    assert (got == want).all()


def test_blocked_runner_equals_dense_runner():
    """The whole compiled generation loop is bit-identical whichever
    ranking primitive it consumes."""
    def eval_fn(X):
        f1 = jnp.sum(X, axis=1).astype(jnp.float32)
        f2 = jnp.sum((X - 12) ** 2, axis=1).astype(jnp.float32)
        cv = jnp.maximum(0.0, 9.0 - X[:, 0]).astype(jnp.float32)
        return jnp.stack([f1, f2], axis=1), cv

    args = dict(n_var=3, lower=0, upper=30, pop_size=48, n_gen=8, seed=3)
    dense = nsga2_jax.jit_nsga2(
        eval_fn, runner=nsga2_jax.make_jit_runner(
            eval_fn, 3, 0, 30, 48, rank_block=0), **args)
    blocked = nsga2_jax.jit_nsga2(
        eval_fn, runner=nsga2_jax.make_jit_runner(
            eval_fn, 3, 0, 30, 48, rank_block=32), **args)
    for a, b in zip(dense, blocked):
        assert (a == b).all()


def test_pareto_indices_blocked_matches_dense():
    rng = np.random.default_rng(4)
    X = rng.integers(0, 6, size=(200, 3))
    F = rng.random((200, 2))
    F[50:100] = F[:50]                       # duplicate decision ties
    CV = np.where(rng.random(200) < 0.4, rng.random(200), 0.0)
    want = pareto_indices(X, F, CV)
    got = nsga2_jax.pareto_indices_blocked(X, F, CV, block=64)
    assert (got == want).all()


# -- env-forced dispatch (the CI kernel-interpret leg) ------------------------

def test_rank_impl_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_RANK_IMPL", "pallas")
    assert ops.resolve_rank_impl("auto") == "pallas"
    # explicit impls are never overridden
    assert ops.resolve_rank_impl("ref") == "ref"
    monkeypatch.delenv("REPRO_RANK_IMPL")
    assert ops.resolve_rank_impl("ref") == "ref"
    with pytest.raises(ValueError, match="rank impl"):
        ops.resolve_rank_impl("mosaic")


def test_rank_impl_invalid_env_raises(monkeypatch):
    """A typo'd REPRO_RANK_IMPL must fail loudly at dispatch, naming the
    variable and the valid choices — not silently fall through to some
    branch (the CI kernel-interpret leg depends on the env actually
    taking effect)."""
    monkeypatch.setenv("REPRO_RANK_IMPL", "palas")
    with pytest.raises(ValueError) as ei:
        ops.resolve_rank_impl("auto")
    msg = str(ei.value)
    assert "REPRO_RANK_IMPL" in msg and "'palas'" in msg
    for choice in ("auto", "ref", "pallas"):
        assert choice in msg
    # explicit non-auto impls bypass the env entirely, even a broken one
    assert ops.resolve_rank_impl("ref") == "ref"


def test_resolve_impl_rejects_unknown():
    """resolve_impl used to return unknown impl strings unchanged, sending
    e.g. quant_matmul(impl='bogus') down the Pallas branch; it must raise
    and list the valid choices instead."""
    with pytest.raises(ValueError, match="valid choices"):
        ops.resolve_impl("bogus")
    assert ops.resolve_impl("ref") == "ref"
    assert ops.resolve_impl("pallas") == "pallas"
    assert ops.resolve_impl("auto") in ("ref", "pallas")


# -- multi-restart runner -----------------------------------------------------

def _toy_eval(X):
    f1 = jnp.sum(X, axis=1).astype(jnp.float32)
    f2 = jnp.sum((X - 20) ** 2, axis=1).astype(jnp.float32)
    cv = jnp.maximum(0.0, 15.0 - X[:, 0]).astype(jnp.float32)
    return jnp.stack([f1, f2], axis=1), cv


def test_restarts_bit_identical_to_sequential_seeds():
    R, pop, n_gen, seed = 3, 48, 10, 7
    Xr, Fr, CVr = nsga2_jax.jit_nsga2_restarts(
        _toy_eval, 3, 0, 40, pop, n_gen, R, seed=seed)
    assert Xr.shape == (R * pop, 3)
    for i in range(R):
        Xi, Fi, CVi = nsga2_jax.jit_nsga2(
            _toy_eval, 3, 0, 40, pop, n_gen, seed=seed + i)
        sl = slice(i * pop, (i + 1) * pop)
        assert (Xr[sl] == Xi).all()
        assert (Fr[sl] == Fi).all()
        assert (CVr[sl] == CVi).all()


def test_restart_front_equals_union_of_seed_fronts():
    """Non-dominated filtering of the merged restart output == filtering
    the union of the per-seed sequential fronts."""
    R, pop, n_gen, seed = 3, 48, 10, 7
    Xr, Fr, CVr = nsga2_jax.jit_nsga2_restarts(
        _toy_eval, 3, 0, 40, pop, n_gen, R, seed=seed)
    merged = Xr[pareto_indices(Xr, Fr, CVr)]

    union_X, union_F, union_CV = [], [], []
    for i in range(R):
        Xi, Fi, CVi = nsga2_jax.jit_nsga2(
            _toy_eval, 3, 0, 40, pop, n_gen, seed=seed + i)
        idx = pareto_indices(Xi, Fi, CVi)
        union_X.append(Xi[idx])
        union_F.append(Fi[idx])
        union_CV.append(CVi[idx])
    uX = np.concatenate(union_X)
    uF = np.concatenate(union_F)
    uCV = np.concatenate(union_CV)
    want = uX[pareto_indices(uX, uF, uCV)]
    assert ({tuple(r) for r in merged} == {tuple(r) for r in want})


def test_restart_candidate_seeding_matches_single():
    cands = [[1, 2, 3], [4, 5, 6], [0, 9, 9]]
    Xr, _, _ = nsga2_jax.jit_nsga2_restarts(
        _toy_eval, 3, 0, 40, 32, 4, 2, seed=1, candidates=cands)
    X0, _, _ = nsga2_jax.jit_nsga2(
        _toy_eval, 3, 0, 40, 32, 4, seed=1, candidates=cands)
    assert (Xr[:32] == X0).all()


# -- sharded tile grid (forced multi-device host) -----------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_sharded_rank_matches_dense_multidev():
    """packed_domination sharded over 4 forced host devices — and the full
    blocked rank consuming it under jit — agree with the dense path."""
    code = """
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import nsga2_jax as J
        from repro.kernels import ops

        assert len(jax.devices()) == 4
        mesh = Mesh(np.asarray(jax.devices()), ("rank",))
        rng = np.random.default_rng(3)
        for n in (97, 130):
            F = jnp.asarray(rng.random((n, 3)), jnp.float32)
            CV = jnp.asarray(np.where(rng.random(n) < 0.3,
                                      rng.random(n), 0.0), jnp.float32)
            dense = np.asarray(J._pack_bits(J.domination_matrix(F, CV)))
            got = np.asarray(ops.packed_domination(F, CV, block=32,
                                                   impl="ref", mesh=mesh))
            assert (got == dense).all(), n
            fn = jax.jit(lambda f, c: J.nondominated_rank(
                f, c, rank_block=32, rank_impl="ref", mesh=mesh))
            assert (np.asarray(fn(F, CV))
                    == np.asarray(J.nondominated_rank(F, CV))).all(), n
        print("MULTIDEV_OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=520,
                         env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "MULTIDEV_OK" in out.stdout
