"""End-to-end system behaviour: the full Fig. 1 flow on a real CNN and the
paper's headline effects at the system level."""

import pytest

from repro.core import (Constraints, Explorer, Platform, QuantSpec,
    SystemConfig, get_link)
from repro.core.hwmodel import EYERISS_LIKE, SIMBA_LIKE
from repro.models.cnn.zoo import build_cnn


@pytest.fixture(scope="module")
def effnet_exploration():
    graph = build_cnn("efficientnet_b0").to_graph()
    system = SystemConfig(
        [Platform("A", EYERISS_LIKE, QuantSpec(bits=16)),
         Platform("B", SIMBA_LIKE, QuantSpec(bits=8))],
        [get_link("gige")])
    ex = Explorer(graph, system,
                  objectives=("latency", "energy", "throughput", "accuracy"))
    return ex, ex.run(seed=0)


def test_partitioning_increases_throughput(effnet_exploration):
    """The paper's headline: EfficientNet-B0 partitioned onto two platforms
    gains large throughput over either platform alone (paper: +47.5 %)."""
    ex, res = effnet_exploration
    best_single = max(b.throughput for b in res.baselines)
    best_cut = max(e.throughput for e in res.all_evals)
    assert best_cut > 1.25 * best_single


def test_accuracy_rises_with_later_cut(effnet_exploration):
    """Fig. 2(f): later cut = more layers on the 16-bit platform = higher
    top-1 (proxy oracle here; measured oracle in benchmarks)."""
    ex, res = effnet_exploration
    pts = sorted((e.cuts[0], e.accuracy) for e in res.all_evals)
    assert pts[-1][1] > pts[0][1]
    ups = sum(1 for (p1, a1), (p2, a2) in zip(pts, pts[1:]) if a2 >= a1 - 1e-9)
    assert ups / (len(pts) - 1) > 0.9


def test_pareto_selected_feasible(effnet_exploration):
    ex, res = effnet_exploration
    assert res.selected.violation <= 0
    assert len(res.pareto) >= 3


def test_constrained_exploration_respects_accuracy_floor():
    graph = build_cnn("squeezenet11", in_hw=64).to_graph()
    system = SystemConfig(
        [Platform("A", EYERISS_LIKE, QuantSpec(bits=16)),
         Platform("B", SIMBA_LIKE, QuantSpec(bits=8))],
        [get_link("gige")])
    ex = Explorer(graph, system, objectives=("latency", "energy"),
                  constraints=Constraints(min_accuracy=0.9))
    res = ex.run(seed=0)
    assert res.selected.accuracy >= 0.9


def test_full_lm_graph_flow():
    """An assigned-architecture graph goes through the same machinery."""
    from repro.models.registry import get_config, build_model
    import dataclasses
    from repro.core.hwmodel.arch import TPU_V5E
    cfg = get_config("qwen3-14b")
    graph = build_model(cfg).to_graph(seq=1024)
    pod = Platform("pod", dataclasses.replace(
        TPU_V5E, mem_bytes=256 * 16 * 2 ** 30), QuantSpec(bits=16))
    system = SystemConfig([pod, pod], [get_link("dci")])
    ex = Explorer(graph, system, objectives=("latency", "throughput"))
    res = ex.run(seed=0)
    # balanced split expected for identical pods
    cut_layer = res.selected.cuts[0]
    assert abs(cut_layer - len(res.schedule) // 2) <= len(res.schedule) // 6
    assert res.selected.throughput > res.baselines[0].throughput * 1.5
