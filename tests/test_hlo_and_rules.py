"""HLO loop-aware analysis + sharding-rule unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.hlo_analysis import analyze_text
from repro.launch.rules import param_spec, _divides
from repro.nn.sharding import logical_to_spec, DEFAULT_RULES


def test_scan_trip_count_multiplies_flops():
    def f_scan(x, w):
        y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=7)
        return y

    def f_single(x, w):
        return x @ w

    x = jnp.ones((64, 64))
    w = jnp.ones((64, 64))
    t_scan = jax.jit(f_scan).lower(x, w).compile().as_text()
    t_one = jax.jit(f_single).lower(x, w).compile().as_text()
    f1 = analyze_text(t_one).flops
    f7 = analyze_text(t_scan).flops
    assert f1 == pytest.approx(2 * 64 ** 3, rel=0.01)
    assert f7 == pytest.approx(7 * f1, rel=0.05)


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jnp.ones((32, 32))
    w = jnp.ones((32, 32))
    text = jax.jit(f).lower(x, w).compile().as_text()
    flops = analyze_text(text).flops
    assert flops == pytest.approx(15 * 2 * 32 ** 3, rel=0.05)


def test_dot_flops_batched():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    a = jnp.ones((4, 16, 32))
    b = jnp.ones((4, 32, 8))
    text = jax.jit(f).lower(a, b).compile().as_text()
    assert analyze_text(text).flops == pytest.approx(2 * 4 * 16 * 32 * 8,
                                                     rel=0.01)


def test_param_spec_paths():
    assert param_spec("blocks_dense/attn/wq", 3) == P(None, "data", "model")
    assert param_spec("blocks_dense/moe/w_gate", 4) == P(None, "model",
                                                         "data", None)
    assert param_spec("embed", 2) == P("model", "data")
    assert param_spec("blocks_dense/ln1", 2) == P(None, None)
    # hybrid double-stacked (group, layer, d, proj)
    assert param_spec("blocks/mixer/w_in", 4, hybrid=True) == \
        P(None, None, "data", "model")
    # shared (unstacked) block params have no layer axis
    assert param_spec("shared/attn/wq", 2) == P("data", "model")
    # dense mlp stacked (L, d, ff) vs moe experts stacked (L, E, d, ff)
    assert param_spec("blocks_dense/mlp/w_gate", 3) == P(None, "data", "model")
    assert param_spec("blocks_moe/moe/w_down", 4) == P(None, "model", None,
                                                       "data")


def test_divides_clears_nondivisible():
    devs = np.array(jax.devices()[:1] * 1).reshape(1, 1)  # fake 1x1 mesh
    mesh = Mesh(devs, ("data", "model"))
    spec = _divides((10, 10), P("data", "model"), mesh)
    assert spec == P("data", "model")   # 1 divides everything


def test_logical_to_spec_dedup():
    rules = dict(DEFAULT_RULES, batch=("pod", "data"), embed="data")
    spec = logical_to_spec(("batch", "seq", "embed"), rules)
    # 'data' already used by batch -> cleared from embed
    assert spec[0] == ("pod", "data")
    assert spec[2] is None
