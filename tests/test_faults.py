"""Fault tolerance (`repro.serve.faults` / `.health` + router failover):
fault-plan determinism, missed-heartbeat failure detection without false
positives, hysteresis that refuses to thrash on transient spikes, and the
headline invariant — a replica crash mid-stream loses zero requests and
the recovered requests' greedy tokens are byte-identical to a no-fault
run."""

import threading
import time

import jax
import numpy as np
import pytest

from repro.core.link import LinkModel
from repro.explore import PlatformSpec, SystemSpec, degrade_link
from repro.models.registry import build_model, get_config
from repro.serve import (DivergenceMonitor, FailureDetector, FaultPlan,
                         FaultTrace, HealthMonitor, LinkDegrade,
                         PipelineServeEngine, ReplicaCrash, ReplicaCrashError,
                         ReplicaRouter, Request, ServeLink, StageStall,
                         poisson_traffic, stream_of)
from repro.serving.pipeline import PartitionedLMRunner


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def runner(lm):
    cfg, model, params = lm
    return PartitionedLMRunner(model, params, cuts=[0])


def _burst(reqs, deadline_s=None):
    return [Request(r.rid, r.prompt, r.max_new, 0.0, deadline_s=deadline_s)
            for r in reqs]


def _traffic(cfg, n=8, max_new=5, seed=2):
    return poisson_traffic(n, rate_rps=1000.0, vocab=cfg.vocab,
                           prompt_len=6, max_new=max_new, seed=seed)


@pytest.fixture(scope="module")
def ref_tokens(runner, lm):
    """Greedy tokens of the shared traffic on a clean single replica —
    the byte-identity reference for every failover test."""
    cfg, *_ = lm
    eng = PipelineServeEngine(runner, n_slots=4, eos=None, mode="async",
                              capacity=32, name="ref")
    eng.warmup(prompt_len=6)
    rep = eng.run(stream_of(_burst(_traffic(cfg))))
    assert rep.n_done == 8 and rep.n_failed == 0
    return {r.rid: list(r.tokens) for r in rep.records}


# -- FaultPlan: pure, validated, deterministic --------------------------------

def test_fault_plan_lookups_and_validation():
    plan = FaultPlan(events=(LinkDegrade(0, 4.0, at_transfer=2,
                                         until_transfer=6),
                             LinkDegrade(0, 2.0, at_transfer=5),
                             StageStall(1, 0.25, at_item=3),
                             StageStall(1, 0.5, at_item=3),
                             ReplicaCrash(at_step=7)))
    assert [plan.link_factor(0, k) for k in range(8)] == \
           [1.0, 1.0, 4.0, 4.0, 4.0, 8.0, 2.0, 2.0]   # windows compound
    assert plan.link_factor(1, 3) == 1.0              # other links healthy
    assert plan.stage_stall_s(1, 3) == 0.75           # stalls sum
    assert plan.stage_stall_s(1, 2) == 0.0
    assert plan.crash_step == 7
    assert FaultPlan().crash_step is None

    with pytest.raises(ValueError, match="factor"):
        LinkDegrade(0, 0.0)
    with pytest.raises(ValueError, match="stall_s"):
        StageStall(0, -1.0)
    with pytest.raises(ValueError, match="at_step"):
        ReplicaCrash(-1)
    with pytest.raises(ValueError, match="at most one"):
        FaultPlan(events=(ReplicaCrash(1), ReplicaCrash(2)))
    with pytest.raises(TypeError, match="unknown fault event"):
        FaultPlan(events=("not-an-event",))


def test_fault_plan_jitter_deterministic_per_seed():
    a = FaultPlan(link_jitter_s=0.01, seed=9)
    b = FaultPlan(link_jitter_s=0.01, seed=9)
    other = FaultPlan(link_jitter_s=0.01, seed=10)
    draws = [a.link_jitter(0, k) for k in range(32)]
    assert draws == [b.link_jitter(0, k) for k in range(32)]
    assert all(0.0 <= j < 0.01 for j in draws)
    assert draws != [other.link_jitter(0, k) for k in range(32)]
    assert a.link_jitter(0, 3) != a.link_jitter(1, 3)  # per-link streams
    assert FaultPlan().link_jitter(0, 3) == 0.0


def test_injected_trace_and_tokens_reproducible(runner, lm):
    """Two runs of the same plan over the same traffic apply the identical
    fault sequence (canonical trace) and decode identical tokens."""
    cfg, *_ = lm

    def one():
        plan = FaultPlan(events=(LinkDegrade(0, 3.0, at_transfer=2,
                                             until_transfer=9),
                                 StageStall(1, 0.01, at_item=4)),
                         link_jitter_s=0.001, seed=11)
        eng = PipelineServeEngine(runner, n_slots=4, eos=None, mode="async",
                                  capacity=32, faults=plan)
        eng.warmup(prompt_len=6)
        rep = eng.run(stream_of(_burst(_traffic(cfg, n=4, max_new=4))))
        assert rep.n_done == 4
        return (eng.fault_trace.canonical(),
                {r.rid: list(r.tokens) for r in rep.records})
    trace1, toks1 = one()
    trace2, toks2 = one()
    assert len(trace1) > 0
    assert trace1 == trace2
    assert toks1 == toks2
    kinds = {e[0] for e in trace1}
    assert {"link_degrade", "link_jitter", "stage_stall"} <= kinds


def test_fault_trace_canonical_sorts_interleavings():
    t1, t2 = FaultTrace(), FaultTrace()
    t1.record("link_degrade", 0, 0, 2.0)
    t1.record("link_degrade", 0, 1, 2.0)
    t2.record("link_degrade", 0, 1, 2.0)   # reversed arrival order
    t2.record("link_degrade", 0, 0, 2.0)
    assert t1.entries != t2.entries
    assert t1.canonical() == t2.canonical()
    assert len(t1) == 2


# -- failure detector ---------------------------------------------------------

def _serve_probing_detector(runner, cfg, plan, timeout_s):
    """Serve a small burst while a probe thread samples the failure
    detector; returns the set of stages ever reported stalled."""
    health = HealthMonitor(runner.n_stages, runner.n_stages - 1)
    eng = PipelineServeEngine(runner, n_slots=4, eos=None, mode="async",
                              capacity=32, faults=plan, health=health)
    eng.warmup(prompt_len=6)
    fd = FailureDetector(health, timeout_s=timeout_s)
    seen, stop = set(), threading.Event()

    def probe():
        while not stop.is_set():
            seen.update(fd.stalled())
            time.sleep(0.01)

    th = threading.Thread(target=probe, daemon=True)
    th.start()
    rep = eng.run(stream_of(_burst(_traffic(cfg, n=4, max_new=4))))
    stop.set()
    th.join(timeout=2.0)
    assert rep.n_done == 4
    return seen


def test_failure_detector_no_false_positive_on_clean_run(runner, lm):
    """Idle workers heartbeat on every queue poll, so a healthy run never
    trips the detector — even while workers sit idle between waves."""
    cfg, *_ = lm
    seen = _serve_probing_detector(runner, cfg, FaultPlan(), timeout_s=0.75)
    assert seen == set()


def test_failure_detector_catches_stalled_stage(runner, lm):
    """A worker stuck inside a stalled stage call stops heartbeating and
    is reported; the run still completes once the stall clears."""
    cfg, *_ = lm
    plan = FaultPlan(events=(StageStall(1, 2.0, at_item=2),))
    seen = _serve_probing_detector(runner, cfg, plan, timeout_s=0.6)
    assert 1 in seen


def test_failure_detector_validation():
    hm = HealthMonitor(2, 1)
    with pytest.raises(ValueError, match="timeout_s"):
        FailureDetector(hm, timeout_s=0.0)
    fd = FailureDetector(hm, timeout_s=1.0)
    assert fd.stalled(now=100.0) == []        # never-heartbeat = not stalled
    hm.heartbeat(0, 10.0)
    assert fd.stalled(now=10.5) == []
    assert fd.stalled(now=12.0) == [0]
    assert not fd.healthy(now=12.0)


# -- health estimators --------------------------------------------------------

def test_health_monitor_divergence_and_rate():
    hm = HealthMonitor(2, 1, alpha=1.0)       # alpha=1: value = last sample
    assert hm.link_divergence(0) == 1.0       # no samples -> "as deployed"
    assert hm.link_rate_bps(0) == 0.0
    hm.record_link(0, nbytes=1000, measured_s=4e-3, model_s=1e-3)
    assert hm.link_divergence(0) == pytest.approx(4.0)
    assert hm.link_rate_bps(0) == pytest.approx(1000 * 8 / 4e-3)
    assert hm.link_samples(0) == 1
    hm.record_stage(1, 0.25, now=5.0)
    assert hm.stage_occupancy_s(1) == pytest.approx(0.25)
    assert hm.last_heartbeat(1) == 5.0
    snap = hm.snapshot()
    assert snap["link_divergence"] == [4.0]
    with pytest.raises(ValueError):
        HealthMonitor(0, 1)


# -- hysteresis ---------------------------------------------------------------

TWO_NODE = SystemSpec(platforms=(PlatformSpec("A", "eyr", bits=16),
                                 PlatformSpec("B", "smb", bits=8)),
                      links=("gige",), name="AB")


def _feed(hm, ratio):
    hm.record_link(0, nbytes=1000, measured_s=ratio * 1e-3, model_s=1e-3)


def test_hysteresis_transient_spike_never_fires():
    """min_breach consecutive observations are required: a 2-observation
    spike at 5x divergence does not trigger a re-partition."""
    hm = HealthMonitor(1, 1, alpha=1.0)
    dm = DivergenceMonitor(TWO_NODE, enter=2.0, exit=1.2, min_breach=3,
                           cooldown_s=10.0, min_samples=1)
    for t, ratio in enumerate([5.0, 5.0, 1.0, 5.0, 5.0, 1.0]):
        _feed(hm, ratio)
        assert dm.observe(hm, now=float(t)) is None
    assert dm.signals == [] and dm.alarmed_links == []
    assert dm.drifted_system() == TWO_NODE


def test_hysteresis_sustained_breach_fires_once_then_latches():
    hm = HealthMonitor(1, 1, alpha=1.0)
    dm = DivergenceMonitor(TWO_NODE, enter=2.0, exit=1.2, min_breach=3,
                           cooldown_s=10.0, min_samples=1)
    fired = []
    for t in range(3):
        _feed(hm, 5.0)
        fired.append(dm.observe(hm, now=float(t)))
    assert fired[:2] == [None, None]
    sig = fired[2]
    assert sig is not None and sig.link == 0
    assert sig.divergence == pytest.approx(5.0)
    assert dm.alarmed_links == [0]
    # latched: hovering above `enter` does not re-fire
    _feed(hm, 5.0)
    assert dm.observe(hm, now=3.0) is None
    assert len(dm.signals) == 1
    # the drifted snapshot degrades the alarmed link by measured divergence
    assert dm.drifted_system() == degrade_link(TWO_NODE, 0, 5.0)
    # recovery below `exit` re-arms and clears the drifted snapshot
    _feed(hm, 1.0)
    assert dm.observe(hm, now=4.0) is None
    assert dm.alarmed_links == []
    assert dm.drifted_system() == TWO_NODE


def test_hysteresis_cooldown_rate_limits_refires():
    hm = HealthMonitor(1, 1, alpha=1.0)
    dm = DivergenceMonitor(TWO_NODE, enter=2.0, exit=1.2, min_breach=3,
                           cooldown_s=10.0, min_samples=1)
    for t in range(3):
        _feed(hm, 5.0)
        dm.observe(hm, now=float(t))
    assert len(dm.signals) == 1               # fired at t=2
    _feed(hm, 1.0)
    dm.observe(hm, now=3.0)                   # recovered: re-armed
    for t in (4.0, 5.0, 6.0, 7.0):            # breaches inside the cooldown
        _feed(hm, 5.0)
        assert dm.observe(hm, now=t) is None
    _feed(hm, 5.0)
    sig = dm.observe(hm, now=13.0)            # cooldown (2 + 10s) elapsed
    assert sig is not None
    assert len(dm.signals) == 2


def test_divergence_monitor_warmup_and_validation():
    hm = HealthMonitor(1, 1, alpha=1.0)
    dm = DivergenceMonitor(TWO_NODE, enter=2.0, exit=1.2, min_breach=1,
                           cooldown_s=0.0, min_samples=4)
    for t in range(3):                        # estimator still warming up
        _feed(hm, 50.0)
        assert dm.observe(hm, now=float(t)) is None
    _feed(hm, 50.0)                           # 4th sample: gate opens
    assert dm.observe(hm, now=3.0) is not None
    with pytest.raises(ValueError, match="enter > exit"):
        DivergenceMonitor(TWO_NODE, enter=1.2, exit=1.2)
    with pytest.raises(ValueError, match="min_breach"):
        DivergenceMonitor(TWO_NODE, min_breach=0)
    # rebase resets alarms against the re-deployed spec
    dm.rebase(degrade_link(TWO_NODE, 0, 50.0))
    assert dm.alarmed_links == [] and len(dm.signals) == 1


def test_cooldown_expiry_still_requires_fresh_breaches():
    """The cooldown gates *when* a fire may happen, never substitutes for
    the breach count: after the cooldown expires, a dip below `enter`
    resets the counter and min_breach fresh consecutive breaches are
    needed before the re-fire."""
    hm = HealthMonitor(1, 1, alpha=1.0)
    dm = DivergenceMonitor(TWO_NODE, enter=2.0, exit=1.2, min_breach=3,
                           cooldown_s=5.0, min_samples=1)
    for t in range(3):
        _feed(hm, 5.0)
        dm.observe(hm, now=float(t))
    assert len(dm.signals) == 1                   # fired at t=2
    _feed(hm, 1.0)
    dm.observe(hm, now=3.0)                       # recovered: re-armed
    _feed(hm, 1.0)
    dm.observe(hm, now=20.0)                      # cooldown long expired...
    _feed(hm, 5.0)
    assert dm.observe(hm, now=21.0) is None       # ...but breaches 1/3
    _feed(hm, 5.0)
    assert dm.observe(hm, now=22.0) is None       # 2/3
    _feed(hm, 5.0)
    sig = dm.observe(hm, now=23.0)                # 3/3: fresh fire
    assert sig is not None and len(dm.signals) == 2


def test_rebase_clears_cooldown_and_breach_state():
    """After acting on a signal the monitor is rebased onto the new
    deployment: the cooldown clock and any half-accumulated breach count
    must not leak into the new spec's epoch."""
    hm = HealthMonitor(1, 1, alpha=1.0)
    dm = DivergenceMonitor(TWO_NODE, enter=2.0, exit=1.2, min_breach=2,
                           cooldown_s=1000.0, min_samples=1)
    for t in range(2):
        _feed(hm, 6.0)
        dm.observe(hm, now=float(t))
    assert len(dm.signals) == 1
    dm.rebase(TWO_NODE)
    assert dm.alarmed_links == []
    # a fire right after rebase: the old cooldown would block until
    # t=1001, the old alarm latch would swallow it entirely
    for t in (2.0, 3.0):
        _feed(hm, 6.0)
        sig = dm.observe(hm, now=t)
    assert sig is not None and len(dm.signals) == 2


def test_observe_records_divergence_history():
    """Every observation lands in `history` as (t, per-link divergence) —
    the measured-vs-modeled series the drift timeline artifact persists —
    whether or not anything fired."""
    hm = HealthMonitor(1, 1, alpha=1.0)
    dm = DivergenceMonitor(TWO_NODE, enter=2.0, exit=1.2, min_breach=3,
                           cooldown_s=10.0, min_samples=1)
    assert list(dm.history) == []
    for t, ratio in enumerate([1.0, 5.0, 1.0]):
        _feed(hm, ratio)
        dm.observe(hm, now=float(t))
    assert [t for t, _ in dm.history] == [0.0, 1.0, 2.0]
    assert dm.history[1][1][0] == pytest.approx(5.0)
    assert all(len(divs) == 1 for _, divs in dm.history)


def test_ewma_first_sample_is_raw():
    """The first sample becomes the value verbatim — no (1-alpha) pull
    toward a phantom zero start — so a single link transfer already
    yields its exact measured/model divergence."""
    from repro.serve.health import Ewma
    e = Ewma(alpha=0.25)
    assert e.value == 0.0 and e.n == 0            # empty: explicit zero
    assert e.update(4.0) == pytest.approx(4.0)    # raw, not 0.75*0+0.25*4
    assert e.n == 1
    assert e.update(8.0) == pytest.approx(0.75 * 4.0 + 0.25 * 8.0)
    # HealthMonitor inherits it: one transfer -> exact divergence even
    # with smoothing enabled
    hm = HealthMonitor(1, 1, alpha=0.25)
    hm.record_link(0, nbytes=100, measured_s=4e-3, model_s=1e-3)
    assert hm.link_divergence(0) == pytest.approx(4.0)


# -- replica crash + router failover ------------------------------------------

def test_engine_crash_stashes_done_records(runner, lm):
    """The engine's failure path leaves completed records in
    ``crash_records`` so the router can salvage them and re-admit only
    the unfinished requests."""
    cfg, *_ = lm
    reqs = _traffic(cfg, n=3, max_new=2)
    eng = PipelineServeEngine(runner, n_slots=2, n_groups=1, eos=None,
                              mode="serial", capacity=32,
                              faults=FaultPlan(events=(ReplicaCrash(1),)),
                              name="crashy")
    eng.warmup(prompt_len=6)
    with pytest.raises(ReplicaCrashError) as ei:
        eng.run(stream_of(_burst(reqs)))
    assert ei.value.replica == "crashy" and ei.value.step >= 1
    assert "injected crash" in str(ei.value)
    # the first decode wave finished requests 0 and 1 (max_new=2); both
    # must be salvageable, request 2 stays stranded for the router
    assert set(eng.crash_records) == {0, 1}
    assert all(rec.done for rec in eng.crash_records.values())
    trace = eng.fault_trace.canonical()
    assert ("replica_crash", 0, ei.value.step) in trace


def test_router_failover_zero_loss_token_identity(runner, lm, ref_tokens):
    """The headline invariant: a replica crash mid-stream loses zero
    requests, and every recovered request's greedy tokens are
    byte-identical to the no-fault run."""
    cfg, *_ = lm
    slow = LinkModel(name="slow", rate_bps=1e9, t_setup_s=0.02)
    crashy = PipelineServeEngine(
        runner, n_slots=2, n_groups=1, eos=None, mode="async", capacity=32,
        links=[ServeLink(model=slow) for _ in range(runner.n_stages - 1)],
        faults=FaultPlan(events=(ReplicaCrash(at_step=2),)), name="crashy")
    survivor = PipelineServeEngine(runner, n_slots=4, eos=None, mode="async",
                                   capacity=32, name="survivor")
    for e in (crashy, survivor):
        e.warmup(prompt_len=6)
    router = ReplicaRouter([crashy, survivor])
    rep = router.serve(_burst(_traffic(cfg)), realtime=False)

    assert rep.extra["n_replica_failures"] == 1
    assert rep.extra["requests_recovered"] >= 1
    assert "recovery_ms" in rep.extra and rep.extra["recovery_ms"] >= 0.0
    assert rep.n_done == 8 and rep.n_failed == 0       # zero lost
    got = {r.rid: list(r.tokens) for r in rep.records}
    assert got == ref_tokens                           # byte-identical
    assert len(crashy.fault_trace) >= 1                # crash was recorded


def test_router_sheds_recovered_requests_past_deadline(runner, lm,
                                                       ref_tokens):
    """Failover honors deadlines: a recovered request whose deadline has
    already passed is recorded ``finish='shed'`` instead of wasting
    survivor capacity — and never silently dropped."""
    cfg, *_ = lm
    slow = LinkModel(name="slow", rate_bps=1e9, t_setup_s=0.02)
    crashy = PipelineServeEngine(
        runner, n_slots=2, n_groups=1, eos=None, mode="async", capacity=32,
        links=[ServeLink(model=slow) for _ in range(runner.n_stages - 1)],
        faults=FaultPlan(events=(ReplicaCrash(at_step=2),)), name="crashy")
    survivor = PipelineServeEngine(runner, n_slots=4, eos=None, mode="async",
                                   capacity=32, name="survivor")
    for e in (crashy, survivor):
        e.warmup(prompt_len=6)
    burst = _burst(_traffic(cfg), deadline_s=1e-4)     # already expired
    rep = ReplicaRouter([crashy, survivor]).serve(burst, realtime=False)

    assert rep.extra["n_replica_failures"] == 1
    assert rep.n_done + rep.n_failed == 8              # all accounted for
    assert rep.n_failed >= 1                           # crashy had >= 1
    shed = [r for r in rep.records if r.failed]
    assert all(r.finish == "shed" for r in shed)
    assert all(r.failed for r in shed) and shed[0].latency_s is None
    # requests that never touched the dead replica still match reference
    got = {r.rid: list(r.tokens) for r in rep.records if r.done}
    assert all(got[rid] == ref_tokens[rid] for rid in got)
    assert rep.summary()["n_failed"] == rep.n_failed


def test_router_retry_budget_marks_lost(runner, lm):
    """With ``max_retries=0`` a recovered request is recorded lost (never
    silently dropped) while untouched requests still complete."""
    cfg, *_ = lm
    slow = LinkModel(name="slow", rate_bps=1e9, t_setup_s=0.02)
    crashy = PipelineServeEngine(
        runner, n_slots=2, n_groups=1, eos=None, mode="async", capacity=32,
        links=[ServeLink(model=slow) for _ in range(runner.n_stages - 1)],
        faults=FaultPlan(events=(ReplicaCrash(at_step=2),)), name="crashy")
    survivor = PipelineServeEngine(runner, n_slots=4, eos=None, mode="async",
                                   capacity=32, name="survivor")
    for e in (crashy, survivor):
        e.warmup(prompt_len=6)
    router = ReplicaRouter([crashy, survivor], max_retries=0)
    rep = router.serve(_burst(_traffic(cfg)), realtime=False)
    assert rep.n_done + rep.n_failed == 8
    assert rep.n_failed >= 1
    assert all(r.finish == "lost" for r in rep.records if r.failed)
    with pytest.raises(ValueError, match="max_retries"):
        ReplicaRouter([survivor], max_retries=-1)
