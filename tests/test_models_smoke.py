"""Per-architecture smoke tests (assignment requirement): REDUCED variant of
each family — one forward + one train step on CPU, shape + finiteness."""

import jax
import jax.numpy as jnp
import pytest

from repro.data.synthetic import make_batch_for
from repro.models.registry import ARCH_IDS, build_model, get_config
from repro.optim.optimizers import get_optimizer
from repro.training.train_lib import make_train_step

B, T = 2, 32


def _batch(cfg, seed=0):
    return {k: jnp.asarray(v) for k, v in
            make_batch_for(cfg, B, T, seed=seed).items()}


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_forward_and_train_step(arch_id):
    cfg = get_config(arch_id).reduced()
    assert cfg.n_layers <= 6 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    model = build_model(cfg)
    params, state = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    logits, aux = model.apply(params, state, batch, train=False)
    t_total = T + (cfg.n_patches if cfg.family == "vlm" else 0)
    if cfg.family == "audio":
        assert logits.shape == (B, T, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (B, t_total, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in forward"

    opt = get_optimizer(cfg.optimizer, 1e-3)
    step = jax.jit(make_train_step(model, cfg, opt))
    opt_state = opt.init(params)
    params2, _, _, metrics = step(params, opt_state, state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), "NaN loss"
    # parameters actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_decode_step(arch_id):
    cfg = get_config(arch_id).reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    caches = model.init_caches(B, capacity=64, dtype=jnp.float32)
    if cfg.family == "audio":
        batch = {"codes": jnp.zeros((B, cfg.n_codebooks, 1), jnp.int32)}
    else:
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, caches2 = model.decode_step(params, caches, batch)
    assert bool(jnp.isfinite(logits).all())
    # cache position advanced
    leaves = [x for p, x in
              jax.tree_util.tree_flatten_with_path(caches2)[0]
              if "pos" in "/".join(str(k) for k in p)]
    assert all(int(l.max()) >= 1 for l in leaves)


@pytest.mark.parametrize("arch_id", ["smollm-360m", "mamba2-370m",
                                     "zamba2-2.7b", "qwen3-14b"])
def test_decode_matches_teacher_forcing(arch_id):
    """Prefill+decode logits == full forward logits (same tokens)."""
    cfg = get_config(arch_id).reduced()
    model = build_model(cfg)
    params, state = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 12), 0, cfg.vocab)
    full_logits, _ = model.apply(params, state, {"tokens": toks},
                                 train=False)
    caches = model.init_caches(B, capacity=32, dtype=jnp.float32)
    outs = []
    for i in range(12):
        lg, caches = model.decode_step(params, caches, {"tokens": toks[:, i:i+1]})
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    assert jnp.allclose(full_logits, dec_logits, rtol=2e-3, atol=2e-3), \
        float(jnp.abs(full_logits - dec_logits).max())
