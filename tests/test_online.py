"""Online re-partitioning: runner-cache reuse, warm starts, drift loop.

The tentpole guarantees under test:

* two same-shape systems with different table *values* share one compiled
  runner (zero recompilation), and the shared-runner fronts are identical
  to what cold per-system compilations produce;
* warm-started re-search is at least as good as cold at equal budget
  (2-objective hypervolume);
* the jit_nsga2 measured-accuracy fallback is *reported*, not silent;
* the gene-snap / warm-population primitives behave;
* the drift loop emits deterministic, bookkept decisions.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.nsga2_jax import warm_population
from repro.explore import (ExplorationSpec, ModelRef, OnlineRepartitioner,
                           PlatformSpec, SearchSettings, SystemSpec,
                           clear_jit_runner_cache, degrade_link, drop_node,
                           jit_runner_cache_size, run_search)
from repro.explore.runner import explore_graph  # noqa: F401  (API check)
from repro.explore.strategies import _cuts_to_genes


def small_system(n_plat=2):
    plats = tuple([PlatformSpec(f"EYR{i}", "eyr", bits=16)
                   for i in range(n_plat // 2)] +
                  [PlatformSpec(f"SMB{i}", "smb", bits=8)
                   for i in range(n_plat - n_plat // 2)])
    return SystemSpec(platforms=plats, links=("gige",) * (n_plat - 1))


OBJECTIVES = ("latency", "energy", "throughput")


def small_spec(system, pop=48, n_gen=6, **kw):
    # throughput (Def. 4) rewards pipelined splits, so link drift actually
    # moves the front — latency/energy alone collapse to one platform
    return ExplorationSpec(
        model=ModelRef("cnn", "squeezenet11", {"in_hw": 64}),
        system=system,
        objectives=OBJECTIVES,
        search=SearchSettings(strategy="jit_nsga2", seed=0,
                              pop_size=pop, n_gen=n_gen, **kw))


def search_front(spec, system, candidates=None, warm_cuts=None):
    """run_search on ``system`` with ``spec``'s model/settings; -> result."""
    from repro.core.accuracy import ProxyAccuracy
    from repro.core.graph import linearize
    from repro.core.partition import PartitionEvaluator

    graph, shared = spec.model.build()
    schedule = linearize(graph, spec.schedule_policy)
    built = system.build()
    ev = PartitionEvaluator(graph, schedule, built,
                            accuracy_fn=ProxyAccuracy(schedule, built),
                            shared_groups=shared)
    return run_search(ev, objectives=spec.objectives, settings=spec.search,
                      candidates=candidates, warm_cuts=warm_cuts)


def front_set(res):
    return sorted(e.cuts for e in res.pareto)


# -- compiled-runner sharing -------------------------------------------------

def test_same_shape_specs_share_one_runner_and_match_cold():
    base = small_system()
    slow = degrade_link(base, 0, 16.0)
    spec = small_spec(base)

    # shared-cache pass: both systems through one process-wide runner
    clear_jit_runner_cache()
    res_base = search_front(spec, base)
    assert jit_runner_cache_size() == 1
    res_slow = search_front(spec, slow)
    assert jit_runner_cache_size() == 1, \
        "same-shape system with different values must not recompile"
    assert res_base.strategy_used == "jit_nsga2"

    # cold pass: fresh compilation for the perturbed system alone
    clear_jit_runner_cache()
    res_cold = search_front(spec, slow)
    assert jit_runner_cache_size() == 1
    assert front_set(res_slow) == front_set(res_cold), \
        "shared-runner front must equal the cold-compile front"

    # and the perturbation must actually matter: objectives differ from base
    def objs(res):
        return [e.as_objectives(OBJECTIVES) for e in res.pareto]
    assert (objs(res_slow) != objs(res_base)
            or front_set(res_slow) != front_set(res_base))


def test_value_only_drift_keeps_shape_signature():
    from repro.core.accuracy import ProxyAccuracy
    from repro.core.graph import linearize
    from repro.core.partition import PartitionEvaluator
    from repro.core.partition_jax import build_eval_tables

    base = small_system(4)
    spec = small_spec(base)
    graph, shared = spec.model.build()
    schedule = linearize(graph, spec.schedule_policy)

    def sig(system_spec):
        built = system_spec.build()
        ev = PartitionEvaluator(graph, schedule, built,
                                accuracy_fn=ProxyAccuracy(schedule, built),
                                shared_groups=shared)
        return build_eval_tables(ev).shape_signature()

    s0 = sig(base)
    assert sig(degrade_link(base, 1, 64.0)) == s0
    assert sig(drop_node(base, 2)) == s0
    assert isinstance(hash(s0), int)


# -- warm start --------------------------------------------------------------

def hypervolume(front, ref):
    """Exact hypervolume (minimization) by recursive slicing — fine for
    the tiny fronts these searches produce."""
    pts = sorted({tuple(p) for p in front
                  if all(f <= r for f, r in zip(p, ref))})
    if not pts:
        return 0.0
    if len(ref) == 1:
        return ref[0] - pts[0][0]
    hv = 0.0
    for i, p in enumerate(pts):
        hi = pts[i + 1][0] if i + 1 < len(pts) else ref[0]
        width = hi - p[0]
        if width > 0:
            hv += width * hypervolume([q[1:] for q in pts[:i + 1]], ref[1:])
    return hv


def test_warm_hypervolume_not_worse_at_equal_budget():
    base = small_system(4)
    drifted = degrade_link(base, 1, 32.0)
    spec = small_spec(base, pop=48, n_gen=4)

    res_base = search_front(spec, base)
    warm_cuts = [e.cuts for e in res_base.pareto]

    res_cold = search_front(spec, drifted)
    res_warm = search_front(spec, drifted, warm_cuts=warm_cuts)

    def objs(res):
        return [e.as_objectives(OBJECTIVES) for e in res.pareto]
    allobjs = objs(res_cold) + objs(res_warm)
    ref = tuple(max(o[k] for o in allobjs) + abs(max(o[k] for o in allobjs))
                * 0.1 + 1e-12 for k in range(len(OBJECTIVES)))
    hv_cold = hypervolume(objs(res_cold), ref)
    hv_warm = hypervolume(objs(res_warm), ref)
    assert hv_warm >= hv_cold * (1 - 1e-9), \
        f"warm start regressed hypervolume: {hv_warm} < {hv_cold}"


def test_warm_start_off_ignores_seeds():
    base = small_system()
    spec = small_spec(base, warm_start=False)
    res_a = search_front(spec, base)
    # junk warm cuts must be ignored entirely when warm_start=False
    res_b = search_front(spec, base, warm_cuts=[(0,)] * 8)
    assert front_set(res_a) == front_set(res_b)


def test_warm_population_composition():
    rng = np.random.default_rng(0)
    warm = np.array([[3, 7], [10, 2]])
    X0 = warm_population(rng, 8, 2, 0, 15, warm)
    assert X0.shape == (8, 2) and X0.dtype.kind == "i"
    # elites lead, verbatim
    np.testing.assert_array_equal(X0[:2], warm)
    # jittered copies stay within +/-2 of an elite row, clipped to bounds
    for row in X0[2:4]:
        assert any(np.all(np.abs(row - w) <= 2) for w in warm)
    assert X0.min() >= 0 and X0.max() <= 15

    # no seeds -> uniform population, in bounds, deterministic per rng seed
    X0a = warm_population(np.random.default_rng(1), 8, 2, 0, 15, None)
    X0b = warm_population(np.random.default_rng(1), 8, 2, 0, 15,
                          np.empty((0, 2), dtype=int))
    np.testing.assert_array_equal(X0a, X0b)


def test_cuts_to_genes_snaps_to_nearest():
    table = np.array([2, 5, 9, 14])
    cuts = np.array([[2, 9], [3, 13], [0, 20]])
    genes = _cuts_to_genes(cuts, table)
    np.testing.assert_array_equal(genes, [[0, 2], [0, 3], [0, 3]])


def test_warm_start_json_round_trip():
    spec = small_spec(small_system(), warm_start=False)
    back = ExplorationSpec.from_json(spec.to_json())
    assert back.search.warm_start is False
    assert back == spec
    default = SearchSettings()
    assert default.warm_start is True


# -- strategy_used reporting -------------------------------------------------

def test_measured_accuracy_fallback_is_reported():
    from repro.core.graph import linearize
    from repro.core.partition import PartitionEvaluator

    base = small_system()
    spec = small_spec(base)
    graph, shared = spec.model.build()
    schedule = linearize(graph, spec.schedule_policy)
    # a bare callable oracle has no proxy_arrays -> tables can't be jitted
    ev = PartitionEvaluator(graph, schedule, base.build(),
                            accuracy_fn=lambda cuts: 0.9,
                            shared_groups=shared)
    res = run_search(ev, objectives=("latency", "accuracy"),
                     settings=spec.search)
    assert res.strategy == "jit_nsga2"          # what was requested
    assert res.strategy_used == "nsga2"         # what actually ran
    assert res.to_report()["strategy_used"] == "nsga2"


# -- the drift loop ----------------------------------------------------------

@pytest.fixture(scope="module")
def drift_run():
    base = small_system(4)
    spec = small_spec(base, pop=48, n_gen=6)
    events = [degrade_link(base, 0, 8.0), drop_node(base, 1)]
    clear_jit_runner_cache()
    rp = OnlineRepartitioner(spec)
    first = rp.update(base)
    rest = list(rp.watch(events))
    return base, spec, rp, first, rest


def test_online_repartitioner_bookkeeping(drift_run):
    base, spec, rp, first, rest = drift_run
    assert jit_runner_cache_size() == 1, "drift loop recompiled"
    assert first.step == 0 and first.changed and first.feasible
    assert all(d.repartition_ms > 0 for d in [first] + rest)
    assert all(d.strategy_used == "jit_nsga2" for d in [first] + rest)
    assert rp.decisions == [first] + rest
    # warm updates skip compilation: orders of magnitude faster
    assert min(d.repartition_ms for d in rest) < first.repartition_ms


def test_online_dropout_routes_off_dead_node(drift_run):
    base, spec, rp, first, rest = drift_run
    dropped = rest[-1]
    assert dropped.feasible
    b = [-1] + list(dropped.cuts)
    assert b[2] <= b[1], \
        f"stage on dead platform 1 still has layers: {dropped.cuts}"


def test_online_decisions_deterministic(drift_run):
    base, spec, rp, first, rest = drift_run
    rp2 = OnlineRepartitioner(spec)
    replay = [rp2.update(base)] + list(
        rp2.watch([degrade_link(base, 0, 8.0), drop_node(base, 1)]))
    assert [d.cuts for d in replay] == [d.cuts for d in [first] + rest]


def test_warm_front_bounded_by_crowding_distance():
    """The carried warm seed is capped at ``max_warm_front`` rows chosen
    by crowding distance, and the cap holds across drift steps (a long
    mission must not grow the seed without bound)."""
    base = small_system(4)
    spec = small_spec(base)
    rp = OnlineRepartitioner(spec, max_warm_front=2)
    d0 = rp.update(base)
    assert d0.trigger == "event"                   # default provenance
    assert rp._front_cuts is not None and len(rp._front_cuts) <= 2
    # every carried row is a member of the front it was truncated from
    front = {tuple(e.cuts) for e in d0.result.pareto}
    assert all(tuple(int(c) for c in row) in front
               for row in rp._front_cuts)
    d1 = rp.update(degrade_link(base, 0, 8.0), trigger="measured")
    assert d1.trigger == "measured"                # observed, not told
    assert len(rp._front_cuts) <= 2
    with pytest.raises(ValueError, match="max_warm_front"):
        OnlineRepartitioner(spec, max_warm_front=0)


def test_online_forces_jit_strategy():
    spec = small_spec(small_system())
    spec = dataclasses.replace(
        spec, search=dataclasses.replace(spec.search, strategy="nsga2"))
    rp = OnlineRepartitioner(spec)
    assert rp.settings.strategy == "jit_nsga2"


def test_perturbation_validation():
    base = small_system()
    with pytest.raises(IndexError):
        degrade_link(base, 5, 2.0)
    with pytest.raises(ValueError):
        degrade_link(base, 0, 0.0)
    with pytest.raises(IndexError):
        drop_node(base, 9)
    assert base.links[0].build().rate_bps == \
        degrade_link(base, 0, 4.0).links[0].build().rate_bps * 4
