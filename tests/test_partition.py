"""Partition evaluation: Definitions 1-4 + constraint violations."""

import pytest

from repro.core import layers as L
from repro.core.graph import LayerGraph
from repro.core.hwmodel import EYERISS_LIKE, SIMBA_LIKE
from repro.core.link import gigabit_ethernet
from repro.core.partition import (Constraints, PartitionEvaluator, Platform,
                                  SystemConfig, single_platform_eval)
from repro.core.quant import QuantSpec


def toy_system(n_platforms=2):
    plats = []
    for i in range(n_platforms):
        arch = EYERISS_LIKE if i % 2 == 0 else SIMBA_LIKE
        plats.append(Platform(f"p{i}", arch,
                              QuantSpec(bits=arch.bits)))
    return SystemConfig(plats, [gigabit_ethernet()] * (n_platforms - 1))


def toy_eval(n_layers=6, n_platforms=2, c=64, hw=56):
    g = LayerGraph(name="toy")
    layers = []
    for i in range(n_layers):
        layers.append(L.conv_layer(f"conv{i}", c, c, (hw, hw), 3))
    g.chain(layers)
    sched = g.topo_sort()
    return PartitionEvaluator(g, sched, toy_system(n_platforms))


def test_throughput_definition4():
    ev = toy_eval().evaluate([2])
    # throughput = 1 / max(stage, link latencies)
    mods = [t for t in ev.stage_latency_s if t > 0] + \
           [t for t in ev.link_latency_s if t > 0]
    assert ev.throughput == pytest.approx(1.0 / max(mods))


def test_latency_is_sum():
    ev = toy_eval().evaluate([2])
    assert ev.latency_s == pytest.approx(
        sum(ev.stage_latency_s) + sum(ev.link_latency_s))


def test_single_platform_has_no_link():
    evaluator = toy_eval()
    for i in range(2):
        ev = single_platform_eval(evaluator, i)
        assert ev.link_bytes == 0
        assert ev.n_partitions == 1
        assert ev.stage_latency_s[i] > 0


def test_cut_at_end_means_platform_a_only():
    evaluator = toy_eval(n_layers=5)
    ev = evaluator.evaluate([4])
    assert ev.stage_latency_s[1] == 0.0
    assert ev.link_bytes == 0


def test_pipelining_beats_single_platform_throughput():
    """A balanced cut on two platforms must beat the slower platform alone
    (the paper's headline effect)."""
    evaluator = toy_eval(n_layers=8)
    best_single = max(single_platform_eval(evaluator, i).throughput
                      for i in range(2))
    best_cut = max(evaluator.evaluate([p]).throughput for p in range(7))
    assert best_cut > best_single


def test_memory_violation_flagged():
    g = LayerGraph(name="big")
    g.chain([L.gemm_layer("fc", 4096, 100_000)])   # ~0.4B params
    sched = g.topo_sort()
    sys2 = toy_system()
    ev = PartitionEvaluator(g, sched, sys2).evaluate([0])
    assert ev.violation > 0     # 16-bit 0.4B params >> 64 MiB


def test_constraint_bandwidth():
    evaluator = toy_eval()
    cons = Constraints(max_link_bytes=10)
    ev = evaluator.evaluate([2], cons)
    assert ev.violation > 0


def test_four_platform_chain():
    evaluator = toy_eval(n_layers=8, n_platforms=4)
    ev = evaluator.evaluate([1, 3, 5])
    assert ev.n_partitions == 4
    assert len(ev.memory_bytes) == 4
    # skipping middle platforms via repeated cuts
    ev2 = evaluator.evaluate([1, 1, 1])
    assert ev2.n_partitions == 2
