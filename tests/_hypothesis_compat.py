"""Optional-``hypothesis`` shim for the property-test modules.

``hypothesis`` is a dev-only dependency (declared in pyproject.toml /
requirements-dev.txt).  When it is missing, importing it at module scope
used to abort collection of six whole test modules; importing *this* module
instead degrades gracefully: property tests decorated with ``@given`` turn
into individual skips while plain tests in the same files keep running.

Usage (replaces the direct hypothesis imports)::

    from _hypothesis_compat import given, settings, st
"""

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: any attribute access or
        call returns the stub itself, enough to evaluate ``@given(...)`` and
        ``@st.composite`` expressions at collection time."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def given(*args, **kwargs):
        def decorate(fn):
            # zero-arg replacement (no functools.wraps: pytest must not see
            # the strategy parameters of the wrapped property test and try
            # to resolve them as fixtures)
            def skipper():
                pytest.skip("hypothesis not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return decorate

    def settings(*args, **kwargs):
        return lambda fn: fn
