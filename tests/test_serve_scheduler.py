"""SlotScheduler admission/eviction/backfill invariants (no JAX needed)."""

import numpy as np
import pytest

from repro.serve.request import Request, poisson_traffic
from repro.serve.scheduler import SlotScheduler


def _req(rid, max_new=4, plen=3):
    return Request(rid=rid, prompt=np.arange(1, plen + 1), max_new=max_new)


def test_admit_fifo_and_backfill():
    s = SlotScheduler(2)
    for rid in range(4):
        s.submit(_req(rid))
    placed = s.admit()
    assert [(i, r.rid) for i, r in placed] == [(0, 0), (1, 1)]
    assert s.n_waiting == 2 and not s.free_slots()
    # evict slot 0 via length (max_new=1 path: record up to the budget)
    for _ in range(4):
        rec = s.record_token(0, 9)
    assert rec is not None and rec.finish == "length"
    # freed slot backfills with the *oldest* waiting request
    placed = s.admit()
    assert [(i, r.rid) for i, r in placed] == [(0, 2)]
    assert s.n_waiting == 1


def test_eos_evicts_and_finish_reason():
    s = SlotScheduler(1, eos=7)
    s.submit(_req(0, max_new=10))
    s.admit()
    assert s.record_token(0, 3) is None
    rec = s.record_token(0, 7)
    assert rec is not None and rec.finish == "eos"
    assert rec.tokens == [3, 7]
    assert s.free_slots() == [0]


def test_duplicate_rid_and_free_slot_errors():
    s = SlotScheduler(1)
    s.submit(_req(0))
    with pytest.raises(ValueError):
        s.submit(_req(0))
    with pytest.raises(ValueError):
        s.record_token(0, 1)          # nothing admitted yet


def test_ttft_and_latency_accounting():
    s = SlotScheduler(1, eos=5)
    s.submit(_req(0, max_new=3), now=1.0)
    s.admit()
    s.record_token(0, 2, now=1.5)
    rec = s.record_token(0, 5, now=2.0)
    assert rec.ttft_s == pytest.approx(0.5)
    assert rec.latency_s == pytest.approx(1.0)


def test_randomized_invariants_no_leak_no_bleed():
    """Randomized arrival/EOS patterns: invariants hold after every
    operation, every token lands in its own request's record, and the
    run drains completely (no slot leak)."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        n_slots = int(rng.integers(1, 5))
        eos = 0
        s = SlotScheduler(n_slots, eos=eos)
        reqs = [_req(rid, max_new=int(rng.integers(1, 6)))
                for rid in range(int(rng.integers(1, 12)))]
        pending = list(reqs)
        expected = {}                   # rid -> tokens we fed that request
        t = 0.0
        while True:
            # random arrivals
            while pending and rng.random() < 0.5:
                s.submit(pending.pop(0), now=t)
                s.check_invariants()
            s.admit()
            s.check_invariants()
            if s.idle and not pending:
                break
            # one decode step over the active slots: random tokens with a
            # random chance of EOS; tokens are tagged per-rid so any
            # cross-request bleed shows up as a wrong record
            for slot in s.active_slots():
                rid = s.slot_request(slot).rid
                tok = eos if rng.random() < 0.2 else 100 + rid
                expected.setdefault(rid, []).append(tok)
                s.record_token(slot, tok, now=t)
                s.check_invariants()
            t += 1.0
        assert not s.active_slots() and s.n_waiting == 0     # no slot leak
        assert set(s.records) == {r.rid for r in reqs}
        for r in reqs:
            rec = s.records[r.rid]
            assert rec.done and rec.finish in ("eos", "length")
            assert rec.tokens == expected[r.rid]             # no bleed
            assert len(rec.tokens) <= r.max_new
            if rec.finish == "eos":
                assert rec.tokens[-1] == eos
                assert eos not in rec.tokens[:-1]


def test_poisson_traffic_shape():
    reqs = poisson_traffic(10, rate_rps=100.0, vocab=64, prompt_len=8,
                           max_new=4, seed=1)
    assert len(reqs) == 10
    assert reqs[0].arrival_s == 0.0
    arr = [r.arrival_s for r in reqs]
    assert arr == sorted(arr)
    for r in reqs:
        assert r.prompt.shape == (8,) and r.prompt.dtype == np.int32
        assert (r.prompt >= 0).all() and (r.prompt < 64).all()
    # same seed reproduces, different seed differs
    again = poisson_traffic(10, rate_rps=100.0, vocab=64, prompt_len=8,
                            max_new=4, seed=1)
    assert all((a.prompt == b.prompt).all() and a.arrival_s == b.arrival_s
               for a, b in zip(reqs, again))
    other = poisson_traffic(10, rate_rps=100.0, vocab=64, prompt_len=8,
                            max_new=4, seed=2)
    assert any(a.arrival_s != b.arrival_s for a, b in zip(reqs, other))
