"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.quant_matmul import quant_matmul as pl_quant_matmul
from repro.kernels.ssd_scan import ssd_scan as pl_ssd_scan
from repro.kernels.window_attn import window_attn as pl_window_attn


# -- quant_matmul ---------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (128, 256, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_sweep(m, k, n, dtype):
    key = jax.random.PRNGKey(m + k + n)
    x = jax.random.normal(key, (m, k), jnp.float32).astype(dtype).astype(jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.05
    w_scale = jnp.abs(w).max(axis=0) / 127.0
    w_q = jnp.clip(jnp.round(w / w_scale[None, :]), -128, 127).astype(jnp.int8)
    x_scale = jnp.abs(x).max() / 127.0
    y_ref = ref.quant_matmul(x, w_q, w_scale, x_scale)
    y_pl = pl_quant_matmul(x, w_q, w_scale, x_scale)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_quant_matmul_blocks():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 256)) * 0.03
    w_scale = jnp.abs(w).max(axis=0) / 127.0
    w_q = jnp.clip(jnp.round(w / w_scale[None, :]), -128, 127).astype(jnp.int8)
    x_scale = jnp.abs(x).max() / 127.0
    y_ref = ref.quant_matmul(x, w_q, w_scale, x_scale)
    for bm, bn, bk in [(128, 128, 128), (256, 128, 128), (128, 256, 256)]:
        y = pl_quant_matmul(x, w_q, w_scale, x_scale, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)


def test_quant_matmul_ops_fallback():
    # off-grid shape falls back to the oracle silently
    x = jnp.ones((100, 96))
    w_q = jnp.ones((96, 50), jnp.int8)
    y = ops.quant_matmul(x, w_q, jnp.ones((50,)), jnp.asarray(0.1))
    assert y.shape == (100, 50)


# -- ssd_scan --------------------------------------------------------------------

@pytest.mark.parametrize("t,chunk", [(128, 32), (256, 64), (192, 64)])
@pytest.mark.parametrize("h,p,n", [(2, 16, 8), (3, 32, 16)])
def test_ssd_scan_sweep(t, chunk, h, p, n):
    if t % chunk:
        pytest.skip("t must be divisible by chunk")
    key = jax.random.PRNGKey(t + h)
    ks = jax.random.split(key, 5)
    b = 2
    x = jax.random.normal(ks[0], (b, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, t, n)) * 0.5
    C = jax.random.normal(ks[4], (b, t, n)) * 0.5
    y_ref, st_ref = ref.ssd_scan(x, dt, A, B, C, chunk)
    y_pl, st_pl = pl_ssd_scan(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_pl), np.asarray(st_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_scan_matches_sequential_recurrence():
    """Chunked SSD == naive token-by-token recurrence."""
    from repro.nn.ssm import ssd_step
    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, 5)
    b, t, h, p, n = 1, 64, 2, 8, 4
    x = jax.random.normal(ks[0], (b, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, t, n)) * 0.5
    C = jax.random.normal(ks[4], (b, t, n)) * 0.5
    y_k, st_k = pl_ssd_scan(x, dt, A, B, C, chunk=16)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for i in range(t):
        y_i, state = ssd_step(state, x[:, i], dt[:, i], A, B[:, i], C[:, i])
        ys.append(y_i)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(state),
                               rtol=1e-4, atol=1e-4)


# -- window_attn ------------------------------------------------------------------

@pytest.mark.parametrize("t,w,bq", [(256, 128, 64), (256, 64, 64),
                                    (512, 256, 128)])
@pytest.mark.parametrize("h,kv,hd", [(4, 2, 64), (4, 4, 32)])
def test_window_attn_sweep(t, w, bq, h, kv, hd):
    key = jax.random.PRNGKey(t + w + h)
    ks = jax.random.split(key, 3)
    b = 2
    q = jax.random.normal(ks[0], (b, t, h, hd))
    k = jax.random.normal(ks[1], (b, t, kv, hd))
    v = jax.random.normal(ks[2], (b, t, kv, hd))
    y_ref = ref.window_attn(q, jnp.repeat(k, h // kv, 2),
                            jnp.repeat(v, h // kv, 2), w)
    y_pl = pl_window_attn(q, k, v, window=w, bq=bq, bk=bq)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)


def test_window_attn_matches_chunked_sdpa():
    from repro.nn.attention import chunked_sdpa
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    b, t, h, kv, hd, w = 1, 256, 4, 2, 32, 128
    q = jax.random.normal(ks[0], (b, t, h, hd))
    k = jax.random.normal(ks[1], (b, t, kv, hd))
    v = jax.random.normal(ks[2], (b, t, kv, hd))
    y1 = chunked_sdpa(q, k, v, window=w, chunk_q=64)
    y2 = pl_window_attn(q, k, v, window=w, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                               rtol=2e-4, atol=2e-4)
