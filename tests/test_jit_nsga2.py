"""The ``jax.jit``-compiled search path: jittable ``evaluate_batch``
fast-path vs the NumPy evaluator, the NSGA-II operator twins
(rank/crowding/repair) vs ``repro.core.nsga2``, seeded Pareto-front
equivalence of ``JitNSGA2Search`` vs ``NSGA2Search`` on the
EfficientNet-style test schedule, spec plumbing, and the strategy-registry
collision semantics."""

import dataclasses
import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import nsga2_jax  # noqa: E402
from repro.core.accuracy import MeasuredAccuracy, ProxyAccuracy  # noqa: E402
from repro.core.graph import linearize  # noqa: E402
from repro.core.nsga2 import (crowding_distance,  # noqa: E402
                              fast_non_dominated_sort)
from repro.core.partition import Constraints, PartitionEvaluator  # noqa: E402
from repro.core.partition_jax import make_batch_eval_fn  # noqa: E402
from repro.explore import (ExplorationSpec, JitNSGA2Search,  # noqa: E402
                           ModelRef, NSGA2Search, PlatformSpec,
                           SearchSettings, SystemSpec, register_strategy,
                           run_spec)
from repro.explore.strategies import STRATEGIES  # noqa: E402
from repro.models.cnn.zoo import build_cnn  # noqa: E402

FOUR_PLATFORM = SystemSpec(
    platforms=(PlatformSpec("A0", "eyr", bits=16),
               PlatformSpec("A1", "eyr", bits=16),
               PlatformSpec("B0", "smb", bits=8),
               PlatformSpec("B1", "smb", bits=8)),
    links=("gige", "gige", "gige"))

ALL_OBJECTIVES = ("latency", "energy", "throughput", "bandwidth",
                  "memory", "accuracy")


@pytest.fixture(scope="module")
def evaluator():
    graph = build_cnn("efficientnet_b0", in_hw=64).to_graph()
    system = FOUR_PLATFORM.build()
    schedule = linearize(graph, "min_memory")
    return PartitionEvaluator(graph, schedule, system,
                              accuracy_fn=ProxyAccuracy(schedule, system))


def random_cuts(evaluator, n, seed=0):
    rng = np.random.default_rng(seed)
    L = len(evaluator.schedule)
    return np.sort(rng.integers(-1, L, size=(n, evaluator.system.n_cuts)),
                   axis=1)


# -- jittable evaluator fast-path ---------------------------------------------

def test_jit_eval_matches_numpy_evaluate_batch(evaluator):
    """Every objective column and the violation vector agree with the NumPy
    evaluator to float32 tolerance, constraints active."""
    C = random_cuts(evaluator, 256)
    mem_cap = int(np.median(
        evaluator.evaluate_batch(C).memory_bytes.max(axis=1)))
    cons = Constraints(max_link_bytes=200_000, min_accuracy=0.9,
                       max_latency_s=0.05, max_energy_j=0.05,
                       min_throughput=10.0)
    be = evaluator.evaluate_batch(C, cons)
    F_np, CV_np = be.as_objectives(ALL_OBJECTIVES), be.violation
    fn = jax.jit(make_batch_eval_fn(evaluator.jax_tables(),
                                    ALL_OBJECTIVES, cons))
    F_j, CV_j = (np.asarray(x) for x in fn(jnp.asarray(C)))
    assert CV_np.max() > 0, "constraints must actually bite in this test"
    np.testing.assert_allclose(F_j, F_np, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(CV_j, CV_np, rtol=2e-5, atol=1e-5)
    assert mem_cap > 0


def test_jit_eval_memory_capacity_violation(evaluator):
    """Platform memory-capacity violations (no explicit constraints)
    agree — exercises the Def.-3 segment-memory twin under pressure."""
    sys_small = SystemSpec(
        platforms=tuple(dataclasses.replace(p, mem_capacity=300_000)
                        for p in FOUR_PLATFORM.platforms),
        links=FOUR_PLATFORM.links).build()
    schedule = evaluator.schedule
    ev = PartitionEvaluator(evaluator.graph, schedule, sys_small,
                            accuracy_fn=ProxyAccuracy(schedule, sys_small))
    C = random_cuts(ev, 256, seed=3)
    be = ev.evaluate_batch(C)
    fn = jax.jit(make_batch_eval_fn(ev.jax_tables(), ("latency", "memory")))
    F_j, CV_j = (np.asarray(x) for x in fn(jnp.asarray(C)))
    assert be.violation.max() > 0
    np.testing.assert_allclose(CV_j, be.violation, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(F_j[:, 1], be.memory_bytes.max(axis=1),
                               rtol=2e-5)


def test_jit_eval_requires_proxy_for_accuracy(evaluator):
    ev = PartitionEvaluator(evaluator.graph, evaluator.schedule,
                            evaluator.system,
                            accuracy_fn=MeasuredAccuracy(lambda c: 0.5))
    with pytest.raises(ValueError, match="proxy"):
        make_batch_eval_fn(ev.jax_tables(), ("latency", "accuracy"))


# -- operator twins -----------------------------------------------------------

def test_rank_and_crowding_twins_match_numpy():
    rng = np.random.default_rng(7)
    n = 300
    F = rng.random((n, 3))
    CV = np.where(rng.random(n) < 0.3, rng.random(n), 0.0)
    fronts = fast_non_dominated_sort(F, CV)
    rank_np = np.empty(n, dtype=int)
    for r, fr in enumerate(fronts):
        rank_np[fr] = r
    rank_j = np.asarray(nsga2_jax.nondominated_rank(
        jnp.asarray(F, jnp.float32), jnp.asarray(CV, jnp.float32)))
    assert (rank_j == rank_np).all()
    crowd_np = np.zeros(n)
    for fr in fronts:
        crowd_np[fr] = crowding_distance(F[fr])
    crowd_j = np.asarray(nsga2_jax.crowding_by_rank(
        jnp.asarray(F, jnp.float32), jnp.asarray(rank_j)))
    finite = np.isfinite(crowd_np)
    assert (np.isfinite(crowd_j) == finite).all()
    np.testing.assert_allclose(crowd_j[finite], crowd_np[finite], atol=1e-5)


def test_rank_cap_covers_selection_prefix():
    """Capped peeling must rank at least `cap` individuals and agree with
    the full sort on every rank it assigned."""
    rng = np.random.default_rng(1)
    F = rng.random((128, 2))
    CV = np.zeros(128)
    rank_full = np.asarray(nsga2_jax.nondominated_rank(
        jnp.asarray(F, jnp.float32), jnp.asarray(CV, jnp.float32)))
    rank_cap = np.asarray(nsga2_jax.nondominated_rank(
        jnp.asarray(F, jnp.float32), jnp.asarray(CV, jnp.float32), cap=64))
    ranked = rank_cap < 128
    assert ranked.sum() >= 64
    assert (rank_cap[ranked] == rank_full[ranked]).all()


def test_repair_twin_matches_numpy():
    from repro.core.nsga2 import _repair_batch
    rng = np.random.default_rng(2)
    X = rng.integers(-5, 40, size=(64, 4))
    want = _repair_batch(X.copy(), 0, 30)
    got = np.asarray(nsga2_jax.repair(jnp.asarray(X, jnp.int32), 0, 30))
    assert (want == got).all()


# -- seeded front equivalence -------------------------------------------------

def _no_clear_domination(Fa, Fb, scale, tol=0.02):
    """No point of Fa dominates any point of Fb by more than tol of the
    per-objective range (both GA fronts approximate the same true front)."""
    for f in Fa:
        margin_dom = np.all(f <= Fb - tol * scale, axis=1)
        assert not margin_dom.any(), (
            f"front point {f} clearly dominates {Fb[margin_dom][0]}")


def test_jit_front_equivalent_to_numpy_front(evaluator):
    """Seeded JIT and NumPy searches on the EfficientNet-style schedule
    converge to equivalent Pareto fronts (neither clearly dominates the
    other anywhere, same ideal point within tolerance)."""
    objectives = ("latency", "energy", "throughput")
    # budget chosen so both stochastic runs converge to the true front
    # (margins go to 0 here); at pop 192 / n_gen 50 the 1-ulp float32
    # difference between baked-constant and runtime-argument tables is
    # enough to send the two trajectories to different front samples
    settings = SearchSettings(strategy="nsga2", seed=0, pop_size=256,
                              n_gen=100)
    from repro.explore import run_search
    res_np = run_search(evaluator, objectives=objectives, settings=settings)
    res_jit = run_search(
        evaluator, objectives=objectives,
        settings=dataclasses.replace(settings, strategy="jit_nsga2"))
    assert res_np.nsga is not None and res_jit.nsga is not None
    assert len(res_jit.pareto) >= 1
    Fn = np.array([e.as_objectives(objectives) for e in res_np.pareto])
    Fj = np.array([e.as_objectives(objectives) for e in res_jit.pareto])
    scale = np.ptp(np.concatenate([Fn, Fj]), axis=0) + 1e-12
    _no_clear_domination(Fn, Fj, scale)
    _no_clear_domination(Fj, Fn, scale)
    # ideal points agree to 8% of each objective's range across both fronts
    # (different arithmetic streams; at this budget seed 0 hits 0% gap)
    assert (np.abs(Fj.min(axis=0) - Fn.min(axis=0)) <= 0.08 * scale).all()


def test_jit_front_points_are_exactly_scored(evaluator):
    """Returned PartitionEvals come from the exact NumPy evaluator (no
    float32 drift in reported metrics)."""
    from repro.explore import run_search
    res = run_search(evaluator, settings=SearchSettings(
        strategy="jit_nsga2", seed=1, pop_size=64, n_gen=10))
    for ev in res.pareto:
        exact = evaluator.evaluate(ev.cuts)
        assert ev.latency_s == exact.latency_s
        assert ev.memory_bytes == exact.memory_bytes


def test_jit_fallback_on_measured_accuracy(evaluator):
    """Accuracy objective + non-proxy oracle falls back to the NumPy
    strategy with a warning instead of mis-searching."""
    ev = PartitionEvaluator(evaluator.graph, evaluator.schedule,
                            evaluator.system,
                            accuracy_fn=MeasuredAccuracy(lambda c: 0.75))
    from repro.explore import run_search
    with pytest.warns(UserWarning, match="falling back"):
        res = run_search(ev, objectives=("latency", "accuracy"),
                         settings=SearchSettings(strategy="jit_nsga2",
                                                 seed=0, pop_size=32,
                                                 n_gen=5))
    assert len(res.pareto) >= 1


# -- spec plumbing ------------------------------------------------------------

def test_spec_json_roundtrip_selects_jit_strategy():
    spec = ExplorationSpec(
        model=ModelRef("cnn", "squeezenet11", {"in_hw": 64}),
        system=FOUR_PLATFORM,
        objectives=("latency", "energy"),
        search=SearchSettings(strategy="jit_nsga2", seed=0, pop_size=64,
                              n_gen=8))
    spec2 = ExplorationSpec.from_json(spec.to_json())
    assert spec2 == spec
    assert spec2.search.strategy == "jit_nsga2"
    res = run_spec(spec2)
    assert res.strategy == "jit_nsga2"
    assert res.nsga is not None
    assert len(res.pareto) >= 1
    assert res.n_evaluated == 64 * 9


def test_spec_roundtrip_scaling_knobs():
    """rank_block / rank_impl / n_restarts / rank_devices survive the JSON
    round-trip and are validated at construction."""
    spec = ExplorationSpec(
        model=ModelRef("cnn", "squeezenet11", {"in_hw": 64}),
        system=FOUR_PLATFORM,
        search=SearchSettings(strategy="jit_nsga2", pop_size=64, n_gen=4,
                              rank_block=512, rank_impl="ref",
                              n_restarts=3, rank_devices=2))
    spec2 = ExplorationSpec.from_json(spec.to_json())
    assert spec2 == spec
    assert spec2.search.rank_block == 512
    assert spec2.search.n_restarts == 3
    with pytest.raises(ValueError, match="rank_impl"):
        SearchSettings(rank_impl="mosaic")
    with pytest.raises(ValueError, match="n_restarts"):
        SearchSettings(n_restarts=0)


def test_jit_strategy_restarts_front_superset(evaluator):
    """n_restarts=2 merges both seeds' fronts: every single-seed front
    point is matched or dominated, and n_evaluated counts both runs."""
    from repro.explore import run_search
    base = SearchSettings(strategy="jit_nsga2", seed=5, pop_size=64,
                          n_gen=8, rank_block=64)
    res1 = run_search(evaluator, settings=base)
    res2 = run_search(evaluator,
                      settings=dataclasses.replace(base, n_restarts=2))
    assert res2.n_evaluated == 2 * 64 * 9
    # seed 5 is restart 0 of the merged run, so its front can only be
    # equalled or improved by the union
    F1 = np.array([e.as_objectives(("latency", "energy")) for e in res1.pareto])
    F2 = np.array([e.as_objectives(("latency", "energy")) for e in res2.pareto])
    for f in F2:
        assert not (F1 < f - 1e-12).all(axis=1).any(), \
            "merged front point dominated by a single-seed point"


# -- strategy registry --------------------------------------------------------

def test_register_strategy_collision_and_override():
    class Custom:
        name = "jit_nsga2"

        def search(self, ctx):
            raise NotImplementedError

    with pytest.raises(ValueError, match="already registered"):
        register_strategy("jit_nsga2", Custom)
    original = STRATEGIES["jit_nsga2"]
    assert original is JitNSGA2Search
    try:
        register_strategy("jit_nsga2", Custom, override=True)
        assert STRATEGIES["jit_nsga2"] is Custom
    finally:
        register_strategy("jit_nsga2", original, override=True)
    # fresh names register without override and are selectable from
    # SearchSettings / resolved to instances (the registry's whole point)
    class Stub:
        name = "my_custom_search"

        def search(self, ctx):
            raise NotImplementedError

    try:
        register_strategy("my_custom_search", Stub)
        assert STRATEGIES["my_custom_search"] is Stub
        settings = SearchSettings(strategy="my_custom_search")
        from repro.explore.strategies import resolve_strategies
        (strat,) = resolve_strategies(settings, n_cuts=3, n_candidates=10)
        assert isinstance(strat, Stub)
    finally:
        STRATEGIES.pop("my_custom_search", None)
    with pytest.raises(ValueError, match="unknown strategy"):
        SearchSettings(strategy="my_custom_search")


def test_lazy_jit_twins_via_nsga2_module():
    """core.nsga2 exposes the twins under jit_* without importing JAX at
    module import time."""
    from repro.core import nsga2
    assert nsga2.jit_repair is nsga2_jax.repair
    assert nsga2.jit_nsga2 is nsga2_jax.jit_nsga2
    with pytest.raises(AttributeError):
        nsga2.jit_does_not_exist


# -- campaign end-to-end ------------------------------------------------------

def test_campaign_runs_jit_strategy():
    from repro.explore import Campaign
    spec = ExplorationSpec(
        model=ModelRef("cnn", "squeezenet11", {"in_hw": 64}),
        system=FOUR_PLATFORM,
        objectives=("latency", "energy"),
        search=SearchSettings(strategy="jit_nsga2", seed=0, pop_size=64,
                              n_gen=6))
    models = [ModelRef("cnn", n, {"in_hw": 64})
              for n in ("squeezenet11", "regnetx_400mf")]
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # no fallback warnings allowed
        cr = Campaign(spec, models=models).run()
    assert len(cr.entries) == 2
    for e in cr.entries:
        assert len(e.result.pareto) >= 1
        assert e.result.selected is not None
    rep = cr.report.to_dict()
    assert rep["template"]["search"]["strategy"] == "jit_nsga2"
