"""Gradient accumulation: exactness vs single-batch gradients."""

import jax
import jax.numpy as jnp
import pytest

from repro.data.synthetic import make_batch_for
from repro.models.registry import build_model, get_config
from repro.optim.optimizers import sgd
from repro.training.train_lib import make_train_step


@pytest.mark.parametrize("arch,tol", [
    ("smollm-360m", 1e-4),
    ("qwen2-vl-7b", 1e-4),
    # MoE gradients are NOT batch-decomposable: expert capacity and the
    # load-balance loss depend on the token-group composition, so
    # accumulation changes routing-drop patterns slightly — loose bound.
    ("deepseek-moe-16b", 0.15),
])
def test_accum_matches_full_batch(arch, tol):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, state = model.init(jax.random.PRNGKey(0))
    opt = sgd(0.1, momentum=0.0)
    batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg, 8, 16).items()}

    results = []
    for ga in (1, 4):
        step = jax.jit(make_train_step(model, cfg, opt, clip_norm=None,
                                       grad_accum=ga))
        p1, _, _, m = step(params, opt.init(params), state, batch)
        results.append((p1, float(m["loss"])))
    (pa, la), (pb, lb) = results
    assert abs(la - lb) < max(tol, 1e-4) * 10
    diff = max(float(jnp.abs(a - b).max()) for a, b in
               zip(jax.tree_util.tree_leaves(pa),
                   jax.tree_util.tree_leaves(pb)))
    assert diff < tol, diff
