"""CNN zoo: forward shapes, graph fidelity (param/MAC counts vs published),
partitioned execution equivalence."""

import jax
import jax.numpy as jnp
import pytest

from repro.models.cnn.zoo import CNN_ZOO, build_cnn, reduced_cnn
from repro.serving.pipeline import PartitionedCNNRunner

KNOWN_PARAMS_M = {   # torchvision reference numbers (±5%)
    "vgg16": 138.4, "resnet50": 25.6, "squeezenet11": 1.24,
    "googlenet": 6.6, "regnetx_400mf": 5.2, "efficientnet_b0": 5.3,
}


@pytest.mark.parametrize("name", list(CNN_ZOO))
def test_reduced_forward(name):
    m = reduced_cnn(name)
    p, s = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 32, 32))
    y, _ = m.apply(p, s, x, train=True)
    assert y.shape == (2, 10)
    assert bool(jnp.isfinite(y).all())


@pytest.mark.parametrize("name", list(CNN_ZOO))
def test_full_graph_param_count(name):
    g = build_cnn(name).to_graph()
    params_m = g.total_params / 1e6
    ref = KNOWN_PARAMS_M[name]
    assert abs(params_m - ref) / ref < 0.06, (name, params_m, ref)


@pytest.mark.parametrize("name", list(CNN_ZOO))
def test_graph_has_usable_cuts(name):
    g = build_cnn(name).to_graph()
    sched = g.topo_sort()
    cuts = g.clean_cuts(sched)
    assert len(cuts) >= 10, f"{name}: only {len(cuts)} clean cuts"


@pytest.mark.parametrize("cuts", [[2], [1, 4]])
def test_partitioned_equals_monolithic(cuts):
    m = reduced_cnn("squeezenet11")
    p, s = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 32, 32))
    y_mono, _ = m.apply(p, s, x, train=False)
    runner = PartitionedCNNRunner(m, p, s, cuts,
                                  quant_specs=[None] * (len(cuts) + 1))
    y_part, report = runner.run(x)
    assert float(jnp.abs(y_part - y_mono).max()) == 0.0
    assert len(report.latency_s) == len(cuts) + 1


def test_quantized_partition_changes_output_slightly():
    from repro.core.quant import QuantSpec
    m = reduced_cnn("squeezenet11")
    p, s = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 32, 32))
    y_mono, _ = m.apply(p, s, x, train=False)
    runner = PartitionedCNNRunner(m, p, s, [4],
                                  [QuantSpec(bits=16), QuantSpec(bits=8)])
    y_q, _ = runner.run(x)
    diff = float(jnp.abs(y_q - y_mono).max())
    assert 0 < diff < 2.0      # perturbed but not destroyed


def test_cut_to_block_mapping():
    m = build_cnn("squeezenet11", in_hw=64)
    g = m.to_graph()
    sched = g.topo_sort()
    # cutting at the last node of block i must map to block i
    for bi, node in m.graph_boundaries[:5]:
        pos = [i for i, l in enumerate(sched) if l.name == node][0]
        assert m.cut_to_block(sched, pos) == bi
