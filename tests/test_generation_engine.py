"""GenerationEngine behavior: EOS early-stop, sampling determinism,
masked-done sequences, and pre-EOS throughput accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import build_model, get_config
from repro.serving.engine import GenResult, GenerationEngine, valid_token_count


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, b=3, t=8, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, size=(b, t)).astype(np.int32)


def test_valid_token_count():
    toks = np.array([[3, 7, 7, 7],      # eos at 1 -> 1 valid
                     [1, 2, 3, 7],      # eos at 3 -> 3 valid
                     [1, 2, 3, 4]])     # never stopped -> 4 valid
    assert valid_token_count(toks, eos=7) == 8
    assert valid_token_count(toks, eos=None) == 12
    assert valid_token_count(np.zeros((0, 4), np.int32), eos=7) == 0


def test_tokens_per_s_zero_decode_and_pre_eos():
    r = GenResult(tokens=np.ones((2, 4), np.int32), decode_s=0.0)
    assert r.tokens_per_s == 0.0        # not inf
    r = GenResult(tokens=np.ones((2, 4), np.int32), decode_s=2.0, n_valid=6)
    assert r.tokens_per_s == pytest.approx(3.0)
    r = GenResult(tokens=np.ones((2, 4), np.int32), decode_s=2.0)
    assert r.tokens_per_s == pytest.approx(4.0)   # n_valid None: all count


def test_eos_early_stop_and_masked_done(lm):
    cfg, model, params = lm
    eng = GenerationEngine(model, params, max_seq=40,
                           cache_dtype=jnp.float32)
    prompts = _prompts(cfg)
    free = eng.generate(prompts, max_new=8)          # no EOS: full budget
    assert free.tokens.shape == (3, 8)
    # use row 0's second greedy token as EOS: that row must stop early and
    # every position after its first EOS must be masked to EOS
    eos = int(free.tokens[0, 1])
    res = eng.generate(prompts, max_new=8, eos=eos)
    toks = res.tokens
    assert toks.shape[1] <= 8
    for row in toks:
        hits = np.flatnonzero(row == eos)
        if hits.size:
            assert (row[hits[0]:] == eos).all()      # masked-done tail
    assert res.n_valid == valid_token_count(toks, eos)
    assert res.n_valid < toks.size                   # row 0 stopped early
    # greedy tokens before the stop are unchanged by the EOS setting
    np.testing.assert_array_equal(toks[:, 0], free.tokens[:, 0])


def test_greedy_and_temperature_determinism(lm):
    cfg, model, params = lm
    eng = GenerationEngine(model, params, max_seq=40,
                           cache_dtype=jnp.float32)
    prompts = _prompts(cfg)
    a = eng.generate(prompts, max_new=6)
    b = eng.generate(prompts, max_new=6)
    np.testing.assert_array_equal(a.tokens, b.tokens)     # greedy: exact
    t1 = eng.generate(prompts, max_new=6, temperature=0.8, seed=1)
    t2 = eng.generate(prompts, max_new=6, temperature=0.8, seed=1)
    np.testing.assert_array_equal(t1.tokens, t2.tokens)   # same seed: exact
    t3 = eng.generate(prompts, max_new=6, temperature=0.8, seed=2)
    assert (t1.tokens != t3.tokens).any()                 # seed changes draw


def test_all_done_stops_decoding(lm):
    """Once every row hit EOS the loop exits early: the token matrix is
    narrower than the budget."""
    cfg, model, params = lm
    eng = GenerationEngine(model, params, max_seq=40,
                           cache_dtype=jnp.float32)
    prompts = _prompts(cfg, b=2)
    free = eng.generate(prompts, max_new=10)
    eos = int(free.tokens[0, 0])
    if int(free.tokens[1, 0]) != eos:
        # force both rows to stop on their own first token by running
        # per-row: each single-row batch stops at width 1
        for row in range(2):
            res = eng.generate(prompts[row:row + 1], max_new=10,
                               eos=int(free.tokens[row, 0]))
            assert res.tokens.shape == (1, 1)
            assert res.n_valid == 0
    else:
        res = eng.generate(prompts, max_new=10, eos=eos)
        assert res.tokens.shape == (2, 1)
