"""Multi-device tests that need >1 host device: run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count so the main pytest process
keeps its single-device view."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 520):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_pipelined_apply_matches_monolithic():
    run_subprocess("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.models.registry import get_config, build_model
        from repro.launch.pipeline import pipelined_apply, stack_stages

        cfg = get_config("smollm-360m").reduced()
        import dataclasses
        cfg = dataclasses.replace(cfg, n_layers=4)
        model = build_model(cfg)
        params, state = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
        batch = {"tokens": toks}
        mono, _ = model.apply(params, state, batch, train=False)

        devs = np.asarray(jax.devices()[:8]).reshape(2, 2, 2)
        mesh = Mesh(devs, ("pod", "data", "model"))
        staged = stack_stages(params, n_stages=2)
        with mesh:
            piped = pipelined_apply(model, staged, batch, mesh,
                                    n_microbatches=2)
        err = float(jnp.abs(piped - mono).max())
        assert err < 2e-4, err
        print("pipeline match", err)
    """)


def test_dryrun_entrypoint_smoke():
    """The real dry-run module must lower+compile smollm decode_32k on the
    (16,16) production mesh (512 fake devices)."""
    run_subprocess("""
        from repro.launch.dryrun import dryrun_one
        row = dryrun_one("smollm-360m", "decode_32k", multi_pod=False,
                         verbose=False)
        assert "error" not in row, row
        assert row["kind"] == "decode"
        assert row["flops_per_device"] > 0
        assert row["coll_bytes_per_device"] >= 0
        print("dryrun ok", row["dominant"])
    """, n_devices=512)


def test_dryrun_multipod_smoke():
    run_subprocess("""
        from repro.launch.dryrun import dryrun_one
        row = dryrun_one("mamba2-370m", "train_4k", multi_pod=True,
                         verbose=False)
        assert "error" not in row, row
        assert row["n_devices"] == 512
        print("multipod ok", row["dominant"])
    """, n_devices=512)


def test_moe_fine_group_dispatch_matches_local():
    """§Perf D3 default: under sequence parallelism the MoE dispatch runs
    in (batch × seq-shard) groups — outputs must still match the unsharded
    reference (capacity pattern changes, so compare with the same grouping
    applied locally)."""
    run_subprocess("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.nn import sharding as shd
        from repro.nn.moe import MoEFFN

        moe = MoEFFN(64, 32, 8, 2, n_shared=1, capacity_factor=8.0)
        # capacity_factor high enough that nothing drops -> grouping can't
        # change results
        key = jax.random.PRNGKey(0)
        p, _ = moe.init(key)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
        shd.set_mesh(None)
        y0, _ = moe.apply(p, {}, x)
        devs = np.asarray(jax.devices()[:8]).reshape(4, 2)
        mesh = Mesh(devs, ("data", "model"))
        rules = dict(shd.DEFAULT_RULES, seq="model")   # sequence parallelism
        shd.set_mesh(mesh, rules)
        with mesh:
            y1, _ = jax.jit(lambda p, x: moe.apply(p, {}, x))(p, x)
        err = float(jnp.abs(y0 - y1).max())
        assert err < 1e-5, err
        print("moe fine-group match", err)
    """)


def test_moe_sharded_matches_local():
    run_subprocess("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.nn import sharding as shd
        from repro.nn.moe import MoEFFN

        moe = MoEFFN(64, 32, 8, 2, n_shared=1)
        key = jax.random.PRNGKey(0)
        p, _ = moe.init(key)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))
        shd.set_mesh(None)
        y0, _ = moe.apply(p, {}, x)
        devs = np.asarray(jax.devices()[:8]).reshape(4, 2)
        mesh = Mesh(devs, ("data", "model"))
        shd.set_mesh(mesh)
        with mesh:
            y1, _ = jax.jit(lambda p, x: moe.apply(p, {}, x))(p, x)
        err = float(jnp.abs(y0 - y1).max())
        assert err < 1e-5, err
        print("moe sharded match", err)
    """)
