"""NSGA-II invariants + convergence on a known discrete front."""

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.nsga2 import (crowding_distance, dominates,
                              fast_non_dominated_sort, nsga2)


def test_dominates():
    assert dominates(np.array([1, 1]), np.array([2, 2]))
    assert dominates(np.array([1, 2]), np.array([2, 2]))
    assert not dominates(np.array([1, 3]), np.array([2, 2]))
    assert not dominates(np.array([2, 2]), np.array([2, 2]))


@given(st.lists(st.tuples(st.floats(0, 10), st.floats(0, 10)),
                min_size=2, max_size=40))
@settings(max_examples=50, deadline=None)
def test_front0_mutually_nondominating(pts):
    F = np.array(pts)
    fronts = fast_non_dominated_sort(F)
    f0 = fronts[0]
    for i in f0:
        for j in f0:
            assert not dominates(F[i], F[j])


@given(st.lists(st.tuples(st.floats(0, 10), st.floats(0, 10)),
                min_size=3, max_size=30))
@settings(max_examples=40, deadline=None)
def test_domination_implies_earlier_front(pts):
    F = np.array(pts)
    fronts = fast_non_dominated_sort(F)
    rank = {}
    for r, fr in enumerate(fronts):
        for i in fr:
            rank[int(i)] = r
    n = len(F)
    for i in range(n):
        for j in range(n):
            if dominates(F[i], F[j]):
                assert rank[i] < rank[j]


def test_crowding_boundary_infinite():
    F = np.array([[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]])
    d = crowding_distance(F)
    assert np.isinf(d[0]) and np.isinf(d[3])
    assert np.isfinite(d[1]) and np.isfinite(d[2])


def test_nsga2_converges_discrete_front():
    """min (x/50, (50-x)/50) over integers: whole range is the true front;
    NSGA-II must find a spread of non-dominated points + respect constraint
    x >= 10."""
    def evaluate(X):
        x = X[:, 0].astype(float)
        F = np.stack([x / 50.0, (50.0 - x) / 50.0], axis=1)
        CV = np.maximum(0.0, 10.0 - x) / 10.0
        return F, CV

    res = nsga2(evaluate, n_var=1, lower=0, upper=50, pop_size=24,
                n_gen=30, seed=1)
    xs = res.pareto_X[:, 0]
    assert (xs >= 10).all()
    assert len(np.unique(xs)) >= 5       # decent spread
    # all returned points feasible & mutually non-dominating
    F, CV = evaluate(res.pareto_X)
    assert (CV <= 0).all()


def test_nsga2_multi_cut_sorted():
    def evaluate(X):
        F = np.stack([X.sum(1).astype(float), (X.max(1) - X.min(1)).astype(float)],
                     axis=1)
        return F, np.zeros(len(X))
    res = nsga2(evaluate, n_var=3, lower=0, upper=20, pop_size=16, n_gen=10,
                seed=0)
    for x in res.X:
        assert list(x) == sorted(x)
