import os
import sys

# tests run on the single real CPU device; the dry-run subprocess tests set
# their own XLA_FLAGS (do NOT set host_platform_device_count globally here)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
