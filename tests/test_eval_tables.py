"""EvalTables device export: field-for-field round-trip against the NumPy
evaluator tables, lazy-export caching, and the ``donate_argnums`` contract
of the jitted NSGA-II runners (the donated ``X0`` buffer must actually be
consumed, or every run holds two copies of the largest array alive)."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import nsga2_jax  # noqa: E402
from repro.core.accuracy import ProxyAccuracy  # noqa: E402
from repro.core.graph import linearize  # noqa: E402
from repro.core.partition import PartitionEvaluator  # noqa: E402
from repro.core.partition_jax import build_eval_tables  # noqa: E402
from repro.explore import PlatformSpec, SystemSpec  # noqa: E402
from repro.models.cnn.zoo import build_cnn  # noqa: E402

FOUR_PLATFORM = SystemSpec(
    platforms=(PlatformSpec("A0", "eyr", bits=16),
               PlatformSpec("A1", "eyr", bits=16),
               PlatformSpec("B0", "smb", bits=8),
               PlatformSpec("B1", "smb", bits=8)),
    links=("gige", "gige", "gige"))


@pytest.fixture(scope="module")
def evaluator():
    graph = build_cnn("efficientnet_b0", in_hw=64).to_graph()
    system = FOUR_PLATFORM.build()
    schedule = linearize(graph, "min_memory")
    return PartitionEvaluator(graph, schedule, system,
                              accuracy_fn=ProxyAccuracy(schedule, system))


def f32(x):
    return np.asarray(x, dtype=np.float32)


# -- device-export round-trip -------------------------------------------------

def test_jax_tables_roundtrip_matches_numpy(evaluator):
    """Every exported device array equals its NumPy source (after the
    documented float32 cast) — the jitted evaluator is only trustworthy if
    the tables it gathers from are bit-faithful to the host evaluator's."""
    t = evaluator.jax_tables()
    system = evaluator.system
    plats = system.platforms
    L = len(evaluator.schedule)

    assert t.L == L
    assert t.n_cuts == system.n_cuts
    assert t.batch == evaluator.batch

    np.testing.assert_array_equal(
        np.asarray(t.cost_prefix),
        f32(np.stack([evaluator._prefix[p.arch.name] for p in plats])))
    np.testing.assert_array_equal(np.asarray(t.cut_elems),
                                  f32(evaluator.cut_elements()))
    np.testing.assert_array_equal(
        np.asarray(t.producer_bpe),
        f32([p.quant.bits / 8.0 for p in plats[:-1]]))

    links = system.links
    np.testing.assert_array_equal(np.asarray(t.link_rate),
                                  f32([l.rate_bps for l in links]))
    np.testing.assert_array_equal(np.asarray(t.link_setup),
                                  f32([l.t_setup_s for l in links]))
    np.testing.assert_array_equal(np.asarray(t.link_payload),
                                  f32([l.payload_bytes for l in links]))
    np.testing.assert_array_equal(np.asarray(t.link_header),
                                  f32([l.header_bytes for l in links]))
    np.testing.assert_array_equal(np.asarray(t.link_power),
                                  f32([l.p_tx_w + l.p_rx_w for l in links]))
    np.testing.assert_array_equal(np.asarray(t.link_e_byte),
                                  f32([l.e_per_byte_j for l in links]))

    mt = evaluator._memtable
    np.testing.assert_array_equal(np.asarray(t.mem_base_prefix),
                                  f32(mt.base_prefix))
    np.testing.assert_array_equal(np.asarray(t.act_sparse),
                                  f32(mt.act_sparse))
    assert len(t.mem_groups) == len(mt.groups)
    for (jpos, jpar), (pos, par) in zip(t.mem_groups, mt.groups):
        np.testing.assert_array_equal(np.asarray(jpos),
                                      np.asarray(pos, dtype=np.int32))
        np.testing.assert_array_equal(np.asarray(jpar), f32(par))

    np.testing.assert_array_equal(
        np.asarray(t.bytes_per_param),
        f32([p.memory_model.bytes_per_param for p in plats]))
    np.testing.assert_array_equal(
        np.asarray(t.bytes_per_act),
        f32([p.memory_model.act_bytes for p in plats]))
    np.testing.assert_array_equal(np.asarray(t.capacity),
                                  f32([p.capacity for p in plats]))

    wpre, noise, base, scale = evaluator.accuracy_fn.proxy_arrays()
    assert t.supports_accuracy
    np.testing.assert_array_equal(np.asarray(t.acc_weight_prefix), f32(wpre))
    np.testing.assert_array_equal(np.asarray(t.acc_noise), f32(noise))
    assert t.acc_base == pytest.approx(float(base))
    assert t.acc_scale == pytest.approx(float(scale))


def test_jax_tables_is_cached(evaluator):
    """The export is lazy and memoized — strategies re-request it per
    search, so rebuilding would re-upload every table each time."""
    assert evaluator.jax_tables() is evaluator.jax_tables()


def test_build_eval_tables_no_accuracy_oracle():
    graph = build_cnn("efficientnet_b0", in_hw=64).to_graph()
    system = FOUR_PLATFORM.build()
    schedule = linearize(graph, "min_memory")
    ev = PartitionEvaluator(graph, schedule, system)
    t = build_eval_tables(ev)
    assert not t.supports_accuracy
    assert t.acc_weight_prefix is None and t.acc_noise is None


# -- donation contract --------------------------------------------------------

def _backend_deletes_donated():
    """Probe whether this backend honors donation by deleting the donor
    (CPU does on current jax; some backends ignore donation hints)."""
    f = jax.jit(lambda x: x + 1, donate_argnums=(0,))
    x = jnp.ones(8)
    f(x)
    return x.is_deleted()


def test_jit_runner_donates_x0():
    """make_jit_runner's X0 really is donated: the input population buffer
    is consumed by the call, so peak memory is one population, not two."""
    if not _backend_deletes_donated():
        pytest.skip("backend does not delete donated buffers")

    def eval_fn(X):
        F = jnp.stack([X.sum(axis=1), -X.sum(axis=1)], axis=1)
        return F.astype(jnp.float32), jnp.zeros(X.shape[0], jnp.float32)

    pop, n_var = 32, 4
    run = nsga2_jax.make_jit_runner(eval_fn, n_var=n_var, lower=-1,
                                    upper=9, pop_size=pop)
    key = jax.random.PRNGKey(0)
    X0 = jnp.zeros((pop, n_var), jnp.int32)
    X, F, CV = run(key, X0, 2)
    assert X0.is_deleted(), "X0 was not donated"
    assert not key.is_deleted(), "only argnum 1 should be donated"
    assert X.shape == (pop, n_var) and F.shape[0] == pop


def test_jit_restart_runner_donates_x0s():
    if not _backend_deletes_donated():
        pytest.skip("backend does not delete donated buffers")

    def eval_fn(X):
        F = jnp.stack([X.sum(axis=1), -X.sum(axis=1)], axis=1)
        return F.astype(jnp.float32), jnp.zeros(X.shape[0], jnp.float32)

    pop, n_var, restarts = 16, 3, 2
    run = nsga2_jax.make_jit_restart_runner(eval_fn, n_var=n_var, lower=-1,
                                            upper=9, pop_size=pop)
    keys = jax.random.split(jax.random.PRNGKey(0), restarts)
    X0s = jnp.zeros((restarts, pop, n_var), jnp.int32)
    X, F, CV = run(keys, X0s, 2)
    assert X0s.is_deleted(), "X0s was not donated"
    assert X.shape == (restarts, pop, n_var)
