"""compare_bench.py sustained-drift gate: the least-squares slope over the
last-K comparable trend runs catches slow regressions the per-run ±20%
gate waves through, skips incomparable/short series, and credits
improvements."""

import json
import os
import subprocess
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

from compare_bench import fit_drift, trend_series  # noqa: E402

BENCH = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                     "compare_bench.py")


def make_runs(values, key="batch_evals_per_s", schema=3, mode="quick"):
    return [{"sha": f"s{i}", "date": "2026-08-01", "mode": mode,
             "bench_schema": schema, "metrics": {key: v}}
            for i, v in enumerate(values)]


# -- pure pieces --------------------------------------------------------------

def test_fit_drift_linear_series():
    # 100 -> 130 linearly: fit drift = +30 / mean(115) ≈ +26%
    series = [100 + 5 * i for i in range(7)]
    assert fit_drift(series) == pytest.approx(30 / 115, rel=1e-6)


def test_fit_drift_flat_and_noisy_endpoint():
    assert fit_drift([50.0] * 6) == 0.0
    # one crashed last point barely moves the fit (last-vs-first would
    # report -50%)
    series = [100.0] * 9 + [50.0]
    assert abs(fit_drift(series)) < 0.35
    assert (series[-1] - series[0]) / series[0] == -0.5


def test_trend_series_filters_incomparable_runs():
    runs = (make_runs([1, 2], schema=2)            # old schema: excluded
            + make_runs([3], mode="full")          # other mode: excluded
            + make_runs([10, 11, 12, 13]))
    trend = {"runs": runs}
    assert trend_series(trend, "batch_evals_per_s", 3, "quick",
                        window=8) == [10, 11, 12, 13]
    assert trend_series(trend, "batch_evals_per_s", 3, "quick",
                        window=2) == [12, 13]
    assert trend_series(trend, "missing_key", 3, "quick", window=8) == []


# -- the gate end-to-end ------------------------------------------------------

def run_gate(tmp_path, runs, extra_args=(), cur_metrics=None):
    cur = {"bench_schema": 3, "mode": "quick"}
    cur.update(cur_metrics or {})
    cp = tmp_path / "cur.json"
    tp = tmp_path / "trend.json"
    cp.write_text(json.dumps(cur))
    tp.write_text(json.dumps({"trend_schema": 1, "runs": runs}))
    return subprocess.run(
        [sys.executable, BENCH, "--current", str(cp),
         "--baseline", str(tmp_path / "missing.json"),
         "--trend", str(tp), *extra_args],
        capture_output=True, text=True, timeout=60)


def test_sustained_regression_fails(tmp_path):
    # -5%/run for 8 runs: each step passes a 20% gate, the trend must not
    runs = make_runs([100 * 0.95 ** i for i in range(8)])
    r = run_gate(tmp_path, runs)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "SUSTAINED REGRESSION" in r.stdout
    assert "sustained trend regression" in r.stderr


def test_flat_and_improving_trends_pass(tmp_path):
    r = run_gate(tmp_path, make_runs([100.0] * 8))
    assert r.returncode == 0, r.stdout + r.stderr
    # improvement in a lower-is-better metric must not be flagged either
    runs = make_runs([10 * 0.9 ** i for i in range(8)],
                     key="campaign_wall_s")
    r = run_gate(tmp_path, runs)
    assert r.returncode == 0, r.stdout + r.stderr
    # but a *rising* wall time is a regression
    runs = make_runs([10 * 1.06 ** i for i in range(8)],
                     key="campaign_wall_s")
    r = run_gate(tmp_path, runs)
    assert r.returncode == 1
    assert "campaign_wall_s" in r.stdout


def test_short_series_skipped(tmp_path):
    r = run_gate(tmp_path, make_runs([100, 50]))   # 2 points: no verdict
    assert r.returncode == 0, r.stdout + r.stderr
    assert "<3 comparable points" in r.stdout


def test_window_and_threshold_flags(tmp_path):
    # old cliff followed by a flat recent window: a tight window passes,
    # a wide one sees the cliff
    runs = make_runs([200.0] * 4 + [100.0] * 4)
    r = run_gate(tmp_path, runs, ["--trend-window", "4"])
    assert r.returncode == 0, r.stdout + r.stderr
    r = run_gate(tmp_path, runs, ["--trend-window", "8"])
    assert r.returncode == 1
    # threshold is adjustable
    r = run_gate(tmp_path, runs, ["--trend-window", "8",
                                  "--max-trend-regression", "0.95"])
    assert r.returncode == 0, r.stdout + r.stderr


def test_missing_trend_file_is_not_fatal(tmp_path):
    cur = tmp_path / "cur.json"
    cur.write_text(json.dumps({"bench_schema": 3, "mode": "quick"}))
    r = subprocess.run(
        [sys.executable, BENCH, "--current", str(cur),
         "--baseline", str(tmp_path / "missing.json"),
         "--trend", str(tmp_path / "no_trend.json")],
        capture_output=True, text=True, timeout=60)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "skipping the sustained-drift check" in r.stdout
