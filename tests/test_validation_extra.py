"""Deeper validation: NSGA-II vs exhaustive ground truth, SSM prefill
equivalence, MoE dispatch properties, multi-stage LM pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import Platform, QuantSpec, SystemConfig, get_link
from repro.core.hwmodel import EYERISS_LIKE, SIMBA_LIKE
from repro.core.nsga2 import dominates
from repro.explore import SearchSettings, explore_graph
from repro.models.cnn.zoo import build_cnn
from repro.models.registry import build_model, get_config


def test_nsga_recovers_exhaustive_front():
    """On a single-cut system the exhaustive Pareto front is ground truth;
    NSGA-II must return only non-dominated points w.r.t. it."""
    g = build_cnn("squeezenet11", in_hw=64).to_graph()
    system = SystemConfig(
        [Platform("A", EYERISS_LIKE, QuantSpec(bits=16)),
         Platform("B", SIMBA_LIKE, QuantSpec(bits=8))],
        [get_link("gige")])
    objectives = ("latency", "energy")
    res_exh = explore_graph(
        g, system, objectives=objectives,
        search=SearchSettings(strategy="exhaustive", seed=0))
    res_nsga = explore_graph(
        g, system, objectives=objectives,
        search=SearchSettings(strategy="nsga2", seed=1, pop_size=24,
                              n_gen=20))
    F_exh = np.array([e.as_objectives(objectives) for e in res_exh.pareto])
    for ev in res_nsga.pareto:
        f = np.array(ev.as_objectives(objectives))
        assert not any(dominates(g_, f) for g_ in F_exh), \
            f"NSGA point {ev.cuts} dominated by exhaustive front"


def test_ssm_prefill_equals_stepwise():
    """Multi-token prefill into the SSM cache == token-by-token decode."""
    cfg = get_config("mamba2-370m").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, cfg.vocab)

    caches = model.init_caches(2, 32, jnp.float32)
    logits_pre, caches_pre = model.decode_step(params, caches,
                                               {"tokens": toks})
    caches2 = model.init_caches(2, 32, jnp.float32)
    outs = []
    for i in range(10):
        lg, caches2 = model.decode_step(params, caches2,
                                        {"tokens": toks[:, i:i + 1]})
        outs.append(lg[:, 0])
    step_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(step_logits), rtol=2e-3, atol=2e-3)
    # final SSM states match
    np.testing.assert_allclose(
        np.asarray(caches_pre["mamba"]["ssm"]),
        np.asarray(caches2["mamba"]["ssm"]), rtol=2e-3, atol=2e-3)


def test_hybrid_prefill_equals_stepwise():
    cfg = get_config("zamba2-2.7b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    c1 = model.init_caches(1, 32, jnp.float32)
    logits_pre, _ = model.decode_step(params, c1, {"tokens": toks})
    c2 = model.init_caches(1, 32, jnp.float32)
    outs = []
    for i in range(8):
        lg, c2 = model.decode_step(params, c2, {"tokens": toks[:, i:i + 1]})
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(jnp.stack(outs, 1)),
                               rtol=3e-3, atol=3e-3)


@given(st.integers(1, 6), st.integers(2, 5), st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_moe_dispatch_conserves_tokens(k, e_pow, seed):
    """Every kept (token, choice) lands in exactly one slot and returns with
    its router weight; capacity-dropped tokens contribute zero."""
    from repro.nn.moe import _dispatch, _combine
    e = 2 ** e_pow
    k = min(k, e)
    key = jax.random.PRNGKey(seed)
    b, t, d = 2, 16, 8
    x = jax.random.normal(key, (b, t, d))
    idx = jax.random.randint(jax.random.PRNGKey(seed + 1), (b, t, k), 0, e)
    cap = max(int(t * k * 1.25 / e), 4)
    x_e, slot, keep = _dispatch(x, idx, cap, e, k)
    # identity combine weights: output = sum over kept choices of the token
    wk = jnp.ones((b, t, k))
    y = _combine(x_e, slot, wk)
    n_kept = np.asarray(keep.sum(-1))            # kept choices per token
    expected = np.asarray(x) * n_kept[..., None]
    np.testing.assert_allclose(np.asarray(y), expected, rtol=1e-5, atol=1e-5)


def test_lm_pipeline_three_stages():
    from repro.serving.pipeline import PartitionedLMRunner
    import dataclasses
    cfg = dataclasses.replace(get_config("smollm-360m").reduced(), n_layers=6)
    model = build_model(cfg)
    params, state = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                                          cfg.vocab)}
    mono, _ = model.apply(params, state, batch, train=False)
    runner = PartitionedLMRunner(model, params, cuts=[1, 3])
    piped, rep = runner.forward(batch)
    np.testing.assert_allclose(np.asarray(piped), np.asarray(mono),
                               rtol=1e-5, atol=1e-5)
    assert len(rep.latency_s) == 3
