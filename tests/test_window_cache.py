"""Sliding-window ring cache: decode past the wrap-around must match a
full-cache reference — the corner that long_500k dense decode lives on."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import build_model, get_config


def test_ring_cache_matches_full_cache_after_wrap():
    cfg = get_config("smollm-360m").reduced()          # window=64 reduced
    window = cfg.window
    assert window is not None
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    n_tokens = window + 24                              # force wrap-around
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, n_tokens), 0,
                              cfg.vocab)

    # windowed model with a ring cache of exactly `window` slots
    ring = model.init_caches(1, capacity=n_tokens + 8, dtype=jnp.float32)
    # init_caches clamps capacity to window for windowed configs
    assert jax.tree_util.tree_leaves(ring)[0].shape
    ring_logits = []
    for i in range(n_tokens):
        lg, ring = model.decode_step(params, ring, {"tokens": toks[:, i:i+1]})
        ring_logits.append(lg[:, 0])

    # reference: full-capacity cache on a window-masked model — the mask
    # logic (not the ring storage) defines the semantics
    cfg_full = dataclasses.replace(cfg)
    model_full = build_model(cfg_full)
    # force a big cache by pretending there's no window, then apply the
    # window via the full forward (teacher forcing) which masks correctly
    full_logits, _ = model_full.apply(params, {}, {"tokens": toks},
                                      train=False)
    ring_stack = jnp.stack(ring_logits, axis=1)
    np.testing.assert_allclose(np.asarray(ring_stack),
                               np.asarray(full_logits),
                               rtol=3e-3, atol=3e-3)


def test_window_cache_capacity_clamped():
    cfg = get_config("qwen3-14b").reduced()
    model = build_model(cfg)
    caches = model.init_caches(2, capacity=10_000, dtype=jnp.float32)
    k = caches["dense"]["k"]
    assert k.shape[2] == cfg.window     # (L, B, S=window, kv, hd)
