"""Requests, synthetic traffic, and per-request serving accounting.

A :class:`Request` is one generation job (prompt + decode budget) with an
arrival offset; :func:`poisson_traffic` draws a stream of them from
``repro.data.synthetic`` token prompts with exponential inter-arrival gaps.
:class:`RequestRecord` is what the runtime hands back — tokens plus the
latency breakdown (TTFT = first decoded token, end-to-end latency) — and
:class:`ServeReport` aggregates records into the throughput/latency summary
the benchmarks gate on.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.data.synthetic import SyntheticTokens
from repro.obs.stats import latency_summary


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (T_prompt,) int32
    max_new: int = 16
    arrival_s: float = 0.0      # offset from stream start
    deadline_s: Optional[float] = None   # absolute finish-by offset; a
    # failover router sheds (finish='shed') instead of re-admitting a
    # recovered request whose deadline already passed

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        assert self.prompt.ndim == 1 and self.prompt.size > 0
        assert self.max_new > 0


@dataclasses.dataclass
class RequestRecord:
    """Completed (or in-flight) request bookkeeping, wall-clock seconds
    measured from the serving run's start."""
    rid: int
    prompt_len: int
    max_new: int
    submit_s: float = 0.0
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    finish: Optional[str] = None        # 'eos' | 'length' | 'lost' | 'shed'
    replica: Optional[str] = None

    @property
    def done(self) -> bool:
        """True once the request finished successfully (EOS or length)."""
        return self.done_s is not None

    @property
    def failed(self) -> bool:
        """True when the request terminated without completing: ``'lost'``
        (stranded by replica death, retry budget exhausted) or ``'shed'``
        (deadline passed before a failover re-admission)."""
        return self.finish in ("lost", "shed")

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (None before the first token lands)."""
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.submit_s

    @property
    def latency_s(self) -> Optional[float]:
        """Submit-to-finish wall seconds (None while running)."""
        if self.done_s is None:
            return None
        return self.done_s - self.submit_s

    def n_valid_tokens(self, eos: Optional[int]) -> int:
        """Pre-EOS tokens this request contributed."""
        if eos is None:
            return len(self.tokens)
        toks = np.asarray(self.tokens, np.int32)
        hit = np.flatnonzero(toks == eos)
        return int(hit[0]) if hit.size else len(self.tokens)


def poisson_traffic(n_requests: int, rate_rps: float, vocab: int,
                    prompt_len: int = 16, max_new: int = 16,
                    seed: int = 0) -> List[Request]:
    """A Poisson request stream: exponential inter-arrival gaps at
    ``rate_rps`` requests/s, prompts drawn from the learnable
    ``SyntheticTokens`` bigram process (fixed ``prompt_len`` so the
    prefill program compiles once)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n_requests)
    arrivals = np.cumsum(gaps) - gaps[0]          # first request at t=0
    prompts = SyntheticTokens(vocab, seed=seed).batch(
        n_requests, prompt_len, seed=seed)[:, :-1]
    return [Request(rid=i, prompt=prompts[i], max_new=max_new,
                    arrival_s=float(arrivals[i]))
            for i in range(n_requests)]


@dataclasses.dataclass
class ServeReport:
    """Aggregate view over a finished serving run."""
    records: List[RequestRecord]
    wall_s: float
    eos: Optional[int] = None
    extra: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def n_done(self) -> int:
        """Requests that finished (EOS or length)."""
        return sum(1 for r in self.records if r.done)

    @property
    def n_failed(self) -> int:
        """Requests that terminated without completing (lost or shed) —
        never silent: a stranded request always leaves a failed record."""
        return sum(1 for r in self.records if r.failed)

    @property
    def total_tokens(self) -> int:
        """Generated tokens summed over all records (EOS excluded)."""
        return sum(r.n_valid_tokens(self.eos) for r in self.records)

    @property
    def tokens_per_s(self) -> float:
        """Aggregate decode throughput over the serving wall clock."""
        return self.total_tokens / self.wall_s if self.wall_s > 0 else 0.0

    def summary(self) -> Dict[str, float]:
        """Flat metrics dict: throughput, TTFT/latency percentiles, and
        the engine's Def.-4 stats when present."""
        done = [r for r in self.records if r.done]
        ttfts = [r.ttft_s for r in done if r.ttft_s is not None]
        lats = [r.latency_s for r in done]
        out = {
            "n_requests": len(self.records),
            "n_done": len(done),
            "n_failed": self.n_failed,
            "wall_s": round(self.wall_s, 4),
            "total_tokens": self.total_tokens,
            "tokens_per_s": round(self.tokens_per_s, 1),
        }
        # one percentile definition for the whole repo: nearest-rank from
        # repro.obs.stats (matches the trace CLI's breakdown exactly)
        if ttfts:
            s = latency_summary(ttfts, unit=1e3)
            out["ttft_p50_ms"] = round(s["p50"], 2)
            out["ttft_p95_ms"] = round(s["p95"], 2)
        if lats:
            s = latency_summary(lats, unit=1e3)
            out["latency_p50_ms"] = round(s["p50"], 2)
            out["latency_p95_ms"] = round(s["p95"], 2)
        out.update(self.extra)
        return out
