"""Measured system health: EWMA link/stage estimators, failure detection,
and hysteresis-gated divergence monitoring.

PR 8's drift loop re-partitions when *told* the system changed.  This
module closes the loop with measurement, using only signals the serve
runtime already produces:

* every link shuttle reports each transfer's ``(bytes, measured wall,
  modeled wall)`` — :class:`HealthMonitor` folds them into EWMA occupancy
  estimates whose ratio (measured / modeled) is a unitless **divergence**
  of the live link from the deployed :class:`SystemSpec`;
* every stage worker heartbeats each queue poll — a worker stuck inside a
  stalled stage call stops heartbeating, which :class:`FailureDetector`
  turns into a stalled-stage verdict (no false positives on a healthy but
  *idle* worker: idle workers keep polling, and so keep heartbeating);
* :class:`DivergenceMonitor` compares the estimates against the deployed
  system with **hysteresis** — an enter threshold held for ``min_breach``
  consecutive observations fires a :class:`DriftSignal`, an exit threshold
  clears the alarm, and a cool-down bounds the re-partition rate — so a
  transient congestion spike never thrashes deployments.

Everything here is host-side bookkeeping (no JAX) and deterministic under
an injected clock: tests drive ``observe(..., now=...)`` with synthetic
samples and explicit timestamps.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Deque, Dict, List, Optional, Tuple

from repro.explore.spec import SystemSpec
from repro.obs.handle import NOOP_OBS, Obs

# divergence observations retained for the drift timeline artifact; at the
# drift driver's 50 Hz poll this holds ~20 minutes of history
_HISTORY_MAX = 65536


class Ewma:
    """Exponentially weighted moving average: ``v <- (1-a)*v + a*x``.

    ``alpha`` trades smoothing for reaction time; ``value`` is the raw
    first sample until a second arrives.  ``n`` counts samples so callers
    can gate decisions on estimator maturity."""

    def __init__(self, alpha: float = 0.25):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self._value: Optional[float] = None
        self.n = 0

    def update(self, x: float) -> float:
        """Fold one sample in; returns the updated average."""
        self._value = (float(x) if self._value is None
                       else (1.0 - self.alpha) * self._value
                       + self.alpha * float(x))
        self.n += 1
        return self._value

    @property
    def value(self) -> float:
        """Current average (0.0 before any sample)."""
        return self._value if self._value is not None else 0.0


class _LinkHealth:
    __slots__ = ("measured_s", "model_s", "bytes_total")

    def __init__(self, alpha: float):
        self.measured_s = Ewma(alpha)
        self.model_s = Ewma(alpha)
        self.bytes_total = 0


class HealthMonitor:
    """Thread-safe collector of live serve-runtime health samples.

    One monitor observes one replica: ``n_stages`` workers (heartbeats +
    per-item busy time) and ``n_links`` shuttles (per-transfer bytes,
    measured wall, modeled wall).  All accessors are cheap and lock-guarded
    so the driver, the router, and a :class:`DivergenceMonitor` can read
    while workers write."""

    def __init__(self, n_stages: int, n_links: int, *, alpha: float = 0.25):
        if n_stages <= 0 or n_links < 0:
            raise ValueError("need n_stages > 0 and n_links >= 0")
        self.n_stages = n_stages
        self.n_links = n_links
        self._lock = threading.Lock()
        self._links = [_LinkHealth(alpha) for _ in range(n_links)]
        self._stage_busy = [Ewma(alpha) for _ in range(n_stages)]
        self._heartbeat: List[Optional[float]] = [None] * n_stages

    # -- writers (called from worker threads) -------------------------------
    def heartbeat(self, stage: int, now: float) -> None:
        """Record liveness of a stage worker at monotonic time ``now``."""
        with self._lock:
            self._heartbeat[stage] = now

    def record_stage(self, stage: int, busy_s: float, now: float) -> None:
        """Record one processed work item: ``busy_s`` of stage occupancy
        (also counts as a heartbeat)."""
        with self._lock:
            self._stage_busy[stage].update(busy_s)
            self._heartbeat[stage] = now

    def record_link(self, link: int, nbytes: int, measured_s: float,
                    model_s: float) -> None:
        """Record one transfer: wire bytes, measured wall seconds (sleep +
        host overhead, i.e. what the resource actually cost), and the wall
        the deployed spec's :class:`~repro.core.link.LinkModel` predicts."""
        with self._lock:
            lh = self._links[link]
            lh.measured_s.update(measured_s)
            lh.model_s.update(model_s)
            lh.bytes_total += int(nbytes)

    # -- readers -------------------------------------------------------------
    def link_samples(self, link: int) -> int:
        """Transfers observed on ``link`` so far."""
        with self._lock:
            return self._links[link].measured_s.n

    def link_divergence(self, link: int) -> float:
        """Measured / modeled occupancy ratio of ``link`` (1.0 = exactly
        as deployed; 8.0 = transfers take 8x the spec's prediction; 1.0
        when the link has no samples or the model predicts zero)."""
        with self._lock:
            lh = self._links[link]
            if lh.measured_s.n == 0 or lh.model_s.value <= 0:
                return 1.0
            return lh.measured_s.value / lh.model_s.value

    def link_rate_bps(self, link: int) -> float:
        """Effective live link rate estimate: EWMA bytes-per-wall-second
        over observed transfers (0.0 with no samples)."""
        with self._lock:
            lh = self._links[link]
            if lh.measured_s.n == 0 or lh.measured_s.value <= 0:
                return 0.0
            return (lh.bytes_total / lh.measured_s.n * 8.0
                    / lh.measured_s.value)

    def stage_occupancy_s(self, stage: int) -> float:
        """EWMA per-item busy seconds of ``stage`` (0.0 with no samples)."""
        with self._lock:
            return self._stage_busy[stage].value

    def last_heartbeat(self, stage: int) -> Optional[float]:
        """Monotonic time of the stage worker's last heartbeat (None
        before the worker first reported)."""
        with self._lock:
            return self._heartbeat[stage]

    def snapshot(self) -> Dict[str, object]:
        """Flat summary for reports: per-link divergence and per-stage
        occupancy (rounded for stable artifacts)."""
        return {
            "link_divergence": [round(self.link_divergence(li), 3)
                                for li in range(self.n_links)],
            "stage_occupancy_s": [round(self.stage_occupancy_s(si), 6)
                                  for si in range(self.n_stages)],
        }


class FailureDetector:
    """Missed-heartbeat failure detector over a :class:`HealthMonitor`.

    A stage worker is *stalled* when it has heartbeat at least once and
    then gone silent for longer than ``timeout_s``.  Healthy-but-idle
    workers heartbeat on every queue poll, so a clean run never trips the
    detector (tested); a worker stuck inside a stalled stage call does."""

    def __init__(self, monitor: HealthMonitor, timeout_s: float = 1.0):
        if timeout_s <= 0:
            raise ValueError(f"timeout_s must be > 0, got {timeout_s}")
        self.monitor = monitor
        self.timeout_s = timeout_s

    def stalled(self, now: Optional[float] = None) -> List[int]:
        """Stage indices silent for longer than ``timeout_s`` at ``now``
        (default: the live monotonic clock)."""
        t = time.monotonic() if now is None else now
        out = []
        for si in range(self.monitor.n_stages):
            hb = self.monitor.last_heartbeat(si)
            if hb is not None and t - hb > self.timeout_s:
                out.append(si)
        return out

    def healthy(self, now: Optional[float] = None) -> bool:
        """True when no stage worker is currently stalled."""
        return not self.stalled(now)


@dataclasses.dataclass(frozen=True)
class DriftSignal:
    """One fired divergence alarm: link index, the measured divergence
    ratio at fire time, and the observation timestamp."""

    link: int
    divergence: float
    at_s: float


class DivergenceMonitor:
    """Hysteresis-gated drift detector: observed system vs deployed spec.

    Each :meth:`observe` call compares every link's measured divergence
    (from a :class:`HealthMonitor`) against the deployed
    :class:`SystemSpec`'s implicit 1.0:

    * divergence >= ``enter`` for ``min_breach`` *consecutive*
      observations fires a :class:`DriftSignal` (a shorter spike never
      fires — the anti-thrash half of hysteresis);
    * once fired, the link is *in alarm* and cannot re-fire until its
      divergence falls to <= ``exit`` (the other half: a link hovering
      around the enter threshold triggers exactly once);
    * ``cooldown_s`` rate-limits fires globally, bounding how often the
      (expensive, deployment-swapping) re-partition downstream can run;
    * links with fewer than ``min_samples`` transfers are ignored —
      estimator warm-up noise cannot fire the alarm.

    :meth:`drifted_system` converts the fired state into a same-shape
    drifted ``SystemSpec`` (measured divergence as the degradation
    factor) ready for ``OnlineRepartitioner.update(..,
    trigger="measured")``; after re-deploying, :meth:`rebase` resets the
    monitor against the new deployed spec.
    """

    def __init__(self, system: SystemSpec, *, enter: float = 2.0,
                 exit: float = 1.3, min_breach: int = 3,
                 cooldown_s: float = 5.0, min_samples: int = 4,
                 obs: Optional[Obs] = None):
        if enter <= exit:
            raise ValueError(f"need enter > exit for hysteresis, got "
                             f"enter={enter} exit={exit}")
        if min_breach < 1:
            raise ValueError(f"min_breach must be >= 1, got {min_breach}")
        self.system = system
        self.enter = enter
        self.exit = exit
        self.min_breach = min_breach
        self.cooldown_s = cooldown_s
        self.min_samples = min_samples
        n_links = len(system.links)
        self._breach = [0] * n_links
        self._alarm = [False] * n_links
        self._fired_div = [1.0] * n_links
        self._last_fire_s: Optional[float] = None
        self.signals: List[DriftSignal] = []
        # every observation's (t, per-link divergence) — the
        # measured-vs-modeled series the drift timeline artifact persists
        self.history: Deque[Tuple[float, Tuple[float, ...]]] = \
            collections.deque(maxlen=_HISTORY_MAX)
        self.obs = obs if obs is not None else NOOP_OBS

    def observe(self, monitor: HealthMonitor,
                now: Optional[float] = None) -> Optional[DriftSignal]:
        """Fold one health observation in; returns the fired
        :class:`DriftSignal` when a link crosses the hysteresis gate (at
        most one per call), else None."""
        t = time.monotonic() if now is None else now
        fired = None
        n_links = len(self.system.links)
        divs = tuple(monitor.link_divergence(li) if li < monitor.n_links
                     else 1.0 for li in range(n_links))
        self.history.append((t, divs))
        for li in range(n_links):
            if li >= monitor.n_links:
                continue            # deployment uses fewer links than spec
            if monitor.link_samples(li) < self.min_samples:
                continue
            div = divs[li]
            if self._alarm[li]:
                if div <= self.exit:           # recovered: re-arm the link
                    self._alarm[li] = False
                    self._breach[li] = 0
                    self._fired_div[li] = 1.0
                continue
            if div >= self.enter:
                self._breach[li] += 1
            else:
                self._breach[li] = 0
            in_cooldown = (self._last_fire_s is not None
                           and t - self._last_fire_s < self.cooldown_s)
            if (self._breach[li] >= self.min_breach and not in_cooldown
                    and fired is None):
                self._alarm[li] = True
                self._fired_div[li] = div
                self._last_fire_s = t
                fired = DriftSignal(link=li, divergence=div, at_s=t)
                self.signals.append(fired)
                if self.obs.enabled:
                    self.obs.tracer.instant(
                        "drift_signal", cat="health", track="health/drift",
                        args={"link": li, "divergence": round(div, 3)})
                    self.obs.metrics.counter("drift_signals_fired").inc()
                    self.obs.metrics.gauge(
                        f"link{li}_divergence").set(round(div, 4))
        return fired

    @property
    def alarmed_links(self) -> List[int]:
        """Links currently in alarm (fired, not yet recovered)."""
        return [li for li, a in enumerate(self._alarm) if a]

    def drifted_system(self) -> SystemSpec:
        """The deployed spec with every alarmed link degraded by its
        measured divergence — the same-shape system snapshot a measured
        re-partition runs against (returns the deployed spec unchanged
        when nothing is in alarm)."""
        from repro.explore.online import degrade_link
        system = self.system
        for li in self.alarmed_links:
            system = degrade_link(system, li, self._fired_div[li])
        return system

    def rebase(self, system: SystemSpec) -> None:
        """Reset against a newly deployed spec (after acting on a signal):
        clears alarms, breach counters, and the cool-down clock."""
        self.system = system
        n_links = len(system.links)
        self._breach = [0] * n_links
        self._alarm = [False] * n_links
        self._fired_div = [1.0] * n_links
        self._last_fire_s = None
