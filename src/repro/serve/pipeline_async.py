"""Partitioned serving runtime: continuous batching over async pipeline
stages.

:class:`PipelineServeEngine` serves a live request stream over the stages
of a :class:`repro.serving.pipeline.PartitionedLMRunner`:

* **Slots & waves.**  The ``n_slots`` decode slots are split into
  ``n_groups`` independent waves (default: one per stage).  Each wave is a
  vmapped batch of per-slot cache lanes (own write positions — see
  ``SlotDecoder``), admitted/evicted per-request by the
  :class:`~repro.serve.scheduler.SlotScheduler`.
* **Async double buffering** (``mode='async'``).  One worker thread per
  stage and one shuttle thread per inter-stage link, connected by bounded
  queues.  Autoregressive decode has a feedback edge (step t+1 needs step
  t's sampled token), so a single wave can never overlap with itself; with
  ``n_groups >= n_stages`` waves in flight, stage k+1 computes wave A's
  step while wave B's activations cross the link into stage k — the
  steady-state step rate approaches Def. 4's ``1/max(stage, link)``.
* **Links.**  Activations crossing stage k -> k+1 are fake-quantized to
  the producer's bit width (the existing ``link_transfer_bytes`` /
  ``QuantSpec`` path) and the wire time of an emulated
  :class:`~repro.core.link.LinkModel` is slept in the shuttle thread, so
  transfers genuinely overlap with compute.
* **Serial baseline** (``mode='serial'``).  Identical scheduler, stage
  programs and link emulation, lockstep handoff in one thread — per step
  it pays ``sum(stage + link)``.  This is the baseline the >=1.5x
  ``serve_bench`` gate compares against, and byte-identical greedy tokens
  across the two modes is a tested invariant.

Thread-side code here is *host* code on purpose: it samples tokens with
NumPy and calls ``.item()``-like syncs outside any jit region (the jitted
programs are the per-stage step functions).  See CONTRIBUTING.md
("RPR1xx-safe patterns").
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.link import LinkModel
from repro.core.quant import QuantSpec, quantize_tensor
from repro.obs.handle import NOOP_OBS, Obs
from repro.obs.stats import mean_tail
from repro.serve.faults import FaultPlan, FaultTrace, ReplicaCrashError
from repro.serve.health import HealthMonitor
from repro.serve.request import Request, RequestRecord, ServeReport
from repro.serve.scheduler import SlotScheduler
from repro.serving.engine import _bump_pos
from repro.serving.pipeline import (PartitionedLMRunner, def4_throughput,
                                    link_transfer_bytes)


class RequestStream:
    """Thread-safe request feed: a traffic player / router pushes, a serve
    engine drains.  ``close()`` marks end-of-stream."""

    def __init__(self):
        self._lock = threading.Lock()
        self._pending: List[Request] = []
        self._closed = False

    def push(self, req: Request) -> None:
        """Append one request; raises ValueError after :meth:`close`."""
        with self._lock:
            if self._closed:
                raise ValueError("push to a closed RequestStream")
            self._pending.append(req)

    def close(self) -> None:
        """Stop accepting requests; the engine drains what remains."""
        with self._lock:
            self._closed = True

    def drain(self) -> List[Request]:
        """Take (and clear) everything pushed since the last drain."""
        with self._lock:
            out, self._pending = self._pending, []
            return out

    @property
    def pending(self) -> int:
        """Requests pushed but not yet drained by the engine."""
        with self._lock:
            return len(self._pending)

    @property
    def closed(self) -> bool:
        """True once closed *and* fully drained."""
        with self._lock:
            return self._closed and not self._pending


def stream_of(requests: List[Request]) -> RequestStream:
    """A pre-closed stream delivering ``requests`` as one burst."""
    s = RequestStream()
    for r in requests:
        s.push(r)
    s.close()
    return s


@dataclasses.dataclass
class ServeLink:
    """Emulated inter-stage link: the producer's bit width quantizes the
    activation crossing it; an optional :class:`LinkModel` prices the wire
    time (slept by the shuttle thread / the serial loop)."""
    model: Optional[LinkModel] = None
    quant: Optional[QuantSpec] = None

    def transfer(self, x):
        """-> (activation as received, wire bytes, wire seconds)."""
        nbytes = link_transfer_bytes(int(x.size), self.quant)
        if self.quant is not None:
            x = quantize_tensor(x, self.quant)
        lat = self.model.latency_s(nbytes) if self.model is not None else 0.0
        return x, nbytes, lat


@dataclasses.dataclass
class _Item:
    """One unit of pipeline work: a wave decode step or a single-lane
    prompt prefill."""
    kind: str                   # 'decode' | 'prefill'
    group: int
    lane: int = -1              # prefill only
    x: Any = None               # tokens entering stage 0, then activations
    link_s: float = 0.0         # accumulated emulated wire seconds


_STOP = object()

# idle stage workers poll their queue at this period so they keep
# heartbeating the HealthMonitor — a quiet queue must not look like a hang
_IDLE_POLL_S = 0.05


class _PrioQueue:
    """Two-priority queue: decode items overtake prefill items.
    Admission prefills ship whole-prompt activations (long transfers /
    long stage calls) and must not head-of-line-block the steady-state
    decode waves; reordering across kinds is safe because the driver never
    lets a wave's decode and its own prefill be in flight together.

    Built from deques + a semaphore rather than ``queue.PriorityQueue``:
    per-item queue cost sits on the steady-state step path, and the
    heap/Condition machinery is measurably slower than C-level semaphore
    handoff.  Depth is bounded by the driver's per-wave in-flight gating,
    so no ``maxsize`` blocking is needed.
    """

    def __init__(self):
        import collections
        self._dqs = [collections.deque(), collections.deque(),
                     collections.deque()]    # decode | prefill | stop
        self._sem = threading.Semaphore(0)
        self._lock = threading.Lock()

    def put(self, item) -> None:
        if item is _STOP:
            prio = 2                      # drain everything else first
        else:
            prio = 0 if item.kind == "decode" else 1
        with self._lock:
            self._dqs[prio].append(item)
        self._sem.release()

    def get(self, timeout: Optional[float] = None):
        """Pop the highest-priority item; with ``timeout``, returns None
        when nothing arrives in time (lets idle workers heartbeat)."""
        if not self._sem.acquire(timeout=timeout):
            return None
        with self._lock:
            for dq in self._dqs:
                if dq:
                    return dq.popleft()
        raise RuntimeError("semaphore/queue accounting out of sync")


class _StageRuntime:
    """One stage's jitted programs + per-wave cache lanes.

    ``decode`` runs the vmapped step over a whole wave (every lane advances
    one token; idle lanes compute from a sentinel cache and are never
    sampled); ``prefill`` runs the single-lane step over a full prompt and
    splices the resulting cache into the wave.
    """

    def __init__(self, runner: PartitionedLMRunner, si: int, lanes: int,
                 n_groups: int, capacity: int, dtype=jnp.float32):
        self.si = si
        self.runner = runner
        self.capacity = capacity
        self.dtype = dtype
        self.weights = runner.stage_weights(si)
        fn = runner.stage_step_fn(si)
        self._step_group = jax.jit(jax.vmap(fn, in_axes=(None, 0, 0)))
        self._step_one = jax.jit(fn)
        # jits are functional, so one immutable zero cache serves every
        # admission; the lane splice is jitted to fuse the per-leaf scatters
        self._fresh = runner.init_stage_caches(si, 1, capacity, dtype)
        self._splice = jax.jit(lambda full, one, lane: jax.tree_util.tree_map(
            lambda f, o: f.at[lane].set(o), full, one))
        idle = _bump_pos(self._fresh)
        self.caches = [jax.tree_util.tree_map(
            lambda x: jnp.stack([x] * lanes), idle) for _ in range(n_groups)]
        self.decode_s: List[float] = []      # per-item compute seconds

    def decode(self, g: int, x):
        t0 = time.perf_counter()
        out, self.caches[g] = self._step_group(self.weights, self.caches[g], x)
        jax.block_until_ready(out)
        self.decode_s.append(time.perf_counter() - t0)
        return out

    def prefill(self, g: int, lane: int, x):
        out, new = self._step_one(self.weights, self._fresh, x)
        self.caches[g] = self._splice(self.caches[g], new, lane)
        jax.block_until_ready(out)
        return out

    def run_item(self, item: _Item):
        if item.kind == "decode":
            item.x = self.decode(item.group, item.x)
        else:
            item.x = self.prefill(item.group, item.lane, item.x)
        return item


class PipelineServeEngine:
    """Continuous-batching serve engine over partitioned LM stages (see
    module docstring).  One instance is one replica; drive it with
    :meth:`run` on a :class:`RequestStream` (directly, or via
    ``repro.serve.router.ReplicaRouter``)."""

    def __init__(self, runner: PartitionedLMRunner, *, n_slots: int = 8,
                 n_groups: Optional[int] = None, eos: Optional[int] = None,
                 links: Optional[List[ServeLink]] = None,
                 capacity: int = 128, temperature: float = 0.0,
                 seed: int = 0, mode: str = "async", name: str = "replica0",
                 faults: Optional[FaultPlan] = None,
                 health: Optional[HealthMonitor] = None,
                 obs: Optional[Obs] = None):
        if mode not in ("async", "serial"):
            raise ValueError(f"mode must be 'async' or 'serial', got {mode!r}")
        self.runner = runner
        self.n_stages = runner.n_stages
        self.n_groups = n_groups or self.n_stages
        if n_slots < self.n_groups or n_slots % self.n_groups:
            raise ValueError(
                f"n_slots={n_slots} must be a positive multiple of "
                f"n_groups={self.n_groups} (each wave holds "
                f"n_slots // n_groups cache lanes)")
        self.lanes = n_slots // self.n_groups
        self.n_slots = n_slots
        self.eos = eos
        self.temperature = temperature
        self.seed = seed
        self.mode = mode
        self.name = name
        self.links = list(links) if links else [
            ServeLink() for _ in range(self.n_stages - 1)]
        assert len(self.links) == self.n_stages - 1
        self.stages = [_StageRuntime(runner, si, self.lanes, self.n_groups,
                                     capacity)
                       for si in range(self.n_stages)]
        # per-link decode occupancy: measured wall (transfer + sleep, i.e.
        # what the link resource actually costs on this host) and the pure
        # modeled wire seconds, kept separately
        self.link_decode_s: List[List[float]] = [[] for _ in self.links]
        self.link_model_s: List[List[float]] = [[] for _ in self.links]
        self._sched: Optional[SlotScheduler] = None
        self.stats: Dict[str, float] = {}
        # fault injection + measured health; a shared HealthMonitor may be
        # passed in so a DivergenceMonitor / FailureDetector outside the
        # engine observes this replica live
        self.faults = faults if faults is not None else FaultPlan()
        self.fault_trace = FaultTrace()
        self.health = health if health is not None else HealthMonitor(
            self.n_stages, len(self.links))
        self._link_xfers = [0] * len(self.links)
        self._stage_items = [0] * self.n_stages
        # on a crash/failure exit, records finished before death land here
        # so the router can merge them and re-admit only the unfinished
        self.crash_records: Dict[int, RequestRecord] = {}
        # spans land on tracks under this replica's name: stage/link rows
        # from the worker threads, sched/driver/requests rows from the
        # driver; NOOP_OBS keeps every site a single attribute check
        self.obs = obs if obs is not None else NOOP_OBS

    # -- wave helpers --------------------------------------------------------
    def _slot(self, g: int, lane: int) -> int:
        return g * self.lanes + lane

    def _group_tokens(self, sched: SlotScheduler, g: int) -> np.ndarray:
        toks = np.zeros(self.lanes, np.int32)
        for lane in range(self.lanes):
            slot = self._slot(g, lane)
            if sched.slot_request(slot) is not None:
                toks[lane] = sched.last_token(slot)
        return toks

    def _group_active(self, sched: SlotScheduler, g: int) -> bool:
        return any(sched.slot_request(self._slot(g, ln)) is not None
                   for ln in range(self.lanes))

    def _sample(self, logits: np.ndarray, rid: int, step: int) -> int:
        if self.temperature <= 0:
            return int(np.argmax(logits))
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed, rid, step)))
        g = rng.gumbel(size=logits.shape)
        return int(np.argmax(logits / self.temperature + g))

    def warmup(self, prompt_len: int) -> None:
        """Compile every stage program (wave decode + one prompt length)
        before the serving clock starts, so TTFT measures serving, not XLA."""
        x = jnp.zeros((self.lanes, 1, 1), jnp.int32)
        p = jnp.zeros((1, prompt_len), jnp.int32)
        for st in self.stages:
            x, _ = st._step_group(st.weights, st.caches[0], x)
            p, new = st._step_one(st.weights, st._fresh, p)
            st._splice(st.caches[0], new, 0)    # discarded: compile only
        jax.block_until_ready((x, p))

    # -- execution backends --------------------------------------------------
    def _stage_run(self, si: int, item: _Item) -> None:
        """Run one work item through stage ``si``, applying any scheduled
        stall and reporting occupancy + heartbeat to the health monitor.
        The per-stage item counter is owned by the single thread running
        this stage, so fault indices are exact."""
        k = self._stage_items[si]
        self._stage_items[si] = k + 1
        stall = self.faults.stage_stall_s(si, k)
        if stall > 0:
            self.fault_trace.record("stage_stall", si, k, stall)
            if self.obs.enabled:
                self.obs.tracer.instant(
                    "stage_stall", cat="fault",
                    track=f"{self.name}/stage{si}",
                    args={"item": k, "stall_s": stall})
                self.obs.metrics.counter("serve_faults_injected").inc()
            time.sleep(stall)
        t0 = time.perf_counter()
        self.stages[si].run_item(item)
        t1 = time.perf_counter()
        self.health.record_stage(si, t1 - t0, time.monotonic())
        if self.obs.enabled:
            # reuse the health clock reads: tracing adds no clock calls here
            self.obs.tracer.complete(
                item.kind, cat="stage", track=f"{self.name}/stage{si}",
                start=t0, end=t1, args={"group": item.group})
            self.obs.metrics.counter("serve_stage_items").inc()

    def _link_run(self, li: int, item: _Item) -> None:
        """Push one activation across link ``li``: quantize, sleep the
        (possibly degraded + jittered) wire time, report measured vs
        modeled occupancy.  The transfer counter is owned by the single
        thread shuttling this link."""
        k = self._link_xfers[li]
        self._link_xfers[li] = k + 1
        t0 = time.perf_counter()
        x, nbytes, lat = self.links[li].transfer(item.x)
        factor = self.faults.link_factor(li, k)
        jitter = self.faults.link_jitter(li, k)
        if factor != 1.0:
            self.fault_trace.record("link_degrade", li, k, factor)
            if self.obs.enabled:
                self.obs.tracer.instant(
                    "link_degrade", cat="fault",
                    track=f"{self.name}/link{li}",
                    args={"xfer": k, "factor": factor})
                self.obs.metrics.counter("serve_faults_injected").inc()
        if jitter > 0.0:
            self.fault_trace.record("link_jitter", li, k, jitter)
        sleep_s = lat * factor + jitter
        if sleep_s > 0:
            time.sleep(sleep_s)
        t1 = time.perf_counter()
        if item.kind == "decode":
            wall = t1 - t0
            self.link_decode_s[li].append(wall)
            self.link_model_s[li].append(lat)
            # the monitor sees measured wall vs the *deployed spec's*
            # prediction — divergence is how it learns about the fault
            self.health.record_link(li, nbytes, wall, lat)
        if self.obs.enabled:
            # modeled wire time rides along with the measured wall so a
            # trace viewer shows the divergence per transfer
            self.obs.tracer.complete(
                item.kind, cat="link", track=f"{self.name}/link{li}",
                start=t0, end=t1,
                args={"bytes": nbytes, "group": item.group,
                      "wall_ms": round((t1 - t0) * 1e3, 3),
                      "model_ms": round(lat * 1e3, 3)})
            self.obs.metrics.counter("serve_link_transfers").inc()
        item.x = x
        item.link_s += sleep_s

    def _serial_dispatch(self, item: _Item, done: "queue.SimpleQueue"):
        for si in range(self.n_stages):
            self._stage_run(si, item)
            if si < len(self.links):
                self._link_run(si, item)
        item.x = np.asarray(item.x)
        done.put(item)

    def _start_workers(self, done: "queue.SimpleQueue"):
        """stage 0 -> link 0 -> stage 1 -> ... -> done; each arrow is a
        bounded queue, each box a thread."""
        self._qs = [_PrioQueue() for _ in range(2 * self.n_stages - 1)]
        self._errors: List[BaseException] = []
        self._threads = []

        def stage_worker(si):
            in_q = self._qs[2 * si]
            last = si == self.n_stages - 1
            out_q = done if last else self._qs[2 * si + 1]
            while True:
                item = in_q.get(timeout=_IDLE_POLL_S)
                if item is None:                   # idle poll: still alive
                    self.health.heartbeat(si, time.monotonic())
                    continue
                if item is _STOP:
                    out_q.put(_STOP)
                    return
                try:
                    # _stage_run heartbeats on completion; a worker stuck
                    # inside a stalled stage call heartbeats *nothing*,
                    # which is exactly what FailureDetector catches
                    self._stage_run(si, item)
                    if last:
                        # hand the driver host memory: the device->host copy
                        # belongs in this worker, not on the driver's
                        # critical sampling path
                        item.x = np.asarray(item.x)
                    out_q.put(item)
                except BaseException as e:          # surface in the driver
                    self._errors.append(e)
                    out_q.put(_STOP)
                    return

        def link_worker(li):
            in_q, out_q = self._qs[2 * li + 1], self._qs[2 * li + 2]
            while True:
                item = in_q.get()
                if item is _STOP:
                    out_q.put(_STOP)
                    return
                try:
                    self._link_run(li, item)
                    out_q.put(item)
                except BaseException as e:
                    self._errors.append(e)
                    out_q.put(_STOP)
                    return

        for si in range(self.n_stages):
            t = threading.Thread(target=stage_worker, args=(si,),
                                 name=f"{self.name}-stage{si}", daemon=True)
            t.start()
            self._threads.append(t)
        for li in range(len(self.links)):
            t = threading.Thread(target=link_worker, args=(li,),
                                 name=f"{self.name}-link{li}", daemon=True)
            t.start()
            self._threads.append(t)

    # -- the serve loop ------------------------------------------------------
    @property
    def outstanding(self) -> int:
        """Queued + in-flight requests (the router's load signal)."""
        sched = self._sched
        return sched.outstanding if sched is not None else 0

    @property
    def n_submitted(self) -> int:
        """Requests this run has drained into its scheduler so far (the
        router's drained-everything signal; 0 outside a run)."""
        sched = self._sched
        return len(sched.records) if sched is not None else 0

    def run(self, stream: RequestStream,
            max_wall_s: float = 120.0) -> ServeReport:
        """Serve the stream to completion (admit -> prefill -> wave decode
        until idle and the stream closes); returns the ServeReport."""
        sched = SlotScheduler(self.n_slots, eos=self.eos, obs=self.obs,
                              track=f"{self.name}/sched")
        self._sched = sched
        for st in self.stages:                   # fresh per-run accounting
            st.decode_s = []
        self.link_decode_s = [[] for _ in self.links]
        self.link_model_s = [[] for _ in self.links]
        self.fault_trace = FaultTrace()          # per-run fault log
        self._link_xfers = [0] * len(self.links)
        self._stage_items = [0] * self.n_stages
        self.crash_records = {}
        crash_at = self.faults.crash_step
        done: "queue.SimpleQueue" = queue.SimpleQueue()
        if self.mode == "async":
            self._start_workers(done)
            dispatch = self._qs[0].put
        else:
            self._errors = []
            dispatch = lambda item: self._serial_dispatch(item, done)  # noqa: E731

        in_flight = [False] * self.n_groups
        pending_prefill = [0] * self.n_groups
        decode_done_t: List[float] = []
        t0 = time.perf_counter()
        now = lambda: time.perf_counter() - t0  # noqa: E731

        def admit_and_dispatch():
            # payloads stay numpy here: the jitted stage programs do the
            # host->device transfer in their own worker thread
            for req in stream.drain():
                sched.submit(req, now())
            for slot, req in sched.admit():
                g, lane = divmod(slot, self.lanes)
                pending_prefill[g] += 1
                dispatch(_Item("prefill", g, lane, x=req.prompt[None]))
            for g in range(self.n_groups):
                if (not in_flight[g] and pending_prefill[g] == 0
                        and self._group_active(sched, g)):
                    in_flight[g] = True
                    toks = self._group_tokens(sched, g)
                    dispatch(_Item("decode", g,
                                   x=toks.reshape(self.lanes, 1, 1)))

        def handle(item: _Item):
            logits = item.x                        # np, converted stage-side
            if item.kind == "prefill":
                g, lane = item.group, item.lane
                pending_prefill[g] -= 1
                slot = self._slot(g, lane)
                req = sched.slot_request(slot)
                if req is not None:
                    tok = self._sample(logits[0, -1], req.rid, 0)
                    sched.record_token(slot, tok, now())
            else:
                g = item.group
                in_flight[g] = False
                decode_done_t.append(now())
                for lane in range(self.lanes):
                    slot = self._slot(g, lane)
                    req = sched.slot_request(slot)
                    if req is None:
                        continue
                    rec = sched.records[req.rid]
                    if not rec.tokens:
                        # Admitted into a free lane after this wave was
                        # dispatched (streaming arrival): these logits
                        # predate the request — its first token comes from
                        # its in-flight prefill.  Lanes genuinely in the
                        # wave always have >=1 token, because decode
                        # dispatch requires pending_prefill[g] == 0.
                        continue
                    tok = self._sample(logits[lane, 0, -1], req.rid,
                                       len(rec.tokens))
                    sched.record_token(slot, tok, now())

        try:
            while True:
                if self._errors:
                    raise RuntimeError(
                        "serve worker failed") from self._errors[0]
                if crash_at is not None and len(decode_done_t) >= crash_at:
                    self.fault_trace.record("replica_crash", 0,
                                            len(decode_done_t))
                    if self.obs.enabled:
                        # marks where this replica's tracks end in the trace
                        self.obs.tracer.instant(
                            "replica_crash", cat="fault",
                            track=f"{self.name}/driver",
                            args={"step": len(decode_done_t)})
                        self.obs.metrics.counter(
                            "serve_replica_crashes").inc()
                    raise ReplicaCrashError(self.name, len(decode_done_t))
                admit_and_dispatch()
                try:
                    item = done.get(timeout=0.002)
                except queue.Empty:
                    item = None
                got_any = False
                while item is not None:            # drain the whole burst
                    if item is not _STOP:
                        handle(item)
                        got_any = True
                    try:
                        item = done.get_nowait()
                    except queue.Empty:
                        item = None
                if got_any:
                    admit_and_dispatch()
                if (stream.closed and sched.idle and not any(in_flight)
                        and not any(pending_prefill)):
                    break
                if now() > max_wall_s:
                    raise TimeoutError(
                        f"serve run exceeded {max_wall_s}s "
                        f"({sched.outstanding} request(s) outstanding)")
            wall = now()
        except BaseException:
            # stash what *did* finish before death so a router can merge
            # these records and re-admit only the genuinely unfinished
            for rid, rec in sched.records.items():
                if rec.done:
                    rec.replica = self.name
                    self.crash_records[rid] = rec
            if self.obs.enabled:
                # finished-before-crash requests still get their spans on
                # this replica's track; the unfinished ones re-appear on
                # whichever survivor the router re-admits them to
                self._emit_request_spans(self.crash_records.values(), t0)
            raise
        finally:
            # error/timeout exits must not leak worker threads (blocked in
            # _PrioQueue.get) or leave the router seeing stale outstanding
            # load for a dead replica
            self._sched = None
            if self.mode == "async":
                self._qs[0].put(_STOP)
                for t in self._threads:
                    t.join(timeout=10.0)
        self._finalize_stats(wall, decode_done_t)
        for rec in sched.records.values():
            rec.replica = self.name
        if self.obs.enabled:
            self.obs.tracer.complete(
                "serve", cat="driver", track=f"{self.name}/driver",
                start=t0, dur=wall,
                args={"mode": self.mode,
                      "decode_steps": len(decode_done_t)})
            self._emit_request_spans(sched.records.values(), t0)
        return ServeReport(records=list(sched.records.values()),
                           wall_s=wall, eos=self.eos,
                           extra=dict(self.stats))

    def _emit_request_spans(self, records, t0: float) -> None:
        """One ``cat='request'`` span per finished record on this
        replica's ``requests`` track, rebuilt from the scheduler's
        bookkeeping (``t0``: the run's ``perf_counter`` origin).  Span
        start/duration equal the record's submit/latency exactly, so the
        ``python -m repro.obs`` breakdown reconciles with
        ``ServeReport.summary()``."""
        for rec in records:
            if not rec.done:
                continue
            args = {"rid": rec.rid, "tokens": len(rec.tokens),
                    "finish": rec.finish, "prompt_len": rec.prompt_len}
            if rec.ttft_s is not None:
                args["ttft_ms"] = round(rec.ttft_s * 1e3, 3)
                self.obs.metrics.histogram("serve_ttft_ms").observe(
                    rec.ttft_s * 1e3)
            if rec.latency_s is not None:
                self.obs.metrics.histogram("serve_latency_ms").observe(
                    rec.latency_s * 1e3)
            self.obs.tracer.complete(
                f"req{rec.rid}", cat="request",
                track=f"{self.name}/requests",
                start=t0 + rec.submit_s, dur=rec.latency_s or 0.0,
                args=args)

    def _finalize_stats(self, wall: float, decode_done_t: List[float]):
        """Measured step rate vs the Def.-4 prediction from per-stage /
        per-link decode times (first ``2 * n_groups`` items dropped: XLA
        warm-up when :meth:`warmup` was skipped, queue fill otherwise).

        Def. 4 takes each resource's *occupancy per item* as input; on this
        emulated deployment that is the measured wall a stage / link spends
        per wave step, so the prediction is fed measured occupancies
        (``stage_step_s`` / ``link_step_s``).  The pure modeled wire time is
        reported alongside as ``link_model_s``.
        """
        skip = 2 * self.n_groups
        stage_means = [mean_tail(st.decode_s, skip) for st in self.stages]
        link_means = [mean_tail(xs, skip) for xs in self.link_decode_s]
        link_model = [mean_tail(xs, skip) for xs in self.link_model_s]
        steps = len(decode_done_t)
        steady = decode_done_t[skip:]
        if len(steady) >= 2:
            measured = (len(steady) - 1) / (steady[-1] - steady[0])
        elif steps >= 1 and wall > 0:
            measured = steps / wall
        else:
            measured = 0.0
        self.stats = {
            "mode": self.mode,
            "decode_steps": steps,
            "stage_step_s": [round(t, 6) for t in stage_means],
            "link_step_s": [round(t, 6) for t in link_means],
            "link_model_s": [round(t, 6) for t in link_model],
            "def4_steps_per_s": round(def4_throughput(stage_means,
                                                      link_means), 2),
            "measured_steps_per_s": round(measured, 2),
            "faults_injected": len(self.fault_trace),
        }
