"""Slot-based continuous-batching scheduler (host-side, no JAX).

Fixed decode slots; requests wait in a FIFO queue, are admitted into free
slots (:meth:`SlotScheduler.admit`), decode one token per step, and are
evicted per-slot the moment they emit EOS or exhaust ``max_new`` — the
freed slot backfills from the waiting queue on the very next ``admit``.
No lockstep waves: every slot has its own request lifetime.

The scheduler owns all request bookkeeping (tokens, TTFT, latency) and is
deliberately execution-agnostic: ``SlotDecoder``, the async stage pipeline
and the serial baseline all drive the same instance, which is what makes
"byte-identical tokens across execution modes" checkable.

Invariants (tested under randomized arrival/EOS patterns):
  * no slot leak — every slot is always either free or owned by exactly
    one in-flight request, and eviction always frees it;
  * no cross-request token bleed — a token recorded against slot ``i``
    lands only in the record of the request *currently* owning ``i``;
  * immediate backfill — after ``admit()``, a slot is only free if the
    waiting queue is empty.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.obs.handle import NOOP_OBS, Obs
from repro.serve.request import Request, RequestRecord


@dataclasses.dataclass
class _SlotState:
    req: Request
    record: RequestRecord
    n_generated: int = 0

    @property
    def position(self) -> int:
        """Next token position = prompt length + tokens generated so far."""
        return self.req.prompt.shape[0] + self.n_generated


class SlotScheduler:
    """Continuous-batching slot allocator + request bookkeeper.

    ``n_slots`` fixed decode slots; :meth:`submit` queues a request,
    :meth:`admit` fills free slots FIFO, :meth:`record_token` appends one
    decoded token and evicts on EOS/length so the slot backfills next
    admit.  Execution-agnostic: the async pipeline, the serial baseline
    and the monolithic engine all drive the same instance (see module
    docstring for the tested invariants)."""

    def __init__(self, n_slots: int, eos: Optional[int] = None, *,
                 obs: Optional[Obs] = None, track: str = "sched"):
        assert n_slots > 0
        self.n_slots = n_slots
        self.eos = eos
        self._slots: List[Optional[_SlotState]] = [None] * n_slots
        self._waiting: collections.deque = collections.deque()
        self.records: Dict[int, RequestRecord] = {}
        # request-lifecycle events (submit/admit/finish instants + the
        # submitted/admitted/finished counters) land on `track`
        self.obs = obs if obs is not None else NOOP_OBS
        self.track = track

    # -- queue side ----------------------------------------------------------
    def submit(self, req: Request, now: float = 0.0) -> RequestRecord:
        """Enqueue a request (FIFO) and open its record; rejects duplicate
        request ids."""
        if req.rid in self.records:
            raise ValueError(f"duplicate request id {req.rid}")
        rec = RequestRecord(rid=req.rid, prompt_len=req.prompt.shape[0],
                            max_new=req.max_new, submit_s=now)
        self.records[req.rid] = rec
        self._waiting.append(req)
        if self.obs.enabled:
            self.obs.tracer.instant("submit", cat="sched", track=self.track,
                                    args={"rid": req.rid})
            self.obs.metrics.counter("serve_requests_submitted").inc()
        return rec

    def admit(self) -> List[Tuple[int, Request]]:
        """Move waiting requests into free slots (FIFO), immediately and
        exhaustively: afterwards a free slot implies an empty queue.
        Returns the new (slot, request) assignments — the caller prefills
        them and records their first token via :meth:`record_token`."""
        placed = []
        for i in range(self.n_slots):
            if self._slots[i] is not None or not self._waiting:
                continue
            req = self._waiting.popleft()
            self._slots[i] = _SlotState(req, self.records[req.rid])
            placed.append((i, req))
        if placed and self.obs.enabled:
            for slot, req in placed:
                self.obs.tracer.instant(
                    "admit", cat="sched", track=self.track,
                    args={"rid": req.rid, "slot": slot})
            self.obs.metrics.counter("serve_requests_admitted").inc(
                len(placed))
        return placed

    # -- decode side ---------------------------------------------------------
    def record_token(self, slot: int, token: int,
                     now: float = 0.0) -> Optional[RequestRecord]:
        """Append one decoded token to the request owning ``slot``.  Evicts
        the slot (returning the finished record) on EOS or length; returns
        None while the request keeps running."""
        st = self._slots[slot]
        if st is None:
            raise ValueError(f"token recorded for free slot {slot}")
        st.record.tokens.append(int(token))
        st.n_generated += 1
        if st.record.first_token_s is None:
            st.record.first_token_s = now
        hit_eos = self.eos is not None and int(token) == self.eos
        if hit_eos or st.n_generated >= st.req.max_new:
            st.record.finish = "eos" if hit_eos else "length"
            st.record.done_s = now
            self._slots[slot] = None
            if self.obs.enabled:
                self.obs.tracer.instant(
                    "evict", cat="sched", track=self.track,
                    args={"rid": st.req.rid, "slot": slot,
                          "finish": st.record.finish})
                self.obs.metrics.counter("serve_requests_finished").inc()
            return st.record
        return None

    # -- views ---------------------------------------------------------------
    def active_slots(self) -> List[int]:
        """Indices of slots currently owned by an in-flight request."""
        return [i for i, s in enumerate(self._slots) if s is not None]

    def free_slots(self) -> List[int]:
        """Indices of unowned slots (empty unless the queue is drained)."""
        return [i for i, s in enumerate(self._slots) if s is None]

    def slot_request(self, slot: int) -> Optional[Request]:
        """The request owning ``slot``, or None when it is free."""
        st = self._slots[slot]
        return st.req if st is not None else None

    def position(self, slot: int) -> int:
        """Next token position of the slot's request (prompt length +
        tokens generated); raises on a free slot."""
        st = self._slots[slot]
        if st is None:
            raise ValueError(f"position of free slot {slot}")
        return st.position

    def last_token(self, slot: int) -> int:
        """The token the slot's request decodes *from* next step (its most
        recently generated token)."""
        st = self._slots[slot]
        if st is None or not st.record.tokens:
            raise ValueError(f"no generated token in slot {slot}")
        return st.record.tokens[-1]

    def unfinished_requests(self) -> List[Request]:
        """In-flight then waiting requests — what a failover router must
        re-admit elsewhere if this scheduler's engine dies."""
        active = [s.req for s in self._slots if s is not None]
        return active + list(self._waiting)

    @property
    def n_waiting(self) -> int:
        """Requests queued but not yet admitted."""
        return len(self._waiting)

    @property
    def n_active(self) -> int:
        """Slots currently decoding a request."""
        return self.n_slots - len(self.free_slots())

    @property
    def outstanding(self) -> int:
        """Queued + in-flight — the router's least-outstanding load signal."""
        return self.n_waiting + self.n_active

    @property
    def idle(self) -> bool:
        """No work anywhere: nothing active, nothing waiting."""
        return self.n_active == 0 and self.n_waiting == 0

    def check_invariants(self) -> None:
        """Assert the slot/bookkeeping invariants (used by tests)."""
        owners = [s.req.rid for s in self._slots if s is not None]
        assert len(owners) == len(set(owners)), "request owns two slots"
        waiting = [r.rid for r in self._waiting]
        assert not set(owners) & set(waiting), "request both active+waiting"
        for s in self._slots:
            if s is None:
                continue
            assert s.record is self.records[s.req.rid]
            assert not s.record.done, "finished request still holds a slot"
            assert s.n_generated == len(s.record.tokens) < s.req.max_new
