"""Deterministic fault injection for the serve runtime.

The paper's deployment environments (automotive buses, robot meshes) fail
in specific, recurring ways: links degrade, nodes stall, replicas die
mid-stream.  Testing the runtime's reaction to those failures is only
useful when every failure is **reproducible** — a crash that lands on a
different decode step each run cannot anchor a byte-identity assertion.

A :class:`FaultPlan` is a declarative schedule of fault events keyed on
*resource-local logical indices*, never on wall-clock time:

* :class:`LinkDegrade` applies from the link's Nth transfer (each link
  shuttle counts its own transfers — single-threaded per link, so the
  index is exact);
* :class:`StageStall` injects a one-shot host sleep before the stage's
  Nth work item (per-stage item counter, same argument);
* :class:`ReplicaCrash` raises :class:`ReplicaCrashError` in the driver
  after the Kth completed decode step (the driver is single-threaded, so
  the step count is exact);
* ``link_jitter_s`` adds seeded per-transfer jitter to every link sleep —
  drawn from ``SeedSequence((seed, link, transfer))``, so the same plan
  produces the same jitter trace on every run.

The engine records every *applied* fault in a :class:`FaultTrace` whose
:meth:`~FaultTrace.canonical` form is independent of thread interleaving
(entries are bucketed per resource and each resource's counter is owned by
exactly one thread).  ``tests/test_faults.py`` asserts that two runs of
the same plan produce identical canonical traces.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class LinkDegrade:
    """Slow link ``link`` down by ``factor`` from its ``at_transfer``-th
    transfer (0-based, counted per link) until ``until_transfer``
    (exclusive; ``None`` = permanent).  The emulated wire sleep is
    multiplied by ``factor``, exactly what a real rate drop does to the
    occupancy the health monitor measures."""

    link: int
    factor: float
    at_transfer: int = 0
    until_transfer: Optional[int] = None

    def __post_init__(self):
        if self.factor <= 0:
            raise ValueError(f"factor must be > 0, got {self.factor}")
        if self.link < 0 or self.at_transfer < 0:
            raise ValueError("link and at_transfer must be >= 0")


@dataclasses.dataclass(frozen=True)
class StageStall:
    """Stall stage ``stage`` for ``stall_s`` host seconds immediately
    before it processes its ``at_item``-th work item (0-based, counted
    per stage) — the hung-node scenario the failure detector must catch
    via missed heartbeats."""

    stage: int
    stall_s: float
    at_item: int = 0

    def __post_init__(self):
        if self.stall_s < 0:
            raise ValueError(f"stall_s must be >= 0, got {self.stall_s}")
        if self.stage < 0 or self.at_item < 0:
            raise ValueError("stage and at_item must be >= 0")


@dataclasses.dataclass(frozen=True)
class ReplicaCrash:
    """Kill the replica (raise :class:`ReplicaCrashError` in its driver
    loop) after ``at_step`` completed decode steps.  In-flight and queued
    requests are stranded — recovering them is the router's job."""

    at_step: int

    def __post_init__(self):
        if self.at_step < 0:
            raise ValueError(f"at_step must be >= 0, got {self.at_step}")


FaultEvent = Union[LinkDegrade, StageStall, ReplicaCrash]


class ReplicaCrashError(RuntimeError):
    """An injected replica crash (see :class:`ReplicaCrash`); carries the
    decode step at which the replica died."""

    def __init__(self, name: str, step: int):
        super().__init__(f"injected crash of {name} at decode step {step}")
        self.replica = name
        self.step = step


class FaultTrace:
    """Applied-fault log with a thread-interleaving-independent canonical
    form.  Entries are appended under a lock by whichever worker applied
    the fault; :meth:`canonical` buckets them per resource and sorts each
    bucket by the resource-local index, which is deterministic because
    each resource counter is owned by exactly one thread."""

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: List[Tuple] = []

    def record(self, kind: str, resource: int, index: int, *detail) -> None:
        """Append one applied-fault entry (thread-safe)."""
        with self._lock:
            self._entries.append((kind, resource, index) + detail)

    @property
    def entries(self) -> List[Tuple]:
        """Raw entries in append order (thread-interleaving dependent)."""
        with self._lock:
            return list(self._entries)

    def canonical(self) -> List[Tuple]:
        """Entries sorted by (kind, resource, index[, detail]) — the form
        two runs of the same plan must agree on byte-for-byte."""
        with self._lock:
            return sorted(self._entries)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


@dataclasses.dataclass
class FaultPlan:
    """A deterministic schedule of injected faults for one serve run.

    ``events`` is any mix of :class:`LinkDegrade`, :class:`StageStall`
    and :class:`ReplicaCrash`; ``link_jitter_s`` > 0 additionally perturbs
    every link sleep by a seeded uniform draw in ``[0, link_jitter_s)``.
    All lookups are pure functions of (resource, local index), so the
    same plan replayed over the same traffic injects the identical fault
    sequence — the property ``tests/test_faults.py`` pins down.
    """

    events: Tuple[FaultEvent, ...] = ()
    link_jitter_s: float = 0.0
    seed: int = 0

    def __post_init__(self):
        self.events = tuple(self.events)
        if self.link_jitter_s < 0:
            raise ValueError("link_jitter_s must be >= 0")
        self._stalls: Dict[Tuple[int, int], float] = {}
        crash = None
        for ev in self.events:
            if isinstance(ev, StageStall):
                key = (ev.stage, ev.at_item)
                self._stalls[key] = self._stalls.get(key, 0.0) + ev.stall_s
            elif isinstance(ev, ReplicaCrash):
                if crash is not None:
                    raise ValueError("a FaultPlan may hold at most one "
                                     "ReplicaCrash")
                crash = ev.at_step
            elif not isinstance(ev, LinkDegrade):
                raise TypeError(f"unknown fault event {ev!r}")
        self._crash_step = crash

    # -- link faults ---------------------------------------------------------
    def link_factor(self, link: int, transfer: int) -> float:
        """Wire-time multiplier for the link's ``transfer``-th transfer
        (compounds overlapping degradations; 1.0 = healthy)."""
        factor = 1.0
        for ev in self.events:
            if (isinstance(ev, LinkDegrade) and ev.link == link
                    and ev.at_transfer <= transfer
                    and (ev.until_transfer is None
                         or transfer < ev.until_transfer)):
                factor *= ev.factor
        return factor

    def link_jitter(self, link: int, transfer: int) -> float:
        """Seeded jitter seconds added to this transfer's wire sleep —
        a pure function of ``(seed, link, transfer)``."""
        if self.link_jitter_s <= 0:
            return 0.0
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed, link, transfer)))
        return float(rng.uniform(0.0, self.link_jitter_s))

    # -- stage faults --------------------------------------------------------
    def stage_stall_s(self, stage: int, item: int) -> float:
        """One-shot stall seconds before the stage's ``item``-th work item
        (0.0 = no stall scheduled there)."""
        return self._stalls.get((stage, item), 0.0)

    # -- replica faults ------------------------------------------------------
    @property
    def crash_step(self) -> Optional[int]:
        """Decode step after which the replica crashes (None = never)."""
        return self._crash_step
