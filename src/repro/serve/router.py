"""Replica router: spread a traffic stream across N partitioned pipelines.

Each replica is a :class:`~repro.serve.pipeline_async.PipelineServeEngine`
running in its own thread on its own :class:`RequestStream`.  The router
plays the traffic's arrival process (real-time, or as one burst) and sends
every request to the replica with the fewest outstanding requests
(queued + in-flight slots) at send time — classic least-outstanding load
balancing, which beats round-robin when decode lengths vary (EOS evictions
make per-request service times heavy-tailed).
"""

from __future__ import annotations

import threading
import time
from typing import List, Optional

from repro.serve.pipeline_async import PipelineServeEngine, RequestStream
from repro.serve.request import Request, ServeReport


class ReplicaRouter:
    """Least-outstanding load balancer over N replica serve engines.

    Construct with a list of :class:`PipelineServeEngine` instances (one
    thread each), then :meth:`serve` a request list; the merged
    :class:`ServeReport` aggregates every replica's records.  A replica
    failure closes its stream and surfaces as a RuntimeError after the
    remaining replicas drain."""

    def __init__(self, replicas: List[PipelineServeEngine]):
        assert replicas
        self.replicas = replicas

    def _pick(self, sent: List[int]) -> int:
        """Least outstanding; ties broken by fewest requests ever sent,
        then lowest index (deterministic for tests)."""
        load = [(r.outstanding, sent[i], i)
                for i, r in enumerate(self.replicas)]
        return min(load)[2]

    def serve(self, requests: List[Request], realtime: bool = True,
              max_wall_s: float = 120.0) -> ServeReport:
        """Play ``requests`` (sorted by ``arrival_s``) into the replica
        fleet and block until every request finishes.  ``realtime=False``
        ignores arrival gaps and routes the whole list as a burst."""
        streams = [RequestStream() for _ in self.replicas]
        reports: List[Optional[ServeReport]] = [None] * len(self.replicas)
        errors: List[BaseException] = []

        def run_replica(i):
            try:
                reports[i] = self.replicas[i].run(streams[i],
                                                  max_wall_s=max_wall_s)
            except BaseException as e:
                errors.append(e)
                streams[i].close()

        threads = [threading.Thread(target=run_replica, args=(i,),
                                    name=f"router-{r.name}", daemon=True)
                   for i, r in enumerate(self.replicas)]
        for t in threads:
            t.start()

        t0 = time.perf_counter()
        sent = [0] * len(self.replicas)
        for req in sorted(requests, key=lambda r: r.arrival_s):
            if errors:
                break          # a replica died — surface its error below
            if realtime:
                lag = req.arrival_s - (time.perf_counter() - t0)
                if lag > 0:
                    time.sleep(lag)
            i = self._pick(sent)
            try:
                streams[i].push(req)
            except ValueError:
                break          # run_replica closed the stream on failure
            sent[i] += 1
        for s in streams:
            s.close()
        for t in threads:
            t.join(timeout=max_wall_s + 10.0)
        if errors:
            raise RuntimeError("replica failed during serve") from errors[0]

        records = [rec for rep in reports if rep is not None
                   for rec in rep.records]
        wall = time.perf_counter() - t0
        extra = {"n_replicas": len(self.replicas),
                 "routed_per_replica": sent}
        for i, rep in enumerate(reports):
            if rep is not None:
                extra[f"replica{i}_tokens_per_s"] = round(rep.tokens_per_s, 1)
        eos = self.replicas[0].eos
        return ServeReport(records=records, wall_s=wall, eos=eos, extra=extra)
