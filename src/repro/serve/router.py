"""Replica router: spread a traffic stream across N partitioned pipelines,
recovering the requests of any replica that dies mid-stream.

Each replica is a :class:`~repro.serve.pipeline_async.PipelineServeEngine`
running in its own thread on its own :class:`RequestStream`.  The router
plays the traffic's arrival process (real-time, or as one burst) and sends
every request to the replica with the fewest outstanding requests
(queued + in-flight slots) at send time — classic least-outstanding load
balancing, which beats round-robin when decode lengths vary (EOS evictions
make per-request service times heavy-tailed).

**Failover.**  When a replica dies (an injected
:class:`~repro.serve.faults.ReplicaCrash`, or any worker error), the
router:

1. merges the records the dead replica *completed* before death (the
   engine stashes them in ``crash_records`` on its failure path);
2. re-admits every unfinished request to the surviving replicas —
   least-outstanding again — within a bounded per-request retry budget
   (``max_retries`` failovers) and sheds requests whose ``deadline_s``
   already passed instead of wasting survivor capacity on them;
3. records anything it cannot re-admit as an explicit failed record
   (``finish='lost'`` / ``'shed'``) in the merged report — a stranded
   request is **never silent**.

Recovered requests re-run from their prompt on a survivor, so under
greedy decoding their tokens are byte-identical to a no-fault run (the
tested invariant).  Only when *every* replica is dead does
:meth:`ReplicaRouter.serve` raise instead of reporting.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.obs.handle import NOOP_OBS, Obs
from repro.serve.pipeline_async import PipelineServeEngine, RequestStream
from repro.serve.request import Request, RequestRecord, ServeReport

# router poll period while waiting on arrivals / drain / failures
_POLL_S = 0.002


def _failed_record(req: Request, finish: str, now: float) -> RequestRecord:
    rec = RequestRecord(rid=req.rid, prompt_len=req.prompt.shape[0],
                        max_new=req.max_new, submit_s=now)
    rec.finish = finish
    return rec


class ReplicaRouter:
    """Least-outstanding load balancer over N replica serve engines, with
    crash failover (see module docstring).

    Construct with a list of :class:`PipelineServeEngine` instances (one
    thread each), then :meth:`serve` a request list; the merged
    :class:`ServeReport` aggregates every replica's records plus any
    salvaged / failed records from crashed replicas.  ``max_retries``
    bounds how many times one request may fail over before it is recorded
    as lost."""

    def __init__(self, replicas: List[PipelineServeEngine], *,
                 max_retries: int = 2, obs: Optional[Obs] = None):
        assert replicas
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.replicas = replicas
        self.max_retries = max_retries
        # routing / failover / salvage events land on the "router" track;
        # pass the same handle to the replicas for per-stage spans
        self.obs = obs if obs is not None else NOOP_OBS

    def _pick(self, sent: List[int],
              alive: Optional[List[bool]] = None) -> Optional[int]:
        """Least outstanding among live replicas; ties broken by fewest
        requests ever sent, then lowest index (deterministic for tests).
        None when no replica is alive."""
        load = [(r.outstanding, sent[i], i)
                for i, r in enumerate(self.replicas)
                if alive is None or alive[i]]
        return min(load)[2] if load else None

    def serve(self, requests: List[Request], realtime: bool = True,
              max_wall_s: float = 120.0) -> ServeReport:
        """Play ``requests`` (sorted by ``arrival_s``) into the replica
        fleet and block until every request finishes, fails over, or is
        explicitly recorded lost/shed.  ``realtime=False`` ignores arrival
        gaps and routes the whole list as a burst.  Raises only when all
        replicas are dead (or the wall budget is exhausted)."""
        n = len(self.replicas)
        streams = [RequestStream() for _ in range(n)]
        reports: List[Optional[ServeReport]] = [None] * n
        failures: List[Tuple[int, BaseException]] = []
        alive = [True] * n
        lock = threading.Lock()

        def run_replica(i):
            try:
                reports[i] = self.replicas[i].run(streams[i],
                                                  max_wall_s=max_wall_s)
            except BaseException as e:
                # engine.crash_records is complete by the time run() raises
                with lock:
                    alive[i] = False
                    failures.append((i, e))
                streams[i].close()

        threads = [threading.Thread(target=run_replica, args=(i,),
                                    name=f"router-{r.name}", daemon=True)
                   for i, r in enumerate(self.replicas)]
        for t in threads:
            t.start()

        t0 = time.perf_counter()
        now = lambda: time.perf_counter() - t0          # noqa: E731
        sent = [0] * n
        pushed: List[Dict[int, Request]] = [dict() for _ in range(n)]
        retries: Dict[int, int] = {}
        salvaged: Dict[int, RequestRecord] = {}
        failed_records: List[RequestRecord] = []
        n_recovered = 0
        n_failures_seen = 0
        first_fail_s: Optional[float] = None
        recovery_done_s: Optional[float] = None

        def route(req: Request) -> bool:
            """Push to the best live replica; False when none is left."""
            while True:
                i = self._pick(sent, alive)
                if i is None:
                    return False
                try:
                    streams[i].push(req)
                except ValueError:
                    continue        # died between pick and push: repick
                pushed[i][req.rid] = req
                sent[i] += 1
                if self.obs.enabled:
                    self.obs.tracer.instant(
                        "route", cat="router", track="router/route",
                        args={"rid": req.rid,
                              "replica": self.replicas[i].name})
                    self.obs.metrics.counter("router_requests_routed").inc()
                return True

        def recover(i: int) -> bool:
            """Fail over replica i's requests; False when nothing is left
            to fail over *to* (all replicas dead)."""
            nonlocal n_recovered
            crashed = self.replicas[i].crash_records
            mine, pushed[i] = pushed[i], {}
            obs_on = self.obs.enabled
            if obs_on:
                self.obs.tracer.instant(
                    "replica_failed", cat="router", track="router/failover",
                    args={"replica": self.replicas[i].name,
                          "unfinished": len(mine) - len(
                              set(mine) & set(crashed))})
                self.obs.metrics.counter("router_replica_failures").inc()
            for rid, rec in crashed.items():
                if rid in mine:
                    salvaged[rid] = rec     # finished before the crash
                    del mine[rid]
                    if obs_on:
                        self.obs.tracer.instant(
                            "salvage", cat="router", track="router/failover",
                            args={"rid": rid})
                        self.obs.metrics.counter(
                            "router_requests_salvaged").inc()
            for rid, req in mine.items():
                retries[rid] = retries.get(rid, 0) + 1
                if retries[rid] > self.max_retries:
                    failed_records.append(_failed_record(req, "lost", now()))
                    if obs_on:
                        self.obs.tracer.instant(
                            "lost", cat="router", track="router/failover",
                            args={"rid": rid})
                        self.obs.metrics.counter(
                            "router_requests_lost").inc()
                elif req.deadline_s is not None and now() > req.deadline_s:
                    failed_records.append(_failed_record(req, "shed", now()))
                    if obs_on:
                        self.obs.tracer.instant(
                            "shed", cat="router", track="router/failover",
                            args={"rid": rid})
                        self.obs.metrics.counter(
                            "router_requests_shed").inc()
                elif route(req):
                    n_recovered += 1
                    if obs_on:
                        self.obs.tracer.instant(
                            "failover", cat="router", track="router/failover",
                            args={"rid": rid, "retry": retries[rid]})
                        self.obs.metrics.counter(
                            "router_requests_recovered").inc()
                else:
                    return False
            return True

        ordered = sorted(requests, key=lambda r: r.arrival_s)
        qi = 0
        all_dead_err: Optional[BaseException] = None
        try:
            while True:
                # 1. play the arrival process (everything due by `now`)
                while qi < len(ordered):
                    req = ordered[qi]
                    if realtime and req.arrival_s > now():
                        break
                    if not route(req):
                        break                     # no live replica left
                    qi += 1
                # 2. fail over any newly dead replicas
                with lock:
                    new = failures[n_failures_seen:]
                n_failures_seen += len(new)
                for i, _e in new:
                    if first_fail_s is None:
                        first_fail_s = now()
                    recovery_done_s = None        # re-arm until drained
                    recover(i)
                # 3. done? every request routed to a live replica that has
                # drained and finished it (n_submitted == routed guards the
                # drain/submit race), no failure left unprocessed
                if not any(alive):
                    all_dead_err = failures[0][1]
                    break
                with lock:
                    settled = n_failures_seen == len(failures)
                if settled and qi == len(ordered):
                    drained = all(
                        not alive[i]
                        or (streams[i].pending == 0
                            and self.replicas[i].n_submitted
                            == len(pushed[i])
                            and self.replicas[i].outstanding == 0)
                        for i in range(n))
                    if drained:
                        if first_fail_s is not None:
                            recovery_done_s = now()
                        break
                if now() > max_wall_s:
                    raise TimeoutError(
                        f"router exceeded {max_wall_s}s "
                        f"({len(ordered) - qi} request(s) unrouted)")
                time.sleep(_POLL_S)
        finally:
            for s in streams:
                s.close()
            for t in threads:
                t.join(timeout=max_wall_s + 10.0)

        # a replica may have died between the drain check and close —
        # its requests all finished, so salvage without re-admission
        for i, _e in failures[n_failures_seen:]:
            crashed = self.replicas[i].crash_records
            for rid, req in pushed[i].items():
                if rid in crashed:
                    salvaged[rid] = crashed[rid]
                else:
                    failed_records.append(_failed_record(req, "lost", now()))
            pushed[i] = {}

        if all_dead_err is not None:
            raise RuntimeError(
                "replica failed during serve") from all_dead_err

        records = [rec for rep in reports if rep is not None
                   for rec in rep.records]
        records += list(salvaged.values()) + failed_records
        # belt and braces: the zero-silent-loss invariant — every routed
        # request must be accounted for in the merged report
        seen = {rec.rid for rec in records}
        for req in ordered:
            if req.rid not in seen:
                failed_records.append(_failed_record(req, "lost", now()))
                records.append(failed_records[-1])
        wall = now()
        if self.obs.enabled:
            self.obs.tracer.complete(
                "serve", cat="router", track="router/route", start=t0,
                dur=wall, args={"n_requests": len(ordered),
                                "n_failures": len(failures)})
        extra = {"n_replicas": n, "routed_per_replica": sent,
                 "requests_recovered": n_recovered,
                 "requests_salvaged": len(salvaged),
                 "n_replica_failures": len(failures)}
        if first_fail_s is not None and recovery_done_s is not None:
            extra["recovery_ms"] = round(
                (recovery_done_s - first_fail_s) * 1e3, 1)
        for i, rep in enumerate(reports):
            if rep is not None:
                extra[f"replica{i}_tokens_per_s"] = round(rep.tokens_per_s, 1)
        eos = self.replicas[0].eos
        return ServeReport(records=records, wall_s=wall, eos=eos, extra=extra)
