"""Production partitioned-serving runtime (paper Sec. V deployment story):
slot-based continuous batching + async double-buffered stage pipelining +
replica routing over the partitions the explorer chose."""

from repro.serve.faults import (FaultPlan, FaultTrace, LinkDegrade,
                                ReplicaCrash, ReplicaCrashError, StageStall)
from repro.serve.health import (DivergenceMonitor, DriftSignal, Ewma,
                                FailureDetector, HealthMonitor)
from repro.serve.pipeline_async import (PipelineServeEngine, RequestStream,
                                        ServeLink, stream_of)
from repro.serve.request import (Request, RequestRecord, ServeReport,
                                 poisson_traffic)
from repro.serve.router import ReplicaRouter
from repro.serve.scheduler import SlotScheduler
