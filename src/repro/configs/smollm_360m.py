"""smollm-360m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-360m", family="dense",
    n_layers=32, d_model=960, vocab=49_152,
    n_heads=15, n_kv=5, d_ff=2560,
    tied_embeddings=True,
    window=4096,
    optimizer="adamw",
    source="hf:HuggingFaceTB/SmolLM-360M (32L d960 15H kv5 ffn2560)",
)
