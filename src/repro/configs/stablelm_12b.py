"""stablelm-12b [dense] — [hf:stabilityai/stablelm-2-1_6b scaled family]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, vocab=100_352,
    n_heads=32, n_kv=8, d_ff=13_824,
    window=4096,
    optimizer="adamw",
    source="hf:stabilityai/stablelm-2-12b (40L d5120 32H kv8 ffn13824)",
)
