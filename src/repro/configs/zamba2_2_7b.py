"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, vocab=32_000,
    n_heads=32, n_kv=32, d_ff=10_240,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_chunk=128,
    attn_every=6,                  # shared attn+MLP block applied every 6
    optimizer="adamw",
    source="arXiv:2411.15242 (Zamba2-2.7B: 54 Mamba2 blocks d2560, shared attn d_ff 10240)",
)
