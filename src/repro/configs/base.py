"""Unified model/run configuration for the assigned architectures.

One :class:`ModelConfig` describes any of the 6 families (dense / moe / ssm /
hybrid / audio / vlm).  ``reduced()`` produces the CPU smoke-test variant
(≤2 layers, d_model ≤ 512, ≤4 experts) of the same family.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv: int = 0
    head_dim: Optional[int] = None
    d_ff: int = 0
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None          # sliding-window attention
    tied_embeddings: bool = False
    # moe
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    moe_d_ff: int = 0                     # per-expert FFN width
    first_dense: int = 0                  # leading dense layers (DeepSeek)
    sigmoid_gate: bool = False
    use_mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    mtp: int = 0                          # multi-token-prediction depth
    # ssm / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0                   # hybrid: shared attn block period
    # audio
    n_codebooks: int = 0
    # vlm
    mrope_sections: Optional[Tuple[int, int, int]] = None
    n_patches: int = 0                    # vision stub token count
    # numerics / training
    dtype: str = "float32"
    remat: bool = True
    optimizer: str = "adamw"              # adafactor for the 70B+ configs
    # citation for the config source
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        from repro.models.registry import count_params_from_config
        return count_params_from_config(self)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/features, tiny dims."""
        d = 256 if self.d_model >= 256 else self.d_model
        heads = min(self.n_heads, 4) or 0
        kv = min(self.n_kv, heads) or 0
        if self.n_kv and self.n_heads and self.n_heads != self.n_kv:
            kv = max(1, heads // 2)       # keep GQA grouping
        layers = min(self.n_layers, 2)
        if self.family == "hybrid":
            layers = min(self.attn_every, 6)  # one full shared-attn group
        return dataclasses.replace(
            self,
            n_layers=layers,
            d_model=d,
            n_heads=heads, n_kv=kv,
            head_dim=64 if self.head_dim else None,
            d_ff=min(self.d_ff, 4 * d) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared=min(self.n_shared, 1),
            moe_d_ff=min(self.moe_d_ff, d) if self.moe_d_ff else 0,
            first_dense=min(self.first_dense, 1),
            q_lora_rank=min(self.q_lora_rank, 64),
            kv_lora_rank=min(self.kv_lora_rank, 32),
            qk_nope_dim=32 if self.use_mla else self.qk_nope_dim,
            qk_rope_dim=16 if self.use_mla else self.qk_rope_dim,
            v_head_dim=32 if self.use_mla else self.v_head_dim,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=min(self.ssm_headdim, 32) if self.ssm_state else 64,
            ssm_chunk=32,
            window=min(self.window, 64) if self.window else None,
            mrope_sections=(8, 12, 12) if self.mrope_sections else None,
            n_patches=min(self.n_patches, 16) if self.n_patches else 0,
            mtp=min(self.mtp, 1),
            remat=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
