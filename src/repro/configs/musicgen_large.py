"""musicgen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284].  The EnCodec frontend is a stub: ``input_specs``
provides precomputed codebook token streams (delay-pattern applied)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-large", family="audio",
    n_layers=48, d_model=2048, vocab=2048,          # per-codebook cardinality
    n_heads=32, n_kv=32, d_ff=8192,
    n_codebooks=4,
    optimizer="adamw",
    source="arXiv:2306.05284 (MusicGen large: 48L d2048 32H ffn8192, 4 RVQ books)",
)
