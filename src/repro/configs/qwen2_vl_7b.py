"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].
ViT/SigLIP frontend is a stub: ``input_specs`` provides precomputed patch
embeddings of shape (B, n_patches, d_model)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, vocab=152_064,
    n_heads=28, n_kv=4, d_ff=18_944,
    qkv_bias=True, rope_theta=1e6,
    mrope_sections=(16, 24, 24),    # t/h/w half-dim bands, sum = head_dim/2
    n_patches=256,
    window=4096,
    optimizer="adamw",
    source="arXiv:2409.12191 (Qwen2-VL-7B: 28L d3584 28H kv4 ffn18944, M-RoPE)",
)
