"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained
[arXiv:2401.06066]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, vocab=102_400,
    n_heads=16, n_kv=16, d_ff=1408 * 8,     # dense first layer FFN (10944≈8x)
    moe_d_ff=1408, n_experts=64, top_k=6, n_shared=2,
    first_dense=1,
    optimizer="adamw",
    source="arXiv:2401.06066 (DeepSeekMoE-16B: 28L d2048, 64e top-6 + 2 shared, expert ffn 1408)",
)
