"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, vocab=129_280,
    n_heads=128, n_kv=128, d_ff=18_432,      # dense layers FFN
    moe_d_ff=2048, n_experts=256, top_k=8, n_shared=1,
    first_dense=3, sigmoid_gate=True,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    mtp=1,
    optimizer="adafactor",        # 671B total params: factored optimizer
    source="arXiv:2412.19437 (DeepSeek-V3: 61L d7168, MLA, 256e top-8 + 1 shared, MTP)",
)
