"""qwen2-72b [dense] — GQA with QKV bias [arXiv:2407.10671]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, vocab=152_064,
    n_heads=64, n_kv=8, d_ff=29_568,
    qkv_bias=True, rope_theta=1e6,
    window=4096,                 # sliding-window variant enables long_500k
    optimizer="adafactor",       # 72B params: factored states to fit HBM
    source="arXiv:2407.10671 (Qwen2-72B: 80L d8192 64H kv8 ffn29568)",
)
