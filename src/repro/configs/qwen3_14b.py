"""qwen3-14b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, vocab=151_936,
    n_heads=40, n_kv=8, head_dim=128, d_ff=17_408,
    qk_norm=True, rope_theta=1e6,
    window=4096,
    optimizer="adamw",
    source="hf:Qwen/Qwen3-14B (40L d5120 40H kv8 ffn17408, qk_norm)",
)
