"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, vocab=50_280,
    d_ff=0,                      # attention-free, no FFN (Mamba2 blocks only)
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=128,
    tied_embeddings=True,
    optimizer="adamw",
    source="arXiv:2405.21060 (Mamba2; 370m: 48L d1024 N128)",
)
