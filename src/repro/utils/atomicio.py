"""Crash-safe file publication: write-temp-then-``os.replace``.

Every artifact this repo publishes for another process to read — fleet
manifest shards, campaign reports, ``BENCH_*.json`` trend files — must
appear atomically: a reader (or a resumed CI job) either sees the complete
previous version or the complete new one, never a truncated half-write
from a killed writer.  These helpers are the one sanctioned way to do
that; the ``repro.analysis`` RPR301 rule flags plain ``open(path, "w")``
dumps that bypass them.

The temp file is created *next to* the destination (same directory, and
therefore the same filesystem) because ``os.replace`` is only atomic
within one filesystem — a ``tempfile.mkstemp()`` default of ``/tmp`` would
turn the rename into a copy+delete on many setups (RPR302).
"""

from __future__ import annotations

import json
import os
import socket
from typing import Any


def _tmp_path(path: str) -> str:
    """Sibling temp name, unique per (host, pid) so concurrent writers on a
    shared filesystem never collide on the temp file itself."""
    host = "".join(c if c.isalnum() else "_" for c in socket.gethostname())
    return f"{path}.tmp.{host}-{os.getpid()}"


def atomic_write_text(path: str, text: str) -> None:
    """Publish ``text`` at ``path`` atomically (temp sibling + fsync +
    ``os.replace``)."""
    tmp = _tmp_path(path)
    try:
        with open(tmp, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def atomic_write_json(path: str, payload: Any, indent: int = 1) -> None:
    """Publish ``payload`` as JSON at ``path`` atomically."""
    atomic_write_text(path, json.dumps(payload, indent=indent))
