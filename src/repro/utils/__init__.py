"""Small shared utilities with no heavy dependencies."""

from repro.utils.atomicio import atomic_write_json, atomic_write_text

__all__ = ["atomic_write_json", "atomic_write_text"]
