"""Online re-partitioning under live system drift.

The paper's deployment scenarios (automotive, robotics) have links that
degrade and nodes that drop out mid-mission; a cold
:func:`~repro.explore.runner.run_spec` reacts in *seconds* because every
perturbed system re-traces and re-compiles the ``jit_nsga2`` program.
:class:`OnlineRepartitioner` turns the search into a service that reacts in
*milliseconds* by exploiting three invariants of drift:

1. **Shapes are static.**  Link degradation changes ``rate_bps`` values and
   node dropout shrinks a ``mem_capacity`` — neither changes any table
   shape, so the compiled runner (whose evaluation tables are runtime
   pytree arguments — :func:`repro.core.partition_jax.make_runtime_eval_fn`)
   is reused across every perturbation via the shared shape-keyed runner
   cache.  Zero recompilation after the first search.
2. **The candidate list is pinned** to the baseline system's filtered cut
   positions, keeping the gene table (and hence the compiled shape)
   identical across drifted systems; feasibility shifts are absorbed by
   Deb constraint domination inside the search, exactly how the paper's
   NSGA-II handles infeasible rows.
3. **Optima move slowly.**  Each re-search warm-starts from the previous
   Pareto front (:func:`repro.core.nsga2_jax.warm_population`), so a small
   generation budget re-converges.

Perturbation helpers (:func:`degrade_link`, :func:`drop_node`) produce
same-shape :class:`~repro.explore.spec.SystemSpec` variants; decisions are
consumed by the serving runtime by swapping
:func:`~repro.explore.deploy.lm_block_cuts` on the replicas when
:attr:`RepartitionDecision.changed` (see ``launch/drift.py`` for the
end-to-end loop and ``benchmarks/drift_bench.py`` for the ≥ 20× warm-vs-cold
gate).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Iterator, List, Optional, Tuple, Union

import numpy as np

from repro.core.accuracy import ProxyAccuracy
from repro.core.graph import linearize
from repro.core.nsga2 import crowding_distance
from repro.core.partition import PartitionEvaluator, SystemConfig
from repro.explore.deploy import lm_block_cuts
from repro.explore.filters import candidate_positions
from repro.explore.result import ExplorationResult
from repro.explore.runner import run_search
from repro.explore.spec import ExplorationSpec, SearchSettings, SystemSpec
from repro.obs.handle import NOOP_OBS, Obs

SystemLike = Union[SystemSpec, SystemConfig]

# a "dropped" node keeps its table slot (shapes must not change) but gets a
# 1-byte memory capacity: every placement that assigns it layers violates
# Def. 3 maximally, so constraint domination routes the search around it
_DROPPED_CAPACITY = 1


def degrade_link(system: SystemSpec, link: int,
                 factor: float) -> SystemSpec:
    """A same-shape copy of ``system`` with ``links[link]`` slowed down.

    The link's effective ``rate_bps`` (registry base plus any existing
    override) is divided by ``factor`` (> 1 degrades, < 1 upgrades).  Only
    a value changes, so the perturbed spec shares the baseline's compiled
    runner.
    """
    if not 0 <= link < len(system.links):
        raise IndexError(f"link {link} out of range "
                         f"(system has {len(system.links)})")
    if factor <= 0:
        raise ValueError(f"factor must be > 0, got {factor}")
    links = list(system.links)
    rate = links[link].build().rate_bps / factor
    links[link] = dataclasses.replace(links[link], rate_bps=rate)
    return dataclasses.replace(
        system, links=tuple(links),
        name=f"{system.label}~link{link}/{factor:g}")


def drop_node(system: SystemSpec, node: int) -> SystemSpec:
    """A same-shape copy of ``system`` with platform ``node`` marked dead.

    The platform keeps its slot in every table (shapes are sacred) but its
    memory capacity collapses to 1 byte, so any placement routing layers
    onto it is maximally infeasible and the re-search steers every stage
    around the node — the paper's node-dropout scenario without a single
    recompilation.
    """
    if not 0 <= node < len(system.platforms):
        raise IndexError(f"node {node} out of range "
                         f"(system has {len(system.platforms)})")
    plats = list(system.platforms)
    plats[node] = dataclasses.replace(plats[node],
                                      mem_capacity=_DROPPED_CAPACITY)
    return dataclasses.replace(
        system, platforms=tuple(plats),
        name=f"{system.label}~drop{node}")


@dataclasses.dataclass
class RepartitionDecision:
    """One re-deployment decision emitted by :class:`OnlineRepartitioner`.

    ``cuts`` is the Def.-2 selected cut vector (``None`` when the front
    came up empty), ``changed`` flags whether deployment must act (the cut
    vector differs from the previous decision's), ``repartition_ms`` is the
    wall-clock of the whole update (evaluator build + warm re-search +
    selection), and ``feasible`` reports whether the selected placement
    satisfies every constraint on the *drifted* system.
    """

    step: int                       # 0-based update counter
    label: str                      # system label at this step
    cuts: Optional[Tuple[int, ...]]
    changed: bool
    repartition_ms: float
    feasible: bool
    pareto_size: int
    strategy_used: str
    result: ExplorationResult = dataclasses.field(repr=False)
    trigger: str = "event"          # 'event' (told) | 'measured' (observed)

    def block_cuts(self, n_layers: int) -> List[int]:
        """Decoder-block cut indices for ``PartitionedLMRunner`` — the
        serve-side form of this decision (falls back to a middle split
        when ``cuts`` is None, so deployment always has a target)."""
        return lm_block_cuts(self.cuts or (), n_layers)


class OnlineRepartitioner:
    """Millisecond re-partitioning service over a stream of drifted systems.

    Construction resolves the spec's model once (graph, schedule, Def.-3
    memory table, per-arch cost cache are all shared across updates) and
    pins the candidate cut positions from the spec's *baseline* system.
    Each :meth:`update` then builds a cheap evaluator for the drifted
    system, re-searches warm from the previous Pareto front on the shared
    compiled runner, and emits a :class:`RepartitionDecision`.

    The search strategy is forced to ``jit_nsga2`` (the only strategy whose
    compilation is reusable across systems); every other knob of
    ``spec.search`` — or of an explicit ``settings`` override — is honored,
    including ``warm_start=False`` for A/B comparisons.
    """

    def __init__(self, spec: ExplorationSpec, *,
                 settings: Optional[SearchSettings] = None,
                 max_warm_front: int = 64,
                 obs: Optional[Obs] = None):
        if max_warm_front < 1:
            raise ValueError(
                f"max_warm_front must be >= 1, got {max_warm_front}")
        self.max_warm_front = max_warm_front
        # repartition decisions land on the "health/repartition" track
        self.obs = obs if obs is not None else NOOP_OBS
        self.spec = spec
        settings = settings or spec.search
        if settings.strategy != "jit_nsga2":
            settings = dataclasses.replace(settings, strategy="jit_nsga2")
        self.settings = settings
        graph, shared = spec.model.build()
        self.graph = graph
        self.shared_groups = shared
        self.schedule = linearize(graph, spec.schedule_policy)
        self._cost_cache: dict = {}
        base_eval = self._evaluator(spec.system.build())
        self._memtable = base_eval._memtable
        # pinned gene space: the baseline system's filtered candidates
        self.candidates: List[int] = candidate_positions(
            base_eval, spec.constraints, settings.allow_multi_tensor_cuts)
        self.decisions: List[RepartitionDecision] = []
        self._front_cuts: Optional[np.ndarray] = None
        self._last_cuts: Optional[Tuple[int, ...]] = None

    def _evaluator(self, system: SystemConfig) -> PartitionEvaluator:
        spec = self.spec
        if spec.accuracy is not None:
            acc = spec.accuracy.build(self.graph, self.schedule, system)
        else:
            acc = ProxyAccuracy(self.schedule, system)
        return PartitionEvaluator(
            self.graph, self.schedule, system, accuracy_fn=acc,
            batch=spec.batch, shared_groups=self.shared_groups,
            cost_cache=self._cost_cache,
            memtable=getattr(self, "_memtable", None))

    def update(self, system: SystemLike, label: Optional[str] = None,
               trigger: str = "event") -> RepartitionDecision:
        """Re-partition for one (possibly drifted) system snapshot.

        ``system`` may be a declarative :class:`SystemSpec` (typically from
        :func:`degrade_link` / :func:`drop_node`, or a
        ``DivergenceMonitor.drifted_system()`` snapshot — in that case pass
        ``trigger='measured'``) or an already-built :class:`SystemConfig`.
        It must be same-shape with the baseline (same platform/link
        counts); a different shape still works but pays one fresh XLA
        compilation.
        """
        t0 = time.perf_counter()
        if isinstance(system, SystemSpec):
            label = label or system.label
            system = system.build()
        label = label or f"step{len(self.decisions)}"
        evaluator = self._evaluator(system)
        res = run_search(
            evaluator, constraints=self.spec.constraints,
            objectives=self.spec.objectives, weights=self.spec.weights,
            settings=self.settings, candidates=self.candidates,
            warm_cuts=self._front_cuts)
        ms = (time.perf_counter() - t0) * 1e3
        cuts = res.selected.cuts if res.selected is not None else None
        feasible = res.selected is not None and res.selected.violation <= 0
        decision = RepartitionDecision(
            step=len(self.decisions), label=label, cuts=cuts,
            changed=cuts != self._last_cuts, repartition_ms=ms,
            feasible=feasible, pareto_size=len(res.pareto),
            strategy_used=res.strategy_used, result=res, trigger=trigger)
        self._last_cuts = cuts
        if self.obs.enabled:
            self.obs.tracer.instant(
                "repartition", cat="health", track="health/repartition",
                args={"label": label, "trigger": trigger,
                      "changed": decision.changed,
                      "feasible": feasible, "ms": round(ms, 3)})
            self.obs.metrics.counter("repartition_decisions").inc()
            if decision.changed:
                self.obs.metrics.counter("repartition_changes").inc()
            self.obs.metrics.histogram("repartition_ms").observe(ms)
        if res.pareto:
            front = res.pareto
            if len(front) > self.max_warm_front:
                # bound the carried warm seed: long drift histories must
                # not grow it without limit, and crowding distance keeps
                # the most diversity-preserving top-k of the front
                F = np.asarray([e.as_objectives(self.spec.objectives)
                                for e in front], dtype=float)
                cd = crowding_distance(F)
                keep = sorted(np.argsort(-cd, kind="stable")
                              [:self.max_warm_front])
                front = [front[int(i)] for i in keep]
            self._front_cuts = np.asarray([e.cuts for e in front],
                                          dtype=int)
        self.decisions.append(decision)
        return decision

    def watch(self, systems: Iterable[SystemLike]
              ) -> Iterator[RepartitionDecision]:
        """Drive :meth:`update` over a stream of system snapshots, yielding
        each decision as it is made (generator — lazy, so a live producer
        can feed it)."""
        for system in systems:
            yield self.update(system)
