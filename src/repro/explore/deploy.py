"""From exploration result to deployment config.

The explorer searches over cut positions in the *layer-graph schedule*
(Embed, Attention_0, FFN_0, Attention_1, ...); the serving runtime
partitions a decoder LM at *block* boundaries (stage k = a contiguous
range of transformer blocks).  This module is the bridge: it maps the
Def.-2 selected cuts of an :class:`ExplorationResult` onto the block
boundaries ``PartitionedLMRunner`` (and the ``repro.serve`` runtime on
top of it) actually deploys.
"""

from __future__ import annotations

from typing import List, Sequence


def lm_block_cuts(cuts: Sequence[int], n_layers: int) -> List[int]:
    """Map explorer cut positions (schedule indices over the LM layer
    graph: Embed, then Attention_i/FFN_i pairs) to decoder block cut
    indices for ``PartitionedLMRunner`` (``cuts=[b]`` = stage boundary
    after block ``b``).

    Position ``-1`` encodes "no cut" and is dropped; cuts inside a block
    (between its attention and FFN) snap to the end of that block; the
    result is clamped so every stage keeps at least one block.  An empty
    result falls back to the middle of the stack, so callers always get a
    deployable >= 2-stage split.
    """
    assert n_layers >= 2, "partitioned serving needs >= 2 blocks"
    out: List[int] = []
    for c in cuts:
        if c < 0:
            continue
        b = max(0, min(n_layers - 2, (int(c) - 1) // 2))
        if b not in out:
            out.append(b)
    return sorted(out) or [max(0, n_layers // 2 - 1)]
