"""Candidate-cut discovery and filtering (Fig. 1 stages 2–3, §IV-B).

Positions are pruned by two feasibility checks before any metric evaluation:

* **memory** — the prefix up to ``p`` must fit the first platform and the
  suffix after ``p`` the last one (interior platforms are handled by
  NSGA-II constraint domination, as in the paper);
* **link** — a per-``(link, position)`` feasibility matrix prices the cut
  tensor at each *producer* platform's bit width.  A position survives if
  it is feasible on at least one link (identical keep-set to the old
  cheapest-producer scalar bound, since ``ceil`` is monotone in the bit
  width), but the matrix additionally lets multi-cut strategies prune
  *exactly*: a full cut vector is dropped only when one of its **active**
  cuts is infeasible on the specific link it lands on
  (:func:`feasible_cut_rows`).
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.memory import prefix_feasible_limit
from repro.core.partition import Constraints, PartitionEvaluator


def memory_filter(evaluator: PartitionEvaluator,
                  positions: List[int]) -> List[int]:
    """§IV-B memory pruning of candidate positions (see module docstring)."""
    schedule, system = evaluator.schedule, evaluator.system
    plat0 = system.platforms[0]
    limit = prefix_feasible_limit(
        schedule, plat0.memory_model, plat0.capacity,
        evaluator.shared_groups, evaluator.batch)
    positions = [p for p in positions if p <= limit]
    platN = system.platforms[-1]
    rev = prefix_feasible_limit(
        list(reversed(schedule)), platN.memory_model, platN.capacity,
        evaluator.shared_groups, evaluator.batch)
    min_p = len(schedule) - 2 - rev   # suffix schedule[p+1..] must fit plat N
    return [p for p in positions if p >= min_p]


def link_feasibility(evaluator: PartitionEvaluator,
                     max_link_bytes: Optional[int]) -> Optional[np.ndarray]:
    """Per-(link, position) feasibility matrix, or ``None`` when unbounded.

    ``feas[k, p]`` is True iff the tensor cut after position ``p``, priced
    at link ``k``'s producer platform (platform ``k``) bit width and the
    evaluator's batch size, fits the per-cut bandwidth budget.  Shape is
    ``(n_links, L - 1)`` over *all* schedule positions so strategies can
    index it by absolute cut position.
    """
    system = evaluator.system
    if not max_link_bytes or len(system.platforms) < 2:
        return None
    elems = evaluator.cut_elements()          # (L-1,) elements over the link
    feas = np.empty((len(system.links), len(elems)), dtype=bool)
    for k in range(len(system.links)):
        bpe = system.platforms[k].quant.bits / 8.0
        nbytes = np.ceil(elems * bpe).astype(np.int64) * evaluator.batch
        feas[k] = nbytes <= max_link_bytes
    return feas


def link_filter(evaluator: PartitionEvaluator, positions: List[int],
                max_link_bytes: Optional[int]) -> List[int]:
    """Keep positions feasible on at least one link they could land on."""
    feas = link_feasibility(evaluator, max_link_bytes)
    if feas is None:
        return positions
    any_link = feas.any(axis=0)
    return [p for p in positions if any_link[p]]


def candidate_positions(evaluator: PartitionEvaluator,
                        constraints: Optional[Constraints] = None,
                        allow_multi_tensor_cuts: bool = False) -> List[int]:
    """Fig.-1 candidate discovery + filtering: clean (Def.-1) cut positions
    that pass the memory and link feasibility checks."""
    graph, schedule = evaluator.graph, evaluator.schedule
    if allow_multi_tensor_cuts:
        cands = [p for p, _ in graph.all_cuts(schedule)]
    else:
        cands = graph.clean_cuts(schedule)
    cands = memory_filter(evaluator, cands)
    cap = constraints.max_link_bytes if constraints else None
    return link_filter(evaluator, cands, cap)


def feasible_cut_rows(C: np.ndarray, evaluator: PartitionEvaluator,
                      feas: Optional[np.ndarray]) -> np.ndarray:
    """Exact per-(link, position) pruning of an ``(N, n_cuts)`` cut matrix.

    Returns a boolean keep-mask.  A row is dropped only when one of its
    *active* cuts (producer ran something, and something remains downstream
    — the same activity rule as ``evaluate_batch``) is infeasible on the
    link it occupies; inactive cuts ship nothing and never disqualify.
    Rows dropped here would carry a positive ``max_link_bytes`` violation,
    so removing them never removes a feasible point.
    """
    n = len(C)
    if feas is None or n == 0:
        return np.ones(n, dtype=bool)
    L = len(evaluator.schedule)
    bounds = np.concatenate(
        [np.full((n, 1), -1, dtype=np.int64), C.astype(np.int64),
         np.full((n, 1), L - 1, dtype=np.int64)], axis=1)
    keep = np.ones(n, dtype=bool)
    for k in range(len(evaluator.system.links)):
        p = C[:, k]
        sent = bounds[:, k + 1] > bounds[:, k]
        remaining = bounds[:, -1] > bounds[:, k + 1]
        active = (p >= 0) & (p < L - 1) & sent & remaining
        keep &= ~active | feas[k, np.clip(p, 0, L - 2)]
    return keep
