"""Pluggable search strategies (Fig. 1 stages 4–5).

Every strategy consumes the same :class:`SearchContext` — a shared
:class:`~repro.core.partition.PartitionEvaluator`, the filtered candidate
positions, constraints, objectives — and returns a :class:`StrategyOutput`
pool of evaluated placements, so strategies are interchangeable through one
:class:`~repro.explore.spec.ExplorationSpec` and directly comparable in
tests:

* :class:`ExhaustiveSearch` — single-cut scan over the candidates (today's
  default path; exact for two-platform systems).
* :class:`MultiCutScan`    — exhaustive enumeration of every sorted k-cut
  vector over the candidate table, chunked through ``evaluate_batch`` with
  a streaming non-dominated archive.  Exact ground truth for small systems
  now that ~1M evals/s are available.
* :class:`NSGA2Search`     — the genetic search of ``repro.core.nsga2``
  with population/generation defaults scaled to the schedule depth and cut
  count (not the old scalar-loop constants).
* :class:`JitNSGA2Search`  — the same search with the *entire* generation
  loop (ranking, crowding, tournaments, variation, repair, batched metric
  evaluation over the precomputed cost tables) compiled into one
  ``jax.jit`` program (``repro.core.nsga2_jax``), for the 10k+-individual
  populations the NumPy path cannot reach.

Register additional strategies with :func:`register_strategy`.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import time
import warnings
from typing import Dict, List, Optional, Protocol, Tuple, Type, runtime_checkable

import numpy as np

from repro.core.nsga2 import (NSGA2Result, dominates_matrix,
                              non_dominated_mask, nsga2, pareto_indices)
from repro.core.partition import (Constraints, PartitionEval,
                                  PartitionEvaluator)
from repro.explore.filters import feasible_cut_rows
from repro.explore.spec import SearchSettings
from repro.obs.metrics import default_registry

# full per-point scans are kept (for Fig.-2-style plots) only below this size
_ALL_EVALS_CAP = 16384


@dataclasses.dataclass
class SearchContext:
    """Everything a strategy needs; shared across strategies of one run."""

    evaluator: PartitionEvaluator
    candidates: List[int]
    constraints: Constraints
    objectives: Tuple[str, ...]
    settings: SearchSettings
    link_feas: Optional[np.ndarray] = None   # (n_links, L-1) or None
    warm_cuts: Optional[np.ndarray] = None   # (n, n_cuts) previous front

    @property
    def n_cuts(self) -> int:
        """Number of cut genes (= platforms - 1) for this system."""
        return self.evaluator.system.n_cuts

    @property
    def depth(self) -> int:
        """Schedule length L (cut positions live in [-1, L-1])."""
        return len(self.evaluator.schedule)


@dataclasses.dataclass
class StrategyOutput:
    """What one strategy hands back to :func:`~repro.explore.runner
    .run_search`: its candidate pool plus bookkeeping."""

    evals: List[PartitionEval]
    all_evals: List[PartitionEval] = dataclasses.field(default_factory=list)
    nsga: Optional[NSGA2Result] = None
    exhaustive: bool = False   # exact scans precede baselines in the pool
    n_evaluated: int = 0       # candidate vectors actually scored
    strategy_used: str = ""    # actual strategy name when != the requested
    #                            one (e.g. jit_nsga2's NumPy fallback)


@runtime_checkable
class SearchStrategy(Protocol):
    """The strategy protocol: a name and one ``search`` method."""

    name: str

    def search(self, ctx: SearchContext) -> StrategyOutput:
        """Produce candidate cut vectors for the runner to score."""
        ...


def scaled_nsga_defaults(n_candidates: int, n_cuts: int,
                         depth: int) -> Tuple[int, int]:
    """Population/generation defaults sized for the batched evaluator.

    The paper sizes the GA by layer count; with ``evaluate_batch`` scoring
    ~1M candidates/s a generation costs one vectorized call, so defaults
    scale with the gene space (candidates × cuts) and the schedule depth
    instead of the old fixed small constants.
    """
    span = n_candidates + 2                  # + the -1 / L-1 sentinels
    pop = int(np.clip(8.0 * np.sqrt(span * max(n_cuts, 1)), 64, 512))
    pop = max(pop // 4 * 4, 16)
    n_gen = int(np.clip(depth // 2, 24, 120))
    return pop, n_gen


def _gene_table(ctx: SearchContext) -> np.ndarray:
    """Gene values: [skip-sentinel -1] + candidates + [end-sentinel L-1]."""
    return np.array([-1] + list(ctx.candidates) + [ctx.depth - 1], dtype=int)


class ExhaustiveSearch:
    """Single-cut scan: every candidate as the first (only) cut, remaining
    platforms idle.  For two-platform systems this is the exact Fig.-2 scan
    and matches the legacy ``Explorer.run`` point set bit-for-bit."""

    name = "exhaustive"

    def search(self, ctx: SearchContext) -> StrategyOutput:
        """Enumerate every single-cut placement (Fig.-2 scan)."""
        if not ctx.candidates:
            return StrategyOutput([], exhaustive=True)
        C = np.full((len(ctx.candidates), ctx.n_cuts), ctx.depth - 1,
                    dtype=int)
        C[:, 0] = ctx.candidates
        evals = ctx.evaluator.evaluate_batch(C, ctx.constraints).to_evals()
        return StrategyOutput(evals, all_evals=evals, exhaustive=True,
                              n_evaluated=len(evals))


class MultiCutScan:
    """Exhaustive k-cut enumeration over the candidate table.

    Enumerates every sorted cut vector (with the skip/end sentinels, so
    fewer-partition schedules are included — the Table-II effect), prunes
    rows whose active cuts fail the per-(link, position) feasibility matrix
    exactly, and streams chunks through ``evaluate_batch`` while keeping a
    running constrained non-dominated archive — memory stays bounded even
    for hundreds of thousands of combinations.
    """

    name = "multicut"

    def search(self, ctx: SearchContext) -> StrategyOutput:
        """Enumerate all sorted cut combinations when the combinatorial
        budget allows (exact small-system solver)."""
        if not ctx.candidates:
            return StrategyOutput([], exhaustive=True)
        table = _gene_table(ctx)
        k = ctx.n_cuts
        n_combos = math.comb(len(table) + k - 1, k)
        if n_combos > ctx.settings.max_scan:
            raise ValueError(
                f"MultiCutScan: {n_combos} cut vectors exceed "
                f"max_scan={ctx.settings.max_scan}; use the 'nsga2' "
                f"strategy for this system or raise SearchSettings.max_scan")
        keep_all = n_combos <= _ALL_EVALS_CAP
        all_evals: List[PartitionEval] = []
        front_evals: List[PartitionEval] = []
        front_F = front_CV = None
        n_evaluated = 0
        chunk = max(int(ctx.settings.scan_chunk), 1)
        combos = itertools.combinations_with_replacement(table.tolist(), k)
        while True:
            block = list(itertools.islice(combos, chunk))
            if not block:
                break
            C = np.asarray(block, dtype=np.int64)
            C = C[feasible_cut_rows(C, ctx.evaluator, ctx.link_feas)]
            if not len(C):
                continue
            be = ctx.evaluator.evaluate_batch(C, ctx.constraints)
            n_evaluated += len(be)
            if keep_all:
                all_evals.extend(be.to_evals())
            F = be.as_objectives(ctx.objectives)
            CV = be.violation
            if front_F is not None:
                # cheap pre-filter: drop rows the archive already dominates
                # (|archive| × chunk) before the quadratic in-chunk mask
                dom = dominates_matrix(front_F, front_CV, F, CV)
                alive = np.flatnonzero(~dom.any(axis=0))
                if not len(alive):
                    continue
                F2 = np.concatenate([front_F, F[alive]])
                CV2 = np.concatenate([front_CV, CV[alive]])
            else:
                alive = np.arange(len(F))
                F2, CV2 = F, CV
            n_arch = len(front_evals)
            fr = np.flatnonzero(non_dominated_mask(F2, CV2))
            front_evals = [front_evals[j] if j < n_arch
                           else be.row(alive[j - n_arch]) for j in fr]
            front_F, front_CV = F2[fr], CV2[fr]
        # all_evals stays empty above the cap: only a full scan may pose as
        # "every point" (n_evaluated records the true coverage either way)
        return StrategyOutput(front_evals, all_evals=all_evals,
                              exhaustive=True, n_evaluated=n_evaluated)


def _gene_seeds(cands: List[int], table: np.ndarray,
                n_cuts: int) -> List[List[int]]:
    """Single-cut seed individuals spread over the candidate table."""
    seeds = []
    for p in cands[:: max(1, len(cands) // 16)]:
        i = 1 + cands.index(p)
        seeds.append([i] + [len(table) - 1] * (n_cuts - 1))
    return seeds


def _rank_mesh(rank_devices: Optional[int]):
    """1-D device mesh for sharded Pareto ranking, or None.

    Clamps to the locally visible device count with a warning — a spec
    written for an 8-device host should still run (slower) on a laptop.
    """
    if not rank_devices or rank_devices <= 1:
        return None
    import jax
    from jax.sharding import Mesh
    devs = jax.devices()
    if len(devs) < rank_devices:
        warnings.warn(
            f"jit_nsga2: rank_devices={rank_devices} but only {len(devs)} "
            f"device(s) visible; using {len(devs)}", stacklevel=2)
        rank_devices = len(devs)
    if rank_devices <= 1:
        return None
    return Mesh(np.asarray(devs[:rank_devices]), ("rank",))


def _pop_gen(ctx: SearchContext) -> Tuple[int, int]:
    """Population/generation budget: explicit settings, else scaled."""
    pop, n_gen = ctx.settings.pop_size, ctx.settings.n_gen
    if pop is None or n_gen is None:
        dpop, dgen = scaled_nsga_defaults(len(ctx.candidates), ctx.n_cuts,
                                          ctx.depth)
        pop, n_gen = pop or dpop, n_gen or dgen
    return pop, n_gen


def _cuts_to_genes(cuts: np.ndarray, table: np.ndarray) -> np.ndarray:
    """Map cut-position rows onto nearest gene-table indices.

    A drifted system keeps the same gene table (the online path pins the
    candidate list), but warm cuts may in general fall between entries —
    each cut snaps to the index of the nearest table value.
    """
    cuts = np.asarray(cuts, dtype=int)
    idx = np.clip(np.searchsorted(table, cuts), 0, len(table) - 1)
    left = np.maximum(idx - 1, 0)
    use_left = (np.abs(table[left] - cuts) <= np.abs(table[idx] - cuts))
    return np.where(use_left, left, idx)


def _warm_genes(ctx: SearchContext, table: np.ndarray) -> Optional[np.ndarray]:
    """Previous-front cut rows as gene rows, or None when warm starting is
    disabled/unavailable."""
    if not ctx.settings.warm_start or ctx.warm_cuts is None:
        return None
    warm = np.asarray(ctx.warm_cuts, dtype=int).reshape(-1, ctx.n_cuts)
    if not len(warm):
        return None
    return _cuts_to_genes(warm, table)


# compiled-runner cache shared across evaluators: keyed by the table shape
# signature plus every static search knob, so re-searches over *different*
# same-shape systems (the online drift loop's perturbed SystemSpecs) reuse
# one XLA compilation — table values ride in as runtime pytree args
_JIT_RUNNER_CACHE: Dict[Tuple, object] = {}


def jit_runner_cache_size() -> int:
    """Number of distinct compiled NSGA-II runners currently cached."""
    return len(_JIT_RUNNER_CACHE)


def clear_jit_runner_cache() -> None:
    """Drop every cached compiled runner (tests / memory pressure)."""
    _JIT_RUNNER_CACHE.clear()


class NSGA2Search:
    """NSGA-II over gene indices into the candidate table (§IV)."""

    name = "nsga2"

    def search(self, ctx: SearchContext) -> StrategyOutput:
        """NumPy NSGA-II over gene indices; honors ``ctx.warm_cuts`` as
        seed individuals."""
        cands = ctx.candidates
        if not cands:
            return StrategyOutput([])
        evaluator = ctx.evaluator
        table = _gene_table(ctx)
        n_cuts = ctx.n_cuts

        def _decode(G: np.ndarray) -> np.ndarray:
            return np.sort(table[G], axis=1)

        def _eval(G: np.ndarray):
            # one vectorized call per generation — the NSGA-II hot path
            be = evaluator.evaluate_batch(_decode(G), ctx.constraints)
            return be.as_objectives(ctx.objectives), be.violation

        pop, n_gen = _pop_gen(ctx)
        seeds = _gene_seeds(cands, table, n_cuts)
        warm = _warm_genes(ctx, table)
        if warm is not None:
            # previous-front rows join the seed pool (nsga2 injects up to
            # pop//2 seed individuals into the initial population)
            seeds = [list(r) for r in warm] + seeds
        res = nsga2(_eval, n_var=n_cuts, lower=0, upper=len(table) - 1,
                    seed=ctx.settings.seed, candidates=seeds,
                    pop_size=pop, n_gen=n_gen)
        evals: List[PartitionEval] = []
        if len(res.pareto_X):
            evals = evaluator.evaluate_batch(
                _decode(res.pareto_X), ctx.constraints).to_evals()
        return StrategyOutput(evals, nsga=res,
                              n_evaluated=pop * (n_gen + 1),
                              strategy_used=self.name)


class JitNSGA2Search:
    """NSGA-II with the whole generation loop compiled by ``jax.jit``.

    The evaluator's prefix-sum cost/memory/link tables are exported once as
    device arrays (:meth:`PartitionEvaluator.jax_tables`), the gene decode
    (indices into the candidate table → sorted cut vectors) happens
    on-device, and selection/variation run as the fixed-shape operator twins
    of ``repro.core.nsga2_jax`` under one ``lax.fori_loop`` — so a whole
    search is a single XLA program and 10k+-individual populations run at
    accelerator rate (~10× the NumPy strategy at pop 2048 on CPU).

    The final front is re-scored through the exact NumPy
    ``evaluate_batch``, so reported metrics carry no float32 drift.  When
    accuracy is searched (objective or ``min_accuracy``) but the evaluator's
    oracle is not jittable (no ``proxy_arrays``), falls back to
    :class:`NSGA2Search` with a warning rather than silently dropping the
    accuracy term.

    Scaling knobs from :class:`~repro.explore.spec.SearchSettings`:
    ``rank_block``/``rank_impl`` select the tiled Pareto-ranking primitive
    (``repro.kernels.pareto_rank``) that keeps 10k–100k+ populations inside
    O(pop · rank_block) working memory, ``n_restarts`` vmaps that many
    independently seeded searches into one compilation and merges their
    fronts, and ``rank_devices`` shards the ranking tile grid across local
    devices with ``shard_map``.
    """

    name = "jit_nsga2"

    # above this population the final front mask comes from the tiled
    # dominator-count primitive instead of the dense host-side sort
    _DENSE_PARETO_MAX = 8192

    def search(self, ctx: SearchContext) -> StrategyOutput:
        """Compiled NSGA-II: one cached runner per table *shape*, gene
        table + EvalTables as runtime args, warm start from
        ``ctx.warm_cuts``; falls back to the NumPy path for measured
        accuracy oracles (reported via ``strategy_used``)."""
        cands = ctx.candidates
        if not cands:
            return StrategyOutput([])
        evaluator = ctx.evaluator
        settings = ctx.settings
        needs_acc = ("accuracy" in ctx.objectives
                     or bool(ctx.constraints.min_accuracy))
        if needs_acc and not hasattr(evaluator.accuracy_fn, "proxy_arrays"):
            warnings.warn(
                "jit_nsga2: accuracy objective/constraint with a non-proxy "
                "accuracy oracle cannot run on-device; falling back to the "
                "NumPy 'nsga2' strategy", stacklevel=2)
            return NSGA2Search().search(ctx)

        import jax.numpy as jnp

        from repro.core.nsga2_jax import (jit_nsga2, jit_nsga2_restarts,
                                          make_jit_restart_runner,
                                          make_jit_runner,
                                          pareto_indices_blocked,
                                          warm_population)
        from repro.core.partition_jax import make_runtime_eval_fn

        table = _gene_table(ctx)
        n_cuts = ctx.n_cuts
        pop, n_gen = _pop_gen(ctx)
        n_restarts = settings.n_restarts
        mesh = _rank_mesh(settings.rank_devices)
        tables = evaluator.jax_tables()

        # shared compiled-runner cache: the gene table and the evaluator
        # tables enter the program as runtime pytree arguments, so the key
        # holds only shape-determining statics — repeated searches over the
        # same evaluator (sweeps, benchmarks) *and* over different
        # same-shape systems (the online drift loop) pay XLA compilation
        # once; n_gen is a traced loop bound, so budgets can vary freely
        key = (tables.shape_signature(), ctx.objectives, ctx.constraints,
               pop, n_cuts, len(table), settings.allow_multi_tensor_cuts,
               settings.rank_block, settings.rank_impl, n_restarts,
               settings.rank_devices)
        reg = default_registry()
        t_search = time.perf_counter()
        runner = _JIT_RUNNER_CACHE.get(key)
        fresh_runner = runner is None
        reg.counter("search_jit_runner_cache_misses" if fresh_runner
                    else "search_jit_runner_cache_hits").inc()
        if runner is None:
            eval_cuts = make_runtime_eval_fn(tables, ctx.objectives,
                                             ctx.constraints)

            def _eval_genes(G, jtable, t):
                return eval_cuts(jnp.sort(jtable[G], axis=1), t)

            if n_restarts > 1:
                runner = make_jit_restart_runner(
                    _eval_genes, n_var=n_cuts, lower=0,
                    upper=len(table) - 1, pop_size=pop,
                    rank_block=settings.rank_block,
                    rank_impl=settings.rank_impl, mesh=mesh, n_eval_args=2)
            else:
                runner = make_jit_runner(
                    _eval_genes, n_var=n_cuts, lower=0,
                    upper=len(table) - 1, pop_size=pop,
                    rank_block=settings.rank_block,
                    rank_impl=settings.rank_impl, mesh=mesh)
            _JIT_RUNNER_CACHE[key] = runner
        eval_args = (jnp.asarray(table), tables)

        seeds = _gene_seeds(cands, table, n_cuts)
        warm = _warm_genes(ctx, table)
        if warm is not None:
            reg.counter("search_warm_starts").inc()
        if n_restarts > 1:
            X0s = None
            if warm is not None:
                X0s = np.stack([
                    warm_population(
                        np.random.default_rng(settings.seed + i), pop,
                        n_cuts, 0, len(table) - 1, warm)
                    for i in range(n_restarts)])
            X, F, CV = jit_nsga2_restarts(
                None, n_var=n_cuts, lower=0, upper=len(table) - 1,
                pop_size=pop, n_gen=n_gen, n_restarts=n_restarts,
                seed=settings.seed, candidates=seeds, runner=runner,
                X0s=X0s, eval_args=eval_args)
        else:
            X0 = None
            if warm is not None:
                X0 = warm_population(np.random.default_rng(settings.seed),
                                     pop, n_cuts, 0, len(table) - 1, warm)
            X, F, CV = jit_nsga2(
                None, n_var=n_cuts, lower=0, upper=len(table) - 1,
                pop_size=pop, n_gen=n_gen, seed=settings.seed,
                candidates=seeds, runner=runner, X0=X0,
                eval_args=eval_args)
        search_s = time.perf_counter() - t_search
        reg.histogram("search_wall_s").observe(search_s)
        if fresh_runner:
            # first call through a fresh runner pays the XLA compilation,
            # so its wall is the compile-cost signal the drift loop watches
            reg.histogram("search_jit_compile_s").observe(search_s)
        if len(X) > self._DENSE_PARETO_MAX:
            p_idx = pareto_indices_blocked(X, F, CV,
                                           block=settings.rank_block or 2048,
                                           impl=settings.rank_impl)
        else:
            p_idx = pareto_indices(X, F, CV)
        res = NSGA2Result(X=X, F=F, CV=CV, pareto_idx=p_idx, history=[])
        evals: List[PartitionEval] = []
        if len(res.pareto_X):
            evals = evaluator.evaluate_batch(
                np.sort(table[res.pareto_X], axis=1),
                ctx.constraints).to_evals()
        return StrategyOutput(evals, nsga=res,
                              n_evaluated=n_restarts * pop * (n_gen + 1),
                              strategy_used=self.name)


STRATEGIES: Dict[str, Type] = {
    "exhaustive": ExhaustiveSearch,
    "multicut": MultiCutScan,
    "nsga2": NSGA2Search,
    "jit_nsga2": JitNSGA2Search,
}


def register_strategy(name: str, cls: Type, override: bool = False) -> None:
    """Register a custom :class:`SearchStrategy` implementation.

    Name collisions raise unless ``override=True`` — re-registering an
    existing name silently would reroute every spec that selects it.
    """
    if name in STRATEGIES and not override:
        raise ValueError(
            f"strategy {name!r} is already registered "
            f"({STRATEGIES[name].__qualname__}); pass override=True to "
            f"replace it")
    STRATEGIES[name] = cls


def resolve_strategies(settings: SearchSettings, n_cuts: int,
                       n_candidates: int) -> List[SearchStrategy]:
    """Map a strategy name to concrete instances.

    ``auto`` reproduces the legacy policy: exhaustive scan for single-cut
    systems, plus NSGA-II when ``n_cuts > 1`` or the candidate list is
    large (``settings.use_nsga`` overrides).
    """
    if settings.strategy == "auto":
        out: List[SearchStrategy] = []
        if n_cuts == 1:
            out.append(ExhaustiveSearch())
        use = settings.use_nsga
        if use is None:
            use = n_cuts > 1 or n_candidates > 64
        if use:
            out.append(NSGA2Search())
        return out
    try:
        return [STRATEGIES[settings.strategy]()]
    except KeyError:
        raise ValueError(f"unknown strategy {settings.strategy!r}; "
                         f"have {['auto'] + sorted(STRATEGIES)}")
