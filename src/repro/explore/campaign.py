"""Campaign runner: fan one exploration-spec template across many models
and/or systems in a single run.

Per model, the schedule, the Def.-3 :class:`SegmentMemoryTable` and the
per-arch ``layer_cost_table`` prefix sums are built **once** and shared
across every system in the fan-out (two systems built from the same
accelerator archs never re-profile a layer).  The outcome is a
:class:`CampaignResult` holding full :class:`ExplorationResult` objects for
programmatic use plus a JSON-serializable :class:`CampaignReport`
(per-model Pareto fronts + Def.-2 selections) for storage and dashboards.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, Sequence

from repro.core.graph import linearize
from repro.core.memory import SegmentMemoryTable
from repro.explore.result import ExplorationResult
from repro.explore.spec import (ExplorationSpec, ModelRef, SweepSpec,
                                SystemSpec)
from repro.utils.atomicio import atomic_write_text


@dataclasses.dataclass
class CampaignEntry:
    """One (model, system) cell of the fan-out, with its live result."""

    model: str
    system: str
    result: ExplorationResult
    wall_s: float


def campaign_entry_dict(model: str, system: str, result: ExplorationResult,
                        wall_s: float) -> Dict[str, Any]:
    """The canonical report-entry dict for one (model, system) cell — shared
    by the serial runner and the fleet workers so a merged fleet report is
    entry-identical to a serial run."""
    return {"model": model, "system": system, "wall_s": round(wall_s, 4),
            **result.to_report()}


@dataclasses.dataclass
class CampaignReport:
    """Serializable campaign outcome (JSON round-trippable)."""

    template: Dict[str, Any]          # the spec template, as a plain dict
    entries: List[Dict[str, Any]]     # flattened per-(model, system) reports
    wall_s: float = 0.0

    def to_dict(self) -> Dict[str, Any]:
        # normalized through JSON so tuples become lists and the dict form
        # is identical before and after a round-trip
        return json.loads(json.dumps(dataclasses.asdict(self)))

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(dataclasses.asdict(self), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CampaignReport":
        return cls(template=d["template"], entries=list(d["entries"]),
                   wall_s=float(d.get("wall_s", 0.0)))

    @classmethod
    def from_json(cls, s: str) -> "CampaignReport":
        return cls.from_dict(json.loads(s))

    def save(self, path: str, indent: int = 1) -> None:
        atomic_write_text(path, self.to_json(indent=indent))

    def summary(self) -> str:
        lines = [f"campaign: {len(self.entries)} (model × system) runs "
                 f"in {self.wall_s:.1f}s"]
        for e in self.entries:
            sel = e.get("selected")
            pick = (f"cuts={tuple(sel['cuts'])} "
                    f"lat={sel['latency_s']*1e3:.2f}ms "
                    f"th={sel['throughput']:.1f}/s"
                    if sel else "no feasible partitioning")
            lines.append(f"  {e['model']} × {e['system']}: "
                         f"|pareto|={len(e['pareto'])}  {pick}")
        return "\n".join(lines)


@dataclasses.dataclass
class CampaignResult:
    entries: List[CampaignEntry]
    report: CampaignReport

    def get(self, model: str, system: Optional[str] = None
            ) -> ExplorationResult:
        for e in self.entries:
            if e.model == model and (system is None or e.system == system):
                return e.result
        raise KeyError(f"no campaign entry for model={model!r} "
                       f"system={system!r}")


class Campaign:
    """Fan an :class:`ExplorationSpec` template across models × systems.

    ``models`` / ``systems`` default to the template's own; objectives,
    constraints, search settings, schedule policy and batch size come from
    the template unchanged, so swapping the search strategy for the whole
    fleet is a one-field edit.
    """

    def __init__(self, template: ExplorationSpec,
                 models: Optional[Sequence[ModelRef]] = None,
                 systems: Optional[Sequence[SystemSpec]] = None):
        self.template = template
        self.models = list(models) if models is not None else [template.model]
        self.systems = (list(systems) if systems is not None
                        else [template.system])

    # -- fleet glue ----------------------------------------------------------
    def to_sweep(self) -> SweepSpec:
        """The campaign as durable data (template × models × systems)."""
        return SweepSpec(template=self.template, models=tuple(self.models),
                         systems=tuple(self.systems))

    @classmethod
    def from_sweep(cls, sweep: SweepSpec) -> "Campaign":
        """Rebuild the runnable campaign from its durable SweepSpec."""
        return cls(sweep.template, models=sweep.models,
                   systems=sweep.systems)

    def to_manifest(self, manifest_dir: str, max_retries: int = 2):
        """Materialize this campaign as a durable fleet work manifest;
        run it with ``python -m repro.fleet run --manifest <dir>`` (see
        :mod:`repro.fleet`).  Returns the created
        :class:`repro.fleet.manifest.Manifest`."""
        from repro.fleet.manifest import Manifest
        return Manifest.create(manifest_dir, self.to_sweep(),
                               max_retries=max_retries)

    def run(self, verbose: bool = False) -> CampaignResult:
        """Explore every (model, system) cell serially, sharing cost caches
        and memory tables per model; returns the merged CampaignResult."""
        from repro.explore.runner import explore_graph
        t_start = time.perf_counter()
        tpl = self.template
        entries: List[CampaignEntry] = []
        for mref in self.models:
            graph, shared = mref.build()
            schedule = linearize(graph, tpl.schedule_policy)
            memtable = SegmentMemoryTable(schedule, shared)
            cost_cache: Dict = {}     # per-arch tables, shared across systems
            for sspec in self.systems:
                t0 = time.perf_counter()
                res = explore_graph(
                    graph, sspec.build(), objectives=tpl.objectives,
                    weights=tpl.weights, constraints=tpl.constraints,
                    search=tpl.search, batch=tpl.batch,
                    accuracy=tpl.accuracy,
                    shared_groups=shared, schedule=schedule,
                    cost_cache=cost_cache, memtable=memtable)
                wall = time.perf_counter() - t0
                entries.append(CampaignEntry(
                    model=mref.label, system=sspec.label, result=res,
                    wall_s=wall))
                if verbose:
                    sel = res.selected
                    print(f"[campaign] {mref.label} × {sspec.label}: "
                          f"|pareto|={len(res.pareto)} "
                          f"cuts={sel.cuts if sel else None} "
                          f"({wall:.2f}s)")
        report = CampaignReport(
            template=tpl.to_dict(),
            entries=[campaign_entry_dict(e.model, e.system, e.result,
                                         e.wall_s) for e in entries],
            wall_s=round(time.perf_counter() - t_start, 4))
        return CampaignResult(entries=entries, report=report)
