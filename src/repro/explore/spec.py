"""Declarative exploration specs — JSON-round-trippable descriptions of one
exploration run: *which model*, *which system*, *which objectives and
constraints*, *which search strategy*.

Everything here is data.  Resolution to live objects (layer graphs,
``SystemConfig``) happens in :meth:`ModelRef.build` / :meth:`SystemSpec.build`
so a spec can be stored, diffed, and shipped between machines, then executed
by :func:`repro.explore.runner.run_spec` or fanned out by
:class:`repro.explore.campaign.Campaign`.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple, Union

from repro.core.link import LinkModel, get_link
from repro.core.partition import Constraints, Platform, SystemConfig
from repro.core.quant import QuantSpec

VALID_OBJECTIVES = ("latency", "energy", "throughput", "bandwidth",
                    "memory", "accuracy")
# built-in strategy names; names added via strategies.register_strategy are
# accepted too (SearchSettings falls back to the live registry)
VALID_STRATEGIES = ("auto", "exhaustive", "multicut", "nsga2", "jit_nsga2")


@dataclasses.dataclass(frozen=True)
class ModelRef:
    """Reference to a model in one of the repo's registries.

    kind:
      * ``cnn``      — ``repro.models.cnn.zoo`` (options: ``in_hw``,
        ``n_classes``, ``w`` …, forwarded to the zoo builder).
      * ``registry`` — ``repro.models.registry`` LLM/SSM configs (options:
        ``seq`` (required for graph extraction), ``reduced``).
    """

    kind: str
    name: str
    options: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def label(self) -> str:
        """Display/report key for this model."""
        return self.name

    def build(self):
        """Resolve to ``(LayerGraph, shared_groups or None)``.

        Imports are lazy: CNN graphs need no JAX, registry models do.
        """
        if self.kind == "cnn":
            from repro.models.cnn.zoo import build_cnn
            return build_cnn(self.name, **self.options).to_graph(), None
        if self.kind == "registry":
            from repro.models.registry import build_model, get_config
            opts = dict(self.options)
            seq = opts.pop("seq", 1024)
            reduced = opts.pop("reduced", False)
            cfg = get_config(self.name)
            if reduced:
                cfg = cfg.reduced()
            model = build_model(cfg)
            shared = (model.shared_groups()
                      if hasattr(model, "shared_groups") else None)
            return model.to_graph(seq), shared
        raise ValueError(f"unknown model kind {self.kind!r} "
                         f"(expected 'cnn' or 'registry')")


@dataclasses.dataclass(frozen=True)
class PlatformSpec:
    """One compute node, by accelerator-registry name (see ``get_arch``)."""

    name: str
    arch: str
    bits: int = 8
    mem_capacity: Optional[int] = None

    def build(self) -> Platform:
        """Resolve the accelerator-registry name into a live Platform."""
        from repro.core.hwmodel.arch import get_arch
        return Platform(self.name, get_arch(self.arch),
                        QuantSpec(bits=self.bits),
                        mem_capacity=self.mem_capacity)


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """A link-registry entry plus optional field overrides (e.g. a slower
    Ethernet for sensitivity sweeps)."""

    base: str = "gige"
    name: Optional[str] = None
    rate_bps: Optional[float] = None
    t_setup_s: Optional[float] = None
    payload_bytes: Optional[int] = None
    header_bytes: Optional[int] = None
    p_tx_w: Optional[float] = None
    p_rx_w: Optional[float] = None
    e_per_byte_j: Optional[float] = None

    _OVERRIDES = ("name", "rate_bps", "t_setup_s", "payload_bytes",
                  "header_bytes", "p_tx_w", "p_rx_w", "e_per_byte_j")

    def build(self) -> LinkModel:
        """The registry link with any non-None field overrides applied."""
        link = get_link(self.base)
        over = {f: getattr(self, f) for f in self._OVERRIDES
                if getattr(self, f) is not None}
        return dataclasses.replace(link, **over) if over else link


LinkLike = Union[str, LinkSpec]


def as_link_spec(link: LinkLike) -> LinkSpec:
    return LinkSpec(base=link) if isinstance(link, str) else link


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """A chain of platforms: ``platforms[i] --links[i]--> platforms[i+1]``."""

    platforms: Tuple[PlatformSpec, ...]
    links: Tuple[LinkSpec, ...]
    name: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "platforms", tuple(self.platforms))
        object.__setattr__(
            self, "links", tuple(as_link_spec(l) for l in self.links))
        if len(self.links) != len(self.platforms) - 1:
            raise ValueError(
                f"{len(self.platforms)} platforms need "
                f"{len(self.platforms) - 1} links, got {len(self.links)}")

    @property
    def label(self) -> str:
        """Display/report key: explicit name or the platform-name join."""
        return self.name or "+".join(p.name for p in self.platforms)

    def build(self) -> SystemConfig:
        """Materialize every platform and link into a SystemConfig."""
        return SystemConfig([p.build() for p in self.platforms],
                            [l.build() for l in self.links])

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SystemSpec":
        """Inverse of ``dataclasses.asdict``; links may be plain strings."""
        return cls(
            platforms=tuple(PlatformSpec(**p) for p in d["platforms"]),
            links=tuple(LinkSpec(**l) if isinstance(l, dict) else l
                        for l in d["links"]),
            name=d.get("name"))


@dataclasses.dataclass(frozen=True)
class AccuracySpec:
    """Declarative accuracy oracle selection.

    ``kind='proxy'`` (the default when the field is omitted) is the analytic
    :class:`~repro.core.accuracy.ProxyAccuracy` noise model with its
    ``base_accuracy``/``noise_scale`` knobs.  ``kind='measured'`` wraps a
    factory registered via
    :func:`repro.core.accuracy.register_accuracy_measure` — called as
    ``factory(graph=..., schedule=..., system=..., **options)`` — in a
    caching :class:`~repro.core.accuracy.MeasuredAccuracy`.  Measured
    oracles run on the NumPy strategies; ``jit_nsga2`` keeps its documented
    fallback (it needs a jittable ``proxy_arrays`` oracle and downgrades to
    ``nsga2`` with a warning when accuracy is searched without one).
    """

    kind: str = "proxy"
    base_accuracy: float = 1.0        # proxy knobs
    noise_scale: float = 4.0
    measure: Optional[str] = None     # registered factory name (measured)
    options: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in ("proxy", "measured"):
            raise ValueError(f"unknown accuracy kind {self.kind!r}; "
                             f"expected 'proxy' or 'measured'")
        if self.kind == "measured" and not self.measure:
            raise ValueError("accuracy kind 'measured' requires a 'measure' "
                             "name registered via "
                             "repro.core.accuracy.register_accuracy_measure")
        if self.kind == "proxy" and (self.measure or self.options):
            raise ValueError(
                "accuracy kind 'proxy' takes no 'measure'/'options' — did "
                "you mean kind='measured'?")

    def build(self, graph, schedule, system):
        """Resolve to a live ``accuracy_fn(cuts) -> float`` oracle."""
        from repro.core.accuracy import (MeasuredAccuracy, ProxyAccuracy,
                                         get_accuracy_measure)
        if self.kind == "proxy":
            return ProxyAccuracy(schedule, system,
                                 base_accuracy=self.base_accuracy,
                                 noise_scale=self.noise_scale)
        factory = get_accuracy_measure(self.measure)
        return MeasuredAccuracy(factory(graph=graph, schedule=schedule,
                                        system=system, **self.options))


@dataclasses.dataclass(frozen=True)
class SearchSettings:
    """Which :class:`~repro.explore.strategies.SearchStrategy` runs and how.

    ``auto`` reproduces the legacy ``Explorer.run`` policy: exhaustive
    single-cut scan when the system has one link, NSGA-II on top when
    ``n_cuts > 1`` or the candidate list is large (override via
    ``use_nsga``).  ``jit_nsga2`` runs the same genetic search as one
    ``jax.jit``-compiled program (see ``JitNSGA2Search``) — pick it for
    multi-thousand populations.  ``pop_size``/``n_gen`` of ``None`` scale
    with the schedule depth and cut count (see ``scaled_nsga_defaults``) —
    sized for the batched evaluator, not the old scalar loop.

    The ``jit_nsga2`` scaling knobs (ignored by the other strategies):

    * ``rank_block`` — row-tile size of the blocked Pareto-ranking
      primitive.  ``None`` auto-selects (dense packed ranking for combined
      populations ≤ 4096, 2048-row tiles beyond — what keeps pop 32768+
      inside O(pop · rank_block) working memory); ``0`` forces dense.
    * ``rank_impl`` — ``'auto' | 'ref' | 'pallas'`` kernel dispatch for the
      ranking primitive (``'auto'``: blocked jnp on CPU, Pallas on TPU).
    * ``n_restarts`` — > 1 runs that many independently seeded searches as
      one vmapped XLA program (seeds ``seed .. seed+n-1``) and merges the
      final fronts.
    * ``rank_devices`` — shard the ranking tile grid across this many local
      devices (``shard_map``); ``None``/1 keeps it single-device.
    * ``warm_start`` — allow the NSGA strategies to seed the initial
      population from a previous Pareto front when the caller provides one
      (``run_search(..., warm_cuts=...)``, as the online re-partitioner
      does).  ``False`` forces a cold uniform init even when warm cuts are
      available — the A/B switch behind the warm-vs-cold quality tests.
    """

    strategy: str = "auto"
    seed: int = 0
    pop_size: Optional[int] = None
    n_gen: Optional[int] = None
    use_nsga: Optional[bool] = None
    max_scan: int = 1_000_000     # MultiCutScan enumeration cap
    scan_chunk: int = 4096        # rows per evaluate_batch call in scans
    allow_multi_tensor_cuts: bool = False
    rank_block: Optional[int] = None
    rank_impl: str = "auto"
    n_restarts: int = 1
    rank_devices: Optional[int] = None
    warm_start: bool = True

    def __post_init__(self):
        if self.rank_impl not in ("auto", "ref", "pallas"):
            raise ValueError(f"unknown rank_impl {self.rank_impl!r}; "
                             f"expected 'auto', 'ref' or 'pallas'")
        if self.n_restarts < 1:
            raise ValueError(f"n_restarts must be >= 1, got {self.n_restarts}")
        if self.strategy in VALID_STRATEGIES:
            return
        # names added at runtime via register_strategy are valid too
        # (lazy import: strategies.py imports this module)
        from repro.explore.strategies import STRATEGIES
        if self.strategy not in STRATEGIES:
            raise ValueError(
                f"unknown strategy {self.strategy!r}; expected one of "
                f"{tuple(dict.fromkeys(VALID_STRATEGIES + tuple(STRATEGIES)))}")


@dataclasses.dataclass(frozen=True)
class ExplorationSpec:
    """One declarative exploration campaign unit: model × system × search.

    JSON-round-trippable (``to_json`` / ``from_json``); resolve and run with
    :func:`repro.explore.runner.run_spec`.
    """

    model: ModelRef
    system: SystemSpec
    objectives: Tuple[str, ...] = ("latency", "energy")
    weights: Optional[Tuple[float, ...]] = None
    constraints: Constraints = dataclasses.field(default_factory=Constraints)
    search: SearchSettings = dataclasses.field(default_factory=SearchSettings)
    schedule_policy: str = "min_memory"
    batch: int = 1
    accuracy: Optional[AccuracySpec] = None   # None -> default proxy oracle

    def __post_init__(self):
        object.__setattr__(self, "objectives", tuple(self.objectives))
        if self.weights is not None:
            object.__setattr__(self, "weights", tuple(self.weights))
        for o in self.objectives:
            if o not in VALID_OBJECTIVES:
                raise ValueError(f"unknown objective {o!r}; "
                                 f"expected one of {VALID_OBJECTIVES}")
        if self.weights is not None and len(self.weights) != len(self.objectives):
            raise ValueError("weights must match objectives")

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        """Plain-dict form; round-trips through :meth:`from_dict`."""
        return dataclasses.asdict(self)

    def to_json(self, indent: Optional[int] = None) -> str:
        """JSON form of :meth:`to_dict` (the on-disk spec format)."""
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExplorationSpec":
        """Inverse of :meth:`to_dict`."""
        system = SystemSpec.from_dict(d["system"])
        weights = d.get("weights")
        acc = d.get("accuracy")
        return cls(
            model=ModelRef(**d["model"]),
            system=system,
            objectives=tuple(d.get("objectives", ("latency", "energy"))),
            weights=tuple(weights) if weights is not None else None,
            constraints=Constraints(**d.get("constraints", {})),
            search=SearchSettings(**d.get("search", {})),
            schedule_policy=d.get("schedule_policy", "min_memory"),
            batch=d.get("batch", 1),
            accuracy=AccuracySpec(**acc) if acc is not None else None)

    @classmethod
    def from_json(cls, s: str) -> "ExplorationSpec":
        """Inverse of :meth:`to_json`."""
        return cls.from_dict(json.loads(s))


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A whole campaign as data: one spec template fanned across
    ``models`` × ``systems`` (defaulting to the template's own).

    This is the durable form a fleet manifest is built from
    (:meth:`repro.explore.campaign.Campaign.to_manifest`): cell order is
    model-major / system-minor — exactly the serial
    :meth:`~repro.explore.campaign.Campaign.run` iteration order — and
    :meth:`spec_hash` fingerprints the canonical JSON so workers refuse to
    execute against a manifest built from a different sweep.
    """

    template: ExplorationSpec
    models: Tuple[ModelRef, ...] = ()
    systems: Tuple[SystemSpec, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "models",
                           tuple(self.models) or (self.template.model,))
        object.__setattr__(self, "systems",
                           tuple(self.systems) or (self.template.system,))

    def cells(self) -> Tuple[Tuple[str, str], ...]:
        """(model label, system label) pairs in serial-run order."""
        return tuple((m.label, s.label)
                     for m in self.models for s in self.systems)

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        """JSON-clean plain-dict form; round-trips via :meth:`from_dict`."""
        return json.loads(json.dumps(dataclasses.asdict(self)))

    def to_json(self, indent: Optional[int] = None) -> str:
        """JSON form of :meth:`to_dict` (what the fleet manifest stores)."""
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SweepSpec":
        """Inverse of :meth:`to_dict`."""
        return cls(
            template=ExplorationSpec.from_dict(d["template"]),
            models=tuple(ModelRef(**m) for m in d.get("models", [])),
            systems=tuple(SystemSpec.from_dict(s)
                          for s in d.get("systems", [])))

    @classmethod
    def from_json(cls, s: str) -> "SweepSpec":
        """Inverse of :meth:`to_json`."""
        return cls.from_dict(json.loads(s))

    def spec_hash(self) -> str:
        """SHA-256 over the canonical JSON form — the fleet manifest's
        sweep identity (resume refuses a mismatching manifest)."""
        import hashlib
        canon = json.dumps(self.to_dict(), sort_keys=True,
                           separators=(",", ":"))
        return hashlib.sha256(canon.encode()).hexdigest()
