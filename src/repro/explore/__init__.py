"""Declarative exploration-campaign API for automated DNN partitioning.

This package is the paper's Fig.-1 framework exposed as composable,
declarative pieces (replacing the monolithic ``repro.core.explorer``
class, which survives only as a deprecation shim):

========================================  ====================================
Paper stage (Fig. 1)                      API piece
========================================  ====================================
DNN model → layer graph                   :class:`ModelRef` (``spec.py``)
System description                        :class:`SystemSpec` /
                                          :class:`PlatformSpec` /
                                          :class:`LinkSpec`
Linear schedule (§IV-A)                   ``schedule_policy`` on
                                          :class:`ExplorationSpec`
Candidate cuts + memory/link filtering    ``filters.candidate_positions``
(§IV-B, Def. 1/3)                         + per-(link, position)
                                          ``filters.link_feasibility``
Metric evaluation (Table I)               ``repro.core.partition``
                                          ``PartitionEvaluator`` (shared by
                                          all strategies)
Search / NSGA-II (§IV)                    :class:`SearchStrategy` protocol —
                                          :class:`ExhaustiveSearch`,
                                          :class:`MultiCutScan`,
                                          :class:`NSGA2Search`,
                                          :class:`JitNSGA2Search` (the same
                                          search as one ``jax.jit`` program)
Pareto front + Def.-2 selection           ``runner.run_search`` →
                                          :class:`ExplorationResult`
Fleet-level studies (many models/         :class:`Campaign` →
systems, shared cost tables)              :class:`CampaignReport`
========================================  ====================================

Typical use::

    from repro.explore import (Campaign, ExplorationSpec, ModelRef,
                               PlatformSpec, SearchSettings, SystemSpec,
                               run_spec)

    spec = ExplorationSpec(
        model=ModelRef("cnn", "squeezenet11"),
        system=SystemSpec(
            platforms=(PlatformSpec("sensor", "eyr", bits=16),
                       PlatformSpec("central", "smb", bits=8)),
            links=("gige",)),
        objectives=("latency", "energy", "throughput"))
    result = run_spec(spec)                       # one model × one system
    print(result.summary())

    fleet = Campaign(spec, models=[ModelRef("cnn", n) for n in zoo])
    report = fleet.run().report                   # serializable fleet report
    report.save("campaign.json")

Specs are JSON-round-trippable (``ExplorationSpec.to_json``/``from_json``),
and strategies are drop-in interchangeable through
``SearchSettings.strategy``.
"""

from repro.explore.deploy import lm_block_cuts
from repro.explore.campaign import (Campaign, CampaignEntry, CampaignReport,
                                    CampaignResult, campaign_entry_dict)
from repro.explore.online import (OnlineRepartitioner, RepartitionDecision,
                                  degrade_link, drop_node)
from repro.explore.filters import (candidate_positions, feasible_cut_rows,
                                   link_feasibility, link_filter,
                                   memory_filter)
from repro.explore.result import (ExplorationResult, eval_from_dict,
                                  eval_to_dict)
from repro.explore.runner import (DEFAULT_OBJECTIVES, explore_graph,
                                  run_search, run_spec, select_weighted)
from repro.explore.spec import (AccuracySpec, ExplorationSpec, LinkSpec,
                                ModelRef, PlatformSpec, SearchSettings,
                                SweepSpec, SystemSpec)
from repro.explore.strategies import (ExhaustiveSearch, JitNSGA2Search,
                                      MultiCutScan, NSGA2Search,
                                      SearchContext, SearchStrategy,
                                      StrategyOutput, clear_jit_runner_cache,
                                      jit_runner_cache_size,
                                      register_strategy,
                                      scaled_nsga_defaults)

__all__ = [
    "AccuracySpec", "Campaign", "CampaignEntry", "CampaignReport",
    "CampaignResult", "DEFAULT_OBJECTIVES", "ExhaustiveSearch",
    "ExplorationResult", "ExplorationSpec", "JitNSGA2Search", "LinkSpec",
    "ModelRef", "MultiCutScan", "NSGA2Search", "OnlineRepartitioner",
    "PlatformSpec", "RepartitionDecision", "SearchContext", "SearchSettings",
    "SearchStrategy", "StrategyOutput", "SweepSpec", "SystemSpec",
    "campaign_entry_dict", "candidate_positions", "clear_jit_runner_cache",
    "degrade_link", "drop_node", "eval_from_dict", "eval_to_dict",
    "explore_graph", "feasible_cut_rows", "jit_runner_cache_size",
    "link_feasibility", "link_filter", "lm_block_cuts", "memory_filter",
    "register_strategy", "run_search", "run_spec", "scaled_nsga_defaults",
    "select_weighted",
]
