"""Exploration results and their serializable report form.

:class:`ExplorationResult` is the in-memory outcome of one search (full
``PartitionEval`` objects, live schedule); ``to_report()`` flattens it into
plain JSON-safe dicts for storage inside a
:class:`~repro.explore.campaign.CampaignReport`.

``summary()`` and the report paths are total: they tolerate empty Pareto
fronts (``selected is None``) and cut indices outside the schedule (the
``-1`` / ``L-1`` sentinels of skipped platforms) without raising.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.core.layers import LayerInfo
from repro.core.nsga2 import NSGA2Result
from repro.core.partition import PartitionEval


def eval_to_dict(ev: PartitionEval) -> Dict[str, Any]:
    """JSON-safe dict form of a :class:`PartitionEval`."""
    d = dataclasses.asdict(ev)
    d["cuts"] = list(d["cuts"])
    d["memory_bytes"] = [int(m) for m in d["memory_bytes"]]
    d["stage_latency_s"] = list(d["stage_latency_s"])
    d["link_latency_s"] = list(d["link_latency_s"])
    return d


def eval_from_dict(d: Dict[str, Any]) -> PartitionEval:
    return PartitionEval(
        cuts=tuple(int(c) for c in d["cuts"]),
        latency_s=float(d["latency_s"]),
        energy_j=float(d["energy_j"]),
        throughput=float(d["throughput"]),
        link_bytes=int(d["link_bytes"]),
        memory_bytes=tuple(int(m) for m in d["memory_bytes"]),
        accuracy=float(d["accuracy"]),
        stage_latency_s=tuple(float(t) for t in d["stage_latency_s"]),
        link_latency_s=tuple(float(t) for t in d["link_latency_s"]),
        violation=float(d.get("violation", 0.0)))


@dataclasses.dataclass
class ExplorationResult:
    """Outcome of the Fig.-1 pipeline for one (model, system) pair."""

    schedule: List[LayerInfo]
    candidates: List[int]                 # feasible clean-cut positions
    all_evals: List[PartitionEval]        # scan points (exhaustive paths)
    pareto: List[PartitionEval]
    selected: Optional[PartitionEval]     # Def.-2 pick; None if front empty
    baselines: List[PartitionEval]        # single-platform runs
    objectives: Tuple[str, ...]
    nsga: Optional[NSGA2Result] = None
    strategy: str = "auto"
    n_evaluated: int = 0          # candidate vectors scored by all strategies
    strategy_used: str = ""       # strategies that actually ran ("+"-joined);
    #                               differs from `strategy` on documented
    #                               downgrades (jit_nsga2 measured-accuracy
    #                               fallback) and for the "auto" policy

    def layer_name(self, cut: int) -> str:
        """Layer name at a cut position; ``"-"`` for the ``-1`` / out-of-
        range sentinels (platform skipped / single-platform schedules)."""
        if 0 <= cut < len(self.schedule):
            return self.schedule[cut].name
        return "-"

    def summary(self) -> str:
        """Human-readable report: schedule size, baselines, Pareto front."""
        lines = [f"schedule: {len(self.schedule)} layers, "
                 f"{len(self.candidates)} feasible cut points "
                 f"[{self.strategy}]"]
        for i, b in enumerate(self.baselines):
            lines.append(
                f"  all-on-platform-{i}: lat={b.latency_s*1e3:.3f} ms  "
                f"E={b.energy_j*1e3:.3f} mJ  th={b.throughput:.1f}/s  "
                f"acc={b.accuracy:.4f}")
        s = self.selected
        if s is None:
            lines.append("  no feasible partitioning found "
                         "(empty Pareto front)")
        else:
            names = [self.layer_name(c) for c in s.cuts]
            lines.append(
                f"  selected cuts {s.cuts} ({','.join(names)}): "
                f"lat={s.latency_s*1e3:.3f} ms  E={s.energy_j*1e3:.3f} mJ  "
                f"th={s.throughput:.1f}/s  acc={s.accuracy:.4f}  "
                f"mem={tuple(int(m/1024) for m in s.memory_bytes)} KiB")
        return "\n".join(lines)

    @classmethod
    def empty_report(cls, strategy: str = "-") -> Dict[str, Any]:
        """A neutral ``to_report()``-shaped dict (no schedule, no points) —
        in sync with real reports by construction; used for failed-cell
        placeholders in fleet merges."""
        return cls(schedule=[], candidates=[], all_evals=[], pareto=[],
                   selected=None, baselines=[], objectives=(),
                   strategy=strategy).to_report()

    def to_report(self) -> Dict[str, Any]:
        """JSON-safe flattened form (Pareto front + selection + baselines);
        the full ``all_evals`` scan is intentionally not serialized."""
        return {
            "n_layers": len(self.schedule),
            "n_candidates": len(self.candidates),
            "n_scanned": len(self.all_evals),
            "n_evaluated": self.n_evaluated,
            "objectives": list(self.objectives),
            "strategy": self.strategy,
            "strategy_used": self.strategy_used or self.strategy,
            "pareto": [eval_to_dict(e) for e in self.pareto],
            "selected": (eval_to_dict(self.selected)
                         if self.selected is not None else None),
            "selected_layers": ([self.layer_name(c) for c in
                                 self.selected.cuts]
                                if self.selected is not None else []),
            "baselines": [eval_to_dict(b) for b in self.baselines],
        }
