"""Exploration engine: resolve a spec, run its strategies, finish with the
final non-dominated filtering and the paper's Def.-2 weighted-sum selection.

Three entry points, from most to least declarative:

* :func:`run_spec`      — resolve an :class:`ExplorationSpec` end-to-end.
* :func:`explore_graph` — run over a live ``LayerGraph``/``SystemConfig``
  (for callers that already hold model objects, e.g. the serving driver).
* :func:`run_search`    — run over a prebuilt ``PartitionEvaluator``
  (campaigns inject shared cost tables here).

All strategies — including the ``jax.jit``-compiled ``jit_nsga2``, which
reads the evaluator's tables as device arrays via
``PartitionEvaluator.jax_tables()`` (built lazily, cached per evaluator) —
consume the same evaluator, so campaign-level cost-table sharing benefits
the JIT path too.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.accuracy import ProxyAccuracy
from repro.core.graph import LayerGraph, linearize
from repro.core.layers import LayerInfo
from repro.core.memory import SegmentMemoryTable
from repro.core.nsga2 import fast_non_dominated_sort
from repro.core.partition import (Constraints, PartitionEval,
                                  PartitionEvaluator, SystemConfig,
                                  single_platform_eval)
from repro.explore.filters import candidate_positions, link_feasibility
from repro.explore.result import ExplorationResult
from repro.explore.spec import AccuracySpec, ExplorationSpec, SearchSettings
from repro.explore.strategies import (SearchContext, resolve_strategies)

DEFAULT_OBJECTIVES = ("latency", "energy")


def select_weighted(pareto: Sequence[PartitionEval],
                    objectives: Sequence[str],
                    weights: Sequence[float]) -> Optional[PartitionEval]:
    """Def. 2: min-max-normalized weighted sum over the front; ``None`` for
    an empty front."""
    if not pareto:
        return None
    F = np.array([ev.as_objectives(objectives) for ev in pareto], dtype=float)
    lo, hi = F.min(axis=0), F.max(axis=0)
    span = np.where(hi - lo > 0, hi - lo, 1.0)
    score = ((F - lo) / span) @ np.asarray(weights)
    return pareto[int(np.argmin(score))]


def run_search(evaluator: PartitionEvaluator, *,
               constraints: Optional[Constraints] = None,
               objectives: Sequence[str] = DEFAULT_OBJECTIVES,
               weights: Optional[Sequence[float]] = None,
               settings: Optional[SearchSettings] = None,
               candidates: Optional[Sequence[int]] = None,
               warm_cuts: Optional[Sequence[Sequence[int]]] = None
               ) -> ExplorationResult:
    """Run the configured strategies over a prebuilt evaluator and finish:
    union pool → final non-dominated filter → Def.-2 selection.

    ``candidates`` overrides the filtered candidate positions — the online
    re-partitioner pins them to the *baseline* system's list so the gene
    table (and hence the compiled-runner shape) stays identical across
    drifted systems; feasibility shifts are then absorbed by constraint
    domination instead of by re-filtering.  ``warm_cuts`` feeds a previous
    Pareto front's cut rows to warm-startable strategies (honored when
    ``settings.warm_start`` is on).
    """
    constraints = constraints or Constraints()
    settings = settings or SearchSettings()
    objectives = tuple(objectives)
    weights = (tuple(weights) if weights
               else tuple(1.0 for _ in objectives))
    if candidates is None:
        cands = candidate_positions(evaluator, constraints,
                                    settings.allow_multi_tensor_cuts)
    else:
        cands = list(candidates)
    ctx = SearchContext(
        evaluator=evaluator, candidates=cands, constraints=constraints,
        objectives=objectives, settings=settings,
        link_feas=link_feasibility(evaluator, constraints.max_link_bytes),
        warm_cuts=(np.asarray(warm_cuts, dtype=int)
                   if warm_cuts is not None and len(warm_cuts) else None))

    baselines = [single_platform_eval(evaluator, i, constraints)
                 for i in range(len(evaluator.system.platforms))]

    scan_pool: List[PartitionEval] = []
    search_pool: List[PartitionEval] = []
    all_evals: List[PartitionEval] = []
    nsga = None
    n_evaluated = 0
    used: List[str] = []
    for strategy in resolve_strategies(settings, ctx.n_cuts, len(cands)):
        out = strategy.search(ctx)
        (scan_pool if out.exhaustive else search_pool).extend(out.evals)
        if not all_evals and out.all_evals:
            all_evals = out.all_evals
        nsga = out.nsga or nsga
        n_evaluated += out.n_evaluated
        used.append(out.strategy_used or strategy.name)

    # pool order mirrors the legacy Explorer: exact scans, then feasible
    # baselines, then heuristic-search points (first-seen wins dedupe ties)
    pool = scan_pool + [b for b in baselines if b.violation <= 0] + search_pool
    if not pool:
        pool = baselines[:]

    pareto: List[PartitionEval] = []
    if pool:
        F = np.array([ev.as_objectives(objectives) for ev in pool])
        CV = np.array([ev.violation for ev in pool])
        fronts = fast_non_dominated_sort(F, CV)
        seen = set()
        for i in fronts[0]:
            if pool[i].cuts not in seen:
                seen.add(pool[i].cuts)
                pareto.append(pool[i])

    selected = select_weighted(pareto, objectives, weights)
    return ExplorationResult(
        schedule=list(evaluator.schedule), candidates=cands,
        all_evals=all_evals, pareto=pareto, selected=selected,
        baselines=baselines, objectives=objectives, nsga=nsga,
        strategy=settings.strategy, n_evaluated=n_evaluated,
        strategy_used="+".join(dict.fromkeys(used)) or settings.strategy)


def explore_graph(graph: LayerGraph, system: SystemConfig, *,
                  objectives: Sequence[str] = DEFAULT_OBJECTIVES,
                  weights: Optional[Sequence[float]] = None,
                  constraints: Optional[Constraints] = None,
                  search: Optional[SearchSettings] = None,
                  schedule_policy: str = "min_memory",
                  batch: int = 1,
                  accuracy_fn: Optional[Callable] = None,
                  accuracy: Optional[AccuracySpec] = None,
                  shared_groups: Optional[Dict[str, str]] = None,
                  schedule: Optional[Sequence[LayerInfo]] = None,
                  cost_cache: Optional[Dict] = None,
                  memtable: Optional[SegmentMemoryTable] = None
                  ) -> ExplorationResult:
    """Run one exploration over live graph/system objects.

    ``schedule`` / ``cost_cache`` / ``memtable`` let campaign runners share
    per-model scheduling and per-arch cost tables across systems.  The
    accuracy oracle resolves in precedence order: a live ``accuracy_fn``
    object, then a declarative ``accuracy`` :class:`AccuracySpec` (proxy
    knobs or a registered measured oracle), then the default
    :class:`ProxyAccuracy`.
    """
    if schedule is None:
        schedule = linearize(graph, schedule_policy)
    acc = accuracy_fn
    if acc is None and accuracy is not None:
        acc = accuracy.build(graph, schedule, system)
    if acc is None:
        acc = ProxyAccuracy(schedule, system)
    evaluator = PartitionEvaluator(
        graph, schedule, system, accuracy_fn=acc, batch=batch,
        shared_groups=shared_groups, cost_cache=cost_cache,
        memtable=memtable)
    return run_search(evaluator, constraints=constraints,
                      objectives=objectives, weights=weights,
                      settings=search)


def run_spec(spec: ExplorationSpec) -> ExplorationResult:
    """Resolve a declarative spec (model + system refs) and run it."""
    graph, shared = spec.model.build()
    system = spec.system.build()
    return explore_graph(
        graph, system, objectives=spec.objectives, weights=spec.weights,
        constraints=spec.constraints, search=spec.search,
        schedule_policy=spec.schedule_policy, batch=spec.batch,
        accuracy=spec.accuracy, shared_groups=shared)
