"""Sharding rules: logical-axis tables per run kind + parameter
PartitionSpecs derived from pytree paths.

Training uses FSDP×TP: weights 2-D sharded over (data, model), activations
batch-over-data with *sequence parallelism* (residual stream seq over
model) so layer-boundary residuals fit HBM at 4k×256 global tokens.
Decode shards the KV cache over batch (data) and sequence (model) — GQA kv
heads are often < 16 so head-sharding the cache is not generally possible.
"""

from __future__ import annotations

import re
from typing import Dict

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn import sharding as shd


def activation_rules(kind: str, multi_pod: bool, batch_divisible: bool,
                     opts: tuple = ()) -> Dict[str, object]:
    """Logical-axis table for with_sharding_constraint hints.

    opts — §Perf optimizations (see EXPERIMENTS.md §Perf):
      "attn_heads": attention-local kv-head sharding (+ kv duplication);
      "mla_latent": shard the MLA compressed latent over the model axis.
    """
    batch_ax = ("pod", "data") if multi_pod else "data"
    rules = dict(shd.DEFAULT_RULES)
    rules["batch"] = batch_ax if batch_divisible else None
    if kind in ("train", "prefill"):
        rules["seq"] = "model"            # sequence parallelism
        rules["expert_cap"] = None
    else:                                  # decode: T == 1
        rules["seq"] = None
    rules["kv_seq"] = "model"
    # kv heads are small (often 4-8): never shard them as activations
    rules["kv_heads"] = None
    if "attn_heads" in opts:
        rules["attn_kv"] = "model"
    if "mla_latent" in opts:
        rules["mla_latent"] = "model"
    if "fsdp" in opts:
        # pure FSDP: batch over EVERY mesh axis, no tensor/sequence
        # parallelism — weights stay 2-D sharded (ZeRO-3 gathers at use)
        all_axes = (("pod", "data", "model") if multi_pod
                    else ("data", "model"))
        rules["batch"] = all_axes if batch_divisible else None
        rules["seq"] = None
        rules["heads"] = None
        rules["kv_heads"] = None
        rules["mlp"] = None
        rules["vocab"] = None
        rules["experts"] = "model"     # expert weights stay expert-sharded
    if "remat_dots" in opts:
        rules["remat_policy"] = "dots"
    if "expert_ep" in opts:
        rules["experts"] = ("data", "model")
    if "softmax_low" in opts:
        rules["softmax_dtype"] = "compute"
    return rules


# -- parameter partition specs ------------------------------------------------

_PARAM_RULES = [
    # (path regex, spec builder given the UNSTACKED leaf ndim)
    (r"embed$", lambda nd: ["model", "data"]),                # (vocab, d)
    (r"head$", lambda nd: ["data", "model"]),                 # (d, vocab)
    (r"vis_proj$", lambda nd: ["data", "model"]),
    (r"mtp_proj$", lambda nd: ["data", "model"]),
    (r"(wq|wk|wv)$", lambda nd: ["data", "model"]),           # (d, h*hd)
    (r"wo$", lambda nd: ["model", "data"]),
    (r"(w_gate|w_up)$", lambda nd: (["model", "data", None]   # (E, d, ff)
                                    if nd == 3 else ["data", "model"])),
    (r"w_down$", lambda nd: (["model", None, "data"]
                             if nd == 3 else ["model", "data"])),
    (r"(sh_gate|sh_up)$", lambda nd: ["data", "model"]),
    (r"sh_down$", lambda nd: ["model", "data"]),
    (r"router$", lambda nd: ["data", None]),
    (r"w_dq$", lambda nd: ["data", None]),                    # MLA
    (r"w_uq$", lambda nd: [None, "model"]),
    (r"w_dkv$", lambda nd: ["data", None]),
    (r"w_kr$", lambda nd: ["data", None]),
    (r"(w_uk|w_uv)$", lambda nd: [None, "model"]),
    (r"w_in$", lambda nd: ["data", "model"]),                 # mamba in-proj
    (r"w_out$", lambda nd: ["model", "data"]),
    (r"conv_w$", lambda nd: [None, "model"]),
    (r"(bq|bk|bv)$", lambda nd: ["model"]),
]


def param_spec(path: str, ndim: int, hybrid: bool = False) -> P:
    """PartitionSpec for a parameter leaf given its '/'-joined path.

    Scan-stacked params ("blocks*" / "mtp_block") get a leading replicated
    layer axis; hybrid (Zamba2) stacks get TWO (group, layer-in-group);
    shared-block params get none.
    """
    stacked = ("blocks" in path or "mtp_block" in path)
    n_stack = (2 if (hybrid and "blocks" in path and "mtp" not in path)
               else 1) if stacked else 0
    nd_eff = ndim - n_stack
    parts = None
    for pat, fn in _PARAM_RULES:
        if re.search(pat, path):
            parts = list(fn(nd_eff))
            break
    if parts is None:
        parts = []                          # norms, scalars: replicated
    parts = [None] * n_stack + parts
    while len(parts) < ndim:
        parts.append(None)
    return P(*parts[:ndim])


def _divides(shape, spec: P, mesh: Mesh) -> P:
    """Clear spec entries whose mesh axes don't divide the dim."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(ax if dim % size == 0 else None)
    return P(*out)


def params_shardings(params_shapes, mesh: Mesh, hybrid: bool = False):
    """Tree of NamedShardings matching a params eval_shape tree."""
    ep_both = shd.current_rules().get("experts") in (("data", "model"),
                                                     ["data", "model"])

    def one(path_leaf):
        path, leaf = path_leaf
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        nd = len(leaf.shape)
        if ep_both and nd == 4 and re.search(r"(w_gate|w_up|w_down)$", key):
            # §Perf "expert_ep": one expert per chip — weights resident,
            # tokens all-to-all (stacked (L, E, d, ff))
            spec = P(None, ("data", "model"), None, None)
        else:
            spec = param_spec(key, nd, hybrid)
        spec = _divides(leaf.shape, spec, mesh)
        return NamedSharding(mesh, spec)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    return jax.tree_util.tree_unflatten(treedef, [one(pl) for pl in leaves])


def batch_shardings(batch_shapes, mesh: Mesh, multi_pod: bool,
                    global_batch: int):
    """Shard every batch leaf on axis 0 over the rules' batch axes."""
    rules_batch = shd.current_rules().get("batch")
    if rules_batch is None:
        batch_axes = ("pod", "data") if multi_pod else ("data",)
    else:
        batch_axes = ((rules_batch,) if isinstance(rules_batch, str)
                      else tuple(rules_batch))
    nb = 1
    for a in batch_axes:
        nb *= mesh.shape[a]
    ax0 = batch_axes if global_batch % nb == 0 else None
    if ax0 is not None and len(ax0) == 1:
        ax0 = ax0[0]

    def one(leaf):
        if leaf.shape and leaf.shape[0] == global_batch and ax0 is not None:
            return NamedSharding(mesh, P(ax0, *([None] * (len(leaf.shape) - 1))))
        if len(leaf.shape) >= 2 and leaf.shape[1] == global_batch:
            # (3, B, T) positions
            spec = [None, ax0] + [None] * (len(leaf.shape) - 2)
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P(*([None] * len(leaf.shape))))
    return jax.tree_util.tree_map(one, batch_shapes)


def cache_shardings(cache_shapes, mesh: Mesh, multi_pod: bool,
                    batch_size: int):
    """KV/SSM caches: batch over data, cache sequence over model.

    Cache leaves are stacked (L, B, S, ...) or (L, B, ...) — axis 1 is
    batch; the sequence axis (if any) is axis 2.

    §Perf "mla_latent": MLA latent caches (ckv/kr) are sharded over the
    LATENT dim instead of the sequence, so the absorbed-attention
    contraction parallelizes and the single-token cache update stays local.
    """
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    nb = 1
    for a in batch_axes:
        nb *= mesh.shape[a]
    bax = (batch_axes if len(batch_axes) > 1 else batch_axes[0]) \
        if batch_size % nb == 0 else None
    mla_latent = shd.current_rules().get("mla_latent") is not None

    def one(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        shp = leaf.shape
        spec = [None] * len(shp)
        is_mla = key.endswith("ckv") or key.endswith("kr")
        for i, d in enumerate(shp):
            if d == batch_size and i <= 2:
                if bax is not None:
                    spec[i] = bax
                if is_mla and mla_latent:
                    if shp[-1] % mesh.shape["model"] == 0:
                        spec[-1] = "model"
                elif i + 1 < len(shp) \
                        and shp[i + 1] % mesh.shape["model"] == 0 \
                        and shp[i + 1] >= mesh.shape["model"] * 8:
                    spec[i + 1] = "model"
                break
        return NamedSharding(mesh, P(*spec))

    leaves, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in leaves])
