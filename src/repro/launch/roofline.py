"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds (per step):

  compute    = FLOPs_per_device / peak_FLOPs
  memory     = HBM_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / link_bw

``compiled.cost_analysis()`` supplies flops and bytes of the post-SPMD
(per-device) module.  Collective bytes are parsed from the partitioned HLO
text: the summed output sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (4 links/chip; we charge the per-link figure, conservative).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.3 = bf16[16,512,1024]{2,1,0} all-gather(...)
_RE_OP = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")\(")
# tuple-result collectives:  = (bf16[...], bf16[...]) all-reduce(
_RE_TUPLE = re.compile(
    r"=\s*\(([^)]*)\)\s*(" + "|".join(_COLLECTIVES) + r")\(")
_RE_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes (per device, post-SPMD HLO)."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        m = _RE_OP.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            continue
        m = _RE_TUPLE.search(line)
        if m:
            shapes, kind = m.groups()
            for dtype, dims in _RE_SHAPE.findall(shapes):
                out[kind] += _shape_bytes(dtype, dims)
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, int]
    n_devices: int
    model_flops: float = 0.0           # 6·N·D (train) / 2·N·D (inference)
    peak_memory_bytes: float = 0.0     # from memory_analysis

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.n_devices
        return self.model_flops / total if total else 0.0

    def row(self) -> Dict[str, object]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_s": self.bound_s,
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "peak_memory_bytes": self.peak_memory_bytes,
            "n_devices": self.n_devices,
        }


def model_flops(cfg, shape, n_layers_equiv_params: int) -> float:
    """6·N·D for training, 2·N·D for inference (N = active params)."""
    d_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                     else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n_layers_equiv_params * d_tokens


def active_params(cfg) -> int:
    """Active (per-token) parameter count — MoE counts top-k+shared only."""
    from repro.models.registry import build_model
    import dataclasses as dc
    if cfg.n_experts:
        # keep first_dense layers' real d_ff: approximate by weighting
        n_moe = cfg.n_layers - cfg.first_dense
        moe_ffn_params = 3 * cfg.d_model * (cfg.top_k + cfg.n_shared) * cfg.moe_d_ff
        dense_ffn_params = 3 * cfg.d_model * cfg.d_ff
        base = build_model(dc.replace(cfg, n_experts=0, top_k=0,
                                      family="dense")).to_graph(8).total_params
        # base counted dense ffn everywhere; swap in moe active ffn
        return base - n_moe * dense_ffn_params + n_moe * moe_ffn_params \
            + cfg.n_layers * 0
    from repro.models.registry import build_model as bm
    return bm(cfg).to_graph(8).total_params


def analyze(compiled, cfg, shape, n_devices: int) -> Roofline:
    """Loop-aware analysis (see hlo_analysis): XLA's cost_analysis counts
    while-loop bodies once, so scanned-layer stacks would be undercounted by
    ~n_layers; we reparse the partitioned HLO with trip-count multipliers."""
    from repro.launch.hlo_analysis import analyze_text
    text = compiled.as_text()
    hc = analyze_text(text)
    flops = hc.flops
    # HBM traffic estimate: operand+result bytes of materializing ops
    # (dots, slices, cache updates, reductions, collectives); elementwise
    # chains are assumed fused on TPU (documented approximation)
    nbytes = hc.write_bytes
    coll = {k: int(v) for k, v in hc.coll_by_kind.items()}
    try:
        ma = compiled.memory_analysis()
        mem_peak = (getattr(ma, "peak_memory_in_bytes", 0) or
                    getattr(ma, "temp_size_in_bytes", 0))
    except Exception:
        mem_peak = 0
    n_active = active_params(cfg)
    return Roofline(
        flops_per_device=flops,
        hbm_bytes_per_device=nbytes,
        coll_bytes_per_device=float(sum(coll.values())),
        coll_breakdown=coll,
        n_devices=n_devices,
        model_flops=model_flops(cfg, shape, n_active),
        peak_memory_bytes=float(mem_peak),
    )
