"""ShapeDtypeStruct input specs for every (arch × input-shape) pair.

No device allocation: the dry-run lowers against these stand-ins.
``run_config`` also derives the shape-adapted model config:

* ``long_500k`` keeps the sliding-window attention variant (the
  sub-quadratic mode); every other shape uses full attention — matching how
  these models are actually served (DESIGN.md §4).
* all production-mesh runs compute in bfloat16.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def run_config(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    window = cfg.window if shape.name == "long_500k" else None
    return dataclasses.replace(cfg, window=window, dtype="bfloat16")


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Batch ShapeDtypeStructs for a train/prefill step (full sequences)."""
    b, t = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.family == "audio":
        return {"codes": jax.ShapeDtypeStruct((b, cfg.n_codebooks, t), i32),
                "labels": jax.ShapeDtypeStruct((b, cfg.n_codebooks, t), i32)}
    specs = {"tokens": jax.ShapeDtypeStruct((b, t), i32),
             "labels": jax.ShapeDtypeStruct((b, t), i32)}
    if cfg.family == "vlm":
        # the ViT frontend stub delivers patch embeddings; text fills the rest
        tv = cfg.n_patches
        tt = t - tv
        specs = {"tokens": jax.ShapeDtypeStruct((b, tt), i32),
                 "labels": jax.ShapeDtypeStruct((b, t), i32),
                 "vision_embeds": jax.ShapeDtypeStruct((b, tv, cfg.d_model),
                                                       jnp.bfloat16),
                 "positions3": jax.ShapeDtypeStruct((3, b, t), i32)}
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeConfig
                 ) -> Dict[str, jax.ShapeDtypeStruct]:
    """One-token decode batch."""
    b = shape.global_batch
    i32 = jnp.int32
    if cfg.family == "audio":
        return {"codes": jax.ShapeDtypeStruct((b, cfg.n_codebooks, 1), i32)}
    out = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.family == "vlm":
        out["positions3"] = jax.ShapeDtypeStruct((3, b, 1), i32)
    return out


def cache_capacity(cfg: ModelConfig, shape: ShapeConfig) -> int:
    cap = shape.seq_len
    if cfg.window is not None:
        cap = min(cap, cfg.window)
    return cap


def eval_shapes(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)
