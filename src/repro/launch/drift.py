"""Drift driver — online re-partitioning feeding the serving runtime.

The paper's automotive/robotics scenarios have links that degrade and
nodes that drop out mid-mission.  This driver plays such a mission:

  1. a reduced decoder LM is resolved and the explorer cold-searches the
     baseline embedded chain (one XLA compilation — the only slow step);
  2. a drift schedule perturbs the system (progressive link degradation,
     then a node dropout); each event triggers a *warm* re-partition
     through :class:`repro.explore.OnlineRepartitioner` — same compiled
     runner, previous front as the seed population, milliseconds of wall;
  3. whenever the decision's block cuts change, the serving side swaps:
     a new :class:`PartitionedLMRunner` over the new cuts, fresh replicas
     behind the least-outstanding :class:`ReplicaRouter`, and (with
     ``--serve``) a burst of traffic through the re-deployed pipeline.

  PYTHONPATH=src python -m repro.launch.drift --arch smollm-360m
  PYTHONPATH=src python -m repro.launch.drift --serve --requests 8
"""

from __future__ import annotations

import argparse
import time

from repro.core import get_link
from repro.explore import (ExplorationSpec, ModelRef, OnlineRepartitioner,
                           PlatformSpec, SearchSettings, SystemSpec,
                           degrade_link, drop_node, jit_runner_cache_size)
from repro.models.registry import ARCH_IDS, build_model, get_config


def drift_schedule(base: SystemSpec):
    """The mission: link 0 degrades 4×, then 32×, then platform 1 dies,
    then the degraded link recovers with the node still down."""
    events = [degrade_link(base, 0, 4.0),
              degrade_link(base, 0, 32.0),
              drop_node(base, 1)]
    events.append(degrade_link(events[-1], 0, 1.0))  # recovered, node down
    return events


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_IDS)
    ap.add_argument("--link", default="eth10",
                    help="baseline inter-stage link (see repro.core.link)")
    ap.add_argument("--pop", type=int, default=128)
    ap.add_argument("--gens", type=int, default=16)
    ap.add_argument("--serve", action="store_true",
                    help="serve a traffic burst through each deployment")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.family not in ("dense",):
        raise SystemExit(f"--arch {args.arch}: partitioned serving needs a "
                         "dense decoder (block-boundary stage cuts)")

    system = SystemSpec(
        platforms=(PlatformSpec("EYR0", "eyr", bits=16),
                   PlatformSpec("EYR1", "eyr", bits=16),
                   PlatformSpec("SMB0", "smb", bits=8),
                   PlatformSpec("SMB1", "smb", bits=8)),
        links=(args.link,) * 3, name="4-chain")
    spec = ExplorationSpec(
        model=ModelRef("registry", args.arch,
                       {"seq": args.prompt_len, "reduced": True}),
        system=system,
        objectives=("latency", "energy", "throughput"),
        search=SearchSettings(strategy="jit_nsga2", seed=0,
                              pop_size=args.pop, n_gen=args.gens))

    # 1. cold baseline search (pays the one XLA compilation)
    t0 = time.perf_counter()
    rp = OnlineRepartitioner(spec)
    d0 = rp.update(system)
    cold_ms = (time.perf_counter() - t0) * 1e3
    cuts = d0.block_cuts(cfg.n_layers)
    print(f"[drift] cold search: {cold_ms:.0f} ms, cuts={d0.cuts} "
          f"-> blocks {cuts} ({jit_runner_cache_size()} compiled runner)")

    serve_ctx = None
    if args.serve:
        import jax
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        serve_ctx = (model, params)
        serve_burst(serve_ctx, cuts, args, cfg, tag="baseline")

    # 2. the drift loop: warm re-partitions, re-deploy on change
    for d in rp.watch(drift_schedule(system)):
        new_cuts = d.block_cuts(cfg.n_layers)
        action = "keep deployment"
        if new_cuts != cuts:
            action = f"RE-DEPLOY blocks {cuts} -> {new_cuts}"
            cuts = new_cuts
        print(f"[drift] {d.label}: {d.repartition_ms:.1f} ms, "
              f"cuts={d.cuts}, feasible={d.feasible} -> {action}")
        if serve_ctx is not None and action.startswith("RE-DEPLOY"):
            serve_burst(serve_ctx, cuts, args, cfg, tag=d.label)

    warm = [d.repartition_ms for d in rp.decisions[1:]]
    print(f"[drift] {len(warm)} warm re-partitions, median "
          f"{sorted(warm)[len(warm) // 2]:.1f} ms vs {cold_ms:.0f} ms cold "
          f"(x{cold_ms / sorted(warm)[len(warm) // 2]:.0f}); compiled "
          f"runners: {jit_runner_cache_size()}")
    return 0


def serve_burst(serve_ctx, cuts, args, cfg, tag: str):
    """One traffic burst through replicas deployed on ``cuts``."""
    from repro.serve import (PipelineServeEngine, ReplicaRouter, Request,
                             ServeLink, poisson_traffic)
    from repro.serving.pipeline import PartitionedLMRunner

    model, params = serve_ctx
    runner = PartitionedLMRunner(model, params, cuts=cuts)
    replicas = []
    for i in range(args.replicas):
        links = [ServeLink(model=get_link(args.link))
                 for _ in range(runner.n_stages - 1)]
        eng = PipelineServeEngine(runner, n_slots=8, n_groups=4, eos=None,
                                  mode="async", capacity=64, links=links,
                                  name=f"replica{i}")
        eng.warmup(prompt_len=args.prompt_len)
        replicas.append(eng)
    reqs = poisson_traffic(args.requests, rate_rps=500.0, vocab=cfg.vocab,
                           prompt_len=args.prompt_len, max_new=args.max_new,
                           seed=7)
    burst = [Request(r.rid, r.prompt, r.max_new, 0.0) for r in reqs]
    rep = ReplicaRouter(replicas).serve(burst, realtime=False)
    s = rep.summary()
    print(f"[drift]   serve[{tag}]: {runner.n_stages} stages, "
          f"{rep.n_done}/{args.requests} done, "
          f"{s['tokens_per_s']:.0f} tok/s")


if __name__ == "__main__":
    raise SystemExit(main())
