"""Drift driver — online re-partitioning feeding the serving runtime.

The paper's automotive/robotics scenarios have links that degrade and
nodes that drop out mid-mission.  This driver plays such a mission:

  1. a reduced decoder LM is resolved and the explorer cold-searches the
     baseline embedded chain (one XLA compilation — the only slow step);
  2. a drift schedule perturbs the system (progressive link degradation,
     then a node dropout); each event triggers a *warm* re-partition
     through :class:`repro.explore.OnlineRepartitioner` — same compiled
     runner, previous front as the seed population, milliseconds of wall;
  3. whenever the decision's block cuts change, the serving side swaps:
     a new :class:`PartitionedLMRunner` over the new cuts, fresh replicas
     behind the least-outstanding :class:`ReplicaRouter`, and (with
     ``--serve``) a burst of traffic through the re-deployed pipeline.

With ``--measured`` the loop is driven by *measurement* instead of the
scripted schedule: a :class:`~repro.serve.faults.FaultPlan` degrades a link
mid-stream, a :class:`~repro.serve.health.HealthMonitor` shared with the
engine estimates live link occupancy, and a
:class:`~repro.serve.health.DivergenceMonitor` (hysteresis + cool-down)
fires the warm re-partition with ``trigger='measured'`` — no explicit
drift event anywhere.

  PYTHONPATH=src python -m repro.launch.drift --arch smollm-360m
  PYTHONPATH=src python -m repro.launch.drift --serve --requests 8
  PYTHONPATH=src python -m repro.launch.drift --measured --degrade 16
"""

from __future__ import annotations

import argparse
import threading
import time

from repro.core import get_link
from repro.explore import (ExplorationSpec, ModelRef, OnlineRepartitioner,
                           PlatformSpec, SearchSettings, SystemSpec,
                           degrade_link, drop_node, jit_runner_cache_size)
from repro.models.registry import ARCH_IDS, build_model, get_config
from repro.obs import NOOP_OBS, Obs, write_chrome_trace
from repro.utils.atomicio import atomic_write_json


def drift_schedule(base: SystemSpec):
    """The mission: link 0 degrades 4×, then 32×, then platform 1 dies,
    then the degraded link recovers with the node still down."""
    events = [degrade_link(base, 0, 4.0),
              degrade_link(base, 0, 32.0),
              drop_node(base, 1)]
    events.append(degrade_link(events[-1], 0, 1.0))  # recovered, node down
    return events


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_IDS)
    ap.add_argument("--link", default="eth10",
                    help="baseline inter-stage link (see repro.core.link)")
    ap.add_argument("--pop", type=int, default=128)
    ap.add_argument("--gens", type=int, default=16)
    ap.add_argument("--serve", action="store_true",
                    help="serve a traffic burst through each deployment")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--measured", action="store_true",
                    help="drive the re-partition from measured divergence "
                         "(injected link fault, no explicit drift event)")
    ap.add_argument("--degrade", type=float, default=8.0,
                    help="--measured: injected link slow-down factor")
    ap.add_argument("--degrade-at", type=int, default=8,
                    help="--measured: link transfer index the fault starts")
    ap.add_argument("--timeline", default="drift_timeline.json",
                    metavar="PATH",
                    help="--measured: where the drift timeline artifact "
                         "(trigger decision + measured-vs-modeled "
                         "divergence series) is written")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="--measured: also write a Chrome trace-event JSON "
                         "of the served burst")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.family not in ("dense",):
        raise SystemExit(f"--arch {args.arch}: partitioned serving needs a "
                         "dense decoder (block-boundary stage cuts)")

    system = SystemSpec(
        platforms=(PlatformSpec("EYR0", "eyr", bits=16),
                   PlatformSpec("EYR1", "eyr", bits=16),
                   PlatformSpec("SMB0", "smb", bits=8),
                   PlatformSpec("SMB1", "smb", bits=8)),
        links=(args.link,) * 3, name="4-chain")
    spec = ExplorationSpec(
        model=ModelRef("registry", args.arch,
                       {"seq": args.prompt_len, "reduced": True}),
        system=system,
        objectives=("latency", "energy", "throughput"),
        search=SearchSettings(strategy="jit_nsga2", seed=0,
                              pop_size=args.pop, n_gen=args.gens))

    # 1. cold baseline search (pays the one XLA compilation)
    t0 = time.perf_counter()
    rp = OnlineRepartitioner(spec)
    d0 = rp.update(system)
    cold_ms = (time.perf_counter() - t0) * 1e3
    cuts = d0.block_cuts(cfg.n_layers)
    print(f"[drift] cold search: {cold_ms:.0f} ms, cuts={d0.cuts} "
          f"-> blocks {cuts} ({jit_runner_cache_size()} compiled runner)")

    serve_ctx = None
    if args.serve or args.measured:
        import jax
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        serve_ctx = (model, params)
        if args.serve:
            serve_burst(serve_ctx, cuts, args, cfg, tag="baseline")

    if args.measured:
        d = measured_drift(serve_ctx, cuts, args, cfg, rp, system)
        return 0 if d is not None else 1

    # 2. the drift loop: warm re-partitions, re-deploy on change
    for d in rp.watch(drift_schedule(system)):
        new_cuts = d.block_cuts(cfg.n_layers)
        action = "keep deployment"
        if new_cuts != cuts:
            action = f"RE-DEPLOY blocks {cuts} -> {new_cuts}"
            cuts = new_cuts
        print(f"[drift] {d.label}: {d.repartition_ms:.1f} ms, "
              f"cuts={d.cuts}, feasible={d.feasible} -> {action}")
        if serve_ctx is not None and action.startswith("RE-DEPLOY"):
            serve_burst(serve_ctx, cuts, args, cfg, tag=d.label)

    warm = [d.repartition_ms for d in rp.decisions[1:]]
    print(f"[drift] {len(warm)} warm re-partitions, median "
          f"{sorted(warm)[len(warm) // 2]:.1f} ms vs {cold_ms:.0f} ms cold "
          f"(x{cold_ms / sorted(warm)[len(warm) // 2]:.0f}); compiled "
          f"runners: {jit_runner_cache_size()}")
    return 0


def measured_drift(serve_ctx, cuts, args, cfg, rp, system):
    """Serve with an injected link degradation and let *measured*
    divergence — not an explicit drift event — trigger the warm
    re-partition.  Persists the drift timeline artifact (trigger decision
    plus the measured-vs-modeled divergence series) to ``args.timeline``
    and returns the measured-trigger decision (None when the monitor never
    fired)."""
    from repro.serve import (DivergenceMonitor, FaultPlan, HealthMonitor,
                             LinkDegrade, PipelineServeEngine, ReplicaRouter,
                             Request, ServeLink, poisson_traffic)
    from repro.serving.pipeline import PartitionedLMRunner

    obs = Obs.on() if getattr(args, "trace", None) else NOOP_OBS
    model, params = serve_ctx
    runner = PartitionedLMRunner(model, params, cuts=cuts)
    links = [ServeLink(model=get_link(args.link))
             for _ in range(runner.n_stages - 1)]
    # monitor sized to the *deployed system's* links: serve link i maps to
    # system link i; unused system links never accumulate samples and are
    # ignored by the divergence monitor's min_samples gate
    health = HealthMonitor(runner.n_stages, len(system.links))
    plan = FaultPlan(events=(
        LinkDegrade(0, args.degrade, at_transfer=args.degrade_at),))
    eng = PipelineServeEngine(runner, n_slots=8, n_groups=4, eos=None,
                              mode="async", capacity=64, links=links,
                              faults=plan, health=health, obs=obs)
    eng.warmup(prompt_len=args.prompt_len)
    dm = DivergenceMonitor(system, enter=max(2.0, args.degrade / 2),
                           exit=1.5, min_breach=3, cooldown_s=2.0,
                           min_samples=4, obs=obs)
    rp.obs = obs

    stop = threading.Event()

    def observer():                  # live sampling while traffic flows
        while not stop.is_set():
            dm.observe(health)
            time.sleep(0.02)

    th = threading.Thread(target=observer, daemon=True)
    th.start()
    reqs = poisson_traffic(args.requests, rate_rps=500.0, vocab=cfg.vocab,
                           prompt_len=args.prompt_len, max_new=args.max_new,
                           seed=7)
    burst = [Request(r.rid, r.prompt, r.max_new, 0.0) for r in reqs]
    rep = ReplicaRouter([eng], obs=obs).serve(burst, realtime=False)
    stop.set()
    th.join(timeout=2.0)
    dm.observe(health)               # catch a fire pending at drain time

    d = None
    if dm.signals:
        sig = dm.signals[0]
        d = rp.update(dm.drifted_system(), label=f"measured~link{sig.link}",
                      trigger="measured")
        print(f"[drift] measured {sig.divergence:.1f}x divergence on link "
              f"{sig.link} (injected {args.degrade:g}x) -> warm re-partition "
              f"{d.repartition_ms:.1f} ms, trigger={d.trigger}, "
              f"changed={d.changed}; served {rep.n_done}/{len(burst)}")
    else:
        print(f"[drift] measured: no divergence fired "
              f"(link0 div {health.link_divergence(0):.2f}x)")

    timeline = drift_timeline(dm, d, args, rep)
    if getattr(args, "timeline", None):
        atomic_write_json(args.timeline, timeline)
        print(f"[drift] wrote drift timeline -> {args.timeline} "
              f"({len(timeline['divergence_series'])} observation(s))")
    if getattr(args, "trace", None):
        write_chrome_trace(args.trace, obs.tracer)
        print(f"[drift] wrote Chrome trace -> {args.trace}")
    return d


def drift_timeline(dm, decision, args, rep) -> dict:
    """The ``--measured`` run's persistent artifact: what fault was
    injected, every (t, per-link divergence) observation the monitor saw
    (measured wire wall vs the deployed spec's model), each fired signal,
    and the re-partition decision the first signal triggered."""
    t_base = dm.history[0][0] if dm.history else 0.0
    out = {
        "timeline_schema": 1,
        "injected_fault": {"kind": "link_degrade", "link": 0,
                           "factor": args.degrade,
                           "at_transfer": args.degrade_at},
        "monitor": {"enter": dm.enter, "exit": dm.exit,
                    "min_breach": dm.min_breach,
                    "cooldown_s": dm.cooldown_s,
                    "min_samples": dm.min_samples},
        "divergence_series": [
            {"t_s": round(t - t_base, 4),
             "links": [round(v, 4) for v in divs]}
            for t, divs in dm.history],
        "signals": [
            {"t_s": round(s.at_s - t_base, 4), "link": s.link,
             "divergence": round(s.divergence, 4)}
            for s in dm.signals],
        "served": {"n_done": rep.n_done, "n_requests": len(rep.records)},
        "decision": None,
    }
    if decision is not None:
        out["decision"] = {
            "label": decision.label, "trigger": decision.trigger,
            "changed": decision.changed, "feasible": decision.feasible,
            "repartition_ms": round(decision.repartition_ms, 3),
            "cuts": list(decision.cuts) if decision.cuts else None,
        }
    return out


def serve_burst(serve_ctx, cuts, args, cfg, tag: str):
    """One traffic burst through replicas deployed on ``cuts``."""
    from repro.serve import (PipelineServeEngine, ReplicaRouter, Request,
                             ServeLink, poisson_traffic)
    from repro.serving.pipeline import PartitionedLMRunner

    model, params = serve_ctx
    runner = PartitionedLMRunner(model, params, cuts=cuts)
    replicas = []
    for i in range(args.replicas):
        links = [ServeLink(model=get_link(args.link))
                 for _ in range(runner.n_stages - 1)]
        eng = PipelineServeEngine(runner, n_slots=8, n_groups=4, eos=None,
                                  mode="async", capacity=64, links=links,
                                  name=f"replica{i}")
        eng.warmup(prompt_len=args.prompt_len)
        replicas.append(eng)
    reqs = poisson_traffic(args.requests, rate_rps=500.0, vocab=cfg.vocab,
                           prompt_len=args.prompt_len, max_new=args.max_new,
                           seed=7)
    burst = [Request(r.rid, r.prompt, r.max_new, 0.0) for r in reqs]
    rep = ReplicaRouter(replicas).serve(burst, realtime=False)
    s = rep.summary()
    print(f"[drift]   serve[{tag}]: {runner.n_stages} stages, "
          f"{rep.n_done}/{args.requests} done, "
          f"{s['tokens_per_s']:.0f} tok/s")


if __name__ == "__main__":
    raise SystemExit(main())
