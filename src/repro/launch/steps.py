"""Jit-able step builders used by both the dry-run and the real drivers.

``build_train_setup`` / ``build_serve_setup`` return (step_fn, arg_specs,
in_shardings, out_shardings) without allocating anything — the dry-run
lowers them against ShapeDtypeStructs; the drivers call them with real
arrays.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import rules as R
from repro.launch import specs as S
from repro.models.registry import build_model
from repro.optim.optimizers import get_optimizer
from repro.training.train_lib import make_train_step


def _replicated(tree, mesh):
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, P(*([None] * len(l.shape)))), tree)


@dataclasses.dataclass
class Setup:
    cfg: ModelConfig
    model: Any
    step_fn: Any                    # callable(*args)
    arg_shapes: Tuple               # ShapeDtypeStructs
    in_shardings: Tuple
    out_shardings: Any


def build_train_setup(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      multi_pod: bool = False, seed: int = 0,
                      grad_accum: int = 1) -> Setup:
    cfg = S.run_config(cfg, shape)
    model = build_model(cfg)
    opt = get_optimizer(cfg.optimizer, 1e-4)
    train_step = make_train_step(model, cfg, opt, grad_accum=grad_accum)

    key = jax.random.PRNGKey(seed)
    params_shapes, state_shapes = jax.eval_shape(model.init, key)
    opt_shapes = jax.eval_shape(opt.init, params_shapes)
    batch_shapes = S.input_specs(cfg, shape)

    hybrid = cfg.family == "hybrid"
    p_shard = R.params_shardings(params_shapes, mesh, hybrid)
    o_shard = R.params_shardings(opt_shapes, mesh, hybrid)
    b_shard = R.batch_shardings(batch_shapes, mesh, multi_pod,
                                shape.global_batch)
    s_shard = _replicated(state_shapes, mesh)
    metrics_shapes = jax.eval_shape(
        train_step, params_shapes, opt_shapes, state_shapes, batch_shapes)[3]
    out_shardings = (p_shard, o_shard, s_shard, _replicated(metrics_shapes, mesh))
    return Setup(cfg, model, train_step,
                 (params_shapes, opt_shapes, state_shapes, batch_shapes),
                 (p_shard, o_shard, s_shard, b_shard), out_shardings)


def build_prefill_setup(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                        multi_pod: bool = False, seed: int = 0) -> Setup:
    cfg = S.run_config(cfg, shape)
    model = build_model(cfg)

    def prefill_step(params, batch):
        logits, _ = model.apply(params, {}, batch, train=False)
        # return only last-token logits (what serving needs)
        return logits[:, -1]

    key = jax.random.PRNGKey(seed)
    params_shapes, _ = jax.eval_shape(model.init, key)
    batch_shapes = S.input_specs(cfg, shape)
    batch_shapes.pop("labels", None)
    hybrid = cfg.family == "hybrid"
    p_shard = R.params_shardings(params_shapes, mesh, hybrid)
    b_shard = R.batch_shardings(batch_shapes, mesh, multi_pod,
                                shape.global_batch)
    out_shapes = jax.eval_shape(prefill_step, params_shapes, batch_shapes)
    out_shard = NamedSharding(
        mesh, P(("pod", "data") if multi_pod else "data",
                *([None] * (len(out_shapes.shape) - 1)))
        if shape.global_batch % (mesh.shape.get("pod", 1) * mesh.shape["data"]) == 0
        else P(*([None] * len(out_shapes.shape))))
    return Setup(cfg, model, prefill_step, (params_shapes, batch_shapes),
                 (p_shard, b_shard), out_shard)


def build_serve_setup(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      multi_pod: bool = False, seed: int = 0) -> Setup:
    """One-token decode step against a seq_len-deep cache."""
    cfg = S.run_config(cfg, shape)
    model = build_model(cfg)

    def serve_step(params, caches, batch):
        logits, new_caches = model.decode_step(params, caches, batch)
        return logits, new_caches

    key = jax.random.PRNGKey(seed)
    params_shapes, _ = jax.eval_shape(model.init, key)
    cap = S.cache_capacity(cfg, shape)
    cache_shapes = jax.eval_shape(
        lambda: model.init_caches(shape.global_batch, cap, jnp.bfloat16))
    batch_shapes = S.decode_specs(cfg, shape)

    hybrid = cfg.family == "hybrid"
    p_shard = R.params_shardings(params_shapes, mesh, hybrid)
    c_shard = R.cache_shardings(cache_shapes, mesh, multi_pod,
                                shape.global_batch)
    b_shard = R.batch_shardings(batch_shapes, mesh, multi_pod,
                                shape.global_batch)
    logits_shapes, _ = jax.eval_shape(serve_step, params_shapes, cache_shapes,
                                      batch_shapes)
    out_shardings = (_replicated(logits_shapes, mesh), c_shard)
    return Setup(cfg, model, serve_step,
                 (params_shapes, cache_shapes, batch_shapes),
                 (p_shard, c_shard, b_shard), out_shardings)


def build_setup(kind: str, cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                multi_pod: bool = False, grad_accum: int = 1) -> Setup:
    if kind == "train":
        return build_train_setup(cfg, shape, mesh, multi_pod,
                                 grad_accum=grad_accum)
    if kind == "prefill":
        return build_prefill_setup(cfg, shape, mesh, multi_pod)
    if kind == "decode":
        return build_serve_setup(cfg, shape, mesh, multi_pod)
    raise KeyError(kind)
