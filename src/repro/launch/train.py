"""Training driver.

CPU (this container): ``--reduced`` trains the reduced variant of any
assigned architecture on the synthetic token stream — the end-to-end
training example.  On a real TPU mesh the same code path jits with the
production shardings (no --reduced, --mesh production).

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save
from repro.data.synthetic import make_batch_for
from repro.models.registry import ARCH_IDS, get_config, build_model
from repro.optim.optimizers import get_optimizer
from repro.optim.schedules import warmup_cosine
from repro.training.train_lib import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params, state = model.init(key)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.arch_id}{' (reduced)' if args.reduced else ''}: "
          f"{n_params/1e6:.1f}M params, {args.steps} steps "
          f"batch={args.batch} seq={args.seq}")

    opt = get_optimizer(cfg.optimizer,
                        warmup_cosine(args.lr, args.steps // 10, args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, cfg, opt))

    t0 = time.time()
    for i in range(args.steps):
        batch = make_batch_for(cfg, args.batch, args.seq, seed=i)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, state, metrics = step_fn(params, opt_state, state,
                                                    batch)
        if (i + 1) % args.log_every == 0 or i == 0:
            m = {k: float(v) for k, v in metrics.items()}
            toks = args.batch * args.seq * (i + 1)
            print(f"  step {i+1:5d}  loss={m['loss']:.4f} ce={m['ce']:.4f} "
                  f"gnorm={m.get('grad_norm', 0):.2f} "
                  f"({toks/(time.time()-t0):.0f} tok/s)")
    if args.ckpt:
        f = save(args.ckpt, params, step=args.steps)
        print(f"[train] checkpoint -> {f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
