"""Loop-aware HLO cost analysis from compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE, ignoring trip
count — an 80-layer scanned transformer shows up as ~1 layer of FLOPs.  This
module reparses the partitioned HLO text, builds the computation call graph,
reads each while op's ``backend_config known_trip_count``, and multiplies
every computation's costs by its execution count.

Per computation we tally:
  * dot FLOPs: 2 · |result| · K (K = product of lhs contracting dims);
  * convolution FLOPs: 2 · |result| · (Cin/g) · prod(kernel spatial dims);
  * collective bytes: result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute;
  * HBM write bytes: result bytes of every materializing op (fusions are
    post-optimization, so op results ≈ buffers that actually hit memory);
    reads are charged as writes × 2 in the roofline (documented estimate).

Elementwise FLOPs are ignored (they are bandwidth-, not compute-bound).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s32": 4, "u32": 4,
    "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_NO_TRAFFIC = {"get-tuple-element", "tuple", "parameter", "constant",
               "bitcast", "after-all", "partition-id", "replica-id", "iota"}

_RE_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_RE_DEF = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_RE_COMP = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{")
_RE_TRIP = re.compile(r'known_trip_count[":{ ]+n["\s:]+\"?(\d+)')
_RE_CALL_SINGLE = re.compile(
    r"(?:calls|body|condition|to_apply|true_computation|false_computation)"
    r"=%?([\w.\-]+)")
_RE_CALL_LIST = re.compile(r"(?:branch_computations|called_computations)"
                           r"=\{([^}]*)\}")


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _RE_SHAPE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    result: List[Tuple[str, List[int]]]
    line: str


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    write_bytes: float = 0.0
    calls: List[Tuple[str, float]] = dataclasses.field(default_factory=list)
    # (callee, multiplier): while bodies get trip count, others 1


def _opcode_of(rest: str) -> Optional[str]:
    """Extract the opcode: first identifier after the result shape."""
    # strip result shape(s): '(a, b)' tuple or single 'bf16[...]...'
    m = re.match(r"\(([^)]*)\)\s+([a-z][\w\-]*)\(", rest)
    if m:
        return m.group(2)
    m = re.match(r"[a-z0-9]+\[[\d,]*\]\S*\s+([a-z][\w\-]*)\(", rest)
    if m:
        return m.group(1)
    return None


def _dot_flops(line: str, result, symbols) -> float:
    # operand names: first parenthesized group after opcode
    m = re.search(r"\bdot\(([^)]*)\)", line)
    if not m:
        return 0.0
    operand_names = re.findall(r"%([\w.\-]+)", m.group(1))
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if not operand_names or cdims is None:
        return 0.0
    lhs = symbols.get(operand_names[0])
    if lhs is None or not lhs:
        return 0.0
    lhs_shape = lhs[0][1]
    k = 1
    for d in (cdims.group(1).split(",") if cdims.group(1) else []):
        di = int(d)
        if di < len(lhs_shape):
            k *= lhs_shape[di]
    n_out = 1
    for dt, dims in result:
        for d in dims:
            n_out *= d
        break
    return 2.0 * n_out * k


def _conv_flops(line: str, result, symbols) -> float:
    m = re.search(r"\bconvolution\(([^)]*)\)", line)
    if not m:
        return 0.0
    names = re.findall(r"%([\w.\-]+)", m.group(1))
    if len(names) < 2:
        return 0.0
    rhs = symbols.get(names[1])
    if not rhs:
        return 0.0
    kshape = rhs[0][1]
    n_out = 1
    for dt, dims in result:
        for d in dims:
            n_out *= d
        break
    # kernel: product of all dims except output-feature dim ~ Cin/g * spatial
    if kshape:
        k = 1
        for d in kshape:
            k *= d
        k //= max(result[0][1][1] if len(result[0][1]) > 1 else 1, 1)
        # crude: divide by output channels (dim 1 in NCHW) — good enough for
        # the CNN graphs; LLM dryruns contain no convolutions
        return 2.0 * n_out * max(k, 1)
    return 0.0


def parse_computations(text: str) -> Dict[str, CompCost]:
    comps: Dict[str, CompCost] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    symbols: Dict[str, list] = {}
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" "):           # computation header or junk
            m = _RE_COMP.match(line.strip())
            if m and "{" in line:
                cur = m.group(1)
                comps[cur] = CompCost()
                symbols = {}
                if line.strip().startswith("ENTRY"):
                    entry = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _RE_DEF.match(line)
        if not m:
            continue
        name, rest = m.groups()
        result = _shape_list(rest.split(" ", 1)[0] if rest.startswith(("(", "f", "b", "s", "u", "p", "c", "t", "o"))
                             else rest)
        # more robust: take shapes before the opcode call paren
        head = rest.split("(")[0]
        result = _shape_list(head) or _shape_list(rest[:80])
        symbols[name] = result
        opcode = _opcode_of(rest) or ""
        cc = comps[cur]

        def _operand_bytes(idx: int) -> int:
            m2 = re.search(r"\b" + re.escape(opcode) + r"\(([^)]*)\)", line)
            if not m2:
                return 0
            names = re.findall(r"%([\w.\-]+)", m2.group(1))
            if idx >= len(names):
                return 0
            return _nbytes(symbols.get(names[idx]) or [])

        if opcode == "dot":
            cc.flops += _dot_flops(line, result, symbols)
            cc.write_bytes += (_nbytes(result) + _operand_bytes(0)
                               + _operand_bytes(1))
        elif opcode == "convolution":
            cc.flops += _conv_flops(line, result, symbols)
            cc.write_bytes += (_nbytes(result) + _operand_bytes(0)
                               + _operand_bytes(1))
        elif opcode in _COLLECTIVES:
            b = _nbytes(result)
            cc.coll_bytes += b
            cc.coll_by_kind[opcode] += b
            cc.write_bytes += 2 * b
        elif opcode in ("dynamic-slice", "gather", "slice", "scatter",
                        "concatenate"):
            cc.write_bytes += _nbytes(result)
        elif opcode == "dynamic-update-slice":
            cc.write_bytes += _operand_bytes(1) or _nbytes(result)
        elif opcode == "reduce":
            cc.write_bytes += _operand_bytes(0) + _nbytes(result)
        elif opcode == "copy":
            cc.write_bytes += 2 * _nbytes(result)
        # everything elementwise is assumed fused into neighbors on TPU
        # call edges
        callees = _RE_CALL_SINGLE.findall(rest)
        for grp in _RE_CALL_LIST.findall(rest):
            callees.extend(re.findall(r"%?([\w.\-]+)", grp))
        if callees:
            mult = 1.0
            if opcode == "while":
                t = _RE_TRIP.search(rest)
                mult = float(t.group(1)) if t else 1.0
            for callee in callees:
                # while body gets trip count; condition ~trip (close enough)
                comps[cur].calls.append((callee, mult))
    comps["__entry__"] = comps.get(entry, CompCost()) if entry else CompCost()
    comps["__entry_name__"] = entry  # type: ignore
    return comps


@dataclasses.dataclass
class HloCosts:
    flops: float
    coll_bytes: float
    coll_by_kind: Dict[str, float]
    write_bytes: float


def top_collectives(text: str, k: int = 15):
    """The k largest collectives (bytes × trip multiplier) with the JAX op
    they came from (metadata op_name) — the §Perf diagnostic."""
    comps = parse_computations(text)
    entry = comps.pop("__entry_name__", None)  # type: ignore
    comps.pop("__entry__", None)
    # recompute multipliers (same as analyze_text)
    import collections
    edges = collections.defaultdict(dict)
    indeg = {c: 0 for c in comps}
    for c, cc in comps.items():
        w = collections.defaultdict(float)
        for callee, m in cc.calls:
            if callee in comps:
                w[callee] += m
        for callee, m in w.items():
            edges[c][callee] = m
            indeg[callee] += 1
    mult = {c: 0.0 for c in comps}
    if entry:
        mult[entry] = 1.0
    order = collections.deque([c for c in comps if indeg[c] == 0])
    while order:
        c = order.popleft()
        for callee, m in edges[c].items():
            mult[callee] += mult[c] * m
            indeg[callee] -= 1
            if indeg[callee] == 0:
                order.append(callee)
    # second pass over text attributing individual collective lines
    out = []
    cur = None
    for line in text.splitlines():
        if not line.startswith(" "):
            m = _RE_COMP.match(line.strip())
            if m and "{" in line:
                cur = m.group(1)
            continue
        if cur is None or not any(c in line for c in _COLLECTIVES):
            continue
        mdef = _RE_DEF.match(line)
        if not mdef:
            continue
        rest = mdef.group(2)
        opcode = _opcode_of(rest)
        if opcode not in _COLLECTIVES:
            continue
        head = rest.split("(")[0]
        shapes = _shape_list(head) or _shape_list(rest[:100])
        nbytes = _nbytes(shapes) * max(mult.get(cur, 0.0), 0.0)
        mname = re.search(r'op_name="([^"]*)"', line)
        out.append((nbytes, opcode, mname.group(1) if mname else "?",
                    cur))
    out.sort(reverse=True)
    return out[:k]


def analyze_text(text: str) -> HloCosts:
    comps = parse_computations(text)
    entry = comps.pop("__entry_name__", None)  # type: ignore
    comps.pop("__entry__", None)
    if entry is None:
        return HloCosts(0, 0, {k: 0 for k in _COLLECTIVES}, 0)
    # execution multipliers: topological propagation over the call DAG
    # (callers processed before callees; edge weights sum over call sites)
    import collections
    edges: Dict[str, Dict[str, float]] = collections.defaultdict(dict)
    indeg: Dict[str, int] = {c: 0 for c in comps}
    for c, cc in comps.items():
        w: Dict[str, float] = collections.defaultdict(float)
        for callee, m in cc.calls:
            if callee in comps:
                w[callee] += m
        for callee, m in w.items():
            edges[c][callee] = m
            indeg[callee] += 1
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    mult[entry] = 1.0
    order = collections.deque([c for c in comps if indeg[c] == 0])
    while order:
        c = order.popleft()
        for callee, m in edges[c].items():
            mult[callee] += mult[c] * m
            indeg[callee] -= 1
            if indeg[callee] == 0:
                order.append(callee)
    tot = HloCosts(0.0, 0.0, {k: 0.0 for k in _COLLECTIVES}, 0.0)
    for name, cc in comps.items():
        f = mult.get(name, 0.0)
        if f <= 0:
            continue
        tot.flops += f * cc.flops
        tot.coll_bytes += f * cc.coll_bytes
        tot.write_bytes += f * cc.write_bytes
        for k, v in cc.coll_by_kind.items():
            tot.coll_by_kind[k] += f * v
    return tot
