"""Serving driver — the paper-kind end-to-end example.

Trains (briefly) a reduced model, then serves batched generation requests
two ways and compares:

  1. monolithic  — the whole model on one platform;
  2. partitioned — the explorer picks the Def.-2 cut for a two-platform
     system, the PartitionedLMRunner executes the stages, and Def. 4
     estimates pipelined throughput from the measured stage latencies.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --requests 8 --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import (Platform, QuantSpec, SystemConfig, get_link)
from repro.core.hwmodel.arch import EYERISS_LIKE, SIMBA_LIKE
from repro.explore import SearchSettings, explore_graph
from repro.data.synthetic import SyntheticTokens, make_batch_for
from repro.models.registry import ARCH_IDS, get_config, build_model
from repro.optim.optimizers import get_optimizer
from repro.serving.engine import GenerationEngine
from repro.serving.pipeline import PartitionedLMRunner, pipeline_report
from repro.training.train_lib import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--warm-steps", type=int, default=30)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params, state = model.init(key)

    # brief warm training so generations aren't pure noise
    opt = get_optimizer("adamw", 1e-3)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, cfg, opt))
    for i in range(args.warm_steps):
        b = make_batch_for(cfg, 8, 64, seed=i)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, state, metrics = step_fn(params, opt_state, state, b)
    print(f"[serve] warm-trained {cfg.arch_id} reduced to "
          f"loss={float(metrics['loss']):.3f}")

    # batched generation (monolithic)
    ds = SyntheticTokens(cfg.vocab)
    prompts = ds.batch(args.requests, args.prompt_len, seed=123)[:, :-1]
    engine = GenerationEngine(model, params,
                              max_seq=args.prompt_len + args.max_new + 8)
    res = engine.generate(prompts, max_new=args.max_new)
    print(f"[serve] monolithic: {args.requests} reqs × {args.max_new} new "
          f"tokens; prefill {res.prefill_s*1e3:.1f} ms, "
          f"decode {res.decode_s*1e3:.1f} ms "
          f"({res.tokens_per_s:.0f} tok/s)")

    # explorer-selected partitioning (two-platform system, Def. 2 + Def. 4)
    if cfg.family in ("dense", "vlm", "audio"):
        graph = model.to_graph(args.prompt_len)
        system = SystemConfig(
            [Platform("A", EYERISS_LIKE, QuantSpec(bits=16)),
             Platform("B", SIMBA_LIKE, QuantSpec(bits=8))],
            [get_link("gige")])
        er = explore_graph(graph, system,
                           objectives=("latency", "energy", "throughput"),
                           search=SearchSettings(seed=0))
        print("[serve] explorer:")
        print(er.summary())
        cut = er.selected.cuts[0] if er.selected is not None else 0
        layer_cut = max(0, min(cfg.n_layers - 2, (cut - 1) // 2))
        runner = PartitionedLMRunner(model, params, [layer_cut])
        batch = {"tokens": jnp.asarray(prompts)}
        logits, rep = runner.forward(batch)
        mono_logits, _ = model.apply(params, state, batch, train=False)
        err = float(jnp.abs(logits - mono_logits).max())
        link_lat = [get_link("gige").latency_s(b) for b in rep.link_bytes]
        info = pipeline_report(rep.latency_s, link_lat)
        print(f"[serve] partitioned after layer {layer_cut}: max |Δlogits| "
              f"= {err:.2e} vs monolithic; stage lat "
              f"{[f'{t*1e3:.1f}ms' for t in rep.latency_s]}, Def.4 "
              f"throughput {info['throughput']:.1f} batches/s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
