"""Serving driver — the paper-kind end-to-end example, now on the
``repro.serve`` runtime.

Trains (briefly) a reduced model, lets the explorer pick the Def.-2 cut
for an embedded two-platform system, then serves a synthetic Poisson
traffic stream over partitioned stages with continuous batching:

  1. the explorer's schedule cut is snapped onto a decoder-block boundary
     (``repro.explore.lm_block_cuts``) and feeds the serving config;
  2. N replicas of the async stage pipeline (thread-per-stage workers,
     emulated link wire time overlapped with compute) serve the stream
     behind a least-outstanding-slots router;
  3. the same burst through the lockstep serial-handoff baseline shows
     what pipelining buys (Def. 4), with per-request TTFT/latency
     percentiles from the router's merged report.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --requests 16 --prompt-len 8 --max-new 12 --replicas 2
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import Platform, QuantSpec, SystemConfig, get_link
from repro.core.hwmodel.arch import EYERISS_LIKE, SIMBA_LIKE
from repro.data.synthetic import make_batch_for
from repro.explore import SearchSettings, explore_graph, lm_block_cuts
from repro.models.registry import ARCH_IDS, build_model, get_config
from repro.obs import NOOP_OBS, Obs, write_chrome_trace
from repro.optim.optimizers import get_optimizer
from repro.serve import (PipelineServeEngine, ReplicaRouter, ServeLink,
                         poisson_traffic)
from repro.serving.pipeline import PartitionedLMRunner
from repro.training.train_lib import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--rate-rps", type=float, default=200.0)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--link", default="eth10",
                    help="emulated inter-stage link (see repro.core.link)")
    ap.add_argument("--warm-steps", type=int, default=30)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the async run "
                         "(open in Perfetto, or `python -m repro.obs PATH`)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write a JSON metrics snapshot after the run")
    args = ap.parse_args()
    obs = Obs.on() if (args.trace or args.metrics) else NOOP_OBS

    cfg = get_config(args.arch).reduced()
    if cfg.family not in ("dense",):
        raise SystemExit(f"--arch {args.arch}: partitioned serving needs a "
                         "dense decoder (block-boundary stage cuts)")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params, state = model.init(key)

    # brief warm training so generations aren't pure noise
    opt = get_optimizer("adamw", 1e-3)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, cfg, opt))
    for i in range(args.warm_steps):
        b = make_batch_for(cfg, 8, 64, seed=i)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, state, metrics = step_fn(params, opt_state,
                                                    state, b)
    print(f"[serve] warm-trained {cfg.arch_id} reduced to "
          f"loss={float(metrics['loss']):.3f}")

    # 1. the explorer picks the cut for a two-platform embedded system
    graph = model.to_graph(args.prompt_len)
    system = SystemConfig(
        [Platform("A", EYERISS_LIKE, QuantSpec(bits=16)),
         Platform("B", SIMBA_LIKE, QuantSpec(bits=8))],
        [get_link(args.link)])
    er = explore_graph(graph, system,
                       objectives=("latency", "energy", "throughput"),
                       search=SearchSettings(seed=0))
    sel = er.selected.cuts if er.selected is not None else (1,)
    cuts = lm_block_cuts(sel, cfg.n_layers)
    print(f"[serve] explorer selected schedule cuts {tuple(sel)} "
          f"-> block cuts {cuts}")

    # 2. traffic + N async replicas behind the least-outstanding router
    runner = PartitionedLMRunner(model, params, cuts=cuts)
    reqs = poisson_traffic(args.requests, rate_rps=args.rate_rps,
                           vocab=cfg.vocab, prompt_len=args.prompt_len,
                           max_new=args.max_new, seed=123)

    def make_replicas(mode, obs=NOOP_OBS):
        reps = []
        for i in range(args.replicas):
            links = [ServeLink(model=get_link(args.link))
                     for _ in range(runner.n_stages - 1)]
            eng = PipelineServeEngine(runner, n_slots=8, n_groups=4,
                                      eos=None, mode=mode, capacity=64,
                                      links=links, name=f"replica{i}",
                                      obs=obs)
            eng.warmup(prompt_len=args.prompt_len)
            reps.append(eng)
        return reps

    # traced run: spans from every replica's stages/links plus the router
    rep_async = ReplicaRouter(make_replicas("async", obs),
                              obs=obs).serve(list(reqs), realtime=False)
    rep_serial = ReplicaRouter(make_replicas("serial")).serve(
        list(reqs), realtime=False)

    # 3. the report: throughput, Def.-4 context, per-request percentiles
    a, s = rep_async.summary(), rep_serial.summary()
    print(f"[serve] serial handoff: {s['tokens_per_s']:.0f} tok/s; "
          f"async pipeline: {a['tokens_per_s']:.0f} tok/s "
          f"(x{a['tokens_per_s'] / max(s['tokens_per_s'], 1e-9):.2f}) over "
          f"{args.replicas} replica(s), {rep_async.n_done} request(s)")
    for k in ("ttft_p50_ms", "ttft_p95_ms", "latency_p50_ms",
              "latency_p95_ms"):
        if k in a:
            print(f"[serve]   async {k} = {a[k]}")
    routed = rep_async.extra.get("routed_per_replica")
    if routed:
        print(f"[serve]   routed per replica: {routed}")
    if args.trace:
        write_chrome_trace(args.trace, obs.tracer)
        print(f"[serve] wrote Chrome trace -> {args.trace} "
              f"(python -m repro.obs {args.trace})")
    if args.metrics:
        obs.metrics.write_snapshot(args.metrics)
        print(f"[serve] wrote metrics snapshot -> {args.metrics}")
    if rep_async.n_done != args.requests or rep_serial.n_done != args.requests:
        print("[serve] ERROR: dropped requests")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
