"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing never touches jax
device state.  The dry-run sets XLA_FLAGS host-device-count=512 before any
jax import; the single-pod mesh then uses the first 256 devices, the
multi-pod mesh all 512 (2 pods × 16 × 16).
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            "=512 before importing jax")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(model: Optional[int] = None) -> Mesh:
    """Tiny mesh over whatever devices exist (tests / CPU examples)."""
    devices = jax.devices()
    n = len(devices)
    m = model or 1
    assert n % m == 0
    return Mesh(np.asarray(devices).reshape(n // m, m), ("data", "model"))
