import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""§Perf diagnostic: lower one (arch × shape), print the roofline terms and
the top collectives with their JAX op provenance.

  PYTHONPATH=src python -m repro.launch.diagnose --arch qwen2-72b --shape train_4k
"""

import argparse

import jax

from repro.configs.base import INPUT_SHAPES
from repro.launch import rules as R
from repro.launch.hlo_analysis import top_collectives
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze
from repro.launch.steps import build_setup
from repro.models.registry import ARCH_IDS, get_config
from repro.nn import sharding as shd


def diagnose(arch: str, shape_name: str, multi_pod: bool = False, k: int = 15,
             opts: tuple = (), grad_accum: int = 1):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = R.activation_rules(
        shape.kind, multi_pod,
        batch_divisible=shape.global_batch % (
            mesh.shape.get("pod", 1) * mesh.shape["data"]) == 0,
        opts=tuple(opts))
    shd.set_mesh(mesh, rules)
    try:
        with mesh:
            setup = build_setup(shape.kind, cfg, shape, mesh, multi_pod,
                                grad_accum=grad_accum)
            jitted = jax.jit(setup.step_fn, in_shardings=setup.in_shardings,
                             out_shardings=setup.out_shardings)
            compiled = jitted.lower(*setup.arg_shapes).compile()
        text = compiled.as_text()
        roof = analyze(compiled, setup.cfg, shape, mesh.devices.size)
        print(f"== {arch} × {shape_name}: compute={roof.compute_s:.3f}s "
              f"memory={roof.memory_s:.3f}s coll={roof.collective_s:.3f}s "
              f"({roof.dominant}-bound) useful={roof.useful_flops_ratio:.2f}")
        print(f"   breakdown: { {k2: f'{v/2**30:.1f}GiB' for k2, v in roof.coll_breakdown.items() if v} }")
        print(f"   temp/dev: {compiled.memory_analysis().temp_size_in_bytes/2**30:.1f} GiB")
        print("   top collectives (bytes x trips | kind | op):")
        for nbytes, kind, op, comp in top_collectives(text, k):
            print(f"     {nbytes/2**30:8.2f} GiB  {kind:20s} {op[:95]}")
        return compiled, roof
    finally:
        shd.set_mesh(None)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--opt", action="append", default=[],
                    choices=["attn_heads", "mla_latent", "fsdp", "remat_dots", "expert_ep", "softmax_low"])
    ap.add_argument("--accum", type=int, default=1)
    args = ap.parse_args()
    diagnose(args.arch, args.shape, args.multi_pod, args.top,
             opts=tuple(args.opt), grad_accum=args.accum)
