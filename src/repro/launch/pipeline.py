"""Pipeline parallelism over the ``pod`` mesh axis — the paper's partitioning
executed on the production mesh.

The explorer (``repro.core``) picks the stage boundary; for a homogeneous
transformer stack on identical pods the latency-balanced Def.-2 optimum is
the equal split (the explorer confirms this — see benchmarks), which lets us
use a stacked-stage ``shard_map``: stage parameters (S, L/S, ...) are sharded
over 'pod', microbatches circulate stage-to-stage with ``lax.ppermute``
(GPipe schedule).  Cross-pod traffic per microbatch is exactly the paper's
link tensor: (b_mb, T, d_model).

``pipelined_apply`` matches the monolithic model's logits (tested), modulo
the embed/final-norm/head which run replicated outside the pipelined stack.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.nn.sharding import shard_map

from repro.configs.base import ModelConfig
from repro.models.decoder import DecoderLM, _scan_blocks
from repro.nn.layers import rms_norm


def stack_stages(params: Dict[str, Any], n_stages: int) -> Dict[str, Any]:
    """Reshape scan-stacked blocks (L, ...) -> (S, L/S, ...)."""
    out = dict(params)
    blocks = params["blocks_dense"]
    def rs(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])
    out["blocks_dense"] = jax.tree_util.tree_map(rs, blocks)
    return out


def pipelined_apply(model: DecoderLM, params: Dict[str, Any], batch: Dict,
                    mesh: Mesh, n_microbatches: int,
                    stage_axis: str = "pod") -> jnp.ndarray:
    """Forward pass with the layer stack pipelined over ``stage_axis``.

    params must already be stage-stacked (see ``stack_stages``).  Embedding,
    final norm and head run outside the pipelined region (replicated over
    the stage axis, sharded over data/model as usual).
    """
    n_stages = mesh.shape[stage_axis]
    x, positions = model._embed(params, batch)
    b, t, d = x.shape
    assert b % n_microbatches == 0, (b, n_microbatches)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    mb = b // n_microbatches
    xs = x.reshape(n_microbatches, mb, t, d)
    pos_mb = positions.reshape(n_microbatches, mb, t) \
        if positions.ndim == 2 else None

    blocks = params["blocks_dense"]

    # everything except the stage axis stays as-is (data/model sharding of
    # microbatches is handled by the outer jit); inside shard_map we only
    # split the stage axis.
    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(stage_axis), P(), P()),
        out_specs=P(),
        check_vma=False)
    def run(blocks_stage, xs_all, pos_all):
        # blocks_stage leaves: (1, L/S, ...) — this pod's slice
        blocks_local = jax.tree_util.tree_map(lambda a: a[0], blocks_stage)
        stage = jax.lax.axis_index(stage_axis)
        n_steps = n_microbatches + n_stages - 1

        def stage_fn(x_mb, pos_):
            y, _, _ = _scan_blocks(model.dense_block, blocks_local, x_mb,
                                   pos_)
            return y

        def body(carry, step):
            buf, outputs = carry
            mb_idx = jnp.clip(step, 0, n_microbatches - 1)
            x_in = jax.lax.dynamic_index_in_dim(xs_all, mb_idx, 0,
                                                keepdims=False)
            p_in = jax.lax.dynamic_index_in_dim(pos_all, mb_idx, 0,
                                                keepdims=False)
            inp = jnp.where(stage == 0, x_in, buf)
            out = stage_fn(inp, p_in)
            # hand off to the next stage
            nxt = jax.lax.ppermute(
                out, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            # last stage emits: microbatch (step - (S-1)) completes at step
            emit_idx = jnp.clip(step - (n_stages - 1), 0, n_microbatches - 1)
            do_emit = step >= (n_stages - 1)
            outputs = jax.lax.cond(
                do_emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, out, emit_idx, 0),
                lambda o: o, outputs)
            return (nxt, outputs), None

        buf0 = jnp.zeros_like(xs_all[0])
        outs0 = jnp.zeros_like(xs_all)
        (_, outputs), _ = jax.lax.scan(body, (buf0, outs0),
                                       jnp.arange(n_steps))
        # only the LAST stage's outputs are real: zero elsewhere + psum
        last = n_stages - 1
        outputs = jnp.where(stage == last, outputs, 0)
        outputs = jax.lax.psum(outputs, stage_axis)
        return outputs

    pos_in = pos_mb if pos_mb is not None else jnp.zeros(
        (n_microbatches, mb, t), jnp.int32)
    outs = run(blocks, xs, pos_in)
    x = outs.reshape(b, t, d)
    x = rms_norm(x, params["final_norm"])
    return model._head(params, x)


def explorer_stage_boundary(cfg: ModelConfig, seq: int, n_stages: int,
                            link: str = "dci") -> Tuple[list, object]:
    """Use the paper's explorer to choose the pipeline cut on TPU pods.

    Returns (cut layer indices, ExplorationResult).  For identical pods the
    Pareto-selected cut is the balanced split; heterogeneous pod mixes move
    it — both come from the same machinery (DESIGN.md §5).
    """
    from repro.core import Platform, QuantSpec, SystemConfig, get_link
    from repro.core.hwmodel.arch import TPU_V5E
    from repro.explore import SearchSettings, explore_graph
    from repro.models.registry import build_model
    import dataclasses as dc

    model = build_model(cfg)
    graph = model.to_graph(seq)
    pod = Platform("pod", dc.replace(TPU_V5E, mem_bytes=256 * 16 * 2 ** 30),
                   QuantSpec(bits=16))
    system = SystemConfig([pod] * n_stages,
                          [get_link(link)] * (n_stages - 1))
    res = explore_graph(graph, system, objectives=("latency", "throughput"),
                        schedule_policy="insertion",
                        search=SearchSettings(seed=0))
    # map graph cut positions back to block indices (2 nodes per block:
    # attention + ffn, plus embed at 0)
    if res.selected is None:          # no feasible partition: balanced split
        step = max(1, cfg.n_layers // n_stages)
        return [min(cfg.n_layers - 1, (k + 1) * step - 1)
                for k in range(n_stages - 1)], res
    cuts = []
    for c in res.selected.cuts:
        layer = max(0, min(cfg.n_layers - 1, c // 2))
        cuts.append(layer)
    return cuts, res
