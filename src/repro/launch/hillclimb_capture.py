import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Capture the §Perf hillclimb results: baseline vs optimized roofline rows
for the three selected pairs, written to experiments/hillclimb_optimized.json.

  PYTHONPATH=src python -m repro.launch.hillclimb_capture
"""


from repro.launch.dryrun import dryrun_one
from repro.utils.atomicio import atomic_write_json

PAIRS = [
    # (arch, shape, final opts)
    ("qwen2-72b", "train_4k", ("fsdp",)),
    ("deepseek-v3-671b", "decode_32k", ("expert_ep",)),
    ("musicgen-large", "prefill_32k", ()),   # loop/layout fixes are default
    ("deepseek-v3-671b", "train_4k", ("attn_heads",)),  # bonus hillclimb D
]


def main():
    out = []
    for arch, shape, opts in PAIRS:
        base = dryrun_one(arch, shape, verbose=False, opts=())
        opt = dryrun_one(arch, shape, verbose=False, opts=opts) if opts else base
        row = {"arch": arch, "shape": shape, "opts": list(opts),
               "baseline": base, "optimized": opt}
        if "error" not in base and "error" not in opt:
            b, o = base["bound_s"], opt["bound_s"]
            row["speedup_on_bound"] = round(b / o, 2) if o else None
            print(f"{arch} × {shape}: bound {b:.3f}s -> {o:.3f}s "
                  f"({row['speedup_on_bound']}x) opts={list(opts)}")
        out.append(row)
    atomic_write_json("experiments/hillclimb_optimized.json", out)
    print("wrote experiments/hillclimb_optimized.json")


if __name__ == "__main__":
    main()
