import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production mesh, print memory/cost analysis, and emit roofline rows.

MUST be run as a module (``python -m repro.launch.dryrun``) so the XLA flag
above is set before jax initializes its backends.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all                  # 10 × 4 single-pod
  python -m repro.launch.dryrun --all --multi-pod      # 2-pod (512 chips)
  python -m repro.launch.dryrun --arch ... --shape ... --out results.json
"""

import argparse
import time
import traceback

import jax

from repro.configs.base import INPUT_SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze
from repro.launch.steps import build_setup
from repro.models.registry import ARCH_IDS, get_config, supports_shape
from repro.nn import sharding as shd
from repro.utils.atomicio import atomic_write_json
from repro.launch import rules as R


def dryrun_one(arch: str, shape_name: str, multi_pod: bool = False,
               verbose: bool = True, opts: tuple = (),
               grad_accum: int = 1) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if not supports_shape(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "full-attention arch without sub-quadratic variant"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    kind = shape.kind
    t0 = time.time()
    rules = R.activation_rules(
        kind, multi_pod,
        batch_divisible=shape.global_batch % (
            mesh.shape.get("pod", 1) * mesh.shape["data"]) == 0,
        opts=tuple(opts))
    shd.set_mesh(mesh, rules)
    try:
        with mesh:
            setup = build_setup(kind, cfg, shape, mesh, multi_pod,
                                grad_accum=grad_accum)
            jitted = jax.jit(setup.step_fn,
                             in_shardings=setup.in_shardings,
                             out_shardings=setup.out_shardings)
            lowered = jitted.lower(*setup.arg_shapes)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            ma = compiled.memory_analysis()
            roof = analyze(compiled, setup.cfg, shape, n_dev)
            row = {
                "arch": arch, "shape": shape_name, "kind": kind,
                "multi_pod": multi_pod, "n_devices": n_dev,
                "opts": list(opts),
                "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1),
                "memory": {
                    "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
                    "output_bytes": getattr(ma, "output_size_in_bytes", 0),
                    "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
                    "generated_code_bytes": getattr(
                        ma, "generated_code_size_in_bytes", 0),
                },
                **roof.row(),
            }
            if verbose:
                print(f"[dryrun] {arch} × {shape_name}"
                      f"{' ×2pod' if multi_pod else ''}: "
                      f"compute={roof.compute_s*1e3:.1f}ms "
                      f"memory={roof.memory_s*1e3:.1f}ms "
                      f"coll={roof.collective_s*1e3:.1f}ms "
                      f"→ {roof.dominant}-bound; "
                      f"args/dev={row['memory']['argument_bytes']/2**30:.2f}GiB "
                      f"temp/dev={row['memory']['temp_bytes']/2**30:.2f}GiB "
                      f"useful={roof.useful_flops_ratio:.2f} "
                      f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
                print(f"         memory_analysis: {ma}")
            return row
    except Exception as e:
        if verbose:
            traceback.print_exc()
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "error": f"{type(e).__name__}: {e}"}
    finally:
        shd.set_mesh(None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--opt", action="append", default=[],
                    choices=["attn_heads", "mla_latent", "fsdp", "remat_dots", "expert_ep", "softmax_low"],
                    help="enable a §Perf optimization (repeatable)")
    args = ap.parse_args()

    rows = []
    if args.all:
        pairs = [(a, s) for a in ARCH_IDS for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]
    for arch, shape in pairs:
        rows.append(dryrun_one(arch, shape, args.multi_pod,
                               opts=tuple(args.opt)))
    if args.out:
        atomic_write_json(args.out, rows)
        print(f"wrote {len(rows)} rows to {args.out}")
    n_err = sum(1 for r in rows if "error" in r)
    n_skip = sum(1 for r in rows if r.get("skipped"))
    print(f"dry-run: {len(rows) - n_err - n_skip} ok, {n_skip} skipped, "
          f"{n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
