"""Unified decoder-only LM covering the dense / moe / audio / vlm families
(ssm + hybrid live in ``repro.models.ssm_lm``).

Layers are stacked and executed with ``lax.scan`` (MaxText-style): fast
compiles at 80 layers, clean remat, and pipeline-stage splitting for the
partitioner.  Parameters are stacked pytrees with a leading ``layers`` axis.

Public API (same for every family — the launcher depends only on this):
  init(key) -> (params, state)
  apply(params, state, batch, train=...) -> (logits, aux)
  init_caches(batch_size, capacity, dtype) -> cache pytree
  decode_step(params, caches, batch) -> (logits, new_caches)
  to_graph(seq) -> LayerGraph (partitioner view, per-block granularity)
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import layers as GL
from repro.core.graph import LayerGraph
from repro.nn.attention import (GQAAttention, MLAAttention, MLAConfig,
                                init_cache, init_mla_cache)
from repro.nn.layers import rms_norm
from repro.nn.moe import MoEFFN
from repro.nn.module import Module, normal_init
from repro.nn.sharding import shard


def _dtype(cfg: ModelConfig):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.dtype]


def gated_mlp_init(key, d, ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": normal_init(k1, (d, ff), d ** -0.5, dtype),
            "w_up": normal_init(k2, (d, ff), d ** -0.5, dtype),
            "w_down": normal_init(k3, (ff, d), ff ** -0.5, dtype)}


def gated_mlp(params, x):
    w_g = shard(params["w_gate"], ("embed", "mlp"))
    w_u = shard(params["w_up"], ("embed", "mlp"))
    w_d = shard(params["w_down"], ("mlp", "embed"))
    h = jax.nn.silu(x @ w_g) * (x @ w_u)
    return h @ w_d


class DecoderBlock(Module):
    """Pre-norm attention + FFN block. kind: 'dense' or 'moe'."""

    def __init__(self, cfg: ModelConfig, kind: str):
        self.cfg = cfg
        self.kind = kind
        dt = _dtype(cfg)
        if cfg.use_mla:
            self.attn = MLAAttention(MLAConfig(
                d_model=cfg.d_model, n_heads=cfg.n_heads,
                q_lora_rank=cfg.q_lora_rank, kv_lora_rank=cfg.kv_lora_rank,
                qk_nope_dim=cfg.qk_nope_dim, qk_rope_dim=cfg.qk_rope_dim,
                v_head_dim=cfg.v_head_dim, rope_theta=cfg.rope_theta), dt)
        else:
            self.attn = GQAAttention(
                cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.resolved_head_dim,
                qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, window=cfg.window,
                rope_theta=cfg.rope_theta,
                mrope_sections=cfg.mrope_sections, dtype=dt)
        if kind == "moe":
            self.ffn = MoEFFN(cfg.d_model, cfg.moe_d_ff, cfg.n_experts,
                              cfg.top_k, cfg.n_shared,
                              sigmoid_gate=cfg.sigmoid_gate, dtype=dt)
        else:
            self.ffn = None
        self.dt = dt

    def init(self, key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {"ln1": jnp.ones((self.cfg.d_model,), self.dt),
             "ln2": jnp.ones((self.cfg.d_model,), self.dt),
             "attn": self.attn.init(k1)[0]}
        if self.kind == "moe":
            p["moe"] = self.ffn.init(k2)[0]
        else:
            p["mlp"] = gated_mlp_init(k3, self.cfg.d_model, self.cfg.d_ff,
                                      self.dt)
        return p, {}

    def apply(self, params, state, x, *, positions=None, cache=None,
              impl="ref", train=False, **kw):
        h = rms_norm(x, params["ln1"])
        if cache is not None:
            a, new_cache = self.attn.apply(params["attn"], {}, h,
                                           positions=positions, cache=cache,
                                           impl=impl)
        else:
            a, _ = self.attn.apply(params["attn"], {}, h,
                                   positions=positions, impl=impl)
            new_cache = None
        x = x + a
        h = rms_norm(x, params["ln2"])
        if self.kind == "moe":
            f, aux = self.ffn.apply(params["moe"], {}, h)
        else:
            f, aux = gated_mlp(params["mlp"], h), {}
        x = x + f
        x = shard(x, ("batch", "seq", "act_embed"))
        return x, (new_cache, aux)


def _stack_init(block: Module, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: block.init(k)[0])(keys)


def _scan_blocks(block: DecoderBlock, stacked_params, x, positions,
                 caches=None, impl="ref", train=False, remat=False):
    """Run x through n stacked blocks via lax.scan.

    caches: stacked cache pytree with leading layer axis (or None).
    Returns (x, new_caches, aux_sums).
    """
    def body(carry, layer_in):
        h = carry
        p, c = layer_in
        h2, (new_c, aux) = block.apply(p, {}, h, positions=positions,
                                       cache=c, impl=impl, train=train)
        aux_vals = tuple(aux[k] for k in sorted(aux)) if aux else ()
        return h2, (new_c, aux_vals)

    if remat:
        from repro.nn.sharding import current_rules
        policy = None
        if current_rules().get("remat_policy") == "dots":
            # §Perf "remat_dots": keep matmul outputs, skip the backward
            # re-gather of ZeRO-3 weights at the cost of saved activations
            policy = jax.checkpoint_policies.checkpoint_dots
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)

    xs = (stacked_params, caches)
    x, (new_caches, aux_stack) = jax.lax.scan(body, x, xs)
    aux = {}
    if aux_stack:
        names = sorted(["lb_loss", "z_loss", "dropped"])
        for name, v in zip(names, aux_stack):
            aux[name] = v.mean()
    return x, new_caches, aux


class DecoderLM(Module):
    """Decoder-only LM for dense / moe / audio / vlm configs."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dt = _dtype(cfg)
        self.block = DecoderBlock(cfg, "dense" if cfg.family != "moe" else "moe")
        self.n_dense = cfg.first_dense if cfg.family == "moe" else cfg.n_layers
        self.n_moe = cfg.n_layers - cfg.first_dense if cfg.family == "moe" else 0
        if self.n_moe:
            self.dense_block = DecoderBlock(cfg, "dense")
            self.moe_block = DecoderBlock(cfg, "moe")
        else:
            self.dense_block = self.block
            self.moe_block = None

    # -- init ----------------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        vocab_rows = cfg.vocab * max(cfg.n_codebooks, 1)
        p: Dict[str, Any] = {
            "embed": normal_init(ks[0], (vocab_rows, cfg.d_model), 0.02,
                                 self.dt),
            "final_norm": jnp.ones((cfg.d_model,), self.dt),
        }
        if self.n_dense:
            p["blocks_dense"] = _stack_init(self.dense_block, ks[1],
                                            self.n_dense)
        if self.n_moe:
            p["blocks_moe"] = _stack_init(self.moe_block, ks[2], self.n_moe)
        if not cfg.tied_embeddings:
            p["head"] = normal_init(ks[3], (cfg.d_model, vocab_rows),
                                    cfg.d_model ** -0.5, self.dt)
        if cfg.family == "vlm":
            # projector stub: maps frontend patch embeddings into d_model
            p["vis_proj"] = normal_init(ks[4], (cfg.d_model, cfg.d_model),
                                        cfg.d_model ** -0.5, self.dt)
        if cfg.mtp:
            p["mtp_block"] = _stack_init(self.dense_block, ks[5], cfg.mtp)
            p["mtp_proj"] = normal_init(ks[6], (2 * cfg.d_model, cfg.d_model),
                                        (2 * cfg.d_model) ** -0.5, self.dt)
        return p, {}

    # -- embedding / head per family ------------------------------------------
    def _embed(self, params, batch) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
        cfg = self.cfg
        table = shard(params["embed"], ("vocab", "embed"))
        if cfg.family == "audio":
            codes = batch["codes"]                       # (B, K, T)
            offs = (jnp.arange(cfg.n_codebooks) * cfg.vocab)[None, :, None]
            x = jnp.take(table, codes + offs, axis=0).sum(axis=1)
            positions = None
        elif cfg.family == "vlm" and "vision_embeds" in batch:
            tokens = batch["tokens"]                     # (B, T_txt)
            vis = batch["vision_embeds"].astype(self.dt)  # (B, T_vis, D)
            vis = vis @ params["vis_proj"]
            txt = jnp.take(table, tokens, axis=0)
            x = jnp.concatenate([vis, txt], axis=1)
            positions = batch.get("positions3")          # (3, B, T_total)
        else:
            x = jnp.take(table, batch["tokens"], axis=0)
            positions = batch.get("positions3", batch.get("positions"))
        return shard(x, ("batch", "seq", "act_embed")), positions

    def _head(self, params, x) -> jnp.ndarray:
        cfg = self.cfg
        w = (params["embed"].T if cfg.tied_embeddings else params["head"])
        w = shard(w, ("embed", "vocab"))
        logits = x @ w
        if cfg.family == "audio":
            b, t, _ = logits.shape
            return logits.reshape(b, t, cfg.n_codebooks, cfg.vocab)
        return logits

    # -- forward ----------------------------------------------------------------
    def apply(self, params, state, batch, *, train=False, impl="ref", **kw):
        cfg = self.cfg
        x, positions = self._embed(params, batch)
        b, t, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        if cfg.mrope_sections is not None and positions.ndim == 2:
            positions = jnp.stack([positions] * 3)
        aux: Dict[str, jnp.ndarray] = {}
        if self.n_dense:
            x, _, _ = _scan_blocks(self.dense_block, params["blocks_dense"],
                                   x, positions, impl=impl, train=train,
                                   remat=cfg.remat and train)
        if self.n_moe:
            x, _, aux = _scan_blocks(self.moe_block, params["blocks_moe"],
                                     x, positions, impl=impl, train=train,
                                     remat=cfg.remat and train)
        x = rms_norm(x, params["final_norm"])
        logits = self._head(params, x)

        if cfg.mtp and train:
            # multi-token prediction: one extra block over shifted stream
            h = x
            emb_next = jnp.roll(self._embed(params, batch)[0], -1, axis=1)
            h = jnp.concatenate([h, emb_next], axis=-1) @ params["mtp_proj"]
            h, _, _ = _scan_blocks(self.dense_block, params["mtp_block"], h,
                                   positions, impl=impl, train=train)
            aux["mtp_logits"] = self._head(params, rms_norm(
                h, params["final_norm"]))
        return logits, aux

    # -- serving ------------------------------------------------------------------
    def init_caches(self, batch_size: int, capacity: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        if cfg.window is not None:
            capacity = min(capacity, cfg.window)
        def one(_):
            if cfg.use_mla:
                return init_mla_cache(batch_size, capacity,
                                      self.block.attn.cfg, dtype)
            return init_cache(batch_size, cfg.n_kv, capacity,
                              cfg.resolved_head_dim, dtype)
        caches = {}
        if self.n_dense:
            caches["dense"] = jax.tree_util.tree_map(
                lambda x: jnp.stack([x] * self.n_dense),
                one(None))
        if self.n_moe:
            caches["moe"] = jax.tree_util.tree_map(
                lambda x: jnp.stack([x] * self.n_moe), one(None))
        return caches

    def decode_step(self, params, caches, batch, *, impl="ref"):
        """One-token decode. batch: tokens (B, 1) (+ positions (B,1))."""
        cfg = self.cfg
        x, positions = self._embed(params, batch)
        b, t, _ = x.shape
        if positions is None:
            pos0 = (caches.get("dense") or caches["moe"])["pos"][0]
            positions = (pos0[None, None] + jnp.arange(t)[None, :]
                         ).astype(jnp.int32)
            positions = jnp.broadcast_to(positions, (b, t))
        if cfg.mrope_sections is not None and positions.ndim == 2:
            positions = jnp.stack([positions] * 3)
        new_caches = {}
        if self.n_dense:
            x, nc, _ = _scan_blocks(self.dense_block, params["blocks_dense"],
                                    x, positions, caches=caches["dense"],
                                    impl=impl)
            new_caches["dense"] = nc
        if self.n_moe:
            x, nc, _ = _scan_blocks(self.moe_block, params["blocks_moe"], x,
                                    positions, caches=caches["moe"], impl=impl)
            new_caches["moe"] = nc
        x = rms_norm(x, params["final_norm"])
        return self._head(params, x), new_caches

    # -- partitioner view ------------------------------------------------------------
    def to_graph(self, seq: int) -> LayerGraph:
        cfg = self.cfg
        g = LayerGraph(name=cfg.arch_id)
        prev = g.add(GL.embed_layer("Embed_0", cfg.vocab * max(cfg.n_codebooks, 1),
                                    cfg.d_model, seq)).name
        for i in range(cfg.n_layers):
            kind = "moe" if (cfg.family == "moe" and i >= cfg.first_dense) else "dense"
            attn = GL.attention_layer(
                f"Attention_{i}", cfg.d_model, cfg.n_heads or 1,
                cfg.n_kv or 1, seq, cfg.resolved_head_dim,
                qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, window=cfg.window)
            prev = g.add(attn, after=[prev]).name
            if kind == "moe":
                ffn = GL.moe_layer(f"MoE_{i}", cfg.d_model, cfg.moe_d_ff, seq,
                                   cfg.n_experts, cfg.top_k, cfg.n_shared)
            else:
                ffn = GL.mlp_layer(f"Mlp_{i}", cfg.d_model, cfg.d_ff, seq)
            prev = g.add(ffn, after=[prev]).name
        g.add(GL.lm_head_layer("Head_0", cfg.d_model,
                               cfg.vocab * max(cfg.n_codebooks, 1), seq,
                               tied=cfg.tied_embeddings), after=[prev])
        return g
