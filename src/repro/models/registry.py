"""Architecture registry: config lookup + model factory."""

from __future__ import annotations

import importlib
from typing import List

from repro.configs.base import INPUT_SHAPES, ModelConfig, ShapeConfig

_CONFIG_MODULES = {
    "mamba2-370m": "repro.configs.mamba2_370m",
    "musicgen-large": "repro.configs.musicgen_large",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "smollm-360m": "repro.configs.smollm_360m",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "stablelm-12b": "repro.configs.stablelm_12b",
}

ARCH_IDS: List[str] = list(_CONFIG_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _CONFIG_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    return importlib.import_module(_CONFIG_MODULES[arch_id]).CONFIG


def build_model(cfg: ModelConfig):
    if cfg.family in ("ssm", "hybrid"):
        from repro.models.ssm_lm import SSMLM
        return SSMLM(cfg)
    from repro.models.decoder import DecoderLM
    return DecoderLM(cfg)


def count_params_from_config(cfg: ModelConfig) -> int:
    model = build_model(cfg)
    return model.to_graph(seq=8).total_params


def shape_config(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic decode: SSM/hybrid state or a sliding
    window (DESIGN.md §4)."""
    if shape.name != "long_500k":
        return True
    return cfg.family in ("ssm", "hybrid") or cfg.window is not None
