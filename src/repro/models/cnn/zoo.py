"""The paper's six CNN workloads (§V-A): VGG-16, ResNet-50, SqueezeNet V1.1,
GoogLeNet, RegNetX-400MF, EfficientNet-B0.

Each model is a runnable JAX Module *and* exports the partitioner's
LayerGraph via ``to_graph()``.  ``reduced()`` variants (narrow, low-res) are
used for CPU training / measured-accuracy exploration; the full-size graphs
drive the cost models exactly as the paper's ONNX graphs do.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax

from repro.core.graph import LayerGraph
from repro.models.cnn.blocks import (Bottleneck, ConvBNAct, Fire, GraphBuilder,
                                     Inception, MBConv, XBlock)
from repro.nn.layers import Dense, avg_pool, global_avg_pool, max_pool
from repro.nn.module import Module


class PoolBlock(Module):
    def __init__(self, k, stride=None, padding=0, kind="max"):
        self.k, self.s, self.p, self.kind = k, stride or k, padding, kind

    def init(self, key):
        return {}, {}

    def apply(self, params, state, x, **kw):
        fn = max_pool if self.kind == "max" else avg_pool
        return fn(x, self.k, self.s, self.p), {}

    def emit(self, gb, cin, hw, after):
        name, hw2 = gb.pool(cin, hw, self.k, self.s, self.p, after)
        return name, hw2, cin


class Classifier(Module):
    """GlobalAvgPool -> flatten -> (fc relu)* -> fc logits."""

    def __init__(self, cin, hidden: Sequence[int], n_classes: int,
                 global_pool: bool = True, in_hw: Optional[int] = None):
        self.cin, self.hidden, self.n = cin, list(hidden), n_classes
        self.gp = global_pool
        self.in_hw = in_hw
        dims = ([cin] if global_pool else [cin * in_hw * in_hw]) + self.hidden
        self.fcs = [Dense(dims[i], dims[i + 1]) for i in range(len(self.hidden))]
        self.head = Dense(dims[-1], n_classes)

    def init(self, key):
        ks = jax.random.split(key, len(self.fcs) + 1)
        p = {f"fc{i}": fc.init(ks[i])[0] for i, fc in enumerate(self.fcs)}
        p["head"] = self.head.init(ks[-1])[0]
        return p, {}

    def apply(self, params, state, x, **kw):
        if self.gp:
            x = global_avg_pool(x)
        else:
            x = x.reshape(x.shape[0], -1)
        for i, fc in enumerate(self.fcs):
            x, _ = fc.apply(params[f"fc{i}"], {}, x)
            x = jax.nn.relu(x)
        x, _ = self.head.apply(params["head"], {}, x)
        return x, {}

    def emit(self, gb, cin, hw, after):
        if self.gp:
            name, hw = gb.pool(cin, hw, 0, after=after, global_pool=True)
            name, d = gb.flatten((cin, 1, 1), name)
        else:
            name, d = gb.flatten((cin, *hw), after)
        for fc in self.fcs:
            name = gb.gemm(d, fc.d_out, name)
            name = gb.relu(fc.d_out, (1, 1), name)
            d = fc.d_out
        name = gb.gemm(d, self.n, name)
        return name, (1, 1), self.n


class CNNModel(Module):
    """Sequence of emit-capable blocks."""

    def __init__(self, name: str, blocks: List[Tuple[str, Module]],
                 in_hw: int, in_ch: int = 3):
        self.name = name
        self.blocks = blocks
        self.in_hw, self.in_ch = in_hw, in_ch

    def init(self, key):
        ks = jax.random.split(key, len(self.blocks))
        p, s = {}, {}
        for (n, b), k in zip(self.blocks, ks):
            bp, bs = b.init(k)
            if bp:
                p[n] = bp
            if bs:
                s[n] = bs
        return p, s

    def apply(self, params, state, x, train=False, **kw):
        ns = {}
        for n, b in self.blocks:
            x, s2 = b.apply(params.get(n, {}), state.get(n, {}), x,
                            train=train)
            if s2:
                ns[n] = s2
        return x, ns

    def to_graph(self) -> LayerGraph:
        gb = GraphBuilder(self.name)
        name, hw, c = None, (self.in_hw, self.in_hw), self.in_ch
        self.graph_boundaries = []   # (block_idx, last node name) per block
        for bi, (_, b) in enumerate(self.blocks):
            name, hw, c = b.emit(gb, c, hw, name)
            self.graph_boundaries.append((bi, name))
        return gb.g

    def cut_to_block(self, schedule, cut_pos: int) -> int:
        """Map a graph cut position (index into ``schedule``) to the largest
        block index fully contained in the prefix — for executing a chosen
        partition with :class:`PartitionedCNNRunner`."""
        assert getattr(self, "graph_boundaries", None), "call to_graph() first"
        prefix = {l.name for l in schedule[: cut_pos + 1]}
        blk = -1
        for bi, node in self.graph_boundaries:
            if node in prefix:
                blk = bi
            else:
                break
        return blk


# ---------------------------------------------------------------------------
# the six models
# ---------------------------------------------------------------------------

def vgg16(n_classes=1000, in_hw=224, w=1.0, fc_dim=4096) -> CNNModel:
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    blocks: List[Tuple[str, Module]] = []
    cin, i = 3, 0
    for v in cfg:
        if v == "M":
            blocks.append((f"pool{i}", PoolBlock(2)))
        else:
            c = max(int(v * w), 8)
            blocks.append((f"conv{i}", ConvBNAct(cin, c, 3, bn=False)))
            cin = c
        i += 1
    out_hw = in_hw // 32
    blocks.append(("cls", Classifier(cin, [fc_dim, fc_dim], n_classes,
                                     global_pool=False, in_hw=out_hw)))
    return CNNModel("vgg16", blocks, in_hw)


def resnet50(n_classes=1000, in_hw=224, w=1.0,
             depths=(3, 4, 6, 3)) -> CNNModel:
    planes = [max(int(p * w), 8) for p in (64, 128, 256, 512)]
    blocks: List[Tuple[str, Module]] = [
        ("stem", ConvBNAct(3, planes[0], 7, 2, 3)),
        ("pool0", PoolBlock(3, 2, 1)),
    ]
    cin = planes[0]
    for s, (pl, n) in enumerate(zip(planes, depths)):
        for b in range(n):
            stride = 2 if (s > 0 and b == 0) else 1
            blk = Bottleneck(cin, pl, stride)
            blocks.append((f"s{s}b{b}", blk))
            cin = blk.cout
    blocks.append(("cls", Classifier(cin, [], n_classes)))
    return CNNModel("resnet50", blocks, in_hw)


def squeezenet11(n_classes=1000, in_hw=224, w=1.0) -> CNNModel:
    def c(v):
        return max(int(v * w), 8)
    blocks: List[Tuple[str, Module]] = [
        ("stem", ConvBNAct(3, c(64), 3, 2, 0, bn=False)),
        ("pool0", PoolBlock(3, 2)),
        ("fire1", Fire(c(64), c(16), c(64), c(64))),
        ("fire2", Fire(2 * c(64), c(16), c(64), c(64))),
        ("pool1", PoolBlock(3, 2)),
        ("fire3", Fire(2 * c(64), c(32), c(128), c(128))),
        ("fire4", Fire(2 * c(128), c(32), c(128), c(128))),
        ("pool2", PoolBlock(3, 2)),
        ("fire5", Fire(2 * c(128), c(48), c(192), c(192))),
        ("fire6", Fire(2 * c(192), c(48), c(192), c(192))),
        ("fire7", Fire(2 * c(192), c(64), c(256), c(256))),
        ("fire8", Fire(2 * c(256), c(64), c(256), c(256))),
        ("conv_f", ConvBNAct(2 * c(256), n_classes, 1, bn=False)),
        ("cls", Classifier(n_classes, [], n_classes, global_pool=True)),
    ]
    # final classifier: squeezenet uses conv then global pool; emulate with
    # identity fc head after pooling
    m = CNNModel("squeezenet11", blocks[:-1], in_hw)
    m.blocks.append(("cls", _GPoolHead()))
    return m


class _GPoolHead(Module):
    """SqueezeNet head: global average pool of the class conv map."""

    def init(self, key):
        return {}, {}

    def apply(self, params, state, x, **kw):
        return global_avg_pool(x), {}

    def emit(self, gb, cin, hw, after):
        name, _ = gb.pool(cin, hw, 0, after=after, global_pool=True)
        name, d = gb.flatten((cin, 1, 1), name)
        return name, (1, 1), cin


def googlenet(n_classes=1000, in_hw=224, w=1.0) -> CNNModel:
    def c(v):
        return max(int(v * w), 8)
    incep = [
        # cin, c1, c3r, c3, c5r, c5, pp
        (192, 64, 96, 128, 16, 32, 32),
        (256, 128, 128, 192, 32, 96, 64),
        (480, 192, 96, 208, 16, 48, 64),
        (512, 160, 112, 224, 24, 64, 64),
        (512, 128, 128, 256, 24, 64, 64),
        (512, 112, 144, 288, 32, 64, 64),
        (528, 256, 160, 320, 32, 128, 128),
        (832, 256, 160, 320, 32, 128, 128),
        (832, 384, 192, 384, 48, 128, 128),
    ]
    blocks: List[Tuple[str, Module]] = [
        ("stem1", ConvBNAct(3, c(64), 7, 2, 3)),
        ("pool0", PoolBlock(3, 2, 1)),
        ("stem2", ConvBNAct(c(64), c(64), 1)),
        ("stem3", ConvBNAct(c(64), c(192), 3)),
        ("pool1", PoolBlock(3, 2, 1)),
    ]
    cin = c(192)
    for i, (ci, c1, c3r, c3, c5r, c5, pp) in enumerate(incep):
        blk = Inception(cin, c(c1), c(c3r), c(c3), c(c5r), c(c5), c(pp))
        blocks.append((f"incep{i}", blk))
        cin = blk.cout
        if i == 1:
            blocks.append(("pool2", PoolBlock(3, 2, 1)))
        if i == 6:
            blocks.append(("pool3", PoolBlock(3, 2, 1)))
    blocks.append(("cls", Classifier(cin, [], n_classes)))
    return CNNModel("googlenet", blocks, in_hw)


def regnetx_400mf(n_classes=1000, in_hw=224, w=1.0) -> CNNModel:
    widths = [max(int(v * w), 8) for v in (32, 64, 160, 384)]
    depths = (1, 2, 7, 12)
    gw = max(int(16 * w), 4)
    blocks: List[Tuple[str, Module]] = [("stem", ConvBNAct(3, widths[0] if w != 1.0 else 32, 3, 2))]
    cin = widths[0] if w != 1.0 else 32
    for s, (cw, n) in enumerate(zip(widths, depths)):
        for b in range(n):
            stride = 2 if b == 0 else 1
            blk = XBlock(cin, cw, stride, gw)
            blocks.append((f"s{s}b{b}", blk))
            cin = cw
    blocks.append(("cls", Classifier(cin, [], n_classes)))
    return CNNModel("regnetx_400mf", blocks, in_hw)


def efficientnet_b0(n_classes=1000, in_hw=224, w=1.0) -> CNNModel:
    # (expand, cout, repeats, kernel, stride)
    stages = [(1, 16, 1, 3, 1), (6, 24, 2, 3, 2), (6, 40, 2, 5, 2),
              (6, 80, 3, 3, 2), (6, 112, 3, 5, 1), (6, 192, 4, 5, 2),
              (6, 320, 1, 3, 1)]
    def c(v):
        return max(int(v * w), 8)
    blocks: List[Tuple[str, Module]] = [("stem", ConvBNAct(3, c(32), 3, 2,
                                                           act="silu"))]
    cin = c(32)
    for s, (e, co, r, k, st) in enumerate(stages):
        for b in range(r):
            blk = MBConv(cin, c(co), k, st if b == 0 else 1, e)
            blocks.append((f"s{s}b{b}", blk))
            cin = c(co)
    blocks.append(("head", ConvBNAct(cin, c(1280), 1, act="silu")))
    blocks.append(("cls", Classifier(c(1280), [], n_classes)))
    return CNNModel("efficientnet_b0", blocks, in_hw)


CNN_ZOO = {
    "vgg16": vgg16,
    "resnet50": resnet50,
    "squeezenet11": squeezenet11,
    "googlenet": googlenet,
    "regnetx_400mf": regnetx_400mf,
    "efficientnet_b0": efficientnet_b0,
}


def build_cnn(name: str, **kw) -> CNNModel:
    return CNN_ZOO[name](**kw)


def reduced_cnn(name: str, n_classes: int = 10, in_hw: int = 32) -> CNNModel:
    """Small trainable variants for CPU experiments (DESIGN.md §3)."""
    kw = {"n_classes": n_classes, "in_hw": in_hw, "w": 0.25}
    if name == "vgg16":
        return vgg16(n_classes, in_hw, w=0.125, fc_dim=128)
    if name == "resnet50":
        return resnet50(n_classes, in_hw, w=0.25, depths=(1, 1, 1, 1))
    return CNN_ZOO[name](**kw)
