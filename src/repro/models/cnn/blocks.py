"""CNN building blocks with dual personality:

* each block is a :class:`Module` (runnable JAX, trainable), and
* each block can ``emit`` its op-level nodes into a :class:`LayerGraph`
  for the partitioner, with ONNX-style names (``Conv_7``, ``Relu_3``, ...)
  matching the paper's naming of partition points.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import layers as GL
from repro.core.graph import LayerGraph
from repro.nn.layers import BatchNorm2d, Conv2d, SqueezeExcite, max_pool
from repro.nn.module import Module


class GraphBuilder:
    """Accumulates LayerInfo nodes with ONNX-export-style running names."""

    def __init__(self, name: str):
        self.g = LayerGraph(name=name)
        self._counts = {}

    def _name(self, kind: str) -> str:
        i = self._counts.get(kind, 0)
        self._counts[kind] = i + 1
        return f"{kind}_{i}"

    def add(self, info: GL.LayerInfo, after) -> str:
        if isinstance(after, str):
            after = [after]
        self.g.add(info, after=after or None)
        return info.name

    def conv(self, cin, cout, hw, k, stride=1, padding=None, groups=1,
             bias=True, after=None) -> Tuple[str, Tuple[int, int], int]:
        info = GL.conv_layer(self._name("Conv"), cin, cout, hw, k, stride,
                             padding, groups, bias)
        name = self.add(info, after)
        return name, info.out_shape[1:], cout

    def bn(self, c, hw, after) -> str:
        return self.add(GL.bn_layer(self._name("BatchNormalization"),
                                    (c, *hw)), after)

    def relu(self, c, hw, after, kind="Relu") -> str:
        return self.add(GL.elementwise_layer(self._name(kind), GL.RELU,
                                             (c, *hw)), after)

    def add_op(self, c, hw, after: Sequence[str]) -> str:
        return self.add(GL.elementwise_layer(self._name("Add"), GL.ADD,
                                             (c, *hw)), list(after))

    def mul_op(self, c, hw, after: Sequence[str]) -> str:
        return self.add(GL.elementwise_layer(self._name("Mul"), GL.MUL,
                                             (c, *hw)), list(after))

    def pool(self, c, hw, k, stride=None, padding=0, after=None,
             global_pool=False) -> Tuple[str, Tuple[int, int]]:
        kind = "GlobalAveragePool" if global_pool else "MaxPool"
        info = GL.pool_layer(self._name(kind), c, hw, k, stride, padding,
                             global_pool)
        return self.add(info, after), info.out_shape[1:]

    def concat(self, shapes, after: Sequence[str]) -> Tuple[str, int]:
        info = GL.concat_layer(self._name("Concat"), shapes, axis=0)
        return self.add(info, list(after)), info.out_shape[0]

    def flatten(self, shape, after) -> Tuple[str, int]:
        info = GL.flatten_layer(self._name("Flatten"), shape)
        return self.add(info, after), info.out_shape[0]

    def gemm(self, cin, cout, after, bias=True) -> str:
        return self.add(GL.gemm_layer(self._name("Gemm"), cin, cout, bias),
                        after)


# ---------------------------------------------------------------------------
# composite blocks
# ---------------------------------------------------------------------------

class ConvBNAct(Module):
    def __init__(self, cin, cout, k, stride=1, padding=None, groups=1,
                 act: str = "relu", bn: bool = True):
        self.conv = Conv2d(cin, cout, k, stride, padding, groups,
                           bias=not bn)
        self.bn = BatchNorm2d(cout) if bn else None
        self.act = act
        self.cfg = (cin, cout, k, stride, padding, groups)

    def init(self, key):
        k1, k2 = jax.random.split(key)
        p, s = {"conv": self.conv.init(k1)[0]}, {}
        if self.bn:
            bp, bs = self.bn.init(k2)
            p["bn"], s["bn"] = bp, bs
        return p, s

    def apply(self, params, state, x, train=False, **kw):
        x, _ = self.conv.apply(params["conv"], {}, x)
        ns = {}
        if self.bn:
            x, ns["bn"] = self.bn.apply(params["bn"], state["bn"], x, train=train)
        if self.act == "relu":
            x = jax.nn.relu(x)
        elif self.act == "silu":
            x = jax.nn.silu(x)
        return x, ns

    def emit(self, gb: GraphBuilder, cin, hw, after):
        _, cout, k, stride, padding, groups = self.cfg
        name, hw, c = gb.conv(cin, cout, hw, k, stride, padding, groups,
                              bias=self.bn is None, after=after)
        if self.bn:
            name = gb.bn(c, hw, name)
        if self.act != "none":
            name = gb.relu(c, hw, name)
        return name, hw, c


class Bottleneck(Module):
    """ResNet-50 bottleneck (1x1 -> 3x3 -> 1x1 + skip)."""

    expansion = 4

    def __init__(self, cin, planes, stride=1):
        cout = planes * self.expansion
        self.b1 = ConvBNAct(cin, planes, 1)
        self.b2 = ConvBNAct(planes, planes, 3, stride)
        self.b3 = ConvBNAct(planes, cout, 1, act="none")
        self.down = (ConvBNAct(cin, cout, 1, stride, act="none")
                     if (stride != 1 or cin != cout) else None)
        self.cout = cout

    def init(self, key):
        ks = jax.random.split(key, 4)
        p, s = {}, {}
        for name, mod, k in [("b1", self.b1, ks[0]), ("b2", self.b2, ks[1]),
                             ("b3", self.b3, ks[2])] + (
                                 [("down", self.down, ks[3])] if self.down else []):
            p[name], s[name] = mod.init(k)
        return p, s

    def apply(self, params, state, x, train=False, **kw):
        ns = {}
        idn = x
        y, ns["b1"] = self.b1.apply(params["b1"], state["b1"], x, train=train)
        y, ns["b2"] = self.b2.apply(params["b2"], state["b2"], y, train=train)
        y, ns["b3"] = self.b3.apply(params["b3"], state["b3"], y, train=train)
        if self.down:
            idn, ns["down"] = self.down.apply(params["down"], state["down"],
                                              x, train=train)
        return jax.nn.relu(y + idn), ns

    def emit(self, gb, cin, hw, after):
        n1, hw1, c1 = self.b1.emit(gb, cin, hw, after)
        n2, hw2, c2 = self.b2.emit(gb, c1, hw1, n1)
        n3, hw3, c3 = self.b3.emit(gb, c2, hw2, n2)
        skip = after
        if self.down:
            skip, _, _ = self.down.emit(gb, cin, hw, after)
        add = gb.add_op(c3, hw3, [n3] + ([skip] if skip else []))
        out = gb.relu(c3, hw3, add)
        return out, hw3, c3


class Fire(Module):
    """SqueezeNet fire module."""

    def __init__(self, cin, squeeze, e1, e3):
        self.sq = ConvBNAct(cin, squeeze, 1, bn=False)
        self.e1 = ConvBNAct(squeeze, e1, 1, bn=False)
        self.e3 = ConvBNAct(squeeze, e3, 3, bn=False)
        self.cout = e1 + e3

    def init(self, key):
        ks = jax.random.split(key, 3)
        return ({"sq": self.sq.init(ks[0])[0], "e1": self.e1.init(ks[1])[0],
                 "e3": self.e3.init(ks[2])[0]}, {})

    def apply(self, params, state, x, train=False, **kw):
        s, _ = self.sq.apply(params["sq"], {}, x, train=train)
        a, _ = self.e1.apply(params["e1"], {}, s, train=train)
        b, _ = self.e3.apply(params["e3"], {}, s, train=train)
        return jnp.concatenate([a, b], axis=1), {}

    def emit(self, gb, cin, hw, after):
        ns, hws, cs = self.sq.emit(gb, cin, hw, after)
        n1, hw1, c1 = self.e1.emit(gb, cs, hws, ns)
        n3, hw3, c3 = self.e3.emit(gb, cs, hws, ns)
        name, cout = gb.concat([(c1, *hw1), (c3, *hw3)], [n1, n3])
        return name, hw1, cout


class Inception(Module):
    """GoogLeNet inception module (v1)."""

    def __init__(self, cin, c1, c3r, c3, c5r, c5, pp):
        self.b1 = ConvBNAct(cin, c1, 1)
        self.b3a = ConvBNAct(cin, c3r, 1)
        self.b3b = ConvBNAct(c3r, c3, 3)
        self.b5a = ConvBNAct(cin, c5r, 1)
        self.b5b = ConvBNAct(c5r, c5, 3)   # torchvision uses 3x3 here
        self.bp = ConvBNAct(cin, pp, 1)
        self.cout = c1 + c3 + c5 + pp

    def init(self, key):
        ks = jax.random.split(key, 6)
        mods = [("b1", self.b1), ("b3a", self.b3a), ("b3b", self.b3b),
                ("b5a", self.b5a), ("b5b", self.b5b), ("bp", self.bp)]
        p, s = {}, {}
        for (n, m), k in zip(mods, ks):
            p[n], s[n] = m.init(k)
        return p, s

    def apply(self, params, state, x, train=False, **kw):
        ns = {}
        y1, ns["b1"] = self.b1.apply(params["b1"], state["b1"], x, train=train)
        y3, ns["b3a"] = self.b3a.apply(params["b3a"], state["b3a"], x, train=train)
        y3, ns["b3b"] = self.b3b.apply(params["b3b"], state["b3b"], y3, train=train)
        y5, ns["b5a"] = self.b5a.apply(params["b5a"], state["b5a"], x, train=train)
        y5, ns["b5b"] = self.b5b.apply(params["b5b"], state["b5b"], y5, train=train)
        yp = max_pool(x, 3, 1, 1)
        yp, ns["bp"] = self.bp.apply(params["bp"], state["bp"], yp, train=train)
        return jnp.concatenate([y1, y3, y5, yp], axis=1), ns

    def emit(self, gb, cin, hw, after):
        n1, hw1, c1 = self.b1.emit(gb, cin, hw, after)
        n3, hw3, c3 = self.b3a.emit(gb, cin, hw, after)
        n3, hw3, c3 = self.b3b.emit(gb, c3, hw3, n3)
        n5, hw5, c5 = self.b5a.emit(gb, cin, hw, after)
        n5, hw5, c5 = self.b5b.emit(gb, c5, hw5, n5)
        np_, hwp = gb.pool(cin, hw, 3, 1, 1, after)
        np_, hwp, cp = self.bp.emit(gb, cin, hwp, np_)
        name, cout = gb.concat([(c1, *hw1), (c3, *hw3), (c5, *hw5),
                                (cp, *hwp)], [n1, n3, n5, np_])
        return name, hw1, cout


class MBConv(Module):
    """EfficientNet MBConv with SE and silu."""

    def __init__(self, cin, cout, k, stride, expand, se_ratio=0.25):
        mid = cin * expand
        self.exp = ConvBNAct(cin, mid, 1, act="silu") if expand != 1 else None
        self.dw = ConvBNAct(mid, mid, k, stride, groups=mid, act="silu")
        self.se = SqueezeExcite(mid, max(1, int(cin * se_ratio)))
        self.proj = ConvBNAct(mid, cout, 1, act="none")
        self.skip = stride == 1 and cin == cout
        self.cout = cout
        self.mid = mid

    def init(self, key):
        ks = jax.random.split(key, 4)
        p, s = {}, {}
        if self.exp:
            p["exp"], s["exp"] = self.exp.init(ks[0])
        p["dw"], s["dw"] = self.dw.init(ks[1])
        p["se"], _ = self.se.init(ks[2])
        p["proj"], s["proj"] = self.proj.init(ks[3])
        return p, s

    def apply(self, params, state, x, train=False, **kw):
        ns = {}
        y = x
        if self.exp:
            y, ns["exp"] = self.exp.apply(params["exp"], state["exp"], y, train=train)
        y, ns["dw"] = self.dw.apply(params["dw"], state["dw"], y, train=train)
        y, _ = self.se.apply(params["se"], {}, y)
        y, ns["proj"] = self.proj.apply(params["proj"], state["proj"], y, train=train)
        if self.skip:
            y = y + x
        return y, ns

    def emit(self, gb, cin, hw, after):
        name, h, c = after, hw, cin
        if self.exp:
            name, h, c = self.exp.emit(gb, c, h, name)
        name, h, c = self.dw.emit(gb, c, h, name)
        # SE: gp -> fc -> fc -> mul
        gp, _ = gb.pool(c, h, 0, after=name, global_pool=True)
        f1 = gb.gemm(c, max(1, int(cin * 0.25)), gp)
        f2 = gb.gemm(max(1, int(cin * 0.25)), c, f1)
        name = gb.mul_op(c, h, [name, f2])
        name, h, c = self.proj.emit(gb, c, h, name)
        if self.skip:
            name = gb.add_op(c, h, [name, after])
        return name, h, c


class XBlock(Module):
    """RegNetX block: 1x1 -> 3x3 group conv -> 1x1 + skip."""

    def __init__(self, cin, cout, stride, group_width):
        groups = max(cout // group_width, 1)
        self.a = ConvBNAct(cin, cout, 1)
        self.b = ConvBNAct(cout, cout, 3, stride, groups=groups)
        self.c = ConvBNAct(cout, cout, 1, act="none")
        self.down = (ConvBNAct(cin, cout, 1, stride, act="none")
                     if (stride != 1 or cin != cout) else None)
        self.cout = cout

    def init(self, key):
        ks = jax.random.split(key, 4)
        p, s = {}, {}
        mods = [("a", self.a), ("b", self.b), ("c", self.c)] + (
            [("down", self.down)] if self.down else [])
        for (n, m), k in zip(mods, ks):
            p[n], s[n] = m.init(k)
        return p, s

    def apply(self, params, state, x, train=False, **kw):
        ns = {}
        y, ns["a"] = self.a.apply(params["a"], state["a"], x, train=train)
        y, ns["b"] = self.b.apply(params["b"], state["b"], y, train=train)
        y, ns["c"] = self.c.apply(params["c"], state["c"], y, train=train)
        idn = x
        if self.down:
            idn, ns["down"] = self.down.apply(params["down"], state["down"],
                                              x, train=train)
        return jax.nn.relu(y + idn), ns

    def emit(self, gb, cin, hw, after):
        n, h, c = self.a.emit(gb, cin, hw, after)
        n, h, c = self.b.emit(gb, c, h, n)
        n, h, c = self.c.emit(gb, c, h, n)
        skip = after
        if self.down:
            skip, _, _ = self.down.emit(gb, cin, hw, after)
        add = gb.add_op(c, h, [n] + ([skip] if skip else []))
        out = gb.relu(c, h, add)
        return out, h, c
