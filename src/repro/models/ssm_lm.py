"""SSM and hybrid decoder LMs: Mamba2 (SSD) and Zamba2-style hybrid.

Mamba2LM: embed → scan(48 × [norm → Mamba2Mixer] ) → norm → tied head.

HybridLM (Zamba2): Mamba2 backbone; after every ``attn_every`` mamba blocks
one SHARED attention+MLP block runs (identical parameters at every
application — the Zamba2 trick).  Executed as a scan over groups whose body
is (scan over ``attn_every`` mamba blocks) + shared block; shared params are
closed over, not scanned, so they appear once in the pytree.  The memory
model sees them via ``shared_groups`` (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import layers as GL
from repro.core.graph import LayerGraph
from repro.nn.attention import GQAAttention, init_cache
from repro.nn.layers import rms_norm
from repro.nn.module import Module, normal_init
from repro.nn.sharding import shard
from repro.nn.ssm import Mamba2Mixer, init_ssm_cache
from repro.models.decoder import _dtype, _stack_init, gated_mlp, gated_mlp_init


class MambaBlock(Module):
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dt = _dtype(cfg)
        self.mixer = Mamba2Mixer(cfg.d_model, cfg.ssm_state, cfg.ssm_expand,
                                 cfg.ssm_headdim, chunk=cfg.ssm_chunk,
                                 dtype=self.dt)

    def init(self, key):
        return {"ln": jnp.ones((self.cfg.d_model,), self.dt),
                "mixer": self.mixer.init(key)[0]}, {}

    def apply(self, params, state, x, *, cache=None, impl="ref", **kw):
        h = rms_norm(x, params["ln"])
        if cache is not None:
            y, new_cache = self.mixer.apply(params["mixer"], {}, h,
                                            cache=cache, impl=impl)
        else:
            y, _ = self.mixer.apply(params["mixer"], {}, h, impl=impl)
            new_cache = None
        x = x + y.astype(x.dtype)
        return shard(x, ("batch", "seq", "act_embed")), new_cache


class SharedAttnBlock(Module):
    """Zamba2 shared transformer block (attention + MLP)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dt = _dtype(cfg)
        self.attn = GQAAttention(cfg.d_model, cfg.n_heads, cfg.n_kv,
                                 cfg.resolved_head_dim, dtype=self.dt)

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"ln1": jnp.ones((self.cfg.d_model,), self.dt),
                "ln2": jnp.ones((self.cfg.d_model,), self.dt),
                "attn": self.attn.init(k1)[0],
                "mlp": gated_mlp_init(k2, self.cfg.d_model, self.cfg.d_ff,
                                      self.dt)}, {}

    def apply(self, params, state, x, *, positions=None, cache=None,
              impl="ref", **kw):
        h = rms_norm(x, params["ln1"])
        if cache is not None:
            a, new_cache = self.attn.apply(params["attn"], {}, h,
                                           positions=positions, cache=cache,
                                           impl=impl)
        else:
            a, _ = self.attn.apply(params["attn"], {}, h,
                                   positions=positions, impl=impl)
            new_cache = None
        x = x + a
        x = x + gated_mlp(params["mlp"], rms_norm(x, params["ln2"]))
        return shard(x, ("batch", "seq", "act_embed")), new_cache


class SSMLM(Module):
    """Mamba2 (family='ssm') or Zamba2 hybrid (family='hybrid')."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dt = _dtype(cfg)
        self.mblock = MambaBlock(cfg)
        self.hybrid = cfg.family == "hybrid"
        if self.hybrid:
            assert cfg.n_layers % cfg.attn_every == 0
            self.n_groups = cfg.n_layers // cfg.attn_every
            self.shared = SharedAttnBlock(cfg)

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        p: Dict[str, Any] = {
            "embed": normal_init(ks[0], (cfg.vocab, cfg.d_model), 0.02, self.dt),
            "final_norm": jnp.ones((cfg.d_model,), self.dt),
        }
        if self.hybrid:
            stacked = _stack_init(self.mblock, ks[1], cfg.n_layers)
            # reshape leading axis to (groups, attn_every)
            p["blocks"] = jax.tree_util.tree_map(
                lambda x: x.reshape(self.n_groups, cfg.attn_every,
                                    *x.shape[1:]), stacked)
            p["shared"] = self.shared.init(ks[2])[0]
        else:
            p["blocks"] = _stack_init(self.mblock, ks[1], cfg.n_layers)
        if not cfg.tied_embeddings:
            p["head"] = normal_init(ks[3], (cfg.d_model, cfg.vocab),
                                    cfg.d_model ** -0.5, self.dt)
        return p, {}

    def _head(self, params, x):
        w = params["embed"].T if self.cfg.tied_embeddings else params["head"]
        return x @ shard(w, ("embed", "vocab"))

    def _run(self, params, x, positions, caches=None, impl="ref",
             train=False):
        cfg = self.cfg
        remat = cfg.remat and train

        def mamba_body(carry, layer_in):
            p, c = layer_in
            h, new_c = self.mblock.apply(p, {}, carry, cache=c, impl=impl)
            return h, new_c
        if remat:
            mamba_body = jax.checkpoint(mamba_body, prevent_cse=False)

        if not self.hybrid:
            x, new_caches = jax.lax.scan(
                mamba_body, x, (params["blocks"],
                                None if caches is None else caches["mamba"]))
            return x, (None if caches is None else {"mamba": new_caches})

        shared_p = params["shared"]

        def group_body(carry, group_in):
            gp, gc = group_in
            h, new_mc = jax.lax.scan(
                mamba_body, carry,
                (gp, None if gc is None else gc["mamba"]))
            h, new_ac = self.shared.apply(shared_p, {}, h,
                                          positions=positions,
                                          cache=None if gc is None
                                          else gc["attn"], impl=impl)
            if gc is None:
                return h, None
            return h, {"mamba": new_mc, "attn": new_ac}
        if remat:
            group_body = jax.checkpoint(group_body, prevent_cse=False)

        x, new_caches = jax.lax.scan(group_body, x,
                                     (params["blocks"], caches))
        return x, new_caches

    def apply(self, params, state, batch, *, train=False, impl="ref", **kw):
        x = jnp.take(shard(params["embed"], ("vocab", "embed")),
                     batch["tokens"], axis=0)
        x = shard(x, ("batch", "seq", "act_embed"))
        b, t, _ = x.shape
        positions = batch.get(
            "positions", jnp.broadcast_to(jnp.arange(t)[None], (b, t)))
        x, _ = self._run(params, x, positions, impl=impl, train=train)
        x = rms_norm(x, params["final_norm"])
        return self._head(params, x), {}

    # -- serving ---------------------------------------------------------------
    def init_caches(self, batch_size: int, capacity: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        ssm_one = init_ssm_cache(batch_size, self.mblock.mixer, jnp.float32)
        if not self.hybrid:
            return {"mamba": jax.tree_util.tree_map(
                lambda x: jnp.stack([x] * cfg.n_layers), ssm_one)}
        attn_one = init_cache(batch_size, cfg.n_kv, capacity,
                              cfg.resolved_head_dim, dtype)
        group_ssm = jax.tree_util.tree_map(
            lambda x: jnp.stack([x] * cfg.attn_every), ssm_one)
        return jax.tree_util.tree_map(
            lambda x: jnp.stack([x] * self.n_groups),
            {"mamba": group_ssm, "attn": attn_one})

    def decode_step(self, params, caches, batch, *, impl="ref"):
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        b, t, _ = x.shape
        if self.hybrid:
            pos0 = caches["attn"]["pos"][0]
        else:
            pos0 = caches["mamba"]["pos"][0]
        positions = batch.get("positions")
        if positions is None:
            positions = (pos0[None, None] + jnp.arange(t)[None, :]
                         ).astype(jnp.int32)
            positions = jnp.broadcast_to(positions, (b, t))
        x, new_caches = self._run(params, x, positions, caches=caches,
                                  impl=impl)
        x = rms_norm(x, params["final_norm"])
        return self._head(params, x), new_caches

    # -- partitioner view --------------------------------------------------------
    def to_graph(self, seq: int) -> LayerGraph:
        cfg = self.cfg
        g = LayerGraph(name=cfg.arch_id)
        prev = g.add(GL.embed_layer("Embed_0", cfg.vocab, cfg.d_model,
                                    seq)).name
        for i in range(cfg.n_layers):
            ssm = GL.ssm_layer(f"SSM_{i}", cfg.d_model, cfg.ssm_state, seq,
                               cfg.ssm_expand, headdim=cfg.ssm_headdim)
            prev = g.add(ssm, after=[prev]).name
            if self.hybrid and (i + 1) % cfg.attn_every == 0:
                a = GL.attention_layer(f"SharedAttn_{i}", cfg.d_model,
                                       cfg.n_heads, cfg.n_kv, seq,
                                       cfg.resolved_head_dim)
                prev = g.add(a, after=[prev]).name
                m = GL.mlp_layer(f"SharedMlp_{i}", cfg.d_model, cfg.d_ff, seq)
                prev = g.add(m, after=[prev]).name
        g.add(GL.lm_head_layer("Head_0", cfg.d_model, cfg.vocab, seq,
                               tied=cfg.tied_embeddings), after=[prev])
        return g

    def shared_groups(self) -> Dict[str, str]:
        """Map shared-block layer names to one weight group (memory model)."""
        if not self.hybrid:
            return {}
        out = {}
        for i in range(self.cfg.n_layers):
            if (i + 1) % self.cfg.attn_every == 0:
                out[f"SharedAttn_{i}"] = "shared_attn"
                out[f"SharedMlp_{i}"] = "shared_mlp"
        return out
