from repro.serving.engine import GenerationEngine
from repro.serving.pipeline import (PartitionedCNNRunner, PartitionedLMRunner,
                                    pipeline_report)
