from repro.serving.engine import (GenerationEngine, GenResult, SlotDecoder,
                                  valid_token_count)
from repro.serving.pipeline import (PartitionedCNNRunner, PartitionedLMRunner,
                                    def4_throughput, link_transfer_bytes,
                                    pipeline_report)
