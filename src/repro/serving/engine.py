"""Batched generation engine: prefill + decode against KV/SSM caches.

Static-slot continuous batching lite: a wave of requests is prefillled
together (right-padded), then decoded in lockstep; finished sequences are
masked.  Greedy or temperature sampling.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class GenResult:
    tokens: np.ndarray          # (B, T_new)
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        n = self.tokens.size
        return n / self.decode_s if self.decode_s > 0 else float("inf")


class GenerationEngine:
    def __init__(self, model, params, max_seq: int = 512,
                 cache_dtype=jnp.float32, impl: str = "ref"):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.impl = impl
        self._prefill = jax.jit(
            lambda p, c, b: model.decode_step(p, c, b, impl=impl))
        self._decode = jax.jit(
            lambda p, c, b: model.decode_step(p, c, b, impl=impl))

    def generate(self, prompts: np.ndarray, max_new: int = 16,
                 eos: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0) -> GenResult:
        """prompts: (B, T_prompt) int32 (right-aligned, no padding support
        needed for synthetic workloads)."""
        import time
        b, tp = prompts.shape
        caches = self.model.init_caches(b, self.max_seq, self.cache_dtype)
        key = jax.random.PRNGKey(seed)

        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, caches,
                                       {"tokens": jnp.asarray(prompts)})
        jax.block_until_ready(logits)
        t1 = time.perf_counter()

        cur = logits[:, -1]
        out: List[np.ndarray] = []
        done = np.zeros(b, bool)
        for i in range(max_new):
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, cur / temperature, axis=-1)
            else:
                nxt = cur.argmax(-1)
            nxt = np.asarray(nxt).astype(np.int32)
            if eos is not None:
                done |= nxt == eos
            out.append(nxt)
            if eos is not None and done.all():
                break
            logits, caches = self._decode(self.params, caches,
                                          {"tokens": jnp.asarray(nxt)[:, None]})
            cur = logits[:, -1]
        jax.block_until_ready(cur)
        t2 = time.perf_counter()
        return GenResult(np.stack(out, axis=1), prefill_s=t1 - t0,
                         decode_s=t2 - t1)
