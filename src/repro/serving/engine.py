"""Batched generation engine: prefill + decode against KV/SSM caches.

Two execution modes:

* :class:`GenerationEngine` — static-slot continuous batching lite: a wave
  of requests is prefilled together (right-padded), then decoded in
  lockstep; finished sequences are masked.  Greedy or temperature sampling.
  This is the *serial reference* the ``repro.serve`` runtime is checked
  against (byte-identical greedy tokens).
* :class:`SlotDecoder` — the slot API under ``repro.serve`` continuous
  batching: every slot is an independent batch=1 cache lane with its own
  write position, decoded together via one vmapped+jitted step, so
  per-request admission/eviction never shares cache state across requests.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def valid_token_count(tokens: np.ndarray, eos: Optional[int]) -> int:
    """Pre-EOS token count over a (B, T) generation: per row, tokens
    strictly before the first ``eos`` (all T when the row never stopped).
    The throughput-accounting denominator — lockstep decoding keeps
    emitting (masked) tokens for finished rows and those must not count."""
    tokens = np.asarray(tokens)
    if eos is None or tokens.size == 0:
        return int(tokens.size)
    hit = tokens == eos
    first = np.where(hit.any(axis=1), hit.argmax(axis=1), tokens.shape[1])
    return int(first.sum())


@dataclasses.dataclass
class GenResult:
    tokens: np.ndarray          # (B, T_new)
    prefill_s: float = 0.0
    decode_s: float = 0.0
    n_valid: Optional[int] = None   # pre-EOS tokens (None: all count)

    @property
    def tokens_per_s(self) -> float:
        if self.decode_s <= 0:
            return 0.0
        n = self.tokens.size if self.n_valid is None else self.n_valid
        return n / self.decode_s


class GenerationEngine:
    def __init__(self, model, params, max_seq: int = 512,
                 cache_dtype=jnp.float32, impl: str = "ref"):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.impl = impl
        self._prefill = jax.jit(
            lambda p, c, b: model.decode_step(p, c, b, impl=impl))
        self._decode = jax.jit(
            lambda p, c, b: model.decode_step(p, c, b, impl=impl))

    def generate(self, prompts: np.ndarray, max_new: int = 16,
                 eos: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0) -> GenResult:
        """prompts: (B, T_prompt) int32 (right-aligned, no padding support
        needed for synthetic workloads)."""
        import time
        b, tp = prompts.shape
        caches = self.model.init_caches(b, self.max_seq, self.cache_dtype)
        key = jax.random.PRNGKey(seed)

        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, caches,
                                       {"tokens": jnp.asarray(prompts)})
        jax.block_until_ready(logits)
        t1 = time.perf_counter()

        cur = logits[:, -1]
        out: List[np.ndarray] = []
        done = np.zeros(b, bool)
        for i in range(max_new):
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, cur / temperature, axis=-1)
            else:
                nxt = cur.argmax(-1)
            nxt = np.asarray(nxt).astype(np.int32)
            if eos is not None:
                # already-done rows are masked to eos: they keep decoding in
                # lockstep but stop contributing (real) tokens
                nxt = np.where(done, eos, nxt).astype(np.int32)
                done |= nxt == eos
            out.append(nxt)
            if eos is not None and done.all():
                break
            logits, caches = self._decode(self.params, caches,
                                          {"tokens": jnp.asarray(nxt)[:, None]})
            cur = logits[:, -1]
        jax.block_until_ready(cur)
        t2 = time.perf_counter()
        tokens = np.stack(out, axis=1)
        return GenResult(tokens, prefill_s=t1 - t0, decode_s=t2 - t1,
                         n_valid=valid_token_count(tokens, eos))


def _bump_pos(cache):
    """Sentinel variant of a fresh cache: ``pos`` advanced past one zero
    key/value row so a never-admitted lane still has >= 1 visible cache
    entry — an all-masked attention row softmaxes to NaN otherwise."""
    if isinstance(cache, dict):
        return {k: (v + 1 if k == "pos" else _bump_pos(v))
                for k, v in cache.items()}
    return cache


class SlotDecoder:
    """Per-slot KV caches + one vmapped decode step (the engine slot API).

    Each of the ``n_slots`` lanes is a batch=1 cache pytree with its own
    write position; :meth:`decode` advances every lane in one jitted
    program (idle lanes compute garbage that is never sampled — the fixed
    cost of static-slot continuous batching), while :meth:`prefill`
    replaces a single lane's cache wholesale with a freshly prefilled one,
    so no token of an evicted request can leak into its successor.
    """

    def __init__(self, model, params, n_slots: int, max_seq: int,
                 cache_dtype=jnp.float32, impl: str = "ref"):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        idle = _bump_pos(model.init_caches(1, max_seq, cache_dtype))
        self.caches = jax.tree_util.tree_map(
            lambda x: jnp.stack([x] * n_slots), idle)
        self._idle = idle
        self._decode = jax.jit(jax.vmap(
            lambda p, c, t: model.decode_step(p, c, {"tokens": t}, impl=impl),
            in_axes=(None, 0, 0)))
        # compiles once per distinct prompt length (documented cost: the
        # synthetic traffic generators emit fixed-length prompts)
        self._prefill = jax.jit(
            lambda p, c, t: model.decode_step(p, c, {"tokens": t}, impl=impl))

    def prefill(self, slot: int, prompt: np.ndarray) -> np.ndarray:
        """Admit a prompt (T,) into ``slot``: fresh lane cache, full-prompt
        prefill, cache written back.  Returns the last-position logits."""
        fresh = self.model.init_caches(1, self.max_seq, self.cache_dtype)
        toks = jnp.asarray(prompt, jnp.int32)[None]
        logits, new = self._prefill(self.params, fresh, toks)
        self.caches = jax.tree_util.tree_map(
            lambda full, one: full.at[slot].set(one), self.caches, new)
        return np.asarray(logits[0, -1])

    def free(self, slot: int) -> None:
        """Reset a lane to the idle sentinel (eviction hygiene — admission
        via :meth:`prefill` overwrites the lane anyway)."""
        self.caches = jax.tree_util.tree_map(
            lambda full, one: full.at[slot].set(one), self.caches, self._idle)

    def decode(self, tokens: np.ndarray) -> np.ndarray:
        """One decode step for every lane. ``tokens``: (n_slots,) int32 —
        idle lanes get a dummy token whose logits the caller ignores.
        Returns (n_slots, vocab) logits."""
        toks = jnp.asarray(tokens, jnp.int32)[:, None, None]
        logits, self.caches = self._decode(self.params, self.caches, toks)
        return np.asarray(logits[:, 0, -1])
