"""Partitioned (multi-platform) inference execution — the paper's Definition 1
acted out: stage k runs its layer segment at its platform's precision, the
activation crossing each link is quantized to the producer's bit width.

Used for (a) the measured-accuracy oracle of the explorer, (b) integration
tests (partitioned ≡ monolithic when quantization is off), and (c) the
end-to-end serving example.  On one CPU device stages run sequentially; the
throughput model (Def. 4) comes from per-stage timings.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import QuantSpec, quantize_pytree, quantize_tensor


def def4_throughput(stage_latencies: Sequence[float],
                    link_latencies: Sequence[float] = ()) -> float:
    """Def. 4: steady-state pipeline throughput is set by the slowest
    module — ``1 / max(stage latencies, link latencies)``.  The single
    shared implementation behind :meth:`StageReport.throughput`,
    :func:`pipeline_report` and the ``repro.serve`` measured-vs-predicted
    gate (``benchmarks/serve_bench.py``)."""
    mods = [t for t in list(stage_latencies) + list(link_latencies) if t > 0]
    return 1.0 / max(mods) if mods else 0.0


@dataclasses.dataclass
class StageReport:
    latency_s: List[float]
    link_bytes: List[int]

    def throughput(self, link_latency_s: Optional[List[float]] = None) -> float:
        """Def. 4 with measured stage latencies."""
        return def4_throughput(self.latency_s, link_latency_s or ())


def pipeline_report(stage_latencies: Sequence[float],
                    link_latencies: Sequence[float]) -> Dict[str, float]:
    lat = sum(stage_latencies) + sum(link_latencies)
    return {"latency_s": lat,
            "throughput": def4_throughput(stage_latencies, link_latencies)}


def link_transfer_bytes(n_elems: int, spec: Optional[QuantSpec]) -> int:
    """Bytes shipped over a link for ``n_elems`` activations quantized to the
    producer's bit width (float32 when unquantized).  Sub-byte widths use
    fractional bytes-per-element — ``bits // 8`` would report 0 bytes for
    4-bit links."""
    if spec is None:
        return int(n_elems * 4)
    return int(math.ceil(n_elems * spec.bits / 8))


class PartitionedCNNRunner:
    """Split a CNNModel at block boundaries across platforms."""

    def __init__(self, model, params, state,
                 cuts: Sequence[int],                 # block indices: stage k
                 quant_specs: Optional[Sequence[Optional[QuantSpec]]] = None,
                 link_quant: bool = True):
        self.model = model
        self.cuts = list(cuts)
        n_stages = len(self.cuts) + 1
        self.quant_specs = list(quant_specs) if quant_specs else [None] * n_stages
        assert len(self.quant_specs) == n_stages
        self.link_quant = link_quant
        bounds = [0] + [c + 1 for c in self.cuts] + [len(model.blocks)]
        self.stage_blocks = [model.blocks[a:b]
                             for a, b in zip(bounds, bounds[1:])]
        # per-stage (possibly weight-quantized) params/state
        self.stage_params = []
        self.stage_state = []
        for blocks, spec in zip(self.stage_blocks, self.quant_specs):
            p = {n: params[n] for n, _ in blocks if n in params}
            s = {n: state[n] for n, _ in blocks if n in state}
            if spec is not None:
                p = quantize_pytree(p, spec)
            self.stage_params.append(p)
            self.stage_state.append(s)
        self._stage_fns = [self._make_stage_fn(i)
                           for i in range(len(self.stage_blocks))]

    def _make_stage_fn(self, i):
        blocks = self.stage_blocks[i]

        def fn(params, state, x):
            for n, b in blocks:
                x, _ = b.apply(params.get(n, {}), state.get(n, {}), x,
                               train=False)
            return x
        return jax.jit(fn)

    def run(self, x, time_stages: bool = False) -> Tuple[jnp.ndarray, StageReport]:
        lat, link_bytes = [], []
        for i, fn in enumerate(self._stage_fns):
            t0 = time.perf_counter()
            x = fn(self.stage_params[i], self.stage_state[i], x)
            if time_stages:
                jax.block_until_ready(x)
            lat.append(time.perf_counter() - t0)
            if i < len(self._stage_fns) - 1:
                spec = self.quant_specs[i]
                link_bytes.append(link_transfer_bytes(int(x.size), spec))
                if self.link_quant and spec is not None:
                    x = quantize_tensor(x, spec)    # fake-quant over the link
        return x, StageReport(lat, link_bytes)


class PartitionedLMRunner:
    """Split a scan-stacked DecoderLM at layer boundaries (pipeline stages).

    Stage 0 owns the embedding, the last stage owns final norm + head.
    This is the single-host reference for the multi-pod pipeline mode in
    ``repro.launch.pipeline`` — outputs must match the monolithic model.
    """

    def __init__(self, model, params, cuts: Sequence[int],
                 quant_specs: Optional[Sequence[Optional[QuantSpec]]] = None,
                 link_quant: bool = False):
        self.model = model
        cfg = model.cfg
        assert cfg.family in ("dense", "vlm", "audio"), \
            "LM pipeline runner supports homogeneous scan stacks"
        self.cuts = list(cuts)
        n_stages = len(self.cuts) + 1
        self.quant_specs = (list(quant_specs) if quant_specs
                            else [None] * n_stages)
        self.link_quant = link_quant
        bounds = [0] + [c + 1 for c in self.cuts] + [cfg.n_layers]
        self.ranges = list(zip(bounds, bounds[1:]))
        self.params = params

    def _stage_blocks(self, a, b):
        return jax.tree_util.tree_map(lambda x: x[a:b],
                                      self.params["blocks_dense"])

    def forward(self, batch) -> Tuple[jnp.ndarray, StageReport]:
        from repro.models.decoder import _scan_blocks
        m, p = self.model, self.params
        lat, link_bytes = [], []
        t0 = time.perf_counter()
        x, positions = m._embed(p, batch)
        b, t, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        if m.cfg.mrope_sections is not None and positions.ndim == 2:
            positions = jnp.stack([positions] * 3)
        for si, (a, bnd) in enumerate(self.ranges):
            blocks = self._stage_blocks(a, bnd)
            spec = self.quant_specs[si]
            if spec is not None:
                blocks = quantize_pytree(blocks, spec)
            x, _, _ = _scan_blocks(m.dense_block, blocks, x, positions)
            jax.block_until_ready(x)
            lat.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            if si < len(self.ranges) - 1:
                link_bytes.append(link_transfer_bytes(int(x.size), spec))
                if self.link_quant and spec is not None:
                    x = quantize_tensor(x, spec)
        from repro.nn.layers import rms_norm
        x = rms_norm(x, p["final_norm"])
        logits = m._head(p, x)
        return logits, StageReport(lat, link_bytes)

    # -- step-wise stage interface (the repro.serve execution layer) ---------
    #
    # ``forward`` above runs the whole pipeline lockstep inside one call;
    # the serve runtime instead drives each stage independently (thread per
    # stage, one decode step at a time), so it needs the stage as a *pure
    # function* over explicit weights/caches it can jit and vmap itself.

    @property
    def n_stages(self) -> int:
        return len(self.ranges)

    def stage_weights(self, si: int):
        """Parameter subtree stage ``si`` owns: its (possibly weight-fake-
        quantized) block slice, plus the embedding on stage 0 and the final
        norm + head on the last stage (the embedding again when tied)."""
        a, b = self.ranges[si]
        blocks = self._stage_blocks(a, b)
        spec = self.quant_specs[si]
        if spec is not None:
            blocks = quantize_pytree(blocks, spec)
        w = {"blocks": blocks}
        cfg = self.model.cfg
        last = si == self.n_stages - 1
        if si == 0 or (last and cfg.tied_embeddings):
            w["embed"] = self.params["embed"]
        if last:
            w["final_norm"] = self.params["final_norm"]
            if not cfg.tied_embeddings:
                w["head"] = self.params["head"]
        return w

    def init_stage_caches(self, si: int, batch: int, capacity: int,
                          dtype=jnp.float32):
        """Fresh decode caches for stage ``si``'s layer range (leading layer
        axis, ``pos`` = 0)."""
        a, b = self.ranges[si]
        full = self.model.init_caches(batch, capacity, dtype)
        return jax.tree_util.tree_map(lambda x: x[a:b], full["dense"])

    def stage_step_fn(self, si: int):
        """Pure ``(weights, caches, x) -> (out, new_caches)`` for one
        prefill/decode step of stage ``si`` — the caller jits it (and vmaps
        it over independent per-slot cache lanes for continuous batching).

        Stage 0 takes ``x`` as int32 tokens (B, T) and embeds them; later
        stages take the predecessor's activations (B, T, D).  The last
        stage applies the final norm + head and returns logits.  Token
        positions are derived from the cache write position exactly like
        ``DecoderLM.decode_step``, so per-lane caches admitted at different
        times decode at their own positions.
        """
        cfg = self.model.cfg
        assert cfg.family == "dense" and self.model.n_moe == 0, \
            "step-wise stage serving supports dense scan stacks"
        assert self.ranges[si][1] > self.ranges[si][0], \
            f"stage {si} owns no blocks (cuts {self.cuts})"
        from repro.models.decoder import _scan_blocks
        from repro.nn.layers import rms_norm
        block = self.model.dense_block
        first, last = si == 0, si == self.n_stages - 1
        tied = cfg.tied_embeddings

        def fn(weights, caches, x):
            if first:
                x = jnp.take(weights["embed"], x, axis=0)
            b, t, _ = x.shape
            pos0 = caches["pos"][0]
            positions = jnp.broadcast_to(
                (pos0[None, None] + jnp.arange(t)[None, :]).astype(jnp.int32),
                (b, t))
            x, new_caches, _ = _scan_blocks(block, weights["blocks"], x,
                                            positions, caches=caches)
            if last:
                x = rms_norm(x, weights["final_norm"])
                head = weights["embed"].T if tied else weights["head"]
                x = x @ head
            return x, new_caches
        return fn
