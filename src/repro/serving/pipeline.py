"""Partitioned (multi-platform) inference execution — the paper's Definition 1
acted out: stage k runs its layer segment at its platform's precision, the
activation crossing each link is quantized to the producer's bit width.

Used for (a) the measured-accuracy oracle of the explorer, (b) integration
tests (partitioned ≡ monolithic when quantization is off), and (c) the
end-to-end serving example.  On one CPU device stages run sequentially; the
throughput model (Def. 4) comes from per-stage timings.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import QuantSpec, quantize_pytree, quantize_tensor


@dataclasses.dataclass
class StageReport:
    latency_s: List[float]
    link_bytes: List[int]

    def throughput(self, link_latency_s: Optional[List[float]] = None) -> float:
        """Def. 4 with measured stage latencies."""
        mods = [t for t in self.latency_s if t > 0]
        if link_latency_s:
            mods += [t for t in link_latency_s if t > 0]
        return 1.0 / max(mods) if mods else 0.0


def pipeline_report(stage_latencies: Sequence[float],
                    link_latencies: Sequence[float]) -> Dict[str, float]:
    lat = sum(stage_latencies) + sum(link_latencies)
    mods = [t for t in list(stage_latencies) + list(link_latencies) if t > 0]
    th = 1.0 / max(mods) if mods else 0.0
    return {"latency_s": lat, "throughput": th}


def link_transfer_bytes(n_elems: int, spec: Optional[QuantSpec]) -> int:
    """Bytes shipped over a link for ``n_elems`` activations quantized to the
    producer's bit width (float32 when unquantized).  Sub-byte widths use
    fractional bytes-per-element — ``bits // 8`` would report 0 bytes for
    4-bit links."""
    if spec is None:
        return int(n_elems * 4)
    return int(math.ceil(n_elems * spec.bits / 8))


class PartitionedCNNRunner:
    """Split a CNNModel at block boundaries across platforms."""

    def __init__(self, model, params, state,
                 cuts: Sequence[int],                 # block indices: stage k
                 quant_specs: Optional[Sequence[Optional[QuantSpec]]] = None,
                 link_quant: bool = True):
        self.model = model
        self.cuts = list(cuts)
        n_stages = len(self.cuts) + 1
        self.quant_specs = list(quant_specs) if quant_specs else [None] * n_stages
        assert len(self.quant_specs) == n_stages
        self.link_quant = link_quant
        bounds = [0] + [c + 1 for c in self.cuts] + [len(model.blocks)]
        self.stage_blocks = [model.blocks[a:b]
                             for a, b in zip(bounds, bounds[1:])]
        # per-stage (possibly weight-quantized) params/state
        self.stage_params = []
        self.stage_state = []
        for blocks, spec in zip(self.stage_blocks, self.quant_specs):
            p = {n: params[n] for n, _ in blocks if n in params}
            s = {n: state[n] for n, _ in blocks if n in state}
            if spec is not None:
                p = quantize_pytree(p, spec)
            self.stage_params.append(p)
            self.stage_state.append(s)
        self._stage_fns = [self._make_stage_fn(i)
                           for i in range(len(self.stage_blocks))]

    def _make_stage_fn(self, i):
        blocks = self.stage_blocks[i]

        def fn(params, state, x):
            for n, b in blocks:
                x, _ = b.apply(params.get(n, {}), state.get(n, {}), x,
                               train=False)
            return x
        return jax.jit(fn)

    def run(self, x, time_stages: bool = False) -> Tuple[jnp.ndarray, StageReport]:
        lat, link_bytes = [], []
        for i, fn in enumerate(self._stage_fns):
            t0 = time.perf_counter()
            x = fn(self.stage_params[i], self.stage_state[i], x)
            if time_stages:
                jax.block_until_ready(x)
            lat.append(time.perf_counter() - t0)
            if i < len(self._stage_fns) - 1:
                spec = self.quant_specs[i]
                link_bytes.append(link_transfer_bytes(int(x.size), spec))
                if self.link_quant and spec is not None:
                    x = quantize_tensor(x, spec)    # fake-quant over the link
        return x, StageReport(lat, link_bytes)


class PartitionedLMRunner:
    """Split a scan-stacked DecoderLM at layer boundaries (pipeline stages).

    Stage 0 owns the embedding, the last stage owns final norm + head.
    This is the single-host reference for the multi-pod pipeline mode in
    ``repro.launch.pipeline`` — outputs must match the monolithic model.
    """

    def __init__(self, model, params, cuts: Sequence[int],
                 quant_specs: Optional[Sequence[Optional[QuantSpec]]] = None,
                 link_quant: bool = False):
        self.model = model
        cfg = model.cfg
        assert cfg.family in ("dense", "vlm", "audio"), \
            "LM pipeline runner supports homogeneous scan stacks"
        self.cuts = list(cuts)
        n_stages = len(self.cuts) + 1
        self.quant_specs = (list(quant_specs) if quant_specs
                            else [None] * n_stages)
        self.link_quant = link_quant
        bounds = [0] + [c + 1 for c in self.cuts] + [cfg.n_layers]
        self.ranges = list(zip(bounds, bounds[1:]))
        self.params = params

    def _stage_blocks(self, a, b):
        return jax.tree_util.tree_map(lambda x: x[a:b],
                                      self.params["blocks_dense"])

    def forward(self, batch) -> Tuple[jnp.ndarray, StageReport]:
        from repro.models.decoder import _scan_blocks
        m, p = self.model, self.params
        lat, link_bytes = [], []
        t0 = time.perf_counter()
        x, positions = m._embed(p, batch)
        b, t, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        if m.cfg.mrope_sections is not None and positions.ndim == 2:
            positions = jnp.stack([positions] * 3)
        for si, (a, bnd) in enumerate(self.ranges):
            blocks = self._stage_blocks(a, bnd)
            spec = self.quant_specs[si]
            if spec is not None:
                blocks = quantize_pytree(blocks, spec)
            x, _, _ = _scan_blocks(m.dense_block, blocks, x, positions)
            jax.block_until_ready(x)
            lat.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            if si < len(self.ranges) - 1:
                link_bytes.append(link_transfer_bytes(int(x.size), spec))
                if self.link_quant and spec is not None:
                    x = quantize_tensor(x, spec)
        from repro.nn.layers import rms_norm
        x = rms_norm(x, p["final_norm"])
        logits = m._head(p, x)
        return logits, StageReport(lat, link_bytes)
