from repro.core.hwmodel.arch import (AcceleratorArch, EYERISS_LIKE,
                                     SIMBA_LIKE, TPU_V5E, get_arch)
from repro.core.hwmodel.energy import EnergyTable
from repro.core.hwmodel.mapper import LayerCost, evaluate_layer, evaluate_segment
