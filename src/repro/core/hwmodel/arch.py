"""Accelerator architecture descriptions (§V-A workloads).

Platform A in the paper is a 16-bit Eyeriss-like accelerator @200 MHz (EYR);
platform B a Simba-like accelerator @200 MHz (SMB).  We also model a TPU v5e
chip so the same explorer can partition LLMs across pods (hardware
adaptation, DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.hwmodel.energy import (EnergyTable, bf16_tpu_table,
                                       int8_table, int16_table)


@dataclasses.dataclass(frozen=True)
class AcceleratorArch:
    name: str
    n_macs: int                   # MAC units active per cycle
    freq_hz: float
    bits: int                     # native operand width
    glb_bytes: int                # global on-chip buffer (tiles live here)
    mem_bytes: int                # total platform memory for Def. 3 capacity
    dram_bw_Bps: float            # off-chip bandwidth
    glb_bw_Bps: float             # on-chip buffer bandwidth
    vector_width: int             # elementwise lanes (cheap ops)
    energy: EnergyTable = dataclasses.field(default_factory=int8_table)
    # PE array geometry for utilization modeling (rows map to one tensor dim,
    # cols to another; Eyeriss row-stationary style)
    pe_rows: int = 0
    pe_cols: int = 0

    @property
    def peak_macs_per_s(self) -> float:
        return self.n_macs * self.freq_hz

    @property
    def bytes_per_elem(self) -> float:
        return self.bits / 8.0

    def roofline_latency_s(self, macs: int, nbytes: float) -> float:
        """Lower bound used for mapper sanity checks."""
        return max(macs / self.peak_macs_per_s, nbytes / self.dram_bw_Bps)


# --- the paper's two platforms ----------------------------------------------

# Eyeriss(v2)-like: 24x16 = 384 PEs, one 16-bit MAC each, 192 KB GLB.
# Fast and accurate (16-bit) but power-hungrier per MAC.
EYERISS_LIKE = AcceleratorArch(
    name="EYR", n_macs=384, freq_hz=200e6, bits=16,
    glb_bytes=192 * 1024, mem_bytes=64 * 1024 * 1024,
    dram_bw_Bps=3.2e9, glb_bw_Bps=25.6e9, vector_width=16,
    energy=int16_table(), pe_rows=24, pe_cols=16)

# Simba-like (single chiplet): 16 PEs x 8 int8 MAC lanes = 128 MACs/cycle,
# 100 KB distributed SRAM. Slower but far more energy-efficient (int8).
SIMBA_LIKE = AcceleratorArch(
    name="SMB", n_macs=128, freq_hz=200e6, bits=8,
    glb_bytes=100 * 1024, mem_bytes=128 * 1024 * 1024,
    dram_bw_Bps=3.2e9, glb_bw_Bps=25.6e9, vector_width=32,
    energy=int8_table(), pe_rows=16, pe_cols=8)

# TPU v5e chip (target hardware for the multi-pod mapping):
# 197 TFLOP/s bf16 = 98.5e12 MACs/s, 819 GB/s HBM, 16 GB HBM.
TPU_V5E = AcceleratorArch(
    name="TPUv5e", n_macs=104_858, freq_hz=940e6, bits=16,
    glb_bytes=128 * 1024 * 1024, mem_bytes=16 * 1024 ** 3,
    dram_bw_Bps=819e9, glb_bw_Bps=8e12, vector_width=8 * 128,
    energy=bf16_tpu_table(), pe_rows=128, pe_cols=128)


_ARCHS: Dict[str, AcceleratorArch] = {
    "eyr": EYERISS_LIKE, "smb": SIMBA_LIKE, "tpu_v5e": TPU_V5E,
}


def get_arch(name: str) -> AcceleratorArch:
    try:
        return _ARCHS[name.lower()]
    except KeyError:
        raise KeyError(f"unknown accelerator {name!r}; have {sorted(_ARCHS)}")


def register_arch(arch: AcceleratorArch, key: Optional[str] = None) -> None:
    _ARCHS[(key or arch.name).lower()] = arch
