"""Timeloop-lite: analytical mapping search for per-layer latency/energy.

The paper uses Timeloop [12] (linear-pruned search, victory condition 100)
plus Accelergy [13].  Offline we replace them with an analytical loop-nest
model searched the same way: enumerate tile candidates (powers of two plus
full extents), keep the best latency (energy tie-break), and stop after
``VICTORY`` consecutive non-improving mappings — the same pruned-search
shape Timeloop's ``linear-pruned`` heuristic uses.

Every MAC-heavy layer is decomposed into GEMM atoms (K×C matrix applied to
P positions).  A conv is a GEMM atom with C·R·S reduction and P = output
pixels; attention score/value matmuls are weight-less atoms whose "weights"
are activations (charged as streaming traffic, not resident parameters).
"""

from __future__ import annotations

import dataclasses
import math
from functools import lru_cache
from typing import List, Sequence, Tuple

from repro.core import layers as L
from repro.core.hwmodel.arch import AcceleratorArch

VICTORY = 100  # non-improving mappings before the search stops
ACC_BYTES = 4  # partial sums are accumulated at 32 bit


@dataclasses.dataclass(frozen=True)
class GemmAtom:
    """One K×C×P matmul: out[P,K] += in[P,C] @ w[C,K].

    ``weight_resident`` False means the "weights" are activations
    (attention scores etc.): they stream and are never counted as params.
    """
    k: int
    c: int
    p: int
    weight_resident: bool = True

    @property
    def macs(self) -> int:
        return self.k * self.c * self.p


@dataclasses.dataclass(frozen=True)
class LayerCost:
    latency_s: float
    energy_j: float
    dram_bytes: float
    macs: int
    compute_s: float = 0.0
    memory_s: float = 0.0
    mapping: str = ""

    def __add__(self, other: "LayerCost") -> "LayerCost":
        return LayerCost(self.latency_s + other.latency_s,
                         self.energy_j + other.energy_j,
                         self.dram_bytes + other.dram_bytes,
                         self.macs + other.macs,
                         self.compute_s + other.compute_s,
                         self.memory_s + other.memory_s, "sum")


ZERO_COST = LayerCost(0.0, 0.0, 0.0, 0)


# ---------------------------------------------------------------------------
# decomposition of LayerInfo into GEMM atoms + elementwise element counts
# ---------------------------------------------------------------------------

def decompose(layer: L.LayerInfo) -> Tuple[List[GemmAtom], int]:
    """Returns (gemm_atoms, elementwise_elems)."""
    op = layer.op
    a = layer.attrs
    if op in (L.CONV, L.DWCONV):
        cin, _, _ = layer.in_shape
        cout, ho, wo = layer.out_shape
        kk = a.get("kernel", 1)
        groups = a.get("groups", 1)
        atom = GemmAtom(k=cout // groups, c=(cin // groups) * kk * kk,
                        p=ho * wo)
        # groups run sequentially on the array: scale P
        atom = GemmAtom(atom.k, atom.c, atom.p * groups)
        return [atom], 0
    if op == L.GEMM:
        seq = layer.in_shape[0] if len(layer.in_shape) > 1 else 1
        cin = layer.in_shape[-1]
        cout = layer.out_shape[-1]
        return [GemmAtom(k=cout, c=cin, p=seq)], 0
    if op == L.MLP:
        seq, d = layer.in_shape
        d_ff = a["d_ff"]
        n = 3 if a.get("gated", True) else 2
        atoms = [GemmAtom(d_ff, d, seq)] * (n - 1) + [GemmAtom(d, d_ff, seq)]
        return atoms, seq * d_ff * (n - 1)
    if op == L.MOE:
        seq, d = layer.in_shape
        d_ff, top_k = a["d_ff"], a["top_k"]
        n_sh = a.get("n_shared", 0)
        tokens = seq * (top_k + n_sh)
        atoms = [GemmAtom(a["n_experts"], d, seq, weight_resident=True),  # router
                 GemmAtom(d_ff, d, tokens), GemmAtom(d_ff, d, tokens),
                 GemmAtom(d, d_ff, tokens)]
        return atoms, tokens * d_ff * 2
    if op == L.ATTENTION:
        seq, d = layer.in_shape
        h, kv, hd = a["n_heads"], a["n_kv"], a["head_dim"]
        ctx = min(seq, a.get("window") or seq)
        atoms = [GemmAtom(h * hd + 2 * kv * hd, d, seq),          # qkv proj
                 GemmAtom(ctx, hd, seq * h, weight_resident=False),  # q·k^T
                 GemmAtom(hd, ctx, seq * h, weight_resident=False),  # p·v
                 GemmAtom(d, h * hd, seq)]                         # out proj
        return atoms, seq * h * ctx  # softmax
    if op == L.SSM:
        seq, d = layer.in_shape
        d_in, d_st = a["d_inner"], a["d_state"]
        nh = a["n_heads"]
        atoms = [GemmAtom(2 * d_in + 2 * d_st + nh, d, seq),      # in proj
                 GemmAtom(d_st, 1, seq * d_in, weight_resident=False),  # state upd
                 GemmAtom(1, d_st, seq * d_in, weight_resident=False),  # C·h
                 GemmAtom(d, d_in, seq)]                           # out proj
        return atoms, seq * d_in * 4
    if op == L.EMBED:
        # gather: no MACs, pure memory traffic
        return [], layer.fmap_out
    # elementwise / reshaping ops
    return [], max(layer.fmap_in, layer.fmap_out)


# ---------------------------------------------------------------------------
# GEMM atom mapping search
# ---------------------------------------------------------------------------

def _tile_candidates(n: int) -> List[int]:
    c = {n}
    t = 1
    while t < n:
        c.add(t)
        t *= 2
    return sorted(c)


def _util(n: int, tile: int, lanes: int) -> float:
    """Array utilization of mapping extent ``n`` in tiles of ``tile`` onto
    ``lanes`` physical lanes."""
    per_tile = min(tile, lanes) / lanes
    edge = (n % tile) or tile
    n_tiles = math.ceil(n / tile)
    return per_tile * ((n_tiles - 1) + min(edge, lanes) / min(tile, lanes)) / n_tiles


@lru_cache(maxsize=200_000)
def _map_gemm(arch_key: Tuple, k: int, c: int, p: int,
              weight_resident: bool, bytes_per_elem: float) -> Tuple:
    """Search tilings of one GEMM atom. Cached on (arch, atom) signature.

    Returns (latency_s, energy_j, dram_bytes, compute_s, memory_s, desc).
    """
    (name, n_macs, freq, glb, dram_bw, glb_bw, rows, cols,
     mac_j, reg_j, glb_j, dram_j, leak_w) = arch_key
    bpe = bytes_per_elem
    macs = k * c * p
    w_bytes = k * c * bpe
    i_bytes = p * c * bpe
    o_bytes = p * k * bpe

    best = None
    stale = 0
    for kt in _tile_candidates(k):
        if stale > VICTORY:
            break
        for pt in _tile_candidates(p):
            for ct in _tile_candidates(c):
                # GLB capacity with double buffering
                tile_bytes = (kt * ct * bpe + pt * ct * bpe
                              + kt * pt * ACC_BYTES)
                if tile_bytes > glb / 2:
                    continue
                n_k = math.ceil(k / kt)
                n_p = math.ceil(p / pt)
                n_c = math.ceil(c / ct)
                # two loop orders; pick min DRAM traffic
                dram_a = w_bytes + i_bytes * n_k + o_bytes          # K outer
                dram_b = w_bytes * n_p + i_bytes + o_bytes          # P outer
                dram = min(dram_a, dram_b)
                if n_c > 1:  # partial-sum spill traffic
                    dram += o_bytes * (n_c - 1) * 2 * (ACC_BYTES / bpe)
                # array utilization: K on cols, P on rows
                util = max(_util(k, kt, cols) * _util(p, pt, rows), 1e-6)
                compute_s = macs / (n_macs * util * freq)
                glb_traffic = dram + macs * bpe / max(min(kt, ct, pt), 1) * 2
                memory_s = max(dram / dram_bw, glb_traffic / glb_bw)
                lat = max(compute_s, memory_s)
                energy = (macs * mac_j + dram * dram_j + glb_traffic * glb_j
                          + macs * 3 * bpe * reg_j + leak_w * lat)
                cand = (lat, energy, dram, compute_s, memory_s,
                        f"kt{kt}ct{ct}pt{pt}")
                if best is None or cand[:2] < best[:2]:
                    best = cand
                    stale = 0
                else:
                    stale += 1
    if best is None:  # nothing fits: stream at minimum tile
        dram = w_bytes + i_bytes + o_bytes
        compute_s = macs / (n_macs * 0.1 * freq)
        memory_s = dram / dram_bw
        lat = max(compute_s, memory_s)
        best = (lat, macs * mac_j + dram * dram_j + leak_w * lat, dram,
                compute_s, memory_s, "stream")
    return best


def _arch_key(arch: AcceleratorArch) -> Tuple:
    e = arch.energy
    return (arch.name, arch.n_macs, arch.freq_hz, arch.glb_bytes,
            arch.dram_bw_Bps, arch.glb_bw_Bps,
            arch.pe_rows or 16, arch.pe_cols or 16,
            e.mac_j, e.reg_j_per_byte, e.glb_j_per_byte, e.dram_j_per_byte,
            e.leakage_w)


def evaluate_layer(layer: L.LayerInfo, arch: AcceleratorArch,
                   batch: int = 1) -> LayerCost:
    """Latency/energy of one layer on one accelerator (batch folded into P)."""
    atoms, elem = decompose(layer)
    key = _arch_key(arch)
    bpe = arch.bytes_per_elem
    lat = en = dram = comp = mem = 0.0
    macs = 0
    for a in atoms:
        l, e, d, cs, ms, _ = _map_gemm(key, a.k, a.c, a.p * batch,
                                       a.weight_resident, bpe)
        lat += l
        en += e
        dram += d
        comp += cs
        mem += ms
        macs += a.macs * batch
    if elem or not atoms:
        elems = (elem or max(layer.fmap_in, layer.fmap_out)) * batch
        nbytes = elems * bpe * 2
        v_lat = max(elems / (arch.vector_width * arch.freq_hz),
                    nbytes / arch.dram_bw_Bps)
        lat += v_lat
        mem += nbytes / arch.dram_bw_Bps
        en += (nbytes * arch.energy.glb_j_per_byte
               + nbytes * arch.energy.dram_j_per_byte * 0.5
               + arch.energy.leakage_w * v_lat)
        dram += nbytes * 0.5
    return LayerCost(lat, en, dram, macs, comp, mem, layer.op)


def evaluate_segment(segment: Sequence[L.LayerInfo], arch: AcceleratorArch,
                     batch: int = 1) -> LayerCost:
    """Sequential execution of a contiguous layer segment on one platform."""
    total = ZERO_COST
    for layer in segment:
        total = total + evaluate_layer(layer, arch, batch)
    return total


def layer_cost_table(schedule: Sequence[L.LayerInfo], arch: AcceleratorArch,
                     batch: int = 1) -> List[LayerCost]:
    return [evaluate_layer(l, arch, batch) for l in schedule]
