"""Accelergy-style per-action energy tables (§IV, [13]).

Energies are 45/28 nm-class ballparks (Horowitz ISSCC'14 scaling): an int8
MAC ≈ 0.2 pJ, int16 ≈ 0.8 pJ; SRAM reads scale with macro size; DRAM is two
orders of magnitude above on-chip access.  Absolute joules matter less than
the *ratios* — they drive the same partitioning trade-offs the paper reports.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class EnergyTable:
    """Per-action energies in joules."""

    mac_j: float                 # one multiply-accumulate at native bits
    reg_j_per_byte: float        # PE-local register file / scratchpad
    glb_j_per_byte: float        # global on-chip buffer (100s of KB)
    dram_j_per_byte: float       # off-chip access
    leakage_w: float             # static power of the whole accelerator

    def scaled_mac(self, bits: int, native_bits: int) -> float:
        """MAC energy ~ quadratic in multiplier width."""
        r = bits / native_bits
        return self.mac_j * r * r


def int16_table() -> EnergyTable:
    return EnergyTable(mac_j=0.8e-12, reg_j_per_byte=0.08e-12,
                       glb_j_per_byte=1.6e-12, dram_j_per_byte=40e-12,
                       leakage_w=0.1)


def int8_table() -> EnergyTable:
    return EnergyTable(mac_j=0.2e-12, reg_j_per_byte=0.06e-12,
                       glb_j_per_byte=1.2e-12, dram_j_per_byte=40e-12,
                       leakage_w=0.02)


def bf16_tpu_table() -> EnergyTable:
    # effective per-MAC energy for a v5e-class chip at ~200 W peak board power
    return EnergyTable(mac_j=1.0e-12, reg_j_per_byte=0.05e-12,
                       glb_j_per_byte=0.8e-12, dram_j_per_byte=8e-12,
                       leakage_w=60.0)
