"""Core library: the paper's contribution — automated, hardware-aware DNN
inference partitioning for distributed systems."""

from repro.core.accuracy import MeasuredAccuracy, ProxyAccuracy
from repro.core.explorer import ExplorationResult, Explorer
from repro.core.graph import LayerGraph, linearize
from repro.core.layers import LayerInfo
from repro.core.link import LinkModel, get_link
from repro.core.memory import MemoryModel, segment_memory, split_memory
from repro.core.partition import (Constraints, PartitionEval,
                                  PartitionEvaluator, Platform, SystemConfig,
                                  single_platform_eval)
from repro.core.quant import QuantSpec
