"""Jittable fast-path of :meth:`PartitionEvaluator.evaluate_batch`.

The NumPy batch evaluator already reduces a candidate evaluation to gathers
over precomputed tables (per-arch latency/energy prefix sums, per-position
link element counts, the Def.-3 :class:`SegmentMemoryTable` and the proxy
accuracy weight prefix).  This module exports exactly those tables as device
arrays (:class:`EvalTables`, built by :func:`build_eval_tables` /
:meth:`PartitionEvaluator.jax_tables`) and a pure function over them
(:func:`make_batch_eval_fn`) so the whole NSGA-II generation loop can run
inside one ``jax.jit`` program (see ``repro.core.nsga2_jax``).

:class:`EvalTables` is a registered pytree: the table *values* are leaves
(traced runtime arguments) while the shape-determining statics (``L``,
``n_cuts``, ``batch``, the accuracy affine knobs) are aux data.  A compiled
search built by :func:`make_runtime_eval_fn` therefore reruns without any
retracing when only the values change — degraded links, shrunk memory
capacities, perturbed cost tables — which is what makes millisecond online
re-partitioning possible (``repro.explore.online``).  Two tables are
runner-compatible iff their :meth:`EvalTables.shape_signature` match.

Semantics mirror ``evaluate_batch`` metric-for-metric (tested in
``tests/test_jit_nsga2.py``); arithmetic is float32 on-device, so agreement
is to float32 tolerance rather than bit-exact.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import Constraints, PartitionEvaluator

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EvalTables:
    """Evaluator state as device arrays (leading dims: P platforms, K links,
    L schedule positions)."""

    L: int                          # schedule length (static)
    n_cuts: int                     # == K (static)
    cost_prefix: Array              # (P, 2, L+1) latency/energy prefix sums
    cut_elems: Array                # (max(L-1, 1),) elements over each cut
    producer_bpe: Array             # (K,) bytes/element at the producer side
    link_rate: Array                # (K,) raw line rate, bit/s
    link_setup: Array               # (K,) per-transfer setup, s
    link_payload: Array             # (K,) MTU payload bytes
    link_header: Array              # (K,) per-packet header bytes
    link_power: Array               # (K,) p_tx + p_rx, W
    link_e_byte: Array              # (K,) transceiver J/byte
    mem_base_prefix: Array          # (L+1,) ungrouped-parameter prefix sum
    mem_groups: Tuple[Tuple[Array, Array], ...]  # per shared group:
    #                                 (sorted member positions, member params)
    act_sparse: Array               # (levels, L) range-max sparse table
    bytes_per_param: Array          # (P,)
    bytes_per_act: Array            # (P,)
    capacity: Array                 # (P,)
    batch: int                      # static
    acc_weight_prefix: Optional[Array]  # (L+1,) or None (no proxy oracle)
    acc_noise: Optional[Array]          # (P,) quantization noise per platform
    acc_base: float
    acc_scale: float

    @property
    def supports_accuracy(self) -> bool:
        """Whether a jittable proxy-accuracy oracle was exported."""
        return self.acc_weight_prefix is not None

    def shape_signature(self) -> Tuple:
        """Hashable signature of everything that forces a retrace.

        Two :class:`EvalTables` with equal signatures can be fed to the
        same compiled runner (``make_runtime_eval_fn`` reads only values
        from the traced leaves): statics, leaf shapes and dtypes all match,
        so only the table *values* differ between the two programs.
        """
        def sig(a):
            if a is None:
                return None
            return (tuple(a.shape), str(a.dtype))
        return (self.L, self.n_cuts, self.batch,
                self.acc_base, self.acc_scale,
                tuple((f, sig(getattr(self, f))) for f in _TABLE_ARRAYS),
                tuple((sig(pos), sig(par)) for pos, par in self.mem_groups))


# pytree registration: array-valued fields are leaves (runtime, traced),
# shape-determining ints/floats are aux data (static, part of the treedef)
_TABLE_ARRAYS = (
    "cost_prefix", "cut_elems", "producer_bpe", "link_rate", "link_setup",
    "link_payload", "link_header", "link_power", "link_e_byte",
    "mem_base_prefix", "act_sparse", "bytes_per_param", "bytes_per_act",
    "capacity", "acc_weight_prefix", "acc_noise")
_TABLE_STATICS = ("L", "n_cuts", "batch", "acc_base", "acc_scale")


def _tables_flatten(t: EvalTables):
    children = tuple(getattr(t, f) for f in _TABLE_ARRAYS) + (t.mem_groups,)
    return children, tuple(getattr(t, f) for f in _TABLE_STATICS)


def _tables_unflatten(aux, children) -> EvalTables:
    kw = dict(zip(_TABLE_ARRAYS, children[:-1]))
    kw["mem_groups"] = children[-1]
    kw.update(zip(_TABLE_STATICS, aux))
    return EvalTables(**kw)


jax.tree_util.register_pytree_node(EvalTables, _tables_flatten,
                                   _tables_unflatten)


def build_eval_tables(evaluator: PartitionEvaluator) -> EvalTables:
    """Export an evaluator's precomputed tables as device arrays.

    Accuracy tables are present only when the evaluator's oracle exposes the
    :meth:`~repro.core.accuracy.ProxyAccuracy.proxy_arrays` protocol
    (measured oracles are host-side by nature and cannot be jitted).
    """
    system = evaluator.system
    plats = system.platforms
    L = len(evaluator.schedule)
    f32 = jnp.float32

    cost_prefix = jnp.asarray(
        np.stack([evaluator._prefix[p.arch.name] for p in plats]), dtype=f32)
    elems = evaluator.cut_elements() if L > 1 else np.zeros(1, dtype=np.int64)
    if len(elems) == 0:
        elems = np.zeros(1, dtype=np.int64)

    links = system.links
    mt = evaluator._memtable
    acc = evaluator.accuracy_fn
    if hasattr(acc, "proxy_arrays"):
        wpre, noise, base, scale = acc.proxy_arrays()
        acc_wpre = jnp.asarray(wpre, dtype=f32)
        acc_noise = jnp.asarray(noise, dtype=f32)
    else:
        acc_wpre = acc_noise = None
        base, scale = 1.0, 0.0

    return EvalTables(
        L=L, n_cuts=system.n_cuts,
        cost_prefix=cost_prefix,
        cut_elems=jnp.asarray(elems, dtype=f32),
        producer_bpe=jnp.asarray([p.quant.bits / 8.0 for p in plats[:-1]]
                                 if len(plats) > 1 else [0.0], dtype=f32),
        link_rate=jnp.asarray([l.rate_bps for l in links] or [1.0], dtype=f32),
        link_setup=jnp.asarray([l.t_setup_s for l in links] or [0.0],
                               dtype=f32),
        link_payload=jnp.asarray([l.payload_bytes for l in links] or [1.0],
                                 dtype=f32),
        link_header=jnp.asarray([l.header_bytes for l in links] or [0.0],
                                dtype=f32),
        link_power=jnp.asarray([l.p_tx_w + l.p_rx_w for l in links] or [0.0],
                               dtype=f32),
        link_e_byte=jnp.asarray([l.e_per_byte_j for l in links] or [0.0],
                                dtype=f32),
        mem_base_prefix=jnp.asarray(mt.base_prefix, dtype=f32),
        mem_groups=tuple(
            (jnp.asarray(pos, dtype=jnp.int32), jnp.asarray(gpar, dtype=f32))
            for pos, gpar in mt.groups),
        act_sparse=jnp.asarray(mt.act_sparse, dtype=f32) if L
        else jnp.zeros((1, 1), dtype=f32),
        bytes_per_param=jnp.asarray([p.memory_model.bytes_per_param
                                     for p in plats], dtype=f32),
        bytes_per_act=jnp.asarray([p.memory_model.act_bytes for p in plats],
                                  dtype=f32),
        capacity=jnp.asarray([p.capacity for p in plats], dtype=f32),
        batch=evaluator.batch,
        acc_weight_prefix=acc_wpre, acc_noise=acc_noise,
        acc_base=float(base), acc_scale=float(scale))


def _segment_memory(t: EvalTables, aa: Array, bb: Array,
                    valid: Array) -> Array:
    """Def.-3 memory of schedule[aa..bb] per (row, platform), elementwise
    twin of :meth:`SegmentMemoryTable.batched` (0 where invalid)."""
    par = t.mem_base_prefix[bb + 1] - t.mem_base_prefix[aa]
    for pos, gpar in t.mem_groups:          # static group count: unrolled
        idx = jnp.minimum(jnp.searchsorted(pos, aa), len(pos) - 1)
        hit = (pos[idx] >= aa) & (pos[idx] <= bb)
        par = par + jnp.where(hit, gpar[idx], 0.0)
    length = (bb - aa + 1).astype(jnp.float32)
    k = jnp.frexp(length)[1] - 1            # floor(log2(len)), exact for ints
    w = jnp.left_shift(jnp.int32(1), k)
    peak = jnp.maximum(t.act_sparse[k, aa],
                       t.act_sparse[k, bb - w + 1]) * t.batch
    mem = (par * t.bytes_per_param[None, :]
           + peak * t.bytes_per_act[None, :])
    return jnp.where(valid, jnp.floor(mem), 0.0)


def make_runtime_eval_fn(template: EvalTables, objectives: Sequence[str],
                         constraints: Optional[Constraints] = None,
                         ) -> Callable[[Array, EvalTables],
                                       Tuple[Array, Array]]:
    """Build ``eval(C, tables) -> (F, CV)`` with the tables as a runtime
    pytree argument.

    ``objectives``/``constraints`` and the shape statics of ``template``
    are baked into the trace; the table *values* are read from the
    ``tables`` argument at call time, so one jitted program serves every
    :class:`EvalTables` whose :meth:`~EvalTables.shape_signature` equals
    the template's — the mechanism behind the compiled-runner reuse of
    ``repro.explore.online``.  Raises if accuracy is needed (objective or
    ``min_accuracy``) but the template has no proxy oracle.
    """
    objectives = tuple(objectives)
    cons = constraints or Constraints()
    needs_acc = "accuracy" in objectives or bool(cons.min_accuracy)
    if needs_acc and not template.supports_accuracy:
        raise ValueError(
            "accuracy objective/constraint requires a jittable proxy "
            "accuracy oracle (ProxyAccuracy.proxy_arrays); measured oracles "
            "must use the NumPy 'nsga2' strategy")
    L, K = template.L, template.n_cuts
    n_plat = template.cost_prefix.shape[0]
    has_acc = template.supports_accuracy

    def eval_cuts(C: Array, t: EvalTables) -> Tuple[Array, Array]:
        C = jnp.maximum(C.astype(jnp.int32), -1)
        n = C.shape[0]
        bounds = jnp.concatenate(
            [jnp.full((n, 1), -1, jnp.int32), C,
             jnp.full((n, 1), L - 1, jnp.int32)], axis=1)   # (N, P+1)
        a = bounds[:, :-1] + 1                               # (N, P)
        b1 = bounds[:, 1:] + 1
        prow = jnp.arange(n_plat)[None, :]
        stage_lat = (t.cost_prefix[prow, 0, b1]
                     - t.cost_prefix[prow, 0, a])            # (N, P)
        energy = (t.cost_prefix[prow, 1, b1]
                  - t.cost_prefix[prow, 1, a]).sum(axis=1)   # (N,)

        if K:
            p = C                                            # (N, K)
            sent = bounds[:, 1:K + 1] > bounds[:, :K]
            remaining = bounds[:, -1:] > bounds[:, 1:K + 1]
            active = (p >= 0) & (p < L - 1) & sent & remaining
            raw = (jnp.ceil(t.cut_elems[jnp.clip(p, 0, max(L - 2, 0))]
                            * t.producer_bpe[None, :]) * t.batch)
            nbytes = jnp.where(active, raw, 0.0)             # (N, K)
            packets = jnp.ceil(nbytes / t.link_payload[None, :])
            wire_bits = (nbytes + packets * t.link_header[None, :]) * 8.0
            link_lat = jnp.where(
                nbytes > 0,
                t.link_setup[None, :] + wire_bits / t.link_rate[None, :], 0.0)
            energy = energy + jnp.where(
                nbytes > 0, t.link_power[None, :] * link_lat
                + t.link_e_byte[None, :] * nbytes, 0.0).sum(axis=1)
            max_link = nbytes.max(axis=1)
        else:
            link_lat = jnp.zeros((n, 1))
            max_link = jnp.zeros(n)

        latency = stage_lat.sum(axis=1) + link_lat.sum(axis=1)
        mods = jnp.concatenate([stage_lat, link_lat], axis=1)
        slowest = jnp.max(jnp.where(mods > 0, mods, 0.0), axis=1)
        throughput = jnp.where(slowest > 0, 1.0 / slowest, 0.0)

        aa_raw, bb_raw = a, bounds[:, 1:]
        valid = aa_raw <= bb_raw
        aa = jnp.where(valid, aa_raw, 0)
        bb = jnp.where(valid, bb_raw, 0)
        mems = _segment_memory(t, aa, bb, valid)             # (N, P)

        if has_acc:
            wpre = t.acc_weight_prefix
            loss = (t.acc_noise[None, :]
                    * (wpre[bounds[:, 1:] + 1] - wpre[bounds[:, :-1] + 1])
                    ).sum(axis=1)
            acc = jnp.maximum(0.0, t.acc_base - t.acc_scale * loss)
        else:
            acc = jnp.ones(n)

        over = mems - t.capacity[None, :]
        cv = jnp.where(over > 0, over / t.capacity[None, :], 0.0).sum(axis=1)
        if cons.max_link_bytes:
            o = max_link - cons.max_link_bytes
            cv = cv + jnp.where(o > 0, o / cons.max_link_bytes, 0.0)
        if cons.min_accuracy:
            cv = cv + jnp.maximum(0.0, cons.min_accuracy - acc)
        if cons.max_latency_s:
            o = latency - cons.max_latency_s
            cv = cv + jnp.where(o > 0, o / cons.max_latency_s, 0.0)
        if cons.max_energy_j:
            o = energy - cons.max_energy_j
            cv = cv + jnp.where(o > 0, o / cons.max_energy_j, 0.0)
        if cons.min_throughput:
            s = cons.min_throughput - throughput
            cv = cv + jnp.where(s > 0, s / cons.min_throughput, 0.0)

        cols = {
            "latency": latency,
            "energy": energy,
            "throughput": -throughput,
            "bandwidth": max_link,
            "memory": mems.max(axis=1),
            "accuracy": -acc,
        }
        F = jnp.stack([cols[k] for k in objectives], axis=1)
        return F, cv

    return eval_cuts


def make_batch_eval_fn(tables: EvalTables, objectives: Sequence[str],
                       constraints: Optional[Constraints] = None,
                       ) -> Callable[[Array], Tuple[Array, Array]]:
    """Build ``eval(C) -> (F, CV)`` over an (N, n_cuts) sorted cut matrix.

    Convenience closure over :func:`make_runtime_eval_fn` with ``tables``
    bound: objectives/constraints *and* the table values are fixed for the
    life of the function (one compiled program per search).  Use
    :func:`make_runtime_eval_fn` directly when the same compilation must
    serve drifting table values.
    """
    fn = make_runtime_eval_fn(tables, objectives, constraints)

    def eval_cuts(C: Array) -> Tuple[Array, Array]:
        return fn(C, tables)

    return eval_cuts
