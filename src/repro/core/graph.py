"""Layer-graph IR: DAG construction, topological scheduling, cut discovery.

This is §IV-A of the paper.  A model is a DAG of :class:`LayerInfo` nodes.
The partitioner needs:

* a *linear schedule* (topological order). The paper breaks ties among
  parallel branches randomly; we additionally provide a memory-minimizing
  tie-break (used by the memory estimator, §IV-B) that schedules parallel
  branches as contiguous subgraphs picked greedily by Definition-3 cost.
* the set of *clean cut points*: positions ``p`` in the schedule where every
  edge from the prefix to the suffix carries the output of the single layer
  ``l_p`` (Definition 1 transmits exactly ``f_p``).  A beyond-paper extension
  also enumerates *multi-tensor cuts* where the full live set is transmitted.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.layers import LayerInfo


class GraphError(ValueError):
    pass


@dataclasses.dataclass
class LayerGraph:
    """A DAG of layers. Edges carry the producer's output feature map."""

    nodes: Dict[str, LayerInfo] = dataclasses.field(default_factory=dict)
    edges: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    name: str = "graph"

    # -- construction -------------------------------------------------------
    def add(self, layer: LayerInfo, after: Optional[Iterable[str]] = None) -> LayerInfo:
        """Insert one layer with edges from each ``after`` predecessor."""
        if layer.name in self.nodes:
            raise GraphError(f"duplicate node {layer.name!r}")
        self.nodes[layer.name] = layer
        for pred in (after or ()):
            if pred not in self.nodes:
                raise GraphError(f"unknown predecessor {pred!r}")
            self.edges.append((pred, layer.name))
        return layer

    def chain(self, layers: Sequence[LayerInfo], after: Optional[str] = None) -> str:
        """Add a linear chain; returns the name of the last layer."""
        prev = after
        for l in layers:
            self.add(l, after=[prev] if prev else None)
            prev = l.name
        assert prev is not None
        return prev

    # -- adjacency ----------------------------------------------------------
    def preds(self, name: str) -> List[str]:
        """Direct predecessors of ``name`` (edge order)."""
        return [u for (u, v) in self.edges if v == name]

    def succs(self, name: str) -> List[str]:
        """Direct successors of ``name`` (edge order)."""
        return [v for (u, v) in self.edges if u == name]

    def _adj(self) -> Tuple[Dict[str, List[str]], Dict[str, int]]:
        out: Dict[str, List[str]] = {n: [] for n in self.nodes}
        indeg: Dict[str, int] = {n: 0 for n in self.nodes}
        for u, v in self.edges:
            out[u].append(v)
            indeg[v] += 1
        return out, indeg

    def __len__(self) -> int:
        return len(self.nodes)

    @property
    def total_params(self) -> int:
        """Parameter count summed over every layer."""
        return sum(l.params for l in self.nodes.values())

    @property
    def total_macs(self) -> int:
        """MAC count summed over every layer."""
        return sum(l.macs for l in self.nodes.values())

    # -- scheduling (§IV-A) --------------------------------------------------
    def topo_sort(self, seed: Optional[int] = None,
                  key=None) -> List[LayerInfo]:
        """Kahn's algorithm.

        ``seed`` reproduces the paper's random tie-break among ready parallel
        layers; ``key`` (name -> sortable) overrides it with a deterministic
        policy (used by the min-memory scheduler).  Default: insertion order.
        """
        out, indeg = self._adj()
        ready = [n for n in self.nodes if indeg[n] == 0]
        if not ready and self.nodes:
            raise GraphError("graph has no source node (cycle?)")
        rng = None
        if seed is not None:
            import random
            rng = random.Random(seed)
        order: List[LayerInfo] = []
        while ready:
            if rng is not None:
                idx = rng.randrange(len(ready))
            elif key is not None:
                idx = min(range(len(ready)), key=lambda i: key(ready[i]))
            else:
                idx = 0
            n = ready.pop(idx)
            order.append(self.nodes[n])
            for m in out[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    ready.append(m)
        if len(order) != len(self.nodes):
            cyc = set(self.nodes) - {l.name for l in order}
            raise GraphError(f"cycle detected among {sorted(cyc)[:5]}...")
        return order

    # -- cut analysis --------------------------------------------------------
    def live_set(self, schedule: Sequence[LayerInfo], p: int) -> List[str]:
        """Tensors live across the cut after position ``p`` (0-indexed).

        A producer in the prefix is live if any consumer is in the suffix,
        or if it is a graph output (no consumers at all) — graph outputs
        are not transmitted, so they are excluded here.
        """
        prefix = {l.name for l in schedule[: p + 1]}
        live: List[str] = []
        for name in prefix:
            consumers = self.succs(name)
            if any(c not in prefix for c in consumers):
                live.append(name)
        return sorted(live)

    def clean_cuts(self, schedule: Sequence[LayerInfo]) -> List[int]:
        """Positions p where the live set is exactly {schedule[p].name}.

        These are the paper's Definition-1 partitioning points: one tensor
        (f_p, the output of l_p) crosses the link.
        """
        cuts: List[int] = []
        for p in range(len(schedule) - 1):
            if self.live_set(schedule, p) == [schedule[p].name]:
                cuts.append(p)
        return cuts

    def all_cuts(self, schedule: Sequence[LayerInfo],
                 max_live: int = 4) -> List[Tuple[int, List[str]]]:
        """Beyond-paper: every position with |live set| <= max_live."""
        out: List[Tuple[int, List[str]]] = []
        for p in range(len(schedule) - 1):
            live = self.live_set(schedule, p)
            if 0 < len(live) <= max_live:
                out.append((p, live))
        return out

    def cut_bytes(self, schedule: Sequence[LayerInfo], p: int,
                  bytes_per_elem: float) -> int:
        """Bytes transmitted over the link for a cut after position p.

        Sub-byte widths round up (a 4-bit link shipping one element still
        moves a byte), matching the serving-side accounting."""
        live = self.live_set(schedule, p)
        total = sum(self.nodes[n].fmap_out for n in live)
        return int(math.ceil(total * bytes_per_elem))

    # -- parallel-branch discovery (for the min-memory scheduler) ------------
    def branch_regions(self, schedule: Sequence[LayerInfo]) -> List[Tuple[int, int]]:
        """Maximal [i, j] index ranges in the schedule that sit between two
        clean cuts — inside such a region parallel branches may be reordered
        without affecting anything outside it."""
        cuts = [-1] + self.clean_cuts(schedule) + [len(schedule) - 1]
        regions = []
        for a, b in zip(cuts, cuts[1:]):
            if b - a > 1:
                regions.append((a + 1, b))
        return regions

    def validate_schedule(self, schedule: Sequence[LayerInfo]) -> bool:
        """True iff ``schedule`` is a topological order covering every
        node exactly once."""
        pos = {l.name: i for i, l in enumerate(schedule)}
        if len(pos) != len(self.nodes):
            return False
        return all(pos[u] < pos[v] for u, v in self.edges)


def linearize(graph: LayerGraph, policy: str = "insertion",
              seed: Optional[int] = None) -> List[LayerInfo]:
    """Produce the linear execution schedule used by the partitioner.

    policies:
      * ``insertion`` — deterministic, model-definition order.
      * ``random``    — the paper's random tie-break (give ``seed``).
      * ``min_memory``— greedy: among ready nodes prefer the one whose
        activation footprint (Def. 3 ``a_j``) is smallest, which empirically
        matches the paper's branch-subgraph memory minimization for the
        CNN zoo (branches are scheduled depth-first, cheapest first).
    """
    if policy == "insertion":
        return graph.topo_sort()
    if policy == "random":
        return graph.topo_sort(seed=0 if seed is None else seed)
    if policy == "min_memory":
        names = list(graph.nodes)
        order_idx = {n: i for i, n in enumerate(names)}
        return graph.topo_sort(
            key=lambda n: (graph.nodes[n].activation_footprint, order_idx[n]))
    raise ValueError(f"unknown schedule policy {policy!r}")
