"""JIT-compiled twins of the NSGA-II operators (``repro.core.nsga2``).

Everything here is shape-static and traceable, so the *entire* generation
loop — non-dominated ranking, crowding, binary tournaments, crossover,
mutation, repair and the batched metric evaluation — runs as one compiled
XLA program over fixed-shape population arrays (:func:`jit_nsga2`).  That is
what lifts the search from the NumPy path's ~1k evals/s at pop 2048 (where
the O(pop²) sort dominates) to accelerator-rate populations of 10k+.

Differences from the NumPy implementation, by construction:

* randomness comes from ``jax.random`` (different stream than
  ``np.random.default_rng``), so runs are seeded/reproducible but not
  bit-identical to the NumPy search — equivalence is at the Pareto-front
  level (tested);
* front peeling stops once ``pop_size`` individuals are ranked (the only
  ranks environmental selection can consume); the tail keeps rank ``n``;
* crowding is computed per rank group over the combined parent+offspring
  population and carried into the next generation's tournaments instead of
  being recomputed on the survivors.
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

Array = jax.Array
EvalFn = Callable[[Array], Tuple[Array, Array]]


# -- jittable domination / ranking / crowding ---------------------------------

def constrained_dominates(Fa: Array, cva: Array,
                          Fb: Array, cvb: Array) -> Array:
    """Broadcasting Deb constraint-domination (twin of the NumPy version)."""
    feas_a, feas_b = cva <= 0, cvb <= 0
    dom = jnp.all(Fa <= Fb, axis=-1) & jnp.any(Fa < Fb, axis=-1)
    return jnp.where(feas_a & ~feas_b, True,
                     jnp.where(feas_b & ~feas_a, False,
                               jnp.where(~feas_a & ~feas_b, cva < cvb, dom)))


def domination_matrix(F: Array, CV: Array) -> Array:
    """D[p, q] = p constraint-dominates q, diagonal cleared."""
    n = F.shape[0]
    D = constrained_dominates(F[:, None, :], CV[:, None],
                              F[None, :, :], CV[None, :])
    return D & ~jnp.eye(n, dtype=bool)


def _pack_bits(B: Array) -> Array:
    """Pack a boolean (n, m) matrix into (ceil(n/32), m) uint32 words along
    axis 0 (bit j of word w, column q = B[32w + j, q])."""
    n, m = B.shape
    pad = (-n) % 32
    Bp = jnp.pad(B, ((0, pad), (0, 0)))
    W = Bp.reshape(-1, 32, m).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (W * weights[None, :, None]).sum(axis=1, dtype=jnp.uint32)


def nondominated_rank(F: Array, CV: Array,
                      cap: Optional[int] = None, *,
                      rank_block: Optional[int] = None,
                      rank_impl: str = "auto",
                      mesh=None) -> Array:
    """Front index per individual (0 = first front), peeled until at least
    ``cap`` individuals are ranked (default: all).  The unpeeled tail keeps
    rank ``n`` — environmental selection never reaches it.

    With ``rank_block`` unset/0 the dense path runs: the full domination
    matrix is built in one broadcast, bit-packed (32 individuals per uint32
    word), and each peel step counts surviving dominators with
    ``population_count`` over a (n/32, n) word matrix — ~n²/8 bytes of
    traffic per front instead of the 4n² a float mat-vec would read.

    ``rank_block > 0`` switches to the tiled primitive
    (``repro.kernels.ops.packed_domination``): the packed words are built
    (rank_block, n)-tile by tile so the dense (n, n[, m]) booleans never
    exist, and only *feasible* Pareto layers are peeled — Deb domination
    totally orders infeasible individuals by violation, so their ranks (the
    equal-CV groups, appended after the feasible layers) come in closed
    form instead of one O(n²/8) popcount pass per (often singleton) front.
    Ranks are bit-identical to the dense path; ``mesh`` (1-D) shards the
    tile rows across devices.
    """
    n = F.shape[0]
    cap = n if cap is None else min(cap, n)
    if rank_block:
        return _rank_blocked(F, CV, cap, rank_block, rank_impl, mesh)
    Dp = _pack_bits(domination_matrix(F, CV))       # (W, n) uint32
    state = (jnp.full(n, n, dtype=jnp.int32),       # rank
             jnp.ones(n, dtype=bool),               # alive (unranked)
             jnp.int32(0), jnp.int32(0))            # front idx, ranked count

    def cond(s):
        _, alive, _, done = s
        return alive.any() & (done < cap)

    def body(s):
        rank, alive, r, done = s
        alive_p = _pack_bits(alive[:, None])[:, 0]  # (W,)
        n_dom = lax.population_count(Dp & alive_p[:, None]).sum(axis=0)
        front = alive & (n_dom == 0)                # no alive dominator
        front = jnp.where(front.any(), front, alive)   # numerical safety
        rank = jnp.where(front, r, rank)
        return (rank, alive & ~front, r + 1,
                done + front.sum(dtype=jnp.int32))

    rank, _, _, _ = lax.while_loop(cond, body, state)
    return rank


def _rank_blocked(F: Array, CV: Array, cap: int, block: int, impl: str,
                  mesh) -> Array:
    """Tiled non-dominated ranking; see :func:`nondominated_rank`."""
    from repro.kernels import ops
    n = F.shape[0]
    Dp = ops.packed_domination(F, CV, block=block, impl=impl, mesh=mesh)
    feas = CV <= 0
    state = (jnp.full(n, n, dtype=jnp.int32), feas,
             jnp.int32(0), jnp.int32(0))

    def cond(s):
        _, alive, _, done = s
        return alive.any() & (done < cap)

    def body(s):
        rank, alive, r, done = s
        alive_p = _pack_bits(alive[:, None])[:, 0]
        n_dom = lax.population_count(Dp & alive_p[:, None]).sum(axis=0)
        front = alive & (n_dom == 0)
        front = jnp.where(front.any(), front, alive)   # numerical safety
        rank = jnp.where(front, r, rank)
        return (rank, alive & ~front, r + 1,
                done + front.sum(dtype=jnp.int32))

    rank, _, n_feas_fronts, done = lax.while_loop(cond, body, state)
    # infeasible tail: every feasible individual dominates every infeasible
    # one and infeasible pairs compare by violation alone, so the remaining
    # fronts are the equal-CV groups in ascending order.  A group is peeled
    # iff the count ranked before it is still under the cap — exactly the
    # dense loop's stopping rule.
    cvs = jnp.where(feas, jnp.inf, CV)
    order = jnp.argsort(cvs)
    scv = cvs[order]
    new_grp = jnp.concatenate([jnp.zeros(1, dtype=bool),
                               scv[1:] != scv[:-1]])
    grp_sorted = jnp.cumsum(new_grp.astype(jnp.int32))
    grp = jnp.zeros(n, jnp.int32).at[order].set(grp_sorted)
    first_idx = jax.ops.segment_min(jnp.arange(n, dtype=jnp.int32),
                                    grp_sorted, num_segments=n)
    before = done + first_idx[grp]                  # ranked before my group
    include = ~feas & (before < cap)
    return jnp.where(include, n_feas_fronts + grp, rank)


def crowding_by_rank(F: Array, rank: Array) -> Array:
    """Crowding distance within each rank group (twin of
    ``crowding_distance`` applied per front, without materializing fronts).

    Per objective: lexsort by (rank, value); interior points accumulate the
    neighbour gap normalized by their group's value span (segment min/max),
    group boundaries get ``inf`` — exactly the NumPy accounting.
    """
    n, m = F.shape
    crowd = jnp.zeros(n)
    for j in range(m):                               # m static, unrolled
        f = F[:, j]
        order = jnp.lexsort((f, rank))
        sr, sf = rank[order], f[order]
        span = (jax.ops.segment_max(f, rank, num_segments=n + 1)
                - jax.ops.segment_min(f, rank, num_segments=n + 1))[sr]
        same = sr[1:] == sr[:-1]
        false1 = jnp.zeros(1, dtype=bool)
        interior = (jnp.concatenate([false1, same])
                    & jnp.concatenate([same, false1]))
        gap = (jnp.concatenate([sf[1:], sf[-1:]])
               - jnp.concatenate([sf[:1], sf[:-1]]))
        contrib = jnp.where(
            interior,
            jnp.where(span > 0, gap / jnp.where(span > 0, span, 1.0), 0.0),
            jnp.inf)
        crowd = crowd.at[order].add(contrib)
    return crowd


# -- jittable GA operators ----------------------------------------------------

def tournament(key: Array, F: Array, CV: Array, crowd: Array,
               n: int) -> Array:
    """n independent binary tournaments → winner indices."""
    ka, kb = jax.random.split(key)
    a = jax.random.randint(ka, (n,), 0, F.shape[0])
    b = jax.random.randint(kb, (n,), 0, F.shape[0])
    a_dom = constrained_dominates(F[a], CV[a], F[b], CV[b])
    b_dom = constrained_dominates(F[b], CV[b], F[a], CV[a])
    return jnp.where(a_dom | (~b_dom & (crowd[a] >= crowd[b])), a, b)


def repair(X: Array, lo: int, hi: int) -> Array:
    """Clip/sort/de-duplicate cut vectors — twin of ``_repair_batch`` (the
    scans run over the short static n_var axis, unrolled)."""
    X = jnp.clip(jnp.sort(X, axis=1), lo, hi)
    n_var = X.shape[1]
    for i in range(1, n_var):
        X = X.at[:, i].set(jnp.where(X[:, i] <= X[:, i - 1],
                                     jnp.minimum(hi, X[:, i - 1] + 1),
                                     X[:, i]))
    for i in range(n_var - 2, -1, -1):     # if saturated at hi, push left
        X = X.at[:, i].set(jnp.where(X[:, i] >= X[:, i + 1],
                                     jnp.maximum(lo, X[:, i + 1] - 1),
                                     X[:, i]))
    return X


def make_offspring(key: Array, X: Array, F: Array, CV: Array, crowd: Array,
                   lo: int, hi: int) -> Array:
    """Tournaments → uniform crossover → blend step → reset/local-step
    mutation → repair, mirroring the NumPy brood construction."""
    pop, n_var = X.shape
    half = (pop + 1) // 2
    k1, k2, k3, k4, k5, k6, k7, k8 = jax.random.split(key, 8)
    P1 = X[tournament(k1, F, CV, crowd, half)]
    P2 = X[tournament(k2, F, CV, crowd, half)]
    mask = jax.random.uniform(k3, (half, n_var)) < 0.5
    Xc = jnp.concatenate([jnp.where(mask, P1, P2),
                          jnp.where(mask, P2, P1)])[:pop]
    if n_var > 0:
        par1 = jnp.concatenate([P1, P1])[:pop]
        par2 = jnp.concatenate([P2, P2])[:pop]
        blend = jax.random.uniform(k4, (pop,)) < 0.3
        j = jax.random.randint(k5, (pop,), 0, n_var)
        rows = jnp.arange(pop)
        mid = (par1[rows, j] + par2[rows, j]) // 2
        Xc = Xc.at[rows, j].set(jnp.where(blend, mid, Xc[rows, j]))
    nv = max(n_var, 1)
    r = jax.random.uniform(k6, (pop, n_var))
    reset = r < 0.5 / nv
    step = ~reset & (r < 2.0 / nv)
    Xc = jnp.where(reset, jax.random.randint(k7, Xc.shape, lo, hi + 1), Xc)
    Xc = jnp.where(step, Xc + jax.random.randint(k8, Xc.shape, -3, 4), Xc)
    return repair(Xc, lo, hi)


# -- the compiled generation loop ---------------------------------------------

# auto rank_block policy: combined (2·pop) populations at/below the
# threshold keep the dense packed path (fastest there, memory irrelevant);
# beyond it the tiled path runs with the default tile rows
_AUTO_DENSE_MAX = 4096
_AUTO_RANK_BLOCK = 2048


def _resolve_rank_block(rank_block: Optional[int], pop_size: int) -> int:
    """None → auto (dense ≤ ``_AUTO_DENSE_MAX`` combined, else 2048-row
    tiles); 0 forces dense; a positive int is the tile row count."""
    if rank_block is None:
        return 0 if 2 * pop_size <= _AUTO_DENSE_MAX else _AUTO_RANK_BLOCK
    return rank_block


def _make_run(eval_fn: EvalFn, lo: int, hi: int, pop_size: int,
              rank_block: int, rank_impl: str, mesh):
    """The whole-search program (unjitted) shared by the single-seed and
    vmapped multi-restart runners.

    ``run(key, X0, n_gen, *eval_args)`` forwards any trailing arguments to
    every ``eval_fn(X, *eval_args)`` call — that is how runtime-valued
    evaluation tables (gene table, :class:`~repro.core.partition_jax
    .EvalTables`) flow through the compiled program without being baked
    into the trace."""

    def gen_step(carry, eval_args):
        key, X, F, CV, crowd = carry
        key, k_off = jax.random.split(key)
        Xc = make_offspring(k_off, X, F, CV, crowd, lo, hi)
        Fc, CVc = eval_fn(Xc, *eval_args)
        Xall = jnp.concatenate([X, Xc])
        Fall = jnp.concatenate([F, Fc])
        CVall = jnp.concatenate([CV, CVc])
        # elitist environmental selection: whole fronts in rank order, the
        # boundary front tie-broken by crowding == lexsort by (rank, -crowd)
        rank = nondominated_rank(Fall, CVall, cap=pop_size,
                                 rank_block=rank_block, rank_impl=rank_impl,
                                 mesh=mesh)
        crowd_all = crowding_by_rank(Fall, rank)
        keep = jnp.lexsort((-crowd_all, rank))[:pop_size]
        return key, Xall[keep], Fall[keep], CVall[keep], crowd_all[keep]

    def run(key: Array, X0: Array, n_gen,
            *eval_args) -> Tuple[Array, Array, Array]:
        X0 = repair(X0, lo, hi)
        F0, CV0 = eval_fn(X0, *eval_args)
        rank0 = nondominated_rank(F0, CV0, rank_block=rank_block,
                                  rank_impl=rank_impl, mesh=mesh)
        crowd0 = crowding_by_rank(F0, rank0)
        carry = (key, X0, F0, CV0, crowd0)
        carry = lax.fori_loop(0, n_gen,
                              lambda _, c: gen_step(c, eval_args), carry)
        return carry[1], carry[2], carry[3]

    return run


def make_jit_runner(eval_fn: EvalFn, n_var: int, lower: int, upper: int,
                    pop_size: int, rank_block: Optional[int] = None,
                    rank_impl: str = "auto", mesh=None):
    """Compile the whole NSGA-II run into one XLA program.

    Returns ``run(key, X0, n_gen, *eval_args) -> (X, F, CV)``; ``n_gen`` is
    a traced loop bound, so one compilation serves any generation budget at
    a given (pop_size, n_var) shape.  ``X0`` is donated — the population
    buffers live in place across the generation loop.  Trailing
    ``eval_args`` are forwarded to ``eval_fn(X, *eval_args)`` as ordinary
    (non-donated) runtime arguments: pass value-bearing tables (gene table,
    ``EvalTables``) here and the same compilation serves every same-shape
    perturbation of them without retracing.

    ``rank_block``/``rank_impl``/``mesh`` select the ranking primitive (see
    :func:`nondominated_rank`): the auto policy keeps the dense packed
    matrix for combined populations ≤ 4096 and tiles beyond, which is what
    lets pop 32768+ run in O(pop · rank_block) working memory.
    """
    run = _make_run(eval_fn, lower, upper, pop_size,
                    _resolve_rank_block(rank_block, pop_size), rank_impl,
                    mesh)
    return jax.jit(run, donate_argnums=(1,))


def make_jit_restart_runner(eval_fn: EvalFn, n_var: int, lower: int,
                            upper: int, pop_size: int,
                            rank_block: Optional[int] = None,
                            rank_impl: str = "auto", mesh=None,
                            n_eval_args: int = 0):
    """The ``vmap``-over-seeds twin of :func:`make_jit_runner`.

    Returns ``run(keys, X0s, n_gen, *eval_args)`` over arrays with a
    leading restart axis — one compilation covers every generation budget
    at a given (n_restarts, pop_size, n_var) shape, and all restarts
    advance in lockstep inside a single XLA program.  ``n_eval_args``
    declares how many trailing runtime arguments ``eval_fn`` takes; they
    are broadcast (not mapped) across restarts.
    """
    run = _make_run(eval_fn, lower, upper, pop_size,
                    _resolve_rank_block(rank_block, pop_size), rank_impl,
                    mesh)
    axes = (0, 0, None) + (None,) * n_eval_args
    return jax.jit(jax.vmap(run, in_axes=axes), donate_argnums=(1,))


def _init_population(rng: np.random.Generator, pop_size: int, n_var: int,
                     lower: int, upper: int,
                     candidates: Optional[Sequence[Sequence[int]]]
                     ) -> np.ndarray:
    """Host-side population init — matches the NumPy
    :func:`repro.core.nsga2.nsga2` draw-for-draw."""
    X0 = rng.integers(lower, upper + 1, size=(pop_size, n_var))
    if candidates is not None and len(candidates):
        cand = np.asarray(list(candidates), dtype=int)
        k = min(len(cand), pop_size // 2)
        X0[:k] = cand[rng.permutation(len(cand))[:k]]
    return X0


def warm_population(rng: np.random.Generator, pop_size: int, n_var: int,
                    lower: int, upper: int,
                    warm: Optional[np.ndarray]) -> np.ndarray:
    """Host-side warm-started population: previous-front rows verbatim,
    then jitter-mutated copies, then a random tail.

    Layout (all counts deterministic given ``pop_size`` and ``len(warm)``):

    * up to ``pop_size // 2`` rows are ``warm`` rows copied verbatim — the
      elites the re-search refines;
    * up to ``pop_size // 4`` rows are elites plus a small integer jitter
      (uniform in [-2, 2] per gene, clipped to bounds) — local exploration
      around the previous optimum, where a drifted system's new optimum
      usually lives;
    * the remainder is uniform random in [lower, upper] — global escape
      hatch so a warm start can never trap the search.

    An empty (or ``None``) ``warm`` degenerates to the cold uniform init.
    """
    if warm is None:
        warm = np.empty((0, n_var), dtype=int)
    warm = np.asarray(warm, dtype=int).reshape(-1, n_var)
    if len(warm) == 0:
        return rng.integers(lower, upper + 1, size=(pop_size, n_var))
    n_elite = min(len(warm), max(pop_size // 2, 1))
    elite = np.clip(warm[:n_elite], lower, upper)
    n_jit = min(pop_size - n_elite, pop_size // 4)
    base = elite[rng.integers(0, n_elite, size=n_jit)]
    jittered = np.clip(base + rng.integers(-2, 3, size=base.shape),
                       lower, upper)
    n_rand = pop_size - n_elite - n_jit
    rand = rng.integers(lower, upper + 1, size=(n_rand, n_var))
    return np.concatenate([elite, jittered, rand])[:pop_size]


def jit_nsga2(eval_fn: EvalFn, n_var: int, lower: int, upper: int,
              pop_size: int, n_gen: int, seed: int = 0,
              candidates: Optional[Sequence[Sequence[int]]] = None,
              runner=None, X0: Optional[np.ndarray] = None,
              eval_args: Tuple = ()
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Run the compiled NSGA-II loop; returns host (X, F, CV) arrays.

    Population init (including ``candidates`` seeding) matches the NumPy
    :func:`repro.core.nsga2.nsga2` exactly and stays host-side; everything
    after the first device transfer is one XLA program.  Pass a prebuilt
    ``runner`` (from :func:`make_jit_runner`) to reuse a compilation, an
    explicit ``X0`` (pop_size, n_var) to override the uniform init (warm
    starts — see :func:`warm_population`), and ``eval_args`` to forward
    runtime table values to ``eval_fn``.
    """
    if X0 is None:
        X0 = _init_population(np.random.default_rng(seed), pop_size, n_var,
                              lower, upper, candidates)
    if runner is None:
        runner = make_jit_runner(eval_fn, n_var, lower, upper, pop_size)
    X, F, CV = runner(jax.random.PRNGKey(seed),
                      jnp.asarray(X0, dtype=jnp.int32), n_gen, *eval_args)
    return (np.asarray(X, dtype=np.int64), np.asarray(F, dtype=np.float64),
            np.asarray(CV, dtype=np.float64))


def jit_nsga2_restarts(eval_fn: EvalFn, n_var: int, lower: int, upper: int,
                       pop_size: int, n_gen: int, n_restarts: int,
                       seed: int = 0,
                       candidates: Optional[Sequence[Sequence[int]]] = None,
                       runner=None, X0s: Optional[np.ndarray] = None,
                       eval_args: Tuple = ()
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Multi-restart search: ``n_restarts`` independently seeded runs as one
    vmapped XLA program, compiled once.

    Restart ``i`` reproduces ``jit_nsga2(..., seed=seed + i)`` bit-for-bit
    (same host init stream, same PRNG key), so the merged output's
    non-dominated front equals the union of the per-seed sequential fronts
    after one final non-dominated filter.  Returns host (X, F, CV) with the
    restart axis flattened to ``n_restarts * pop_size`` rows.  ``X0s``
    overrides the per-restart init (shape (n_restarts, pop_size, n_var));
    ``eval_args`` are broadcast to every restart (the runner must have been
    built with a matching ``n_eval_args``).
    """
    if X0s is None:
        X0s = np.stack([
            _init_population(np.random.default_rng(seed + i), pop_size,
                             n_var, lower, upper, candidates)
            for i in range(n_restarts)])
    keys = jnp.stack([jax.random.PRNGKey(seed + i)
                      for i in range(n_restarts)])
    if runner is None:
        runner = make_jit_restart_runner(eval_fn, n_var, lower, upper,
                                         pop_size,
                                         n_eval_args=len(eval_args))
    X, F, CV = runner(keys, jnp.asarray(X0s, dtype=jnp.int32), n_gen,
                      *eval_args)
    flat = n_restarts * pop_size
    return (np.asarray(X, dtype=np.int64).reshape(flat, n_var),
            np.asarray(F, dtype=np.float64).reshape(flat, -1),
            np.asarray(CV, dtype=np.float64).reshape(flat))


def pareto_indices_blocked(X: np.ndarray, F: np.ndarray, CV: np.ndarray,
                           block: int = 2048,
                           impl: str = "auto") -> np.ndarray:
    """Memory-bounded twin of :func:`repro.core.nsga2.pareto_indices`: the
    first-front mask comes from the tiled dominator-count primitive
    (O(n · block) peak) instead of the dense host-side sort, then the same
    feasible-subset / unique-decision-vector selection applies."""
    from repro.kernels import ops
    counts = np.asarray(ops.domination_counts(
        jnp.asarray(F, jnp.float32), jnp.asarray(CV, jnp.float32),
        block=block, impl=impl))
    first = np.flatnonzero(counts == 0)
    if not len(first):                    # numerical safety, as in the dense
        first = np.arange(len(F))
    feas = first[CV[first] <= 0]
    pareto = feas if len(feas) else first
    _, uniq = np.unique(X[pareto], axis=0, return_index=True)
    return pareto[np.sort(uniq)]
