"""Link models — latency and energy for shipping a cut tensor between
platforms (§IV, CNNParted-style Gigabit Ethernet model) plus TPU-era links
(PCIe, ICI, inter-pod DCI) for the multi-pod mapping.

The CNNParted GigE model charges a constant per-packet overhead on top of
wire bytes and a per-byte transceiver energy; we reproduce that shape:

  d_link(bytes)  = t_setup + ceil(bytes / payload) * (payload + header) * 8 / rate
  e_link(bytes)  = (p_tx + p_rx) * d_link + e_per_byte * bytes
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class LinkModel:
    name: str
    rate_bps: float                 # raw line rate
    t_setup_s: float = 0.0          # per-transfer setup latency
    payload_bytes: int = 1460       # MTU payload
    header_bytes: int = 58          # Ethernet+IP+TCP header + IFG equivalent
    p_tx_w: float = 0.0             # active transmit power
    p_rx_w: float = 0.0             # active receive power
    e_per_byte_j: float = 0.0       # transceiver energy per byte

    def latency_s(self, nbytes: int) -> float:
        """Transfer wall seconds for ``nbytes``: setup + packetized wire
        time including per-packet headers (paper Eq. for t_link)."""
        if nbytes <= 0:
            return 0.0
        packets = math.ceil(nbytes / self.payload_bytes)
        wire_bits = (nbytes + packets * self.header_bytes) * 8
        return self.t_setup_s + wire_bits / self.rate_bps

    def energy_j(self, nbytes: int) -> float:
        """Transfer energy: TX+RX power over the wall time plus the
        per-byte transceiver cost."""
        if nbytes <= 0:
            return 0.0
        d = self.latency_s(nbytes)
        return (self.p_tx_w + self.p_rx_w) * d + self.e_per_byte_j * nbytes

    def latency_s_vec(self, nbytes: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`latency_s` over an array of transfer sizes."""
        nb = np.asarray(nbytes, dtype=np.float64)
        packets = np.ceil(nb / self.payload_bytes)
        wire_bits = (nb + packets * self.header_bytes) * 8
        return np.where(nb > 0, self.t_setup_s + wire_bits / self.rate_bps,
                        0.0)

    def energy_j_vec(self, nbytes: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`energy_j` over an array of transfer sizes."""
        nb = np.asarray(nbytes, dtype=np.float64)
        d = self.latency_s_vec(nb)
        return np.where(nb > 0,
                        (self.p_tx_w + self.p_rx_w) * d
                        + self.e_per_byte_j * nb, 0.0)

    def effective_bw(self, nbytes: int) -> float:
        """bytes/s actually achieved for a transfer of this size."""
        d = self.latency_s(nbytes)
        return nbytes / d if d > 0 else float("inf")


# -- canonical links ---------------------------------------------------------

def gigabit_ethernet() -> LinkModel:
    """CNNParted-style GigE: 1 Gbit/s, TCP framing, ~100 µs setup,
    ~1.2 W tx / 1.0 W rx NIC power, 5 nJ/byte PHY energy."""
    return LinkModel("gige", rate_bps=1e9, t_setup_s=100e-6,
                     payload_bytes=1460, header_bytes=58,
                     p_tx_w=1.2, p_rx_w=1.0, e_per_byte_j=5e-9)


def pcie_gen4_x4() -> LinkModel:
    return LinkModel("pcie4x4", rate_bps=64e9, t_setup_s=2e-6,
                     payload_bytes=4096, header_bytes=24,
                     p_tx_w=2.0, p_rx_w=2.0, e_per_byte_j=1e-9)


def tpu_ici() -> LinkModel:
    """Single v5e ICI link ~50 GB/s, negligible setup, ~1 pJ/bit."""
    return LinkModel("ici", rate_bps=50e9 * 8, t_setup_s=1e-6,
                     payload_bytes=1 << 20, header_bytes=0,
                     e_per_byte_j=8e-12)


def inter_pod_dci() -> LinkModel:
    """Inter-pod data-center interconnect: ~6.25 GB/s effective per pod pair
    (conservative), higher setup cost than ICI."""
    return LinkModel("dci", rate_bps=6.25e9 * 8, t_setup_s=10e-6,
                     payload_bytes=1 << 20, header_bytes=0,
                     e_per_byte_j=30e-12)


def embedded_ethernet_10() -> LinkModel:
    """10BASE-T-class industrial/embedded Ethernet: 10 Mbit/s, ~300 µs
    stack setup, small MTU — the low end of the distributed-embedded links
    the partitioner targets."""
    return LinkModel("eth10", rate_bps=10e6, t_setup_s=300e-6,
                     payload_bytes=1460, header_bytes=58,
                     p_tx_w=0.3, p_rx_w=0.25, e_per_byte_j=20e-9)


def can_fd() -> LinkModel:
    """CAN-FD automotive bus: 5 Mbit/s data phase, 64-byte frames with
    ~8 bytes framing overhead, ~200 µs arbitration/setup per transfer."""
    return LinkModel("canfd", rate_bps=5e6, t_setup_s=200e-6,
                     payload_bytes=64, header_bytes=8,
                     p_tx_w=0.1, p_rx_w=0.1, e_per_byte_j=50e-9)


LINKS = {
    "gige": gigabit_ethernet,
    "pcie4x4": pcie_gen4_x4,
    "ici": tpu_ici,
    "dci": inter_pod_dci,
    "eth10": embedded_ethernet_10,
    "canfd": can_fd,
}


def get_link(name: str) -> LinkModel:
    """Registry lookup: a fresh LinkModel by name ('gige', 'eth10', ...)."""
    try:
        return LINKS[name]()
    except KeyError:
        raise KeyError(f"unknown link {name!r}; available: {sorted(LINKS)}")
