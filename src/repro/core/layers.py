"""Layer descriptors: the op-level vocabulary of the partitioner's graph IR.

The paper ingests ONNX; offline we use a native IR at the same granularity.
A :class:`LayerInfo` records everything the cost models need about one node:
tensor shapes, parameter count, MACs, and the feature-map sizes of
Definition 3.  Shapes are static (inference partitioning is a compile-time
decision in the paper, too).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

# Op types understood by the cost models.  COMPUTE ops get a Timeloop-lite
# mapping; CHEAP ops are modeled as bandwidth-bound elementwise traffic.
CONV = "Conv"
GEMM = "Gemm"  # fully-connected / matmul
DWCONV = "DepthwiseConv"
POOL = "Pool"
GLOBALPOOL = "GlobalPool"
RELU = "Relu"
ADD = "Add"
MUL = "Mul"
CONCAT = "Concat"
FLATTEN = "Flatten"
SOFTMAX = "Softmax"
BN = "BatchNorm"
LN = "LayerNorm"
EMBED = "Embedding"
ATTENTION = "Attention"       # fused decoder-attention block node (LLM graphs)
SSM = "SSM"                   # fused Mamba2 mixer node
MOE = "MoE"                   # fused MoE FFN node
MLP = "Mlp"                   # fused transformer FFN node
IDENTITY = "Identity"

MACCY_OPS = frozenset({CONV, GEMM, DWCONV, ATTENTION, SSM, MOE, MLP, EMBED})


@dataclasses.dataclass(frozen=True)
class LayerInfo:
    """Static description of one graph node.

    Attributes:
      name: unique node name, e.g. ``Conv_45`` (paper naming convention).
      op: one of the op-type constants above.
      in_shape: primary input feature-map shape (no batch dim).
      out_shape: output feature-map shape (no batch dim).
      params: number of learnable scalars held by the node.
      macs: multiply-accumulates for one inference (batch=1).
      attrs: op-specific attributes (kernel size, stride, heads, ...).
    """

    name: str
    op: str
    in_shape: Tuple[int, ...]
    out_shape: Tuple[int, ...]
    params: int = 0
    macs: int = 0
    attrs: Dict[str, object] = dataclasses.field(default_factory=dict)

    # -- Definition 3 ingredients ------------------------------------------
    @property
    def fmap_in(self) -> int:
        """f_{j,in}: number of elements of the input feature map."""
        return int(math.prod(self.in_shape)) if self.in_shape else 0

    @property
    def fmap_out(self) -> int:
        """f_{j,out}: number of elements of the output feature map."""
        return int(math.prod(self.out_shape)) if self.out_shape else 0

    @property
    def activation_footprint(self) -> int:
        """a_j = f_{j,in} + f_{j,out} (Definition 3)."""
        return self.fmap_in + self.fmap_out

    @property
    def flops(self) -> int:
        """2 x MACs (multiply + accumulate)."""
        return 2 * self.macs

    def __repr__(self) -> str:  # compact for exploration logs
        return f"LayerInfo({self.name}, {self.op}, in={self.in_shape}, out={self.out_shape}, P={self.params}, MACs={self.macs})"


# ---------------------------------------------------------------------------
# Constructors that compute params/MACs from op hyper-parameters. These are
# used both by models/*.to_graph() and by unit tests as ground truth.
# ---------------------------------------------------------------------------

def conv_layer(name: str, cin: int, cout: int, hw_in: Tuple[int, int],
               kernel: int, stride: int = 1, padding: Optional[int] = None,
               groups: int = 1, bias: bool = True) -> LayerInfo:
    h, w = hw_in
    if padding is None:  # 'same'-style default
        padding = kernel // 2
    ho = (h + 2 * padding - kernel) // stride + 1
    wo = (w + 2 * padding - kernel) // stride + 1
    params = cout * (cin // groups) * kernel * kernel + (cout if bias else 0)
    macs = ho * wo * cout * (cin // groups) * kernel * kernel
    op = DWCONV if groups == cin and cin == cout and groups > 1 else CONV
    return LayerInfo(name, op, (cin, h, w), (cout, ho, wo), params, macs,
                     attrs={"kernel": kernel, "stride": stride,
                            "padding": padding, "groups": groups})


def gemm_layer(name: str, cin: int, cout: int, bias: bool = True) -> LayerInfo:
    params = cin * cout + (cout if bias else 0)
    return LayerInfo(name, GEMM, (cin,), (cout,), params, cin * cout)


def pool_layer(name: str, c: int, hw_in: Tuple[int, int], kernel: int,
               stride: Optional[int] = None, padding: int = 0,
               global_pool: bool = False) -> LayerInfo:
    h, w = hw_in
    if global_pool:
        return LayerInfo(name, GLOBALPOOL, (c, h, w), (c, 1, 1))
    stride = stride or kernel
    ho = (h + 2 * padding - kernel) // stride + 1
    wo = (w + 2 * padding - kernel) // stride + 1
    return LayerInfo(name, POOL, (c, h, w), (c, ho, wo),
                     attrs={"kernel": kernel, "stride": stride,
                            "padding": padding})


def elementwise_layer(name: str, op: str, shape: Tuple[int, ...]) -> LayerInfo:
    return LayerInfo(name, op, shape, shape)


def bn_layer(name: str, shape: Tuple[int, ...]) -> LayerInfo:
    c = shape[0]
    return LayerInfo(name, BN, shape, shape, params=4 * c)


def concat_layer(name: str, in_shapes, axis: int = 0) -> LayerInfo:
    out = list(in_shapes[0])
    out[axis] = sum(s[axis] for s in in_shapes)
    total_in = sum(int(math.prod(s)) for s in in_shapes)
    # in_shape is recorded as flat element count on axis-0 for Def. 3 purposes
    return LayerInfo(name, CONCAT, (total_in,), tuple(out),
                     attrs={"axis": axis, "n_inputs": len(in_shapes)})


def flatten_layer(name: str, in_shape: Tuple[int, ...]) -> LayerInfo:
    n = int(math.prod(in_shape))
    return LayerInfo(name, FLATTEN, in_shape, (n,))


# -- fused transformer-block nodes (LLM graphs operate per-block) -----------

def embed_layer(name: str, vocab: int, d_model: int, seq: int) -> LayerInfo:
    return LayerInfo(name, EMBED, (seq,), (seq, d_model),
                     params=vocab * d_model, macs=0,
                     attrs={"vocab": vocab, "d_model": d_model})


def attention_layer(name: str, d_model: int, n_heads: int, n_kv: int,
                    seq: int, head_dim: Optional[int] = None,
                    qkv_bias: bool = False, qk_norm: bool = False,
                    window: Optional[int] = None) -> LayerInfo:
    hd = head_dim or d_model // n_heads
    q_p = d_model * n_heads * hd
    kv_p = 2 * d_model * n_kv * hd
    o_p = n_heads * hd * d_model
    params = q_p + kv_p + o_p + (2 * d_model if qk_norm else 0)
    params += (n_heads * hd + 2 * n_kv * hd) if qkv_bias else 0
    ctx = min(seq, window) if window else seq
    proj_macs = seq * (q_p + kv_p + o_p)
    attn_macs = seq * ctx * n_heads * hd  # qk^T + av, triangular ~ /2 *2 = 1
    return LayerInfo(name, ATTENTION, (seq, d_model), (seq, d_model),
                     params=params, macs=proj_macs + attn_macs,
                     attrs={"n_heads": n_heads, "n_kv": n_kv, "head_dim": hd,
                            "window": window, "qk_norm": qk_norm})


def mlp_layer(name: str, d_model: int, d_ff: int, seq: int,
              gated: bool = True) -> LayerInfo:
    n_mats = 3 if gated else 2
    params = n_mats * d_model * d_ff
    return LayerInfo(name, MLP, (seq, d_model), (seq, d_model),
                     params=params, macs=seq * params,
                     attrs={"d_ff": d_ff, "gated": gated})


def moe_layer(name: str, d_model: int, d_ff: int, seq: int, n_experts: int,
              top_k: int, n_shared: int = 0, gated: bool = True) -> LayerInfo:
    n_mats = 3 if gated else 2
    per_expert = n_mats * d_model * d_ff
    params = (n_experts + n_shared) * per_expert + d_model * n_experts
    active = (top_k + n_shared) * per_expert
    return LayerInfo(name, MOE, (seq, d_model), (seq, d_model),
                     params=params, macs=seq * (active + d_model * n_experts),
                     attrs={"n_experts": n_experts, "top_k": top_k,
                            "n_shared": n_shared, "d_ff": d_ff,
                            "active_params": active})


def ssm_layer(name: str, d_model: int, d_state: int, seq: int,
              expand: int = 2, conv_kernel: int = 4,
              headdim: int = 64) -> LayerInfo:
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    # in_proj produces z, x, B, C, dt ; out_proj back to d_model
    proj_in = d_model * (2 * d_inner + 2 * d_state + n_heads)
    proj_out = d_inner * d_model
    conv_p = conv_kernel * (d_inner + 2 * d_state)
    params = proj_in + proj_out + conv_p + n_heads * 2 + d_inner  # A,dt_bias,norm
    scan_macs = seq * d_inner * d_state * 2  # state update + output
    params_macs = seq * (proj_in + proj_out)
    return LayerInfo(name, SSM, (seq, d_model), (seq, d_model),
                     params=params, macs=scan_macs + params_macs,
                     attrs={"d_state": d_state, "d_inner": d_inner,
                            "n_heads": n_heads, "headdim": headdim})


def lm_head_layer(name: str, d_model: int, vocab: int, seq: int,
                  tied: bool = False) -> LayerInfo:
    return LayerInfo(name, GEMM, (seq, d_model), (seq, vocab),
                     params=0 if tied else d_model * vocab,
                     macs=seq * d_model * vocab, attrs={"tied": tied})


def norm_layer(name: str, shape: Tuple[int, ...], kind: str = LN) -> LayerInfo:
    d = shape[-1]
    return LayerInfo(name, kind, shape, shape, params=d)
