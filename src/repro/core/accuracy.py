"""Accuracy oracles for the exploration (§IV-C).

Two implementations of the ``accuracy_fn(cuts) -> float`` protocol:

* :class:`ProxyAccuracy` — analytic noise model, used when no trained model
  is attached (fast path, and the only option during early filtering).
  Quantizing a layer to ``b`` bits injects noise ~ 2^-b weighted by a
  per-layer sensitivity (default: parameter count share — heavier layers
  hurt more).  This reproduces the paper's qualitative finding that later
  cuts (more layers on the 16-bit platform) give higher top-1.

* :class:`MeasuredAccuracy` — runs real fake-quant inference of a JAX model
  on a validation set for each platform assignment, optionally after QAT
  (see ``repro.quantize``).  Results are cached per cut vector.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence, Tuple

import numpy as np

from repro.core.layers import LayerInfo
from repro.core.partition import SystemConfig


@dataclasses.dataclass
class ProxyAccuracy:
    schedule: Sequence[LayerInfo]
    system: SystemConfig
    base_accuracy: float = 1.0
    noise_scale: float = 4.0      # accuracy points lost per unit noise

    def __post_init__(self):
        total = sum(max(l.params, 1) for l in self.schedule) or 1
        self._weight = [max(l.params, 1) / total for l in self.schedule]
        self._weight_prefix = np.concatenate([[0.0], np.cumsum(self._weight)])

    @staticmethod
    def _noise(bits: int) -> float:
        return 2.0 ** (-bits + 4)   # 8b -> 1/16, 16b -> ~6e-5

    def __call__(self, cuts: Sequence[int]) -> float:
        bounds = [-1] + [max(int(c), -1) for c in cuts] + [len(self.schedule) - 1]
        loss = 0.0
        for k, plat in enumerate(self.system.platforms):
            n = self._noise(plat.quant.bits)
            for i in range(bounds[k] + 1, bounds[k + 1] + 1):
                loss += self._weight[i] * n
        return max(0.0, self.base_accuracy - self.noise_scale * loss)

    def proxy_arrays(self):
        """Arrays for the jittable evaluator fast-path: the per-layer weight
        prefix, per-platform noise, and the (base, scale) affine map.  Any
        accuracy oracle exposing this protocol can run inside
        ``JitNSGA2Search``; measured oracles cannot and fall back to the
        NumPy strategy."""
        noise = np.array([self._noise(p.quant.bits)
                          for p in self.system.platforms])
        return self._weight_prefix, noise, self.base_accuracy, self.noise_scale

    def evaluate_batch(self, cuts: np.ndarray) -> np.ndarray:
        """Vectorized proxy accuracy for a whole (N, n_cuts) matrix.

        Same model as ``__call__`` but with the per-segment weight sums read
        off a prefix-sum table — one gather per platform instead of a Python
        loop over layers per candidate.
        """
        C = np.maximum(np.asarray(cuts, dtype=np.int64), -1)
        n = C.shape[0]
        tail = np.full((n, 1), len(self.schedule) - 1, dtype=np.int64)
        bounds = np.concatenate(
            [np.full((n, 1), -1, dtype=np.int64), C, tail], axis=1)
        wpre = self._weight_prefix
        loss = np.zeros(n)
        for k, plat in enumerate(self.system.platforms):
            loss += self._noise(plat.quant.bits) * (
                wpre[bounds[:, k + 1] + 1] - wpre[bounds[:, k] + 1])
        return np.maximum(0.0, self.base_accuracy - self.noise_scale * loss)


@dataclasses.dataclass
class MeasuredAccuracy:
    """Wraps an expensive measured evaluation with caching.

    ``measure(cuts)`` should run calibrated fake-quant inference (and QAT if
    enabled) for the platform assignment implied by ``cuts`` and return
    top-1 accuracy in [0, 1].
    """
    measure: Callable[[Tuple[int, ...]], float]
    _cache: Dict[Tuple[int, ...], float] = dataclasses.field(default_factory=dict)

    def __call__(self, cuts: Sequence[int]) -> float:
        key = tuple(int(c) for c in cuts)
        if key not in self._cache:
            self._cache[key] = float(self.measure(key))
        return self._cache[key]

    def evaluate_batch(self, cuts: np.ndarray) -> np.ndarray:
        """Batch protocol shared with :class:`ProxyAccuracy`; measurements
        are inherently per-assignment, so this is a cached scalar loop."""
        return np.array([self(row) for row in np.asarray(cuts)])


# -- measured-oracle registry (declarative path) ------------------------------
#
# A spec is pure data, so ``accuracy: {kind: "measured", measure: <name>}``
# references a factory registered here.  A factory is called as
# ``factory(graph=..., schedule=..., system=..., **options)`` and returns the
# ``measure(cuts) -> float`` callable that MeasuredAccuracy wraps (so every
# declarative measured oracle gets per-cut caching for free).

ACCURACY_MEASURES: Dict[str, Callable] = {}


def register_accuracy_measure(name: str, factory: Callable,
                              override: bool = False) -> None:
    """Register a measured-accuracy factory under ``name``.

    Name collisions raise unless ``override=True`` — silently re-registering
    would reroute every spec that selects the name.
    """
    if name in ACCURACY_MEASURES and not override:
        raise ValueError(
            f"accuracy measure {name!r} is already registered; "
            f"pass override=True to replace it")
    ACCURACY_MEASURES[name] = factory


def get_accuracy_measure(name: str) -> Callable:
    try:
        return ACCURACY_MEASURES[name]
    except KeyError:
        raise ValueError(
            f"unknown accuracy measure {name!r}; registered: "
            f"{sorted(ACCURACY_MEASURES)} "
            f"(see repro.core.accuracy.register_accuracy_measure)")


def _cnn_fakequant_measure(graph=None, schedule=None, system=None, *,
                           name: str, steps: int = 200, eval_size: int = 256,
                           **build_opts):
    """Built-in measured oracle: trains a CNN-zoo model on the synthetic
    task and scores real partitioned fake-quant inference per cut vector
    (``repro.quantize.evaluate.cnn_measured_accuracy``), weights at each
    platform's bit width.  ``build_opts`` must mirror the spec's
    ``ModelRef`` options (e.g. ``in_hw``/``w``/``n_classes``) so the trained
    model's graph matches the explorer schedule the cut indices refer to.
    Heavy — meant for §IV-C-style studies, not the search inner loop
    (MeasuredAccuracy caches per cut vector on top)."""
    import jax
    import jax.numpy as jnp

    from repro.data.synthetic import SyntheticImages
    from repro.models.cnn.zoo import build_cnn
    from repro.optim.optimizers import adamw
    from repro.optim.schedules import warmup_cosine
    from repro.quantize.evaluate import cnn_measured_accuracy
    from repro.training.train_lib import make_classifier_train_step

    m = build_cnn(name, **build_opts)
    p, s = m.init(jax.random.PRNGKey(0))
    ds = SyntheticImages(noise=0.2)
    opt = adamw(warmup_cosine(2e-3, max(steps // 10, 1), steps))
    os_ = opt.init(p)
    step = jax.jit(make_classifier_train_step(m, opt))
    for i in range(steps):
        x, y = ds.batch(64, i)
        p, os_, s, _ = step(p, os_, s, jnp.asarray(x), jnp.asarray(y))
    vx, vy = ds.eval_set(eval_size)
    sched = schedule if schedule is not None else m.to_graph().topo_sort()
    specs = [plat.quant for plat in system.platforms]
    return cnn_measured_accuracy(m, p, s, sched, vx, vy, specs)


def _table_measure(graph=None, schedule=None, system=None, *,
                   table: Dict[str, float], default: float = 0.0):
    """Measured oracle backed by an explicit ``{"c0,c1": acc}`` table —
    pre-recorded measurements (e.g. a lab sweep) replayed declaratively."""
    lut = {tuple(int(t) for t in k.split(",")): float(v)
           for k, v in table.items()}

    def measure(cuts):
        return lut.get(tuple(int(c) for c in cuts), float(default))

    return measure


register_accuracy_measure("cnn_fakequant", _cnn_fakequant_measure)
register_accuracy_measure("table", _table_measure)
