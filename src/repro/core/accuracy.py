"""Accuracy oracles for the exploration (§IV-C).

Two implementations of the ``accuracy_fn(cuts) -> float`` protocol:

* :class:`ProxyAccuracy` — analytic noise model, used when no trained model
  is attached (fast path, and the only option during early filtering).
  Quantizing a layer to ``b`` bits injects noise ~ 2^-b weighted by a
  per-layer sensitivity (default: parameter count share — heavier layers
  hurt more).  This reproduces the paper's qualitative finding that later
  cuts (more layers on the 16-bit platform) give higher top-1.

* :class:`MeasuredAccuracy` — runs real fake-quant inference of a JAX model
  on a validation set for each platform assignment, optionally after QAT
  (see ``repro.quantize``).  Results are cached per cut vector.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence, Tuple

import numpy as np

from repro.core.layers import LayerInfo
from repro.core.partition import SystemConfig


@dataclasses.dataclass
class ProxyAccuracy:
    schedule: Sequence[LayerInfo]
    system: SystemConfig
    base_accuracy: float = 1.0
    noise_scale: float = 4.0      # accuracy points lost per unit noise

    def __post_init__(self):
        total = sum(max(l.params, 1) for l in self.schedule) or 1
        self._weight = [max(l.params, 1) / total for l in self.schedule]
        self._weight_prefix = np.concatenate([[0.0], np.cumsum(self._weight)])

    @staticmethod
    def _noise(bits: int) -> float:
        return 2.0 ** (-bits + 4)   # 8b -> 1/16, 16b -> ~6e-5

    def __call__(self, cuts: Sequence[int]) -> float:
        bounds = [-1] + [max(int(c), -1) for c in cuts] + [len(self.schedule) - 1]
        loss = 0.0
        for k, plat in enumerate(self.system.platforms):
            n = self._noise(plat.quant.bits)
            for i in range(bounds[k] + 1, bounds[k + 1] + 1):
                loss += self._weight[i] * n
        return max(0.0, self.base_accuracy - self.noise_scale * loss)

    def proxy_arrays(self):
        """Arrays for the jittable evaluator fast-path: the per-layer weight
        prefix, per-platform noise, and the (base, scale) affine map.  Any
        accuracy oracle exposing this protocol can run inside
        ``JitNSGA2Search``; measured oracles cannot and fall back to the
        NumPy strategy."""
        noise = np.array([self._noise(p.quant.bits)
                          for p in self.system.platforms])
        return self._weight_prefix, noise, self.base_accuracy, self.noise_scale

    def evaluate_batch(self, cuts: np.ndarray) -> np.ndarray:
        """Vectorized proxy accuracy for a whole (N, n_cuts) matrix.

        Same model as ``__call__`` but with the per-segment weight sums read
        off a prefix-sum table — one gather per platform instead of a Python
        loop over layers per candidate.
        """
        C = np.maximum(np.asarray(cuts, dtype=np.int64), -1)
        n = C.shape[0]
        tail = np.full((n, 1), len(self.schedule) - 1, dtype=np.int64)
        bounds = np.concatenate(
            [np.full((n, 1), -1, dtype=np.int64), C, tail], axis=1)
        wpre = self._weight_prefix
        loss = np.zeros(n)
        for k, plat in enumerate(self.system.platforms):
            loss += self._noise(plat.quant.bits) * (
                wpre[bounds[:, k + 1] + 1] - wpre[bounds[:, k] + 1])
        return np.maximum(0.0, self.base_accuracy - self.noise_scale * loss)


@dataclasses.dataclass
class MeasuredAccuracy:
    """Wraps an expensive measured evaluation with caching.

    ``measure(cuts)`` should run calibrated fake-quant inference (and QAT if
    enabled) for the platform assignment implied by ``cuts`` and return
    top-1 accuracy in [0, 1].
    """
    measure: Callable[[Tuple[int, ...]], float]
    _cache: Dict[Tuple[int, ...], float] = dataclasses.field(default_factory=dict)

    def __call__(self, cuts: Sequence[int]) -> float:
        key = tuple(int(c) for c in cuts)
        if key not in self._cache:
            self._cache[key] = float(self.measure(key))
        return self._cache[key]

    def evaluate_batch(self, cuts: np.ndarray) -> np.ndarray:
        """Batch protocol shared with :class:`ProxyAccuracy`; measurements
        are inherently per-assignment, so this is a cached scalar loop."""
        return np.array([self(row) for row in np.asarray(cuts)])
