"""NSGA-II for the partition-point search (§IV, [14] pymoo replacement).

Decision variables are integer vectors (sorted cut positions). Implements:
fast non-dominated sorting, crowding distance, constrained-domination binary
tournament, uniform + blend integer crossover, reset mutation, elitism.

All objectives are minimized.  Constraints are "violation amounts":
``g_i(x) <= 0`` feasible; total violation = Σ max(0, g_i).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np


# -- non-dominated sorting ----------------------------------------------------

def dominates(f: np.ndarray, g: np.ndarray) -> bool:
    """True iff f Pareto-dominates g (minimization)."""
    return bool(np.all(f <= g) and np.any(f < g))


def constrained_dominates(f: np.ndarray, cv_f: float,
                          g: np.ndarray, cv_g: float) -> bool:
    """Deb's constraint-domination."""
    if cv_f <= 0 < cv_g:
        return True
    if cv_g <= 0 < cv_f:
        return False
    if cv_f > 0 and cv_g > 0:
        return cv_f < cv_g
    return dominates(f, g)


def _constrained_dominates_vec(Fa: np.ndarray, cva: np.ndarray,
                               Fb: np.ndarray, cvb: np.ndarray) -> np.ndarray:
    """Row-wise Deb constraint-domination: does a[i] dominate b[i]?"""
    feas_a, feas_b = cva <= 0, cvb <= 0
    dom = np.all(Fa <= Fb, axis=-1) & np.any(Fa < Fb, axis=-1)
    return np.where(feas_a & ~feas_b, True,
                    np.where(feas_b & ~feas_a, False,
                             np.where(~feas_a & ~feas_b, cva < cvb, dom)))


def _domination_matrix(F: np.ndarray, CV: np.ndarray) -> np.ndarray:
    """D[p, q] = p constraint-dominates q, for the whole population."""
    D = _constrained_dominates_vec(F[:, None, :], CV[:, None],
                                   F[None, :, :], CV[None, :])
    np.fill_diagonal(D, False)
    return D


def dominates_matrix(Fa: np.ndarray, CVa: np.ndarray,
                     Fb: np.ndarray, CVb: np.ndarray) -> np.ndarray:
    """(len(a), len(b)) matrix of constrained domination a[i] ≻ b[j]."""
    return _constrained_dominates_vec(
        np.asarray(Fa, dtype=float)[:, None, :],
        np.asarray(CVa, dtype=float)[:, None],
        np.asarray(Fb, dtype=float)[None, :, :],
        np.asarray(CVb, dtype=float)[None, :])


def non_dominated_mask(F: np.ndarray,
                       CV: Optional[np.ndarray] = None) -> np.ndarray:
    """Boolean mask of the first (constrained) non-dominated front only.

    One broadcast domination matrix, no front peeling — the cheap primitive
    for streaming archives that never need ranks beyond the first front.
    """
    F = np.asarray(F, dtype=float)
    n = len(F)
    if n == 0:
        return np.zeros(0, dtype=bool)
    if CV is None:
        CV = np.zeros(n)
    D = _domination_matrix(F, np.asarray(CV, dtype=float))
    return D.sum(axis=0) == 0


def fast_non_dominated_sort(F: np.ndarray,
                            CV: Optional[np.ndarray] = None) -> List[np.ndarray]:
    """Return fronts (lists of indices), best front first.

    Builds the full pairwise domination matrix with one broadcast compare
    and peels fronts by domination count — no Python-level pair loop.
    """
    F = np.asarray(F, dtype=float)
    n = len(F)
    if CV is None:
        CV = np.zeros(n)
    D = _domination_matrix(F, np.asarray(CV, dtype=float))
    n_dom = D.sum(axis=0)          # how many dominate each q
    assigned = np.zeros(n, dtype=bool)
    fronts: List[np.ndarray] = []
    while not assigned.all():
        front = np.flatnonzero((n_dom == 0) & ~assigned)
        if not len(front):         # numerical safety: cannot happen for a DAG
            front = np.flatnonzero(~assigned)
        assigned[front] = True
        n_dom = n_dom - D[front].sum(axis=0)
        fronts.append(front)
    return fronts


def crowding_distance(F: np.ndarray) -> np.ndarray:
    """Crowding distance of points in one front."""
    n, m = F.shape
    if n <= 2:
        return np.full(n, np.inf)
    d = np.zeros(n)
    for j in range(m):
        idx = np.argsort(F[:, j], kind="stable")
        fmin, fmax = F[idx[0], j], F[idx[-1], j]
        d[idx[0]] = d[idx[-1]] = np.inf
        if fmax - fmin <= 0:
            continue
        d[idx[1:-1]] += (F[idx[2:], j] - F[idx[:-2], j]) / (fmax - fmin)
    return d


# -- GA machinery -------------------------------------------------------------

@dataclasses.dataclass
class NSGA2Result:
    X: np.ndarray            # population decision vectors
    F: np.ndarray            # objectives
    CV: np.ndarray           # constraint violations
    pareto_idx: np.ndarray   # indices of the final first front (feasible)
    history: List[dict]

    @property
    def pareto_X(self) -> np.ndarray:
        return self.X[self.pareto_idx]

    @property
    def pareto_F(self) -> np.ndarray:
        return self.F[self.pareto_idx]


def _tournament_batch(rng, F, CV, crowd, n: int) -> np.ndarray:
    """n independent binary tournaments, returned as winner indices."""
    a = rng.integers(0, len(F), size=n)
    b = rng.integers(0, len(F), size=n)
    a_dom = _constrained_dominates_vec(F[a], CV[a], F[b], CV[b])
    b_dom = _constrained_dominates_vec(F[b], CV[b], F[a], CV[a])
    pick_a = a_dom | (~b_dom & (crowd[a] >= crowd[b]))
    return np.where(pick_a, a, b)


def _repair_batch(X: np.ndarray, lo: int, hi: int) -> np.ndarray:
    """Clip to bounds, sort, and de-duplicate cut vectors (strictly
    increasing positions) for a whole (N, n_var) population — the scans run
    over the short n_var axis, the work per step is vectorized over N."""
    X = np.clip(np.sort(X, axis=1), lo, hi)
    n_var = X.shape[1]
    for i in range(1, n_var):
        X[:, i] = np.where(X[:, i] <= X[:, i - 1],
                           np.minimum(hi, X[:, i - 1] + 1), X[:, i])
    for i in range(n_var - 2, -1, -1):   # if saturated at hi, push left
        X[:, i] = np.where(X[:, i] >= X[:, i + 1],
                           np.maximum(lo, X[:, i + 1] - 1), X[:, i])
    return X


def _repair(x: np.ndarray, lo: int, hi: int) -> np.ndarray:
    """Single-vector convenience wrapper around :func:`_repair_batch`."""
    return _repair_batch(np.asarray(x)[None, :], lo, hi)[0]


def nsga2(evaluate: Callable[[np.ndarray], Tuple[np.ndarray, np.ndarray]],
          n_var: int, lower: int, upper: int,
          pop_size: Optional[int] = None, n_gen: Optional[int] = None,
          seed: int = 0, candidates: Optional[Sequence[Sequence[int]]] = None,
          ) -> NSGA2Result:
    """Run NSGA-II over integer cut vectors in [lower, upper]^n_var.

    ``evaluate`` is *batch-eval-aware*: it always receives the whole
    population as one (pop, n_var) matrix and must return (F, CV) — an
    objectives matrix (pop, n_obj) and a violation vector (pop,).  Pair it
    with ``PartitionEvaluator.evaluate_batch`` so a generation costs one
    vectorized evaluation instead of pop_size Python calls.  ``candidates``
    optionally seeds the population (e.g. the feasible-filtered cut list
    from the explorer).

    The paper sizes population/generations by layer count; we mirror that:
    pop = clip(4·L_range^0.5, 16, 96) rounded to 4, gens = clip(L/2, 10, 60).
    """
    rng = np.random.default_rng(seed)
    span = upper - lower + 1
    if pop_size is None:
        pop_size = int(np.clip(4 * np.sqrt(span * n_var), 16, 96)) // 4 * 4
    if n_gen is None:
        n_gen = int(np.clip(span // 2, 10, 60))

    # init population
    X = rng.integers(lower, upper + 1, size=(pop_size, n_var))
    if candidates is not None and len(candidates):
        cand = np.asarray(list(candidates), dtype=int)
        k = min(len(cand), pop_size // 2)
        X[:k] = cand[rng.permutation(len(cand))[:k]]
    X = _repair_batch(X, lower, upper)
    F, CV = evaluate(X)
    history: List[dict] = []
    nv = max(n_var, 1)

    for gen in range(n_gen):
        fronts = fast_non_dominated_sort(F, CV)
        crowd = np.zeros(len(F))
        for fr in fronts:
            crowd[fr] = crowding_distance(F[fr])
        # offspring: vectorized tournaments, uniform crossover, blend step
        # and reset/local-step mutation for the whole brood at once
        half = (pop_size + 1) // 2
        P1 = X[_tournament_batch(rng, F, CV, crowd, half)]
        P2 = X[_tournament_batch(rng, F, CV, crowd, half)]
        mask = rng.random((half, n_var)) < 0.5
        Xc = np.concatenate([np.where(mask, P1, P2),
                             np.where(mask, P2, P1)])[:pop_size]
        par1 = np.concatenate([P1, P1])[:pop_size]
        par2 = np.concatenate([P2, P2])[:pop_size]
        if n_var > 0:
            # blend step: move a coordinate toward the midpoint sometimes
            blend = rng.random(pop_size) < 0.3
            j = rng.integers(n_var, size=pop_size)
            rows = np.arange(pop_size)
            mid = (par1[rows, j] + par2[rows, j]) // 2
            Xc[rows[blend], j[blend]] = mid[blend]
        # mutation: random reset or +-local step
        r = rng.random((pop_size, n_var))
        reset = r < 0.5 / nv
        step = ~reset & (r < 2.0 / nv)
        Xc = np.where(reset,
                      rng.integers(lower, upper + 1, size=Xc.shape), Xc)
        Xc = np.where(step, Xc + rng.integers(-3, 4, size=Xc.shape), Xc)
        Xc = _repair_batch(Xc, lower, upper)
        Fc, CVc = evaluate(Xc)
        # elitist environmental selection
        Xall = np.concatenate([X, Xc])
        Fall = np.concatenate([F, Fc])
        CVall = np.concatenate([CV, CVc])
        fronts = fast_non_dominated_sort(Fall, CVall)
        keep: List[int] = []
        for fr in fronts:
            if len(keep) + len(fr) <= pop_size:
                keep.extend(fr.tolist())
            else:
                cd = crowding_distance(Fall[fr])
                order = np.argsort(-cd, kind="stable")
                keep.extend(fr[order[: pop_size - len(keep)]].tolist())
                break
        keep_arr = np.asarray(keep)
        X, F, CV = Xall[keep_arr], Fall[keep_arr], CVall[keep_arr]
        history.append({"gen": gen,
                        "best": F.min(axis=0).tolist(),
                        "feasible": int((CV <= 0).sum())})

    return NSGA2Result(X=X, F=F, CV=CV, pareto_idx=pareto_indices(X, F, CV),
                       history=history)


def pareto_indices(X: np.ndarray, F: np.ndarray, CV: np.ndarray) -> np.ndarray:
    """Final-front extraction shared by the NumPy and JIT search paths:
    first constrained front, feasible subset when non-empty, unique decision
    vectors (first occurrence wins, ascending index order)."""
    fronts = fast_non_dominated_sort(F, CV)
    first = fronts[0]
    feas = first[CV[first] <= 0]
    pareto = feas if len(feas) else first
    _, uniq = np.unique(X[pareto], axis=0, return_index=True)
    return pareto[np.sort(uniq)]


_JAX_TWINS = ("constrained_dominates", "domination_matrix",
              "nondominated_rank", "crowding_by_rank", "tournament",
              "repair", "make_offspring", "make_jit_runner",
              "make_jit_restart_runner", "pareto_indices_blocked")
_JAX_DIRECT = ("jit_nsga2", "jit_nsga2_restarts")


def __getattr__(name: str):
    """Lazy access to the JIT-compiled operator twins (``jit_`` prefixed),
    e.g. ``nsga2.jit_nondominated_rank`` → ``nsga2_jax.nondominated_rank``.
    Keeps this module importable without pulling in JAX."""
    if name.startswith("jit_") and name[4:] in _JAX_TWINS:
        import repro.core.nsga2_jax as _jx
        return getattr(_jx, name[4:])
    if name in _JAX_DIRECT:
        import repro.core.nsga2_jax as _jx
        return getattr(_jx, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
