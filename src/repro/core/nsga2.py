"""NSGA-II for the partition-point search (§IV, [14] pymoo replacement).

Decision variables are integer vectors (sorted cut positions). Implements:
fast non-dominated sorting, crowding distance, constrained-domination binary
tournament, uniform + blend integer crossover, reset mutation, elitism.

All objectives are minimized.  Constraints are "violation amounts":
``g_i(x) <= 0`` feasible; total violation = Σ max(0, g_i).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np


# -- non-dominated sorting ----------------------------------------------------

def dominates(f: np.ndarray, g: np.ndarray) -> bool:
    """True iff f Pareto-dominates g (minimization)."""
    return bool(np.all(f <= g) and np.any(f < g))


def constrained_dominates(f: np.ndarray, cv_f: float,
                          g: np.ndarray, cv_g: float) -> bool:
    """Deb's constraint-domination."""
    if cv_f <= 0 < cv_g:
        return True
    if cv_g <= 0 < cv_f:
        return False
    if cv_f > 0 and cv_g > 0:
        return cv_f < cv_g
    return dominates(f, g)


def fast_non_dominated_sort(F: np.ndarray,
                            CV: Optional[np.ndarray] = None) -> List[np.ndarray]:
    """Return fronts (lists of indices), best front first."""
    n = len(F)
    if CV is None:
        CV = np.zeros(n)
    S: List[List[int]] = [[] for _ in range(n)]
    n_dom = np.zeros(n, dtype=int)
    fronts: List[List[int]] = [[]]
    for p in range(n):
        for q in range(n):
            if p == q:
                continue
            if constrained_dominates(F[p], CV[p], F[q], CV[q]):
                S[p].append(q)
            elif constrained_dominates(F[q], CV[q], F[p], CV[p]):
                n_dom[p] += 1
        if n_dom[p] == 0:
            fronts[0].append(p)
    i = 0
    while fronts[i]:
        nxt: List[int] = []
        for p in fronts[i]:
            for q in S[p]:
                n_dom[q] -= 1
                if n_dom[q] == 0:
                    nxt.append(q)
        i += 1
        fronts.append(nxt)
    return [np.asarray(f, dtype=int) for f in fronts if len(f)]


def crowding_distance(F: np.ndarray) -> np.ndarray:
    """Crowding distance of points in one front."""
    n, m = F.shape
    if n <= 2:
        return np.full(n, np.inf)
    d = np.zeros(n)
    for j in range(m):
        idx = np.argsort(F[:, j], kind="stable")
        fmin, fmax = F[idx[0], j], F[idx[-1], j]
        d[idx[0]] = d[idx[-1]] = np.inf
        if fmax - fmin <= 0:
            continue
        d[idx[1:-1]] += (F[idx[2:], j] - F[idx[:-2], j]) / (fmax - fmin)
    return d


# -- GA machinery -------------------------------------------------------------

@dataclasses.dataclass
class NSGA2Result:
    X: np.ndarray            # population decision vectors
    F: np.ndarray            # objectives
    CV: np.ndarray           # constraint violations
    pareto_idx: np.ndarray   # indices of the final first front (feasible)
    history: List[dict]

    @property
    def pareto_X(self) -> np.ndarray:
        return self.X[self.pareto_idx]

    @property
    def pareto_F(self) -> np.ndarray:
        return self.F[self.pareto_idx]


def _tournament(rng, F, CV, crowd) -> int:
    a, b = rng.integers(0, len(F), size=2)
    if constrained_dominates(F[a], CV[a], F[b], CV[b]):
        return int(a)
    if constrained_dominates(F[b], CV[b], F[a], CV[a]):
        return int(b)
    return int(a if crowd[a] >= crowd[b] else b)


def _repair(x: np.ndarray, lo: int, hi: int) -> np.ndarray:
    """Clip to bounds, sort, and de-duplicate cut vectors (strictly
    increasing positions)."""
    x = np.clip(np.sort(x), lo, hi)
    for i in range(1, len(x)):
        if x[i] <= x[i - 1]:
            x[i] = min(hi, x[i - 1] + 1)
    for i in range(len(x) - 2, -1, -1):  # if saturated at hi, push left
        if x[i] >= x[i + 1]:
            x[i] = max(lo, x[i + 1] - 1)
    return x


def nsga2(evaluate: Callable[[np.ndarray], Tuple[np.ndarray, np.ndarray]],
          n_var: int, lower: int, upper: int,
          pop_size: Optional[int] = None, n_gen: Optional[int] = None,
          seed: int = 0, candidates: Optional[Sequence[Sequence[int]]] = None,
          ) -> NSGA2Result:
    """Run NSGA-II over integer cut vectors in [lower, upper]^n_var.

    evaluate(X) -> (F, CV): objectives matrix (pop, n_obj) and violation
    vector (pop,). ``candidates`` optionally seeds the population (e.g. the
    feasible-filtered cut list from the explorer).

    The paper sizes population/generations by layer count; we mirror that:
    pop = clip(4·L_range^0.5, 16, 96) rounded to 4, gens = clip(L/2, 10, 60).
    """
    rng = np.random.default_rng(seed)
    span = upper - lower + 1
    if pop_size is None:
        pop_size = int(np.clip(4 * np.sqrt(span * n_var), 16, 96)) // 4 * 4
    if n_gen is None:
        n_gen = int(np.clip(span // 2, 10, 60))

    # init population
    X = rng.integers(lower, upper + 1, size=(pop_size, n_var))
    if candidates is not None and len(candidates):
        cand = np.asarray(list(candidates), dtype=int)
        k = min(len(cand), pop_size // 2)
        X[:k] = cand[rng.permutation(len(cand))[:k]]
    X = np.stack([_repair(x, lower, upper) for x in X])
    F, CV = evaluate(X)
    history: List[dict] = []

    for gen in range(n_gen):
        fronts = fast_non_dominated_sort(F, CV)
        crowd = np.zeros(len(F))
        for fr in fronts:
            crowd[fr] = crowding_distance(F[fr])
        # offspring
        children = []
        while len(children) < pop_size:
            p1 = X[_tournament(rng, F, CV, crowd)]
            p2 = X[_tournament(rng, F, CV, crowd)]
            mask = rng.random(n_var) < 0.5
            c1 = np.where(mask, p1, p2).copy()
            c2 = np.where(mask, p2, p1).copy()
            for c in (c1, c2):
                # blend step: move a coordinate toward the midpoint sometimes
                if rng.random() < 0.3 and n_var > 0:
                    j = rng.integers(n_var)
                    c[j] = (int(p1[j]) + int(p2[j])) // 2
                # mutation: random reset or +-local step
                for j in range(n_var):
                    r = rng.random()
                    if r < 0.5 / max(n_var, 1):
                        c[j] = rng.integers(lower, upper + 1)
                    elif r < 2.0 / max(n_var, 1):
                        c[j] += rng.integers(-3, 4)
                children.append(_repair(c, lower, upper))
        Xc = np.stack(children[:pop_size])
        Fc, CVc = evaluate(Xc)
        # elitist environmental selection
        Xall = np.concatenate([X, Xc]); Fall = np.concatenate([F, Fc])
        CVall = np.concatenate([CV, CVc])
        fronts = fast_non_dominated_sort(Fall, CVall)
        keep: List[int] = []
        for fr in fronts:
            if len(keep) + len(fr) <= pop_size:
                keep.extend(fr.tolist())
            else:
                cd = crowding_distance(Fall[fr])
                order = np.argsort(-cd, kind="stable")
                keep.extend(fr[order[: pop_size - len(keep)]].tolist())
                break
        keep_arr = np.asarray(keep)
        X, F, CV = Xall[keep_arr], Fall[keep_arr], CVall[keep_arr]
        history.append({"gen": gen,
                        "best": F.min(axis=0).tolist(),
                        "feasible": int((CV <= 0).sum())})

    fronts = fast_non_dominated_sort(F, CV)
    first = fronts[0]
    feas = first[CV[first] <= 0]
    pareto = feas if len(feas) else first
    # unique decision vectors on the front
    _, uniq = np.unique(X[pareto], axis=0, return_index=True)
    return NSGA2Result(X=X, F=F, CV=CV, pareto_idx=pareto[np.sort(uniq)],
                       history=history)
