"""Partition evaluation — Definitions 1–4 over a concrete system.

A *system* is a chain of platforms connected by links (the paper's §V-C
four-platform chain generalizes the two-platform case).  Given a linear
schedule and a sorted cut vector, this module produces every optimization
metric of Table I's last row: latency, bandwidth, energy, memory, accuracy
and throughput.

Cut encoding: platform ``k`` executes ``schedule[cuts[k-1]+1 .. cuts[k]]``
(with ``cuts[-1] := -1`` and ``cuts[n] := L-1`` implied).  A cut may be
``-1`` (empty leading segment) or repeat the previous value (platform
skipped); that is how the explorer discovers that *fewer* partitions can be
optimal (Table II).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import LayerGraph
from repro.core.hwmodel.arch import AcceleratorArch
from repro.core.hwmodel.mapper import LayerCost, layer_cost_table
from repro.core.layers import LayerInfo
from repro.core.link import LinkModel
from repro.core.memory import (MemoryModel, SegmentMemoryTable,
                               segment_memory)
from repro.core.quant import QuantSpec


@dataclasses.dataclass(frozen=True)
class Platform:
    """One compute node in the chain."""
    name: str
    arch: AcceleratorArch
    quant: QuantSpec
    mem_capacity: Optional[int] = None   # defaults to arch.mem_bytes

    @property
    def capacity(self) -> int:
        """Usable memory bytes: explicit override or the arch default."""
        return self.mem_capacity if self.mem_capacity is not None else self.arch.mem_bytes

    @property
    def memory_model(self) -> MemoryModel:
        """Bytes-per-parameter model implied by the quantization bits."""
        return MemoryModel(bytes_per_param=self.quant.bits / 8.0)


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    """A chain: platforms[i] --links[i]--> platforms[i+1]."""
    platforms: Sequence[Platform]
    links: Sequence[LinkModel]

    def __post_init__(self):
        assert len(self.links) == len(self.platforms) - 1

    @property
    def n_cuts(self) -> int:
        """Number of cut positions (= platforms - 1)."""
        return len(self.platforms) - 1


@dataclasses.dataclass(frozen=True)
class Constraints:
    max_link_bytes: Optional[int] = None       # per-cut bandwidth budget
    min_accuracy: Optional[float] = None
    max_latency_s: Optional[float] = None
    max_energy_j: Optional[float] = None
    min_throughput: Optional[float] = None


@dataclasses.dataclass
class PartitionEval:
    cuts: Tuple[int, ...]
    latency_s: float
    energy_j: float
    throughput: float              # inferences / s (Def. 4)
    link_bytes: int                # max bytes over any active link
    memory_bytes: Tuple[int, ...]  # per platform (Def. 3)
    accuracy: float
    stage_latency_s: Tuple[float, ...]
    link_latency_s: Tuple[float, ...]
    violation: float = 0.0

    @property
    def n_partitions(self) -> int:
        """Number of platforms that execute at least one layer."""
        return sum(1 for t in self.stage_latency_s if t > 0)

    def as_objectives(self, keys: Sequence[str]) -> List[float]:
        table = {
            "latency": self.latency_s,
            "energy": self.energy_j,
            "throughput": -self.throughput,       # maximize
            "bandwidth": float(self.link_bytes),
            "memory": float(max(self.memory_bytes)),
            "accuracy": -self.accuracy,           # maximize
        }
        return [table[k] for k in keys]


@dataclasses.dataclass
class BatchEval:
    """Column-oriented result of :meth:`PartitionEvaluator.evaluate_batch`.

    Every field is an array whose leading axis indexes the N candidate cut
    vectors; :meth:`row` materializes a single :class:`PartitionEval` and
    :meth:`as_objectives` hands NSGA-II its (N, n_obj) matrix directly.
    """

    cuts: np.ndarray             # (N, n_cuts) int
    latency_s: np.ndarray        # (N,)
    energy_j: np.ndarray         # (N,)
    throughput: np.ndarray       # (N,)
    link_bytes: np.ndarray       # (N,) int — max over active links
    memory_bytes: np.ndarray     # (N, n_platforms) int
    accuracy: np.ndarray         # (N,)
    stage_latency_s: np.ndarray  # (N, n_platforms)
    link_latency_s: np.ndarray   # (N, n_links)
    violation: np.ndarray        # (N,)

    def __len__(self) -> int:
        return len(self.cuts)

    def as_objectives(self, keys: Sequence[str]) -> np.ndarray:
        table = {
            "latency": self.latency_s,
            "energy": self.energy_j,
            "throughput": -self.throughput,
            "bandwidth": self.link_bytes.astype(float),
            "memory": self.memory_bytes.max(axis=1).astype(float),
            "accuracy": -self.accuracy,
        }
        return np.stack([table[k] for k in keys], axis=1)

    def row(self, i: int) -> PartitionEval:
        return PartitionEval(
            cuts=tuple(int(c) for c in self.cuts[i]),
            latency_s=float(self.latency_s[i]),
            energy_j=float(self.energy_j[i]),
            throughput=float(self.throughput[i]),
            link_bytes=int(self.link_bytes[i]),
            memory_bytes=tuple(int(m) for m in self.memory_bytes[i]),
            accuracy=float(self.accuracy[i]),
            stage_latency_s=tuple(float(t) for t in self.stage_latency_s[i]),
            link_latency_s=tuple(float(t) for t in self.link_latency_s[i]),
            violation=float(self.violation[i]))

    def to_evals(self) -> List[PartitionEval]:
        return [self.row(i) for i in range(len(self))]


class PartitionEvaluator:
    """Evaluates cut vectors against a system; caches per-arch cost tables."""

    def __init__(self, graph: LayerGraph, schedule: Sequence[LayerInfo],
                 system: SystemConfig,
                 accuracy_fn: Optional[Callable[[Sequence[int]], float]] = None,
                 batch: int = 1,
                 shared_groups: Optional[Dict[str, str]] = None,
                 cost_cache: Optional[Dict[str, Tuple[List[LayerCost],
                                                      np.ndarray]]] = None,
                 memtable: Optional[SegmentMemoryTable] = None):
        """``cost_cache`` / ``memtable`` optionally inject precomputed
        per-arch cost tables and the Def.-3 memory table so campaign
        runners can share them across systems; the cache is keyed by arch
        name and is only valid for this exact (schedule, batch) pair —
        callers own that invariant."""
        self.graph = graph
        self.schedule = list(schedule)
        self.system = system
        self.batch = batch
        self.accuracy_fn = accuracy_fn or (lambda cuts: 1.0)
        self.shared_groups = shared_groups
        self._tables: Dict[str, List[LayerCost]] = {}
        self._prefix: Dict[str, np.ndarray] = {}
        self._cut_bytes_cache: Dict[Tuple[int, float], int] = {}
        self._memtable = (memtable if memtable is not None
                          else SegmentMemoryTable(self.schedule, shared_groups))
        self._cut_elems: Optional[np.ndarray] = None  # lazy, O(L·E) to build
        self._jax_tables = None                       # lazy EvalTables export
        cache = cost_cache if cost_cache is not None else {}
        for plat in system.platforms:
            key = plat.arch.name
            if key not in self._tables:
                if key in cache:
                    tab, pre = cache[key]
                else:
                    tab = layer_cost_table(self.schedule, plat.arch, batch)
                    lat = np.array([c.latency_s for c in tab])
                    en = np.array([c.energy_j for c in tab])
                    pre = np.stack([
                        np.concatenate([[0.0], np.cumsum(lat)]),
                        np.concatenate([[0.0], np.cumsum(en)])])
                    cache[key] = (tab, pre)
                self._tables[key] = tab
                self._prefix[key] = pre

    # -- O(1) segment cost via prefix sums -----------------------------------
    def _segment_cost(self, arch_name: str, a: int, b: int) -> Tuple[float, float]:
        """Latency/energy of schedule[a..b] inclusive; zero when a > b."""
        if a > b:
            return 0.0, 0.0
        pre = self._prefix[arch_name]
        return float(pre[0, b + 1] - pre[0, a]), float(pre[1, b + 1] - pre[1, a])

    def _cut_bytes(self, p: int, bpe: float) -> int:
        key = (p, bpe)
        if key not in self._cut_bytes_cache:
            self._cut_bytes_cache[key] = self.graph.cut_bytes(
                self.schedule, p, bpe)
        return self._cut_bytes_cache[key]

    def _cut_elems_vec(self) -> np.ndarray:
        """Elements crossing the link for every cut position p in [0, L-1)."""
        if self._cut_elems is None:
            self._cut_elems = np.array(
                [self.graph.cut_bytes(self.schedule, p, 1.0)
                 for p in range(len(self.schedule) - 1)], dtype=np.int64)
        return self._cut_elems

    def cut_elements(self) -> np.ndarray:
        """Public view of the per-position link element counts (length
        L-1), used by the candidate filters' feasibility matrices."""
        return self._cut_elems_vec()

    def jax_tables(self):
        """All precomputed tables as device arrays (cached).

        Returns the :class:`repro.core.partition_jax.EvalTables` feeding the
        jittable ``evaluate_batch`` fast-path used by ``JitNSGA2Search`` —
        per-arch prefix sums, link/memory tables and (when the accuracy
        oracle is a proxy) the accuracy weight prefix.  Import is lazy so
        NumPy-only callers never pay for JAX.
        """
        if self._jax_tables is None:
            from repro.core.partition_jax import build_eval_tables
            self._jax_tables = build_eval_tables(self)
        return self._jax_tables

    def evaluate(self, cuts: Sequence[int],
                 constraints: Optional[Constraints] = None) -> PartitionEval:
        """Score one sorted cut vector: per-stage latency/energy/memory,
        link costs, Def.-2/3 feasibility, and the composite objectives."""
        L = len(self.schedule)
        cuts = tuple(max(int(c), -1) for c in cuts)
        assert list(cuts) == sorted(cuts), f"cuts must be sorted: {cuts}"
        assert len(cuts) == self.system.n_cuts
        bounds = [-1] + list(cuts) + [L - 1]
        plats = self.system.platforms

        stage_lat: List[float] = []
        energy = 0.0
        for k, plat in enumerate(plats):
            a, b = bounds[k] + 1, bounds[k + 1]
            lat, en = self._segment_cost(plat.arch.name, a, b)
            stage_lat.append(lat)
            energy += en

        link_lat: List[float] = []
        link_bytes_all: List[int] = []
        for k, link in enumerate(self.system.links):
            p = cuts[k]
            sent = bounds[k + 1] > bounds[k]       # producer side ran something
            remaining = bounds[-1] > bounds[k + 1]  # anything left downstream
            if p < 0 or p >= L - 1 or not (sent and remaining):
                link_lat.append(0.0)
                link_bytes_all.append(0)
                continue
            nbytes = self._cut_bytes(p, plats[k].quant.bits / 8.0) * self.batch
            link_lat.append(link.latency_s(nbytes))
            energy += link.energy_j(nbytes)
            link_bytes_all.append(nbytes)

        latency = sum(stage_lat) + sum(link_lat)
        # Def. 4: asynchronous pipeline — slowest active module bounds rate
        active = [t for t in stage_lat if t > 0] + [t for t in link_lat if t > 0]
        throughput = 1.0 / max(active) if active else 0.0

        mems = []
        for k, plat in enumerate(plats):
            seg = self.schedule[bounds[k] + 1: bounds[k + 1] + 1]
            mems.append(segment_memory(seg, plat.memory_model,
                                       self.shared_groups, self.batch))
        acc = float(self.accuracy_fn(cuts))
        ev = PartitionEval(cuts=cuts, latency_s=latency, energy_j=energy,
                           throughput=throughput,
                           link_bytes=max(link_bytes_all) if link_bytes_all else 0,
                           memory_bytes=tuple(mems), accuracy=acc,
                           stage_latency_s=tuple(stage_lat),
                           link_latency_s=tuple(link_lat))
        ev.violation = self._violation(ev, constraints)
        return ev

    def evaluate_batch(self, cuts: np.ndarray,
                       constraints: Optional[Constraints] = None) -> BatchEval:
        """Vectorized :meth:`evaluate` over an (N, n_cuts) matrix of sorted
        cut vectors — the NSGA-II hot path (one call per generation).

        Stage latency/energy come from the per-arch prefix-sum tables via
        gathers, link bytes from the precomputed per-position element counts,
        memory from :class:`SegmentMemoryTable`, accuracy from the accuracy
        oracle's ``evaluate_batch`` when it has one.  Matches the scalar path
        metric-for-metric (tested) up to float summation order.
        """
        C = np.maximum(np.asarray(cuts, dtype=np.int64), -1)
        if C.ndim != 2:
            raise ValueError(f"cuts matrix must be 2-D, got shape {C.shape}")
        L = len(self.schedule)
        assert C.shape[1] == self.system.n_cuts
        assert np.all(C < L), "cut positions must be < len(schedule)"
        assert np.all(np.diff(C, axis=1) >= 0), "cut rows must be sorted"
        n = C.shape[0]
        plats = self.system.platforms
        bounds = np.concatenate(
            [np.full((n, 1), -1, dtype=np.int64), C,
             np.full((n, 1), L - 1, dtype=np.int64)], axis=1)

        stage_lat = np.empty((n, len(plats)))
        energy = np.zeros(n)
        for k, plat in enumerate(plats):
            pre = self._prefix[plat.arch.name]
            a, b1 = bounds[:, k] + 1, bounds[:, k + 1] + 1
            stage_lat[:, k] = pre[0, b1] - pre[0, a]
            energy += pre[1, b1] - pre[1, a]

        n_links = len(self.system.links)
        link_lat = np.zeros((n, n_links))
        link_bytes = np.zeros((n, n_links), dtype=np.int64)
        elems = self._cut_elems_vec()
        for k, link in enumerate(self.system.links):
            p = C[:, k]
            sent = bounds[:, k + 1] > bounds[:, k]
            remaining = bounds[:, -1] > bounds[:, k + 1]
            active = (p >= 0) & (p < L - 1) & sent & remaining
            bpe = plats[k].quant.bits / 8.0
            raw = (np.ceil(elems[np.clip(p, 0, L - 2)] * bpe)
                   .astype(np.int64) * self.batch if len(elems)
                   else np.zeros(n, dtype=np.int64))
            nbytes = np.where(active, raw, 0)
            link_lat[:, k] = link.latency_s_vec(nbytes)
            energy += link.energy_j_vec(nbytes)
            link_bytes[:, k] = nbytes

        latency = stage_lat.sum(axis=1) + link_lat.sum(axis=1)
        mods = np.concatenate([stage_lat, link_lat], axis=1)
        slowest = np.max(np.where(mods > 0, mods, 0.0), axis=1)
        throughput = np.divide(1.0, slowest, where=slowest > 0,
                               out=np.zeros(n))

        mems = np.empty((n, len(plats)), dtype=np.int64)
        for k, plat in enumerate(plats):
            mems[:, k] = self._memtable.batched(
                bounds[:, k] + 1, bounds[:, k + 1], plat.memory_model,
                self.batch)

        if hasattr(self.accuracy_fn, "evaluate_batch"):
            acc = np.asarray(self.accuracy_fn.evaluate_batch(C), dtype=float)
        else:
            acc = np.array([float(self.accuracy_fn(tuple(int(c) for c in row)))
                            for row in C])

        max_link = (link_bytes.max(axis=1) if n_links
                    else np.zeros(n, dtype=np.int64))
        be = BatchEval(cuts=C, latency_s=latency, energy_j=energy,
                       throughput=throughput, link_bytes=max_link,
                       memory_bytes=mems, accuracy=acc,
                       stage_latency_s=stage_lat, link_latency_s=link_lat,
                       violation=np.zeros(n))
        be.violation = self._violation_batch(be, constraints)
        return be

    def _violation_batch(self, be: BatchEval,
                         cons: Optional[Constraints]) -> np.ndarray:
        v = np.zeros(len(be))
        for k, plat in enumerate(self.system.platforms):
            cap = plat.capacity
            over = be.memory_bytes[:, k] - cap
            v += np.where(over > 0, over / cap, 0.0)
        if cons is None:
            return v
        if cons.max_link_bytes:
            over = be.link_bytes - cons.max_link_bytes
            v += np.where(over > 0, over / cons.max_link_bytes, 0.0)
        if cons.min_accuracy:
            v += np.maximum(0.0, cons.min_accuracy - be.accuracy)
        if cons.max_latency_s:
            over = be.latency_s - cons.max_latency_s
            v += np.where(over > 0, over / cons.max_latency_s, 0.0)
        if cons.max_energy_j:
            over = be.energy_j - cons.max_energy_j
            v += np.where(over > 0, over / cons.max_energy_j, 0.0)
        if cons.min_throughput:
            short = cons.min_throughput - be.throughput
            v += np.where(short > 0, short / cons.min_throughput, 0.0)
        return v

    def _violation(self, ev: PartitionEval,
                   cons: Optional[Constraints]) -> float:
        v = 0.0
        for k, plat in enumerate(self.system.platforms):
            cap = plat.capacity
            if ev.memory_bytes[k] > cap:
                v += (ev.memory_bytes[k] - cap) / cap
        if cons is None:
            return v
        if cons.max_link_bytes and ev.link_bytes > cons.max_link_bytes:
            v += (ev.link_bytes - cons.max_link_bytes) / cons.max_link_bytes
        if cons.min_accuracy and ev.accuracy < cons.min_accuracy:
            v += cons.min_accuracy - ev.accuracy
        if cons.max_latency_s and ev.latency_s > cons.max_latency_s:
            v += (ev.latency_s - cons.max_latency_s) / cons.max_latency_s
        if cons.max_energy_j and ev.energy_j > cons.max_energy_j:
            v += (ev.energy_j - cons.max_energy_j) / cons.max_energy_j
        if cons.min_throughput and ev.throughput < cons.min_throughput:
            v += (cons.min_throughput - ev.throughput) / cons.min_throughput
        return v


def single_platform_eval(evaluator: PartitionEvaluator, platform_idx: int,
                         constraints: Optional[Constraints] = None
                         ) -> PartitionEval:
    """Run the whole DNN on one platform (the paper's square markers)."""
    L = len(evaluator.schedule)
    n = evaluator.system.n_cuts
    cuts = [(-1 if k < platform_idx else L - 1) for k in range(n)]
    return evaluator.evaluate(cuts, constraints)
