"""Quantization machinery — §IV-C accuracy exploration.

Implements calibration (range estimation over feature maps and weights),
fake quantization (quantize→dequantize in float, so accuracy can be measured
quickly, exactly as the paper does) and the straight-through estimator used
by Quantization-Aware Training.

Everything is pure JAX; model integration lives in ``repro.quantize``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Uniform symmetric/affine quantizer description for one platform."""

    bits: int = 8
    symmetric: bool = True
    per_channel: bool = False     # weights: quantize per output channel
    channel_axis: int = 0

    @property
    def qmin(self) -> int:
        """Smallest representable integer code."""
        return -(2 ** (self.bits - 1)) if self.symmetric else 0

    @property
    def qmax(self) -> int:
        """Largest representable integer code."""
        return 2 ** (self.bits - 1) - 1 if self.symmetric else 2 ** self.bits - 1


def compute_scale_zp(lo: jnp.ndarray, hi: jnp.ndarray,
                     spec: QuantSpec) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scale and zero-point from calibrated ranges."""
    if spec.symmetric:
        amax = jnp.maximum(jnp.abs(lo), jnp.abs(hi))
        scale = jnp.maximum(amax / spec.qmax, 1e-12)
        zp = jnp.zeros_like(scale)
    else:
        lo = jnp.minimum(lo, 0.0)
        hi = jnp.maximum(hi, 0.0)
        scale = jnp.maximum((hi - lo) / (spec.qmax - spec.qmin), 1e-12)
        zp = jnp.round(spec.qmin - lo / scale)
    return scale, zp


def fake_quant(x: jnp.ndarray, scale: jnp.ndarray, zp: jnp.ndarray,
               spec: QuantSpec) -> jnp.ndarray:
    """Quantize→dequantize with straight-through gradients (QAT-ready)."""
    q = jnp.clip(jnp.round(x / scale + zp), spec.qmin, spec.qmax)
    dq = (q - zp) * scale
    # STE: identity gradient inside the representable range
    return x + jax.lax.stop_gradient(dq - x)


def calibrate(x: jnp.ndarray, spec: QuantSpec,
              percentile: Optional[float] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Range estimation. ``percentile`` (e.g. 99.9) clips outliers —
    minmax when None (the paper's parameter calibration step)."""
    if spec.per_channel:
        axes = tuple(i for i in range(x.ndim) if i != spec.channel_axis)
        if percentile is None:
            lo, hi = x.min(axis=axes), x.max(axis=axes)
        else:
            flat = jnp.moveaxis(x, spec.channel_axis, 0).reshape(x.shape[spec.channel_axis], -1)
            lo = jnp.percentile(flat, 100 - percentile, axis=1)
            hi = jnp.percentile(flat, percentile, axis=1)
        shape = [1] * x.ndim
        shape[spec.channel_axis] = -1
        return lo.reshape(shape), hi.reshape(shape)
    if percentile is None:
        return x.min(), x.max()
    return jnp.percentile(x, 100 - percentile), jnp.percentile(x, percentile)


def quantize_tensor(x: jnp.ndarray, spec: QuantSpec,
                    percentile: Optional[float] = None) -> jnp.ndarray:
    """One-shot calibrate + fake-quant (used for weights)."""
    lo, hi = calibrate(x, spec, percentile)
    scale, zp = compute_scale_zp(lo, hi, spec)
    return fake_quant(x, scale, zp, spec)


class ActObserver:
    """Running min/max observer for activation calibration passes."""

    def __init__(self, spec: QuantSpec):
        self.spec = spec
        self.lo: Optional[jnp.ndarray] = None
        self.hi: Optional[jnp.ndarray] = None

    def update(self, x: jnp.ndarray) -> None:
        lo, hi = calibrate(x, self.spec)
        self.lo = lo if self.lo is None else jnp.minimum(self.lo, lo)
        self.hi = hi if self.hi is None else jnp.maximum(self.hi, hi)

    def quantizer(self):
        assert self.lo is not None, "observer never saw data"
        scale, zp = compute_scale_zp(self.lo, self.hi, self.spec)
        spec = self.spec
        return lambda x: fake_quant(x, scale, zp, spec)


def quantize_pytree(params, spec: QuantSpec, percentile: Optional[float] = None):
    """Fake-quantize every float leaf of a parameter pytree (weights path).

    1-D leaves (biases, norms) are left in float — standard practice and what
    integer accelerators do (bias is accumulated at full precision).
    """
    def q(leaf):
        if not isinstance(leaf, jnp.ndarray) or leaf.ndim <= 1 \
           or not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        s = spec
        if spec.per_channel and leaf.ndim >= 2:
            s = dataclasses.replace(spec, channel_axis=leaf.ndim - 1)
        return quantize_tensor(leaf, s, percentile)
    return jax.tree_util.tree_map(q, params)


def quantization_error(x: jnp.ndarray, spec: QuantSpec) -> float:
    """RMS fake-quant error, used by tests and the accuracy proxy."""
    return float(jnp.sqrt(jnp.mean((quantize_tensor(x, spec) - x) ** 2)))
