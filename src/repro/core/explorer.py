"""DEPRECATED — thin shim over :mod:`repro.explore`.

The monolithic :class:`Explorer` (the original Fig.-1 driver with an
inlined search loop) has been replaced by the declarative exploration API:

* :class:`repro.explore.ExplorationSpec` — JSON-round-trippable run spec,
* :class:`repro.explore.SearchStrategy` implementations
  (``ExhaustiveSearch`` / ``MultiCutScan`` / ``NSGA2Search``),
* :class:`repro.explore.Campaign` — multi-model/system fan-out with shared
  cost tables.

This module keeps the old constructor/``run`` surface working (it emits a
:class:`DeprecationWarning` and delegates to ``ExhaustiveSearch`` /
``NSGA2Search`` through :func:`repro.explore.run_search`) so existing
callers keep functioning while they migrate.
"""

from __future__ import annotations

import warnings
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.accuracy import ProxyAccuracy
from repro.core.graph import LayerGraph, linearize
from repro.core.partition import (Constraints, PartitionEval,
                                  PartitionEvaluator, SystemConfig)
from repro.explore.filters import (candidate_positions, link_filter,
                                   memory_filter)
from repro.explore.result import ExplorationResult  # re-export (compat)
from repro.explore.runner import (DEFAULT_OBJECTIVES, run_search,
                                  select_weighted)
from repro.explore.spec import SearchSettings

__all__ = ["DEFAULT_OBJECTIVES", "ExplorationResult", "Explorer"]


class Explorer:
    """Deprecated facade over the pluggable exploration API."""

    def __init__(self, graph: LayerGraph, system: SystemConfig,
                 constraints: Optional[Constraints] = None,
                 objectives: Sequence[str] = DEFAULT_OBJECTIVES,
                 weights: Optional[Sequence[float]] = None,
                 schedule_policy: str = "min_memory",
                 accuracy_fn: Optional[Callable] = None,
                 batch: int = 1,
                 shared_groups: Optional[Dict[str, str]] = None,
                 allow_multi_tensor_cuts: bool = False):
        warnings.warn(
            "repro.core.Explorer is deprecated; use repro.explore "
            "(ExplorationSpec + run_spec / explore_graph, or Campaign for "
            "multi-model fan-out)", DeprecationWarning, stacklevel=2)
        self.graph = graph
        self.system = system
        self.constraints = constraints or Constraints()
        self.objectives = tuple(objectives)
        self.weights = tuple(weights) if weights else tuple(
            1.0 for _ in self.objectives)
        self.schedule = linearize(graph, schedule_policy)
        acc = accuracy_fn or ProxyAccuracy(self.schedule, system)
        self.evaluator = PartitionEvaluator(
            graph, self.schedule, system, accuracy_fn=acc, batch=batch,
            shared_groups=shared_groups)
        self.allow_multi_tensor_cuts = allow_multi_tensor_cuts

    # -- candidate discovery & filtering (now repro.explore.filters) ---------
    def candidate_cuts(self) -> List[int]:
        return candidate_positions(self.evaluator, self.constraints,
                                   self.allow_multi_tensor_cuts)

    def _memory_filter(self, cands: List[int]) -> List[int]:
        return memory_filter(self.evaluator, cands)

    def _link_filter(self, cands: List[int]) -> List[int]:
        return link_filter(self.evaluator, cands,
                           self.constraints.max_link_bytes)

    # -- evaluation + search (now repro.explore.strategies/runner) -----------
    def run(self, seed: int = 0, use_nsga: Optional[bool] = None,
            pop_size: Optional[int] = None,
            n_gen: Optional[int] = None) -> ExplorationResult:
        settings = SearchSettings(
            strategy="auto", seed=seed, use_nsga=use_nsga,
            pop_size=pop_size, n_gen=n_gen,
            allow_multi_tensor_cuts=self.allow_multi_tensor_cuts)
        return run_search(self.evaluator, constraints=self.constraints,
                          objectives=self.objectives, weights=self.weights,
                          settings=settings)

    # -- Def. 2 selection (now repro.explore.runner.select_weighted) ---------
    def _select(self, pareto: List[PartitionEval]) -> PartitionEval:
        return select_weighted(pareto, self.objectives, self.weights)
