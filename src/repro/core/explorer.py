"""The end-to-end exploration framework of Fig. 1.

Pipeline: graph → linear schedule → candidate cut discovery → memory/link
filtering → accuracy evaluation → HW evaluation → NSGA-II → Pareto front →
Def.-2 weighted-sum selection.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.accuracy import ProxyAccuracy
from repro.core.graph import LayerGraph, linearize
from repro.core.layers import LayerInfo
from repro.core.memory import prefix_feasible_limit
from repro.core.nsga2 import NSGA2Result, fast_non_dominated_sort, nsga2
from repro.core.partition import (Constraints, PartitionEval,
                                  PartitionEvaluator, SystemConfig,
                                  single_platform_eval)

DEFAULT_OBJECTIVES = ("latency", "energy")


@dataclasses.dataclass
class ExplorationResult:
    schedule: List[LayerInfo]
    candidates: List[int]                     # feasible clean-cut positions
    all_evals: List[PartitionEval]            # every candidate (n_cuts==1)
    pareto: List[PartitionEval]
    selected: PartitionEval
    baselines: List[PartitionEval]            # single-platform runs
    objectives: Tuple[str, ...]
    nsga: Optional[NSGA2Result] = None

    def summary(self) -> str:
        lines = [f"schedule: {len(self.schedule)} layers, "
                 f"{len(self.candidates)} feasible cut points"]
        for i, b in enumerate(self.baselines):
            lines.append(
                f"  all-on-platform-{i}: lat={b.latency_s*1e3:.3f} ms  "
                f"E={b.energy_j*1e3:.3f} mJ  th={b.throughput:.1f}/s  "
                f"acc={b.accuracy:.4f}")
        s = self.selected
        names = [self.schedule[c].name if 0 <= c < len(self.schedule) else "-"
                 for c in s.cuts]
        lines.append(
            f"  selected cuts {s.cuts} ({','.join(names)}): "
            f"lat={s.latency_s*1e3:.3f} ms  E={s.energy_j*1e3:.3f} mJ  "
            f"th={s.throughput:.1f}/s  acc={s.accuracy:.4f}  "
            f"mem={tuple(int(m/1024) for m in s.memory_bytes)} KiB")
        return "\n".join(lines)


class Explorer:
    """Automated partitioning-point exploration (the paper's framework)."""

    def __init__(self, graph: LayerGraph, system: SystemConfig,
                 constraints: Optional[Constraints] = None,
                 objectives: Sequence[str] = DEFAULT_OBJECTIVES,
                 weights: Optional[Sequence[float]] = None,
                 schedule_policy: str = "min_memory",
                 accuracy_fn: Optional[Callable] = None,
                 batch: int = 1,
                 shared_groups: Optional[Dict[str, str]] = None,
                 allow_multi_tensor_cuts: bool = False):
        self.graph = graph
        self.system = system
        self.constraints = constraints or Constraints()
        self.objectives = tuple(objectives)
        self.weights = tuple(weights) if weights else tuple(
            1.0 for _ in self.objectives)
        self.schedule = linearize(graph, schedule_policy)
        acc = accuracy_fn or ProxyAccuracy(self.schedule, system)
        self.evaluator = PartitionEvaluator(
            graph, self.schedule, system, accuracy_fn=acc, batch=batch,
            shared_groups=shared_groups)
        self.allow_multi_tensor_cuts = allow_multi_tensor_cuts

    # -- step 1+2: candidate discovery & filtering ---------------------------
    def candidate_cuts(self) -> List[int]:
        if self.allow_multi_tensor_cuts:
            cands = [p for p, _ in self.graph.all_cuts(self.schedule)]
        else:
            cands = self.graph.clean_cuts(self.schedule)
        cands = self._memory_filter(cands)
        cands = self._link_filter(cands)
        return cands

    def _memory_filter(self, cands: List[int]) -> List[int]:
        """§IV-B: prune cuts whose prefix overflows platform-0 memory or
        whose suffix overflows the last platform (interior platforms are
        handled by NSGA-II constraint domination)."""
        plat0 = self.system.platforms[0]
        limit = prefix_feasible_limit(
            self.schedule, plat0.memory_model, plat0.capacity,
            self.evaluator.shared_groups, self.evaluator.batch)
        cands = [p for p in cands if p <= limit]
        platN = self.system.platforms[-1]
        rev = prefix_feasible_limit(
            list(reversed(self.schedule)), platN.memory_model, platN.capacity,
            self.evaluator.shared_groups, self.evaluator.batch)
        L = len(self.schedule)
        min_p = L - 2 - rev   # suffix schedule[p+1..] must fit platform N
        return [p for p in cands if p >= min_p]

    def _link_filter(self, cands: List[int]) -> List[int]:
        cap = self.constraints.max_link_bytes
        if not cap or len(self.system.platforms) < 2:
            return cands
        # a candidate position may end up on any link, and the bytes it
        # ships are priced at its *producer* platform's bit width — so only
        # prune positions that violate the budget even under the cheapest
        # producer (the last platform never produces).  Pricing every cut at
        # the global max bit width over-prunes heterogeneous systems.
        bpe = min(p.quant.bits for p in self.system.platforms[:-1]) / 8.0
        return [p for p in cands
                if self.graph.cut_bytes(self.schedule, p, bpe)
                * self.evaluator.batch <= cap]

    # -- steps 3-5: evaluation + search --------------------------------------
    def run(self, seed: int = 0, use_nsga: Optional[bool] = None,
            pop_size: Optional[int] = None,
            n_gen: Optional[int] = None) -> ExplorationResult:
        cands = self.candidate_cuts()
        L = len(self.schedule)
        n_cuts = self.system.n_cuts
        evaluator = self.evaluator

        baselines = [single_platform_eval(evaluator, i, self.constraints)
                     for i in range(len(self.system.platforms))]

        # exhaustive scan of single-cut systems: cheap and exact, and the
        # figure benchmarks want every point anyway
        all_evals: List[PartitionEval] = []
        if n_cuts == 1 and cands:
            all_evals = evaluator.evaluate_batch(
                np.asarray(cands, dtype=int)[:, None],
                self.constraints).to_evals()

        nsga_res = None
        pool: List[PartitionEval] = list(all_evals) + [
            b for b in baselines if b.violation <= 0]
        if use_nsga is None:
            use_nsga = n_cuts > 1 or len(cands) > 64
        if use_nsga and cands:
            # genes are indices into [sentinel -1] + cands + [L-1]
            table = np.array([-1] + cands + [L - 1], dtype=int)

            def _decode(G: np.ndarray) -> np.ndarray:
                return np.sort(table[G], axis=1)

            def _eval(G: np.ndarray):
                # one vectorized call per generation instead of pop_size
                # Python evaluations — the NSGA-II hot path
                be = evaluator.evaluate_batch(_decode(G), self.constraints)
                return be.as_objectives(self.objectives), be.violation

            seeds = []
            for p in cands[:: max(1, len(cands) // 16)]:
                i = 1 + cands.index(p)
                seeds.append([i] * 1 + [len(table) - 1] * (n_cuts - 1))
            nsga_res = nsga2(_eval, n_var=n_cuts, lower=0,
                             upper=len(table) - 1, seed=seed,
                             candidates=seeds, pop_size=pop_size,
                             n_gen=n_gen)
            if len(nsga_res.pareto_X):
                pool.extend(evaluator.evaluate_batch(
                    _decode(nsga_res.pareto_X), self.constraints).to_evals())

        if not pool:
            pool = baselines[:]

        # final non-dominated filtering over the union pool
        F = np.array([ev.as_objectives(self.objectives) for ev in pool])
        CV = np.array([ev.violation for ev in pool])
        fronts = fast_non_dominated_sort(F, CV)
        seen = set()
        pareto: List[PartitionEval] = []
        for i in fronts[0]:
            if pool[i].cuts not in seen:
                seen.add(pool[i].cuts)
                pareto.append(pool[i])

        selected = self._select(pareto)
        return ExplorationResult(schedule=self.schedule, candidates=cands,
                                 all_evals=all_evals, pareto=pareto,
                                 selected=selected, baselines=baselines,
                                 objectives=self.objectives, nsga=nsga_res)

    # -- Def. 2: weighted-sum selection over the front ------------------------
    def _select(self, pareto: List[PartitionEval]) -> PartitionEval:
        F = np.array([ev.as_objectives(self.objectives) for ev in pareto],
                     dtype=float)
        lo, hi = F.min(axis=0), F.max(axis=0)
        span = np.where(hi - lo > 0, hi - lo, 1.0)
        score = ((F - lo) / span) @ np.asarray(self.weights)
        return pareto[int(np.argmin(score))]
