"""Memory-size estimation — §IV-B, Definition 3.

``m_A(l_n..l_m) = (Σ_i s_i + max_j a_j) · b_A`` with ``a_j = f_in,j + f_out,j``.

For a multi-platform schedule the model is applied per segment.  Shared
weights (Zamba2-style blocks reused across the depth) are counted **once per
platform** that executes any layer referencing them — a beyond-paper
extension controlled by ``shared_groups``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.layers import LayerInfo


@dataclasses.dataclass(frozen=True)
class MemoryModel:
    """Per-platform memory accounting parameters."""

    bytes_per_param: float = 2.0   # b_A for weights (quantized bit width / 8)
    bytes_per_act: Optional[float] = None  # defaults to bytes_per_param

    @property
    def act_bytes(self) -> float:
        return self.bytes_per_act if self.bytes_per_act is not None else self.bytes_per_param


def segment_memory(layers: Sequence[LayerInfo], model: MemoryModel,
                   shared_groups: Optional[Dict[str, str]] = None,
                   batch: int = 1) -> int:
    """Definition 3 for one contiguous segment on one platform.

    shared_groups maps layer name -> group id; all layers of a group share
    one copy of their parameters (counted once).
    """
    if not layers:
        return 0
    params = 0
    seen_groups = set()
    for l in layers:
        g = (shared_groups or {}).get(l.name)
        if g is None:
            params += l.params
        elif g not in seen_groups:
            params += l.params
            seen_groups.add(g)
    peak_act = max(l.activation_footprint for l in layers) * batch
    return int(params * model.bytes_per_param + peak_act * model.act_bytes)


class SegmentMemoryTable:
    """Precomputed Definition-3 structures for batched segment queries.

    Built once per (schedule, shared_groups); ``batched(a, b, model, batch)``
    then returns the memory of ``schedule[a..b]`` for whole index arrays in
    O(1) per segment:

    * ungrouped parameters via a prefix sum,
    * shared-group parameters via per-group sorted member positions
      (``searchsorted`` finds the first member inside each segment, matching
      the scalar first-seen accounting of :func:`segment_memory`),
    * peak activation via a sparse table (range-max in two overlapping
      power-of-two windows).
    """

    def __init__(self, schedule: Sequence[LayerInfo],
                 shared_groups: Optional[Dict[str, str]] = None):
        groups = shared_groups or {}
        self.L = len(schedule)
        params = np.array([l.params for l in schedule], dtype=np.int64)
        acts = np.array([l.activation_footprint for l in schedule],
                        dtype=np.int64)
        grouped = np.array([groups.get(l.name) is not None for l in schedule],
                           dtype=bool) if self.L else np.zeros(0, dtype=bool)
        base = np.where(grouped, 0, params) if self.L else params
        self.base_prefix = np.concatenate([[0], np.cumsum(base)])
        by_group: Dict[str, List[int]] = {}
        for i, l in enumerate(schedule):
            g = groups.get(l.name)
            if g is not None:
                by_group.setdefault(g, []).append(i)
        # (sorted member positions, member params) per group
        self.groups = [(np.asarray(pos, dtype=np.int64), params[pos])
                       for pos in by_group.values()]
        if self.L:
            levels = int(self.L).bit_length()
            st = np.zeros((levels, self.L), dtype=np.int64)
            st[0] = acts
            for j in range(1, levels):
                w, half = 1 << j, 1 << (j - 1)
                st[j, : self.L - w + 1] = np.maximum(
                    st[j - 1, : self.L - w + 1],
                    st[j - 1, half: self.L - half + 1])
            self.act_sparse = st

    def batched(self, a: np.ndarray, b: np.ndarray, model: MemoryModel,
                batch: int = 1) -> np.ndarray:
        """Memory bytes of ``schedule[a..b]`` inclusive; 0 where ``a > b``."""
        a = np.asarray(a, dtype=np.int64)
        b = np.asarray(b, dtype=np.int64)
        valid = a <= b
        aa = np.where(valid, a, 0)
        bb = np.where(valid, b, 0)
        par = self.base_prefix[bb + 1] - self.base_prefix[aa]
        for pos, gpar in self.groups:
            idx = np.minimum(np.searchsorted(pos, aa), len(pos) - 1)
            hit = pos[idx] >= aa
            hit &= pos[idx] <= bb
            par = par + np.where(hit, gpar[idx], 0)
        length = bb - aa + 1
        k = np.frexp(length.astype(np.float64))[1] - 1
        peak = np.maximum(self.act_sparse[k, aa],
                          self.act_sparse[k, bb - (1 << k) + 1]) * batch
        mem = par * model.bytes_per_param + peak * model.act_bytes
        return np.where(valid, mem.astype(np.int64), 0)


def split_memory(schedule: Sequence[LayerInfo], cut_positions: Sequence[int],
                 models: Sequence[MemoryModel],
                 shared_groups: Optional[Dict[str, str]] = None,
                 batch: int = 1) -> List[int]:
    """Memory per platform for a multi-cut partition of ``schedule``.

    ``cut_positions`` are sorted indices p; platform k executes
    schedule[p_{k-1}+1 .. p_k].  len(models) == len(cut_positions) + 1.
    """
    cuts = list(cut_positions)
    assert cuts == sorted(cuts), "cut positions must be sorted"
    assert len(models) == len(cuts) + 1
    bounds = [-1] + cuts + [len(schedule) - 1]
    out: List[int] = []
    for k in range(len(models)):
        seg = schedule[bounds[k] + 1: bounds[k + 1] + 1]
        out.append(segment_memory(seg, models[k], shared_groups, batch))
    return out


def prefix_feasible_limit(schedule: Sequence[LayerInfo], model: MemoryModel,
                          capacity_bytes: int,
                          shared_groups: Optional[Dict[str, str]] = None,
                          batch: int = 1) -> int:
    """Largest p such that schedule[0..p] fits in ``capacity_bytes``.

    The paper prunes *all following* candidate points once the prefix
    exceeds platform-A memory (§IV-B) — Def. 3 prefix cost is monotone in p,
    so a single limit suffices.  Returns -1 if even the first layer doesn't
    fit.
    """
    params = 0.0
    peak_act = 0
    seen = set()
    limit = -1
    for p, l in enumerate(schedule):
        g = (shared_groups or {}).get(l.name)
        if g is None:
            params += l.params
        elif g not in seen:
            params += l.params
            seen.add(g)
        peak_act = max(peak_act, l.activation_footprint * batch)
        total = params * model.bytes_per_param + peak_act * model.act_bytes
        if total <= capacity_bytes:
            limit = p
        else:
            break
    return limit


def min_memory_schedule(graph, model: MemoryModel, batch: int = 1):
    """§IV-B: among topological orders, pick one minimizing the peak a_j-driven
    footprint inside parallel-branch regions.

    Exact search over all topological orders is exponential; the paper builds
    subgraphs for parallel branches and evaluates their orders.  We use the
    greedy min-activation-first policy (optimal for series-parallel regions
    whose branches are chains with monotone footprints — true for the CNN
    zoo) and fall back to comparing against the insertion order, returning
    whichever has the lower Definition-3 segment cost.
    """
    from repro.core.graph import linearize
    cands = [linearize(graph, "insertion"), linearize(graph, "min_memory")]
    costs = [segment_memory(s, model, batch=batch) for s in cands]
    return cands[costs.index(min(costs))]
