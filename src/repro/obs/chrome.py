"""Chrome trace-event JSON export: spans -> a Perfetto-loadable timeline.

The `trace-event format <https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU>`_
is the lingua franca of timeline viewers: ``chrome://tracing`` and
https://ui.perfetto.dev both open the emitted file directly.  Each span's
``track`` ("process/thread" path, e.g. ``"replica0/stage1"``) becomes one
timeline row: the process part groups rows per replica (or ``router``,
``health``), the thread part is the stage / link / driver / requests row.
Timestamps are microseconds; ``"X"`` complete events carry ``dur``,
``"i"`` instant events mark faults, admissions, and failovers.

:func:`validate_chrome_trace` is the same check the ``obs-smoke`` CI job
and the ``python -m repro.obs`` CLI run before trusting a file.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Sequence, Tuple, Union

from repro.obs.trace import Span, Tracer
from repro.utils.atomicio import atomic_write_json


def _split_track(track: str) -> Tuple[str, str]:
    proc, _, thread = track.partition("/")
    return (proc or "main"), (thread or "main")


def to_chrome_trace(spans: Sequence[Span], *,
                    dropped: int = 0) -> Dict[str, Any]:
    """Render ``spans`` as a Chrome trace-event JSON object.

    Tracks are assigned stable integer pid/tid in first-seen order and
    named via ``process_name`` / ``thread_name`` metadata events;
    ``dropped`` (spans evicted from full rings) lands in
    ``otherData.dropped_spans`` so a truncated trace is self-describing."""
    pids: Dict[str, int] = {}
    tids: Dict[Tuple[str, str], int] = {}
    events: List[Dict[str, Any]] = []
    for s in spans:
        proc, thread = _split_track(s.track)
        if proc not in pids:
            pids[proc] = len(pids) + 1
            events.append({"ph": "M", "name": "process_name",
                           "pid": pids[proc], "tid": 0,
                           "args": {"name": proc}})
        key = (proc, thread)
        if key not in tids:
            tids[key] = len(tids) + 1
            events.append({"ph": "M", "name": "thread_name",
                           "pid": pids[proc], "tid": tids[key],
                           "args": {"name": thread}})
        ev: Dict[str, Any] = {
            "ph": s.ph, "name": s.name, "cat": s.cat or "default",
            "ts": round(s.ts * 1e6, 3),
            "pid": pids[proc], "tid": tids[key],
        }
        if s.ph == "X":
            ev["dur"] = round(s.dur * 1e6, 3)
        else:
            ev["s"] = "t"                      # instant scoped to its row
        if s.args:
            ev["args"] = dict(s.args)
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"dropped_spans": dropped}}


def write_chrome_trace(path: str,
                       source: Union[Tracer, Sequence[Span]]) -> None:
    """Export a tracer (or a span list) to ``path`` atomically."""
    if isinstance(source, Tracer) or hasattr(source, "spans"):
        payload = to_chrome_trace(source.spans(), dropped=source.dropped)
    else:
        payload = to_chrome_trace(source)
    atomic_write_json(path, payload)


def load_chrome_trace(path: str) -> Dict[str, Any]:
    """Parse a trace-event JSON file (as written by
    :func:`write_chrome_trace`)."""
    with open(path) as f:
        return json.load(f)


def validate_chrome_trace(trace: Dict[str, Any]) -> List[str]:
    """Structural check of a trace-event object; returns the list of
    violations (empty = loads cleanly in Perfetto / ``chrome://tracing``).

    Checks: ``traceEvents`` is a list of dicts; every event has ``ph`` and
    ``name``; ``X``/``i`` events carry numeric non-negative ``ts`` and
    integer ``pid``/``tid``; ``X`` events carry numeric non-negative
    ``dur``; every pid/tid referenced is named by a metadata event."""
    errors: List[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    named_pids, named_tids = set(), set()
    for ev in events:
        if isinstance(ev, dict) and ev.get("ph") == "M":
            if ev.get("name") == "process_name":
                named_pids.add(ev.get("pid"))
            elif ev.get("name") == "thread_name":
                named_tids.add((ev.get("pid"), ev.get("tid")))
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if not ph:
            errors.append(f"event {i}: missing ph")
            continue
        if not isinstance(ev.get("name"), str):
            errors.append(f"event {i}: missing name")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"event {i} ({ev.get('name')}): bad ts {ts!r}")
        if not isinstance(ev.get("pid"), int) \
                or not isinstance(ev.get("tid"), int):
            errors.append(f"event {i} ({ev.get('name')}): "
                          "pid/tid must be integers")
        elif ev["pid"] not in named_pids:
            errors.append(f"event {i}: pid {ev['pid']} has no "
                          "process_name metadata")
        elif (ev["pid"], ev["tid"]) not in named_tids:
            errors.append(f"event {i}: tid {ev['tid']} has no "
                          "thread_name metadata")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {i} ({ev.get('name')}): "
                              f"bad dur {dur!r}")
    return errors
