"""Low-overhead, thread-safe span recorder for the serve/search/fleet
runtime.

Design constraints, in order:

* **Cheap on the hot path.**  A serve replica emits a span per stage item
  and per link transfer from its worker threads; recording must not
  serialize them.  Each thread appends to its *own* bounded ring
  (``collections.deque``), registered once under the tracer lock on the
  thread's first span — steady-state recording takes no shared lock.
* **Bounded.**  Rings drop their oldest span once ``capacity_per_thread``
  is reached and count the drops (:attr:`Tracer.dropped`); a runaway run
  degrades the trace, never the process.
* **Monotonic.**  All timestamps are ``time.perf_counter()`` seconds
  relative to the tracer's construction epoch — never ``time.time()``
  (the RPR401 analyzer rule enforces this repo-wide for durations).

Spans carry a ``track`` — a ``"process/thread"`` path like
``"replica0/stage1"`` — which the Chrome exporter
(:mod:`repro.obs.chrome`) turns into one timeline row per stage / link /
replica.  Use :meth:`Tracer.span` as a context manager around live work,
:meth:`Tracer.complete` to record an interval whose endpoints were already
measured (zero extra clock reads), and :meth:`Tracer.instant` for
point events (faults, admissions, failovers).
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class Span:
    """One recorded event: a complete interval (``ph='X'``) or an instant
    (``ph='i'``).  ``ts``/``dur`` are seconds relative to the tracer's
    epoch; ``track`` is the ``"process/thread"`` timeline row."""

    name: str
    cat: str
    track: str
    ts: float
    dur: float = 0.0
    ph: str = "X"
    args: Optional[Dict[str, Any]] = None

    @property
    def end(self) -> float:
        """Interval end (``ts`` itself for instants)."""
        return self.ts + self.dur


class _ThreadRing:
    """One thread's bounded span buffer (drops oldest past capacity)."""

    __slots__ = ("spans", "dropped", "capacity")

    def __init__(self, capacity: int):
        self.spans: collections.deque = collections.deque()
        self.dropped = 0
        self.capacity = capacity

    def append(self, span: Span) -> None:
        if len(self.spans) >= self.capacity:
            self.spans.popleft()
            self.dropped += 1
        self.spans.append(span)


class _SpanCtx:
    """Context manager recording one live interval on exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_track", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, track: str,
                 args: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._track = track
        self._args = args

    def __enter__(self) -> "_SpanCtx":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._tracer.complete(self._name, cat=self._cat, track=self._track,
                              start=self._t0, end=time.perf_counter(),
                              args=self._args)


class Tracer:
    """Thread-safe span recorder (see module docstring).

    All recording methods may be called from any thread; :meth:`spans`
    merges every thread's ring into one ``ts``-sorted list (a snapshot —
    recording may continue concurrently)."""

    enabled = True

    def __init__(self, capacity_per_thread: int = 65536):
        if capacity_per_thread <= 0:
            raise ValueError("capacity_per_thread must be > 0, got "
                             f"{capacity_per_thread}")
        self._epoch = time.perf_counter()
        self._capacity = capacity_per_thread
        self._lock = threading.Lock()
        self._rings: List[_ThreadRing] = []
        self._local = threading.local()

    @property
    def epoch(self) -> float:
        """``time.perf_counter()`` value all span timestamps are relative
        to (the tracer's construction instant)."""
        return self._epoch

    def now(self) -> float:
        """Seconds since the tracer epoch (monotonic)."""
        return time.perf_counter() - self._epoch

    def _ring(self) -> _ThreadRing:
        ring = getattr(self._local, "ring", None)
        if ring is None:
            ring = self._local.ring = _ThreadRing(self._capacity)
            with self._lock:
                self._rings.append(ring)
        return ring

    def span(self, name: str, cat: str = "", track: str = "",
             **args: Any) -> _SpanCtx:
        """Context manager recording a complete span around the ``with``
        body (clocked with ``perf_counter`` at entry/exit)."""
        return _SpanCtx(self, name, cat, track, args or None)

    def complete(self, name: str, cat: str = "", track: str = "", *,
                 start: float, end: Optional[float] = None,
                 dur: Optional[float] = None,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """Record an interval whose endpoints were already measured:
        ``start`` (and ``end``) are absolute ``perf_counter`` values, or
        pass ``dur`` seconds instead of ``end``.  Lets instrumented code
        reuse clock reads it takes anyway (health/Def.-4 accounting)."""
        if dur is None:
            dur = (end if end is not None else time.perf_counter()) - start
        self._ring().append(Span(name=name, cat=cat, track=track,
                                 ts=start - self._epoch, dur=max(dur, 0.0),
                                 args=args))

    def instant(self, name: str, cat: str = "", track: str = "",
                ts: Optional[float] = None,
                args: Optional[Dict[str, Any]] = None) -> None:
        """Record a point event (``ts``: absolute ``perf_counter`` value;
        default now)."""
        t = time.perf_counter() if ts is None else ts
        self._ring().append(Span(name=name, cat=cat, track=track,
                                 ts=t - self._epoch, ph="i", args=args))

    def spans(self) -> List[Span]:
        """Snapshot of every recorded span, sorted by start time."""
        with self._lock:
            rings = list(self._rings)
        out: List[Span] = []
        for ring in rings:
            out.extend(ring.spans)
        out.sort(key=lambda s: (s.ts, s.track, s.name))
        return out

    @property
    def dropped(self) -> int:
        """Spans evicted from full per-thread rings (0 = complete trace)."""
        with self._lock:
            rings = list(self._rings)
        return sum(r.dropped for r in rings)


class _NullSpanCtx:
    """Reusable no-op ``with`` target for :class:`NullTracer.span`."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpanCtx":
        return self

    def __exit__(self, *exc) -> None:
        return None


_NULL_CTX = _NullSpanCtx()


class NullTracer:
    """No-op :class:`Tracer` twin: same surface, records nothing.  The
    disabled :class:`~repro.obs.handle.Obs` carries one so instrumented
    code never branches on ``None``."""

    enabled = False

    def now(self) -> float:
        """Monotonic seconds (still real so callers can use it freely)."""
        return time.perf_counter()

    def span(self, name: str, cat: str = "", track: str = "",
             **args: Any) -> _NullSpanCtx:
        """No-op context manager."""
        return _NULL_CTX

    def complete(self, name: str, cat: str = "", track: str = "", *,
                 start: float, end: Optional[float] = None,
                 dur: Optional[float] = None,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """Discard the interval."""

    def instant(self, name: str, cat: str = "", track: str = "",
                ts: Optional[float] = None,
                args: Optional[Dict[str, Any]] = None) -> None:
        """Discard the event."""

    def spans(self) -> List[Span]:
        """Always empty."""
        return []

    @property
    def dropped(self) -> int:
        """Always 0."""
        return 0
