"""The ``Obs`` handle: one object carrying a tracer + metrics registry
through the runtime.

Every instrumented layer (scheduler, serve engine, router, health
monitors, launch drivers) takes an optional ``obs`` parameter and defaults
to :data:`NOOP_OBS` — a shared disabled handle whose tracer and metrics
are no-ops, so observability costs nothing unless explicitly switched on
with :meth:`Obs.on`.  Hot paths additionally guard span construction with
``if obs.enabled:`` so the disabled path never even builds args dicts.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import NullTracer, Tracer


class _NullMetrics:
    """No-op :class:`MetricsRegistry` twin for the disabled handle."""

    def __init__(self):
        self._counter = Counter("null")
        self._gauge = Gauge("null")
        self._histogram = Histogram("null", keep=1)

    def counter(self, name: str) -> Counter:
        """A shared throwaway counter."""
        return self._counter

    def gauge(self, name: str) -> Gauge:
        """A shared throwaway gauge."""
        return self._gauge

    def histogram(self, name: str) -> Histogram:
        """A shared throwaway histogram."""
        return self._histogram

    def snapshot(self) -> dict:
        """Always empty."""
        return {}

    def write_snapshot(self, path: str) -> None:
        """No-op."""

    def reset(self) -> None:
        """No-op."""


@dataclasses.dataclass
class Obs:
    """Observability handle: a span :class:`~repro.obs.trace.Tracer` plus
    a :class:`~repro.obs.metrics.MetricsRegistry`, passed together through
    the serve/search/fleet layers.

    ``enabled`` is the hot-path guard: instrumented code checks it before
    building span arguments, so a disabled handle's cost is one attribute
    read per site."""

    tracer: Union[Tracer, NullTracer]
    metrics: Union[MetricsRegistry, _NullMetrics]
    enabled: bool = True

    @classmethod
    def on(cls, capacity_per_thread: int = 65536,
           metrics: Optional[MetricsRegistry] = None) -> "Obs":
        """A live handle: fresh tracer, fresh registry (or the one passed
        in, e.g. :func:`repro.obs.metrics.default_registry` to merge with
        process-global search/fleet metrics)."""
        return cls(tracer=Tracer(capacity_per_thread),
                   metrics=metrics if metrics is not None
                   else MetricsRegistry(), enabled=True)

    @classmethod
    def off(cls) -> "Obs":
        """The shared disabled handle (:data:`NOOP_OBS`)."""
        return NOOP_OBS


NOOP_OBS = Obs(tracer=NullTracer(), metrics=_NullMetrics(), enabled=False)
