"""One latency-statistics definition for every report in the repo.

Percentiles used to be computed ad hoc wherever a report needed them
(``np.percentile`` with its interpolating default in ``ServeReport``,
hand-rolled tail means in the serve engine), so two artifacts could
disagree about "p95" on the same samples.  This module is the single
definition — **nearest rank**: the p-th percentile of ``n`` sorted values
is the value at 1-based rank ``ceil(p/100 * n)`` (rank 1 for p = 0).  It
always returns an observed sample, never an interpolated one, and matches
NumPy's ``method='inverted_cdf'`` exactly (property-tested in
``tests/test_obs.py``).

Everything here is pure stdlib so the serve runtime, the benchmarks, and
the ``python -m repro.obs`` CLI can all share it without importing NumPy.
"""

from __future__ import annotations

import math
from typing import Dict, Sequence


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of ``values`` (``0 <= q <= 100``).

    Returns the sorted sample at 1-based rank ``ceil(q/100 * n)`` (the
    minimum for ``q=0``, the maximum for ``q=100``) — identical to
    ``np.percentile(values, q, method='inverted_cdf')``.  Raises
    ``ValueError`` on an empty sequence or an out-of-range ``q``.
    """
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    vals = sorted(float(v) for v in values)
    if not vals:
        raise ValueError("percentile of an empty sequence")
    rank = math.ceil(q / 100.0 * len(vals))
    return vals[max(rank, 1) - 1]


def mean_tail(values: Sequence[float], skip: int) -> float:
    """Mean of ``values[skip:]``, falling back to the full sequence when
    fewer than ``skip`` samples exist (0.0 when empty).  This is the
    warm-up-dropping mean the serve engine feeds Def. 4."""
    tail = list(values[skip:]) or list(values)
    return sum(tail) / len(tail) if tail else 0.0


def latency_summary(values: Sequence[float],
                    unit: float = 1.0) -> Dict[str, float]:
    """Standard latency digest of ``values``: ``p50`` / ``p95`` (nearest
    rank), ``mean`` and ``max``, each scaled by ``unit`` (pass ``1e3`` for
    seconds -> milliseconds).  Returns ``{}`` for an empty sequence."""
    vals = [float(v) for v in values]
    if not vals:
        return {}
    return {
        "p50": percentile(vals, 50) * unit,
        "p95": percentile(vals, 95) * unit,
        "mean": sum(vals) / len(vals) * unit,
        "max": max(vals) * unit,
    }
