"""Unified metrics: named counters / gauges / histograms behind one
registry.

The serve, search, and fleet layers used to smuggle operational numbers
out through per-report ``extra`` dicts — write-once, aggregate-only, and
invisible to anything that wasn't holding the report object.  A
:class:`MetricsRegistry` replaces that: instruments are created on first
use by name, are thread-safe (one lock per instrument — increments happen
on serve worker threads and fleet heartbeat threads), and
:meth:`MetricsRegistry.snapshot` flattens everything into a JSON-ready
dict published via ``repro.utils.atomicio``.

A process-global :func:`default_registry` serves call sites that have no
natural handle to thread an :class:`~repro.obs.handle.Obs` through
(``JitNSGA2Search``'s compiled-runner cache, fleet worker loops); the
serve runtime uses the registry carried by its ``Obs`` handle instead so
concurrent replicas/tests can keep their numbers separate.
"""

from __future__ import annotations

import collections
import threading
from typing import Deque, Dict, Union

from repro.obs.stats import percentile
from repro.utils.atomicio import atomic_write_json

# histogram percentile estimates come from a bounded reservoir of the most
# recent observations; count/sum/min/max stay exact over the full stream
_HIST_KEEP = 1024


class Counter:
    """Monotonically increasing named count (requests routed, cache hits,
    faults injected)."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        """Add ``n`` (default 1) to the count."""
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        """Current count."""
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins named value (queue depth, divergence ratio)."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, value: float) -> None:
        """Record the current level."""
        with self._lock:
            self._value = float(value)

    @property
    def value(self) -> float:
        """Most recently set value (0.0 before any set)."""
        with self._lock:
            return self._value


class Histogram:
    """Streaming distribution of named observations (latencies, walls).

    Exact ``count`` / ``total`` / ``min`` / ``max`` over every observation;
    :meth:`quantile` estimates come from a bounded reservoir of the most
    recent observations so memory stays constant on long runs."""

    def __init__(self, name: str, keep: int = _HIST_KEEP):
        self.name = name
        self._lock = threading.Lock()
        self._recent: Deque[float] = collections.deque(maxlen=keep)
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        """Fold one observation in."""
        v = float(value)
        with self._lock:
            self._recent.append(v)
            self.count += 1
            self.total += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)

    def quantile(self, q: float) -> float:
        """Nearest-rank percentile over the retained reservoir (0.0 before
        any observation)."""
        with self._lock:
            if not self._recent:
                return 0.0
            return percentile(self._recent, q)

    def summary(self) -> Dict[str, float]:
        """Flat digest: count, mean, p50/p95 (reservoir), min/max."""
        with self._lock:
            if not self.count:
                return {"count": 0}
            recent = list(self._recent)
        return {
            "count": self.count,
            "mean": self.total / self.count,
            "p50": percentile(recent, 50),
            "p95": percentile(recent, 95),
            "min": self.min,
            "max": self.max,
        }


_Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Get-or-create registry of named instruments.

    One name is one instrument of one kind for the registry's lifetime —
    asking for an existing name as a different kind raises ``TypeError``
    (a silent re-kind would corrupt the snapshot)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        """The :class:`Counter` named ``name`` (created on first use)."""
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        """The :class:`Gauge` named ``name`` (created on first use)."""
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        """The :class:`Histogram` named ``name`` (created on first use)."""
        return self._get(name, Histogram)

    def snapshot(self) -> Dict[str, object]:
        """Flatten every instrument into a JSON-ready dict: counters and
        gauges as ``name``, histograms as ``name.count`` / ``name.mean`` /
        ``name.p50`` / ``name.p95`` / ``name.min`` / ``name.max``."""
        with self._lock:
            metrics = dict(self._metrics)
        out: Dict[str, object] = {}
        for name in sorted(metrics):
            m = metrics[name]
            if isinstance(m, Histogram):
                for k, v in m.summary().items():
                    out[f"{name}.{k}"] = round(v, 6) if isinstance(
                        v, float) else v
            else:
                v = m.value
                out[name] = round(v, 6) if isinstance(v, float) else v
        return out

    def write_snapshot(self, path: str) -> None:
        """Publish :meth:`snapshot` at ``path`` atomically (crash-safe,
        same discipline as every other artifact — RPR301)."""
        atomic_write_json(path, self.snapshot())

    def reset(self) -> None:
        """Drop every instrument (tests; a long-lived process keeps its
        instruments for the process lifetime)."""
        with self._lock:
            self._metrics.clear()


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-global registry used by call sites without an ``Obs``
    handle (search strategy internals, fleet worker loops)."""
    return _DEFAULT
