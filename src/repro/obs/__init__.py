"""Observability for the serve/search/fleet runtime: request-level spans,
a unified metrics registry, and Chrome-trace export.

* :class:`Obs` — the handle threaded through ``SlotScheduler``,
  ``PipelineServeEngine``, ``ReplicaRouter``, the health monitors and the
  launch drivers; disabled (:data:`NOOP_OBS`) by default, switched on with
  ``Obs.on()``.
* :class:`Tracer` / :class:`Span` — low-overhead, thread-safe span
  recording on monotonic clocks (:mod:`repro.obs.trace`).
* :class:`MetricsRegistry` / :func:`default_registry` — counters, gauges,
  histograms replacing ad-hoc ``extra`` dicts (:mod:`repro.obs.metrics`).
* :func:`write_chrome_trace` and friends — Perfetto-loadable trace-event
  JSON (:mod:`repro.obs.chrome`); read back with ``python -m repro.obs``.
* :func:`percentile` / :func:`latency_summary` / :func:`mean_tail` — the
  single nearest-rank statistics definition (:mod:`repro.obs.stats`).
"""

from repro.obs.chrome import (load_chrome_trace, to_chrome_trace,
                              validate_chrome_trace, write_chrome_trace)
from repro.obs.handle import NOOP_OBS, Obs
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               default_registry)
from repro.obs.stats import latency_summary, mean_tail, percentile
from repro.obs.trace import NullTracer, Span, Tracer

__all__ = [
    "Obs", "NOOP_OBS", "Tracer", "NullTracer", "Span",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "default_registry",
    "to_chrome_trace", "write_chrome_trace", "load_chrome_trace",
    "validate_chrome_trace",
    "percentile", "latency_summary", "mean_tail",
]
