"""``python -m repro.obs`` — read a Chrome trace back as tables.

Renders, from a trace file written by
:func:`repro.obs.chrome.write_chrome_trace`:

* the **per-request latency breakdown** (one row per ``cat='request'``
  span: replica, submit offset, TTFT, end-to-end latency, tokens, finish
  reason) with a nearest-rank p50/p95 footer that matches
  ``ServeReport.summary()`` on the same run;
* the **top-N slowest spans** (stage items, link transfers) — where the
  wall actually went.

  PYTHONPATH=src python -m repro.obs trace.json
  PYTHONPATH=src python -m repro.obs trace.json --top 20
  PYTHONPATH=src python -m repro.obs trace.json --metrics metrics.json
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.obs.chrome import load_chrome_trace, validate_chrome_trace
from repro.obs.stats import latency_summary


def _track_names(events: Sequence[Dict[str, Any]]
                 ) -> Dict[Tuple[int, int], str]:
    procs: Dict[int, str] = {}
    threads: Dict[Tuple[int, int], str] = {}
    for ev in events:
        if ev.get("ph") != "M":
            continue
        if ev.get("name") == "process_name":
            procs[ev["pid"]] = ev["args"]["name"]
        elif ev.get("name") == "thread_name":
            threads[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    return {key: f"{procs.get(pid, pid)}/{name}"
            for (pid, tid), name in threads.items()
            for key in [(pid, tid)]}


def request_rows(trace: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Per-request breakdown rows from the trace's ``cat='request'``
    spans, sorted by submit time."""
    events = trace.get("traceEvents", [])
    tracks = _track_names(events)
    rows = []
    for ev in events:
        if ev.get("ph") != "X" or ev.get("cat") != "request":
            continue
        args = ev.get("args", {})
        track = tracks.get((ev.get("pid"), ev.get("tid")), "")
        rows.append({
            "rid": args.get("rid", ev.get("name", "?")),
            "replica": track.split("/")[0],
            "submit_ms": ev["ts"] / 1e3,
            "ttft_ms": args.get("ttft_ms"),
            "latency_ms": ev.get("dur", 0.0) / 1e3,
            "tokens": args.get("tokens"),
            "finish": args.get("finish", ""),
        })
    rows.sort(key=lambda r: (r["submit_ms"], str(r["rid"])))
    return rows


def slowest_spans(trace: Dict[str, Any], top: int = 10
                  ) -> List[Dict[str, Any]]:
    """The ``top`` longest non-request spans (stage items, link
    transfers, driver runs), longest first."""
    events = trace.get("traceEvents", [])
    tracks = _track_names(events)
    spans = [ev for ev in events
             if ev.get("ph") == "X" and ev.get("cat") != "request"]
    spans.sort(key=lambda ev: -ev.get("dur", 0.0))
    return [{
        "name": ev.get("name", "?"),
        "cat": ev.get("cat", ""),
        "track": tracks.get((ev.get("pid"), ev.get("tid")), "?"),
        "start_ms": ev["ts"] / 1e3,
        "dur_ms": ev.get("dur", 0.0) / 1e3,
    } for ev in spans[:top]]


def _table(headers: Sequence[str], rows: Sequence[Sequence[Any]]) -> str:
    def fmt(v: Any) -> str:
        if isinstance(v, float):
            return f"{v:.2f}"
        return "-" if v is None else str(v)
    cells = [[fmt(v) for v in row] for row in rows]
    widths = [max(len(h), *(len(r[i]) for r in cells)) if cells else len(h)
              for i, h in enumerate(headers)]
    lines = ["  ".join(h.rjust(w) for h, w in zip(headers, widths))]
    lines.append("  ".join("-" * w for w in widths))
    lines.extend("  ".join(c.rjust(w) for c, w in zip(row, widths))
                 for row in cells)
    return "\n".join(lines)


def render_report(trace: Dict[str, Any], top: int = 10) -> str:
    """The full text report for a loaded trace: request breakdown table,
    nearest-rank percentile footer, top-N slowest spans."""
    out = []
    rows = request_rows(trace)
    if rows:
        out.append(f"per-request breakdown ({len(rows)} request(s)):")
        out.append(_table(
            ("rid", "replica", "submit_ms", "ttft_ms", "latency_ms",
             "tokens", "finish"),
            [(r["rid"], r["replica"], r["submit_ms"], r["ttft_ms"],
              r["latency_ms"], r["tokens"], r["finish"]) for r in rows]))
        lats = [r["latency_ms"] for r in rows if r["latency_ms"]]
        ttfts = [r["ttft_ms"] for r in rows if r["ttft_ms"] is not None]
        if lats:
            s = latency_summary(lats)
            line = (f"latency_ms p50={s['p50']:.2f} p95={s['p95']:.2f} "
                    f"max={s['max']:.2f}")
            if ttfts:
                t = latency_summary(ttfts)
                line += f" | ttft_ms p50={t['p50']:.2f} p95={t['p95']:.2f}"
            out.append(line)
    else:
        out.append("no request spans in trace")
    slow = slowest_spans(trace, top)
    if slow:
        out.append(f"\ntop {len(slow)} slowest spans:")
        out.append(_table(
            ("name", "cat", "track", "start_ms", "dur_ms"),
            [(r["name"], r["cat"], r["track"], r["start_ms"], r["dur_ms"])
             for r in slow]))
    dropped = trace.get("otherData", {}).get("dropped_spans", 0)
    if dropped:
        out.append(f"\nWARNING: {dropped} span(s) dropped from full rings")
    return "\n".join(out)


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI entry point; returns 2 when the trace fails validation."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="render a repro.obs Chrome trace as tables")
    ap.add_argument("trace", help="trace-event JSON file "
                                  "(repro.obs.write_chrome_trace)")
    ap.add_argument("--top", type=int, default=10,
                    help="slowest spans to list (default 10)")
    ap.add_argument("--metrics", default=None,
                    help="also print a metrics snapshot JSON file")
    args = ap.parse_args(argv)

    trace = load_chrome_trace(args.trace)
    errors = validate_chrome_trace(trace)
    if errors:
        for e in errors[:20]:
            print(f"INVALID: {e}", file=sys.stderr)
        return 2
    print(render_report(trace, top=args.top))
    if args.metrics:
        with open(args.metrics) as f:
            snap = json.load(f)
        print(f"\nmetrics snapshot ({args.metrics}):")
        for k in sorted(snap):
            print(f"  {k} = {snap[k]}")
    return 0
