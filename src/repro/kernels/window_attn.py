"""Pallas TPU kernel: sliding-window flash attention (prefill).

Enables the dense architectures to run the ``long_500k`` shape: position i
attends to (i-window, i], so compute and KV memory are O(T·W), not O(T²).

Grid: (B, H, T/bq, W/bk + 1) — the last (kv) axis is sequential; online
softmax stats (m, l) and the output accumulator live in VMEM scratch across
it.  The k/v block index is derived from (query block, kv step) in the
BlockSpec index map (clamped at 0; out-of-range positions are masked).
GQA is handled by mapping query head h to kv head h // group in the k/v
index maps — no materialized head broadcast.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, window: int):
    qi = pl.program_id(2)
    j = pl.program_id(3)
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :]                    # (bq, hd)
    k = k_ref[0, :, 0, :]                    # (bk, hd)
    v = v_ref[0, :, 0, :]                    # (bk, hd)
    hd = q.shape[-1]

    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)
    kv_blk = qi + j - (nj - 1)               # may be negative (clamped in map)
    k_pos = jnp.maximum(kv_blk, 0) * bk + jax.lax.iota(jnp.int32, bk)
    valid = ((kv_blk >= 0)
             & (k_pos[None, :] <= q_pos[:, None])
             & (k_pos[None, :] > q_pos[:, None] - window))

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) / jnp.sqrt(
        jnp.float32(hd))
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = (acc_scr[...] * alpha[:, None]
                    + jnp.dot(p, v.astype(jnp.float32),
                              preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _emit():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "bq", "bk", "interpret"))
def window_attn(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                window: int, bq: int = 128, bk: int = 128,
                interpret: bool = True) -> jnp.ndarray:
    """q: (B,T,H,hd); k/v: (B,T,Kv,hd) with H % Kv == 0. Causal + window."""
    b, t, h, hd = q.shape
    kv = k.shape[2]
    assert h % kv == 0 and t % bq == 0 and t % bk == 0, (q.shape, k.shape)
    assert window % bk == 0, (window, bk)
    group = h // kv
    nj = window // bk + 1
    grid = (b, h, t // bq, nj)

    def kv_map(bi, hi, qi, j):
        return bi, jnp.maximum(qi + j - (nj - 1), 0), hi // group, 0
    scratch = [] if _VMEM is None else [
        _VMEM((bq,), jnp.float32), _VMEM((bq,), jnp.float32),
        _VMEM((bq, hd), jnp.float32)]
    kern = functools.partial(_kernel, bq=bq, bk=bk, window=window)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda bi, hi, qi, j: (bi, qi, hi, 0)),
            pl.BlockSpec((1, bk, 1, hd), kv_map),
            pl.BlockSpec((1, bk, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda bi, hi, qi, j: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
