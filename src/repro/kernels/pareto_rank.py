"""Pallas TPU kernel: tiled constrained Pareto-domination primitives.

The NSGA-II ranking hot path needs, for every individual q, which (and how
many) individuals p Deb-dominate it.  Materializing that as a dense
(pop, pop) matrix — as the original ``nsga2_jax`` path did — costs
O(pop² · m) bytes of broadcast temporaries and caps populations around 2k.
These kernels walk the pair space in (row-tile × column-tile) blocks so the
dense relation never exists in memory:

* :func:`packed_domination` — each grid step compares a (32·wb, bq) tile
  and writes it bit-packed (32 dominators per uint32 word, the layout
  ``nsga2_jax._pack_bits`` produces), straight into the (ceil(r/32), n)
  output.  Peak live memory is the packed words plus one tile.
* :func:`domination_counts` — reduces tiles into per-column dominator
  counts with an optional alive-mask on the dominator side; the grid
  revisits each (bq,) output block across row steps and accumulates in
  place (the standard Pallas matmul accumulation pattern).  Peak memory is
  O(n · block).

Both take the dominator rows and the column population separately so the
row space can be sharded across devices (``shard_map`` over row tiles in
``kernels.ops``).  The pure-jnp blocked twins live in ``kernels.ref``;
ground truth for both is the dense ``nsga2_jax.domination_matrix``.
Objectives/violations are compared in float32; ``interpret=True`` runs the
same grid on CPU (the correctness harness; compiled Mosaic on real TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# one Deb constrained-domination tile definition for both impls: plain jnp
# ops, so it traces identically inside pallas_call and in the blocked twins
from repro.kernels.ref import dominates_tile as _dom_tile


def _packed_kernel(fp_ref, cvp_ref, fq_ref, cvq_ref, o_ref):
    dom = _dom_tile(fp_ref[...], cvp_ref[...], fq_ref[...], cvq_ref[...])
    bp, bq = dom.shape
    words = dom.reshape(bp // 32, 32, bq).astype(jnp.uint32)
    bits = jax.lax.broadcasted_iota(jnp.uint32, (1, 32, 1), 1)
    o_ref[...] = (words << bits).sum(axis=1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("bp", "bq", "interpret"))
def packed_domination(f_rows: jnp.ndarray, cv_rows: jnp.ndarray,
                      f_cols: jnp.ndarray, cv_cols: jnp.ndarray, *,
                      bp: int = 256, bq: int = 256,
                      interpret: bool = True) -> jnp.ndarray:
    """Bit-packed domination rows: out word (w, q) bit j = row 32w+j of
    (f_rows, cv_rows) Deb-dominates column q of (f_cols, cv_cols).

    f_rows: (r, m); f_cols: (n, m); r % bp == 0, n % bq == 0, bp % 32 == 0
    (the ops wrapper pads with +inf violations, which dominate nothing).
    Returns (r // 32, n) uint32.
    """
    r, m = f_rows.shape
    n = f_cols.shape[0]
    assert r % bp == 0 and n % bq == 0 and bp % 32 == 0, (r, n, bp, bq)
    grid = (r // bp, n // bq)
    return pl.pallas_call(
        _packed_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, m), lambda i, j: (i, 0)),
            pl.BlockSpec((bp,), lambda i, j: (i,)),
            pl.BlockSpec((bq, m), lambda i, j: (j, 0)),
            pl.BlockSpec((bq,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bp // 32, bq), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r // 32, n), jnp.uint32),
        interpret=interpret,
    )(f_rows.astype(jnp.float32), cv_rows.astype(jnp.float32),
      f_cols.astype(jnp.float32), cv_cols.astype(jnp.float32))


def _counts_kernel(fp_ref, cvp_ref, alive_ref, fq_ref, cvq_ref, o_ref):
    p_idx = pl.program_id(1)

    @pl.when(p_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    dom = _dom_tile(fp_ref[...], cvp_ref[...], fq_ref[...], cvq_ref[...])
    dom &= (alive_ref[...] > 0)[:, None]
    o_ref[...] += jnp.sum(dom, axis=0, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("bp", "bq", "interpret"))
def domination_counts(f_rows: jnp.ndarray, cv_rows: jnp.ndarray,
                      alive_rows: jnp.ndarray,
                      f_cols: jnp.ndarray, cv_cols: jnp.ndarray, *,
                      bp: int = 256, bq: int = 256,
                      interpret: bool = True) -> jnp.ndarray:
    """Per-column count of alive dominator rows; (n,) int32.

    Grid (n/bq, r/bp) with the row axis innermost: each (bq,) output block
    is revisited across the row steps and accumulated in place.
    """
    r, m = f_rows.shape
    n = f_cols.shape[0]
    assert r % bp == 0 and n % bq == 0, (r, n, bp, bq)
    grid = (n // bq, r // bp)
    return pl.pallas_call(
        _counts_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, m), lambda i, p: (p, 0)),
            pl.BlockSpec((bp,), lambda i, p: (p,)),
            pl.BlockSpec((bp,), lambda i, p: (p,)),
            pl.BlockSpec((bq, m), lambda i, p: (i, 0)),
            pl.BlockSpec((bq,), lambda i, p: (i,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, p: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(f_rows.astype(jnp.float32), cv_rows.astype(jnp.float32),
      alive_rows.astype(jnp.int32), f_cols.astype(jnp.float32),
      cv_cols.astype(jnp.float32))
