"""Jit'd kernel wrappers with implementation dispatch.

``impl`` resolution: 'pallas' uses the Pallas kernel (interpret=True on CPU
— a correctness harness; compiled Mosaic on real TPU), 'ref' uses the
pure-jnp oracle, 'auto' picks ref on CPU backends and pallas on TPU.
Dry-run lowering always uses 'ref' (DESIGN.md §6).
"""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


_IMPLS = ("auto", "ref", "pallas")


def resolve_impl(impl: str) -> str:
    if impl not in _IMPLS:
        raise ValueError(f"unknown impl {impl!r}; valid choices: "
                         f"{', '.join(_IMPLS)}")
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def resolve_rank_impl(impl: str) -> str:
    """Like :func:`resolve_impl`, with an env override for 'auto': the CI
    kernel-interpret leg sets ``REPRO_RANK_IMPL=pallas`` so every 'auto'
    caller exercises the Pallas branch (interpret=True) on CPU."""
    if impl not in _IMPLS:
        raise ValueError(f"unknown rank impl {impl!r}; valid choices: "
                         f"{', '.join(_IMPLS)}")
    if impl == "auto":
        env = os.environ.get("REPRO_RANK_IMPL", "auto")
        if env not in _IMPLS:
            raise ValueError(
                f"invalid REPRO_RANK_IMPL={env!r}; valid choices: "
                f"{', '.join(_IMPLS)} (unset the variable for backend "
                "auto-detection)")
        impl = env
    return resolve_impl(impl)


# -- pareto_rank ----------------------------------------------------------------

# fixed column tile for the Pallas branch: rows follow the caller's block
# (the knob trades tile-loop overhead against working-set size) while the
# column width stays VMEM-friendly at any row count
_PALLAS_COL_TILE = 256


def _row_tile(block: int) -> int:
    return max(32, block // 32 * 32)


def _packed_rows(Fr, cvr, Fq, cvq, block: int, impl: str) -> jnp.ndarray:
    """(ceil(r/32), n) packed domination rows, shape-legalizing pads."""
    r, n = Fr.shape[0], Fq.shape[0]
    if impl == "ref":
        return _ref.packed_domination(Fr, cvr, Fq, cvq, block)
    from repro.kernels.pareto_rank import packed_domination as k
    bp, bq = _row_tile(block), _PALLAS_COL_TILE
    Fr, cvr = _ref._pad_rows(Fr, cvr, bp)
    Fq, cvq = _ref._pad_rows(Fq, cvq, bq)
    out = k(Fr, cvr, Fq, cvq, bp=bp, bq=bq, interpret=_interpret())
    return out[: (r + 31) // 32, :n]


def packed_domination(F, CV, *, block: int = 1024, impl: str = "auto",
                      mesh=None) -> jnp.ndarray:
    """Bit-packed constrained-domination matrix, built tile-by-tile.

    Returns (ceil(n/32), n) uint32 in the ``nsga2_jax._pack_bits`` layout —
    bit-identical to packing the dense ``domination_matrix``, but the dense
    (n, n[, m]) boolean temporaries never exist: peak working memory is the
    packed words plus one (block, n) tile.  With a 1-D ``mesh`` the
    dominator row-tiles are sharded across its devices through the
    ``repro.nn.sharding`` shard_map shim.
    """
    impl = resolve_rank_impl(impl)
    F = jnp.asarray(F, jnp.float32)
    CV = jnp.asarray(CV, jnp.float32)
    n = F.shape[0]
    W = (n + 31) // 32
    if mesh is not None and mesh.size > 1:
        from jax.sharding import PartitionSpec as P

        from repro.nn.sharding import shard_map
        ax = mesh.axis_names[0]
        Fr, cvr = _ref._pad_rows(F, CV, 32 * mesh.size)
        fn = shard_map(
            lambda fr, cr, fq, cq: _packed_rows(fr, cr, fq, cq, block, impl),
            mesh=mesh, in_specs=(P(ax, None), P(ax), P(None, None), P(None)),
            out_specs=P(ax, None), check_rep=False)
        return fn(Fr, cvr, F, CV)[:W]
    return _packed_rows(F, CV, F, CV, block, impl)[:W]


def domination_counts(F, CV, alive: Optional[jnp.ndarray] = None, *,
                      block: int = 1024, impl: str = "auto") -> jnp.ndarray:
    """(n,) int32 count of alive constrained dominators per individual,
    accumulated tile-by-tile — O(n · block) peak memory.  ``counts == 0``
    is the first constrained front (used to merge restart fronts without a
    dense host-side sort)."""
    impl = resolve_rank_impl(impl)
    F = jnp.asarray(F, jnp.float32)
    CV = jnp.asarray(CV, jnp.float32)
    n = F.shape[0]
    if alive is None:
        alive = jnp.ones(n, dtype=bool)
    if impl == "ref":
        return _ref.domination_counts(F, CV, alive, block)
    from repro.kernels.pareto_rank import domination_counts as k
    bp, bq = _row_tile(block), _PALLAS_COL_TILE
    Fp, cvp = _ref._pad_rows(F, CV, bp)
    ap = jnp.pad(alive, (0, Fp.shape[0] - n))
    Fq, cvq = _ref._pad_rows(F, CV, bq)
    return k(Fp, cvp, ap, Fq, cvq, bp=bp, bq=bq, interpret=_interpret())[:n]


def quant_matmul(x, w_q, w_scale, x_scale, impl: str = "pallas"):
    if resolve_impl(impl) == "ref":
        return _ref.quant_matmul(x, w_q, w_scale, x_scale)
    from repro.kernels.quant_matmul import quant_matmul as k
    m, kk = x.shape
    n = w_q.shape[1]
    if m % 128 or n % 128 or kk % 128:   # fall back off-grid shapes
        return _ref.quant_matmul(x, w_q, w_scale, x_scale)
    return k(x, w_q, w_scale, x_scale, interpret=_interpret())


def ssd_scan(x, dt, A, B, C, chunk: int = 128, impl: str = "pallas"
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if resolve_impl(impl) == "ref":
        return _ref.ssd_scan(x, dt, A, B, C, chunk)
    from repro.kernels.ssd_scan import ssd_scan as k
    return k(x, dt, A, B, C, chunk=chunk, interpret=_interpret())


def window_attn(q, k, v, window: int, impl: str = "pallas"):
    if resolve_impl(impl) == "ref":
        group = q.shape[2] // k.shape[2]
        k_e = jnp.repeat(k, group, axis=2)
        v_e = jnp.repeat(v, group, axis=2)
        return _ref.window_attn(q, k_e, v_e, window)
    from repro.kernels.window_attn import window_attn as kern
    t = q.shape[1]
    bq = bk = 128 if t % 128 == 0 and window % 128 == 0 else None
    if bq is None:
        group = q.shape[2] // k.shape[2]
        return _ref.window_attn(q, jnp.repeat(k, group, axis=2),
                                jnp.repeat(v, group, axis=2), window)
    return kern(q, k, v, window=window, bq=bq, bk=bk,
                interpret=_interpret())
