"""Jit'd kernel wrappers with implementation dispatch.

``impl`` resolution: 'pallas' uses the Pallas kernel (interpret=True on CPU
— a correctness harness; compiled Mosaic on real TPU), 'ref' uses the
pure-jnp oracle, 'auto' picks ref on CPU backends and pallas on TPU.
Dry-run lowering always uses 'ref' (DESIGN.md §6).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def quant_matmul(x, w_q, w_scale, x_scale, impl: str = "pallas"):
    if resolve_impl(impl) == "ref":
        return _ref.quant_matmul(x, w_q, w_scale, x_scale)
    from repro.kernels.quant_matmul import quant_matmul as k
    m, kk = x.shape
    n = w_q.shape[1]
    if m % 128 or n % 128 or kk % 128:   # fall back off-grid shapes
        return _ref.quant_matmul(x, w_q, w_scale, x_scale)
    return k(x, w_q, w_scale, x_scale, interpret=_interpret())


def ssd_scan(x, dt, A, B, C, chunk: int = 128, impl: str = "pallas"
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if resolve_impl(impl) == "ref":
        return _ref.ssd_scan(x, dt, A, B, C, chunk)
    from repro.kernels.ssd_scan import ssd_scan as k
    return k(x, dt, A, B, C, chunk=chunk, interpret=_interpret())


def window_attn(q, k, v, window: int, impl: str = "pallas"):
    if resolve_impl(impl) == "ref":
        group = q.shape[2] // k.shape[2]
        k_e = jnp.repeat(k, group, axis=2)
        v_e = jnp.repeat(v, group, axis=2)
        return _ref.window_attn(q, k_e, v_e, window)
    from repro.kernels.window_attn import window_attn as kern
    t = q.shape[1]
    bq = bk = 128 if t % 128 == 0 and window % 128 == 0 else None
    if bq is None:
        group = q.shape[2] // k.shape[2]
        return _ref.window_attn(q, jnp.repeat(k, group, axis=2),
                                jnp.repeat(v, group, axis=2), window)
    return kern(q, k, v, window=window, bq=bq, bk=bk,
                interpret=_interpret())
