"""Pallas TPU kernel: Mamba2 SSD chunked scan.

TPU adaptation of the SSD algorithm (DESIGN.md §6): the intra-chunk term is
a (C×C)·(C×P) matmul chain (MXU work — this is exactly the "duality" the
paper exploits), the inter-chunk recurrence is carried in a VMEM scratch
state that persists across the sequential chunk axis of the grid.

Grid: (B, H, NC) — NC (chunks) is the innermost, sequential dimension, so
the (P, N) state scratch is a true running carry per (batch, head).
Block shapes: x (1, C, 1, P), B/C (1, C, N), state scratch (P, N) f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pltpu scratch shapes; interpret mode emulates them on CPU
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state):
    nc = pl.program_id(2)
    n_chunks = pl.num_programs(2)

    @pl.when(nc == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0, :, 0, :]                       # (C, P)
    dt = dt_ref[0, :, 0]                        # (C,)
    a = a_ref[0]                                # scalar (negative)
    bm = b_ref[0]                               # (C, N)
    cm = c_ref[0]                               # (C, N)

    chunk = x.shape[0]
    dA = dt * a                                 # (C,) log-decay
    cs = jnp.cumsum(dA)                         # inclusive cumsum

    # intra-chunk: (C B^T ⊙ L) (dt x)
    seg = cs[:, None] - cs[None, :]
    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    L = jnp.where(mask, jnp.exp(seg), 0.0)
    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)
    xdt = x * dt[:, None]                       # (C, P)
    y_intra = jnp.dot(cb * L, xdt, preferred_element_type=jnp.float32)

    # inter-chunk: carried state contribution
    y_inter = jnp.dot(cm, state[...].T,
                      preferred_element_type=jnp.float32) * jnp.exp(cs)[:, None]
    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: H <- exp(Σ dA) H + Σ_i decay_i B_i (dt x)_i
    decay_to_end = jnp.exp(cs[-1] - cs)         # (C,)
    s_new = jnp.dot(xdt.T, bm * decay_to_end[:, None],
                    preferred_element_type=jnp.float32)   # (P, N)
    state[...] = jnp.exp(cs[-1]) * state[...] + s_new

    @pl.when(nc == n_chunks - 1)
    def _emit_state():
        st_ref[0, 0] = state[...].astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, *, chunk: int = 128,
             interpret: bool = True):
    """x: (b,T,h,p); dt: (b,T,h); A: (h,); B,C: (b,T,n).

    Returns (y (b,T,h,p), final_state (b,h,p,n)).  D-skip is applied by the
    caller (ops.ssd_scan)."""
    b, t, h, p = x.shape
    n = B.shape[-1]
    assert t % chunk == 0, (t, chunk)
    ncs = t // chunk
    grid = (b, h, ncs)
    scratch = [] if _VMEM is None else [_VMEM((p, n), jnp.float32)]
    y, st = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(x, dt, A, B, C)
    return y, st
