"""Pallas TPU kernel: fused fake-quant int8 matmul.

Used by the quantized-inference path that the accuracy-exploration stage
evaluates (§IV-C): activations are quantized on the fly (symmetric int8),
weights arrive pre-quantized (int8 + per-channel scales), accumulation is
f32 in VMEM, and the dequant epilogue is fused.

Blocking: (bm, bk) x (bk, bn) -> (bm, bn), all MXU-aligned multiples of 128.
Grid (M/bm, N/bn, K/bk) with K innermost: the output block is revisited
across the K steps and accumulated in place (standard Pallas matmul
pattern); quant/dequant happen per tile so the working set stays in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, wq_ref, wscale_ref, xscale_ref, o_ref):
    k_idx = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    x_scale = xscale_ref[0]
    xq = jnp.clip(jnp.round(x / x_scale), -128, 127).astype(jnp.float32)
    wq = wq_ref[...].astype(jnp.float32)
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float32)
    o_ref[...] += acc

    @pl.when(k_idx == nk - 1)
    def _epilogue():
        o_ref[...] = o_ref[...] * x_scale * wscale_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quant_matmul(x: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray,
                 x_scale: jnp.ndarray, *, bm: int = 128, bn: int = 128,
                 bk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """x: (M, K) f32; w_q: (K, N) int8; w_scale: (N,); x_scale: scalar."""
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (x.shape, w_q.shape, (bm, bn, bk))
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w_q, w_scale, jnp.reshape(x_scale, (1,)).astype(jnp.float32))
