"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# -- quant_matmul -------------------------------------------------------------

def quant_matmul(x: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray,
                 x_scale: jnp.ndarray) -> jnp.ndarray:
    """Fake-quant matmul: y = q(x) @ (w_q * w_scale).

    x: (M, K) float; w_q: (K, N) int8; w_scale: (N,); x_scale: scalar.
    x is quantized symmetric-8bit on the fly with the given scale.
    """
    xq = jnp.clip(jnp.round(x / x_scale), -128, 127)
    acc = (xq.astype(jnp.float32) @ w_q.astype(jnp.float32))
    return acc * x_scale * w_scale[None, :]


# -- ssd_scan ------------------------------------------------------------------

def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, chunk: int,
             init_state: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD without the D skip term (the op adds it outside).

    Shapes as in repro.nn.ssm.ssd_chunked.  Returns (y, final_state)."""
    from repro.nn.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, B, C, chunk, D=None, init_state=init_state)


# -- window_attn ----------------------------------------------------------------

def window_attn(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                window: int) -> jnp.ndarray:
    """Sliding-window causal attention.

    q, k, v: (B, T, H, hd) (same head count — GQA expansion happens in the
    caller).  Position i attends to j in (i-window, i].  Returns (B,T,H,hd).
    """
    b, t, h, hd = q.shape
    pos = jnp.arange(t)
    mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - window)
    scores = jnp.einsum("bihd,bjhd->bhij", q, k) / jnp.sqrt(hd)
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhij,bjhd->bihd", p, v)
