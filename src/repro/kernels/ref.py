"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# -- quant_matmul -------------------------------------------------------------

def quant_matmul(x: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray,
                 x_scale: jnp.ndarray) -> jnp.ndarray:
    """Fake-quant matmul: y = q(x) @ (w_q * w_scale).

    x: (M, K) float; w_q: (K, N) int8; w_scale: (N,); x_scale: scalar.
    x is quantized symmetric-8bit on the fly with the given scale.
    """
    xq = jnp.clip(jnp.round(x / x_scale), -128, 127)
    acc = (xq.astype(jnp.float32) @ w_q.astype(jnp.float32))
    return acc * x_scale * w_scale[None, :]


# -- ssd_scan ------------------------------------------------------------------

def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, chunk: int,
             init_state: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD without the D skip term (the op adds it outside).

    Shapes as in repro.nn.ssm.ssd_chunked.  Returns (y, final_state)."""
    from repro.nn.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, B, C, chunk, D=None, init_state=init_state)


# -- pareto_rank ---------------------------------------------------------------

def dominates_tile(Fp: jnp.ndarray, cvp: jnp.ndarray,
                   Fq: jnp.ndarray, cvq: jnp.ndarray) -> jnp.ndarray:
    """Deb constrained-domination tile: out[i, j] = (Fp[i], cvp[i]) dominates
    (Fq[j], cvq[j]).  The objective loop is unrolled over the (static, small)
    objective count so no (rows, cols, m) temporary is ever materialized —
    the building block every blocked/tiled Pareto primitive shares."""
    rows, cols = Fp.shape[0], Fq.shape[0]
    all_le = jnp.ones((rows, cols), dtype=bool)
    any_lt = jnp.zeros((rows, cols), dtype=bool)
    for j in range(Fp.shape[1]):
        a, b = Fp[:, j, None], Fq[None, :, j]
        all_le &= a <= b
        any_lt |= a < b
    feas_p, feas_q = (cvp <= 0)[:, None], (cvq <= 0)[None, :]
    cv_lt = cvp[:, None] < cvq[None, :]
    return jnp.where(feas_p & ~feas_q, True,
                     jnp.where(feas_q & ~feas_p, False,
                               jnp.where(~feas_p & ~feas_q, cv_lt,
                                         all_le & any_lt)))


def _pack_rows(B: jnp.ndarray) -> jnp.ndarray:
    """Pack a (rows, n) bool tile into (rows // 32, n) uint32 words (bit j of
    word w = B[32w + j] — the ``nsga2_jax._pack_bits`` layout)."""
    rows, n = B.shape
    W = B.reshape(rows // 32, 32, n).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return (W * weights[None, :, None]).sum(axis=1, dtype=jnp.uint32)


def _pad_rows(Fr, cvr, rows):
    pad = (-Fr.shape[0]) % rows
    if pad:
        # +inf violation: padding rows dominate nothing, so their bits are 0
        Fr = jnp.pad(Fr, ((0, pad), (0, 0)))
        cvr = jnp.pad(cvr, (0, pad), constant_values=jnp.inf)
    return Fr, cvr


def packed_domination(Fr: jnp.ndarray, cvr: jnp.ndarray,
                      Fq: jnp.ndarray, cvq: jnp.ndarray,
                      block: int = 1024) -> jnp.ndarray:
    """Bit-packed constrained-domination rows, built tile-by-tile.

    Returns (ceil(len(Fr)/32), len(Fq)) uint32 — bit-for-bit the packing of
    the dense ``domination_matrix`` rows, but peak working memory is
    O(len(Fq) * block) instead of O(rows * cols * m): a ``lax.map`` walks
    row tiles of dominators against the full column set.
    """
    r = Fr.shape[0]
    rows = max(32, min(block, r + (-r) % 32) // 32 * 32)
    Fr, cvr = _pad_rows(Fr, cvr, rows)
    def tile(args):
        fp, cp = args
        return _pack_rows(dominates_tile(fp, cp, Fq, cvq))
    words = jax.lax.map(tile, (Fr.reshape(-1, rows, Fr.shape[1]),
                               cvr.reshape(-1, rows)))
    return words.reshape(-1, Fq.shape[0])[: (r + 31) // 32]


def domination_counts(F: jnp.ndarray, CV: jnp.ndarray,
                      alive: Optional[jnp.ndarray] = None,
                      block: int = 1024) -> jnp.ndarray:
    """Per-individual count of (alive) constrained dominators, accumulated
    tile-by-tile over dominator row blocks — O(n * block) peak memory, the
    streaming twin of ``domination_matrix(...).sum(axis=0)``."""
    n = F.shape[0]
    if alive is None:
        alive = jnp.ones(n, dtype=bool)
    rows = max(32, min(block, n + (-n) % 32) // 32 * 32)
    Fp, cvp = _pad_rows(F, CV, rows)
    ap = jnp.pad(alive, (0, Fp.shape[0] - n))
    def step(acc, args):
        fp, cp, al = args
        d = dominates_tile(fp, cp, F, CV) & al[:, None]
        return acc + jnp.sum(d, axis=0, dtype=jnp.int32), None
    acc, _ = jax.lax.scan(
        step, jnp.zeros(n, dtype=jnp.int32),
        (Fp.reshape(-1, rows, F.shape[1]), cvp.reshape(-1, rows),
         ap.reshape(-1, rows)))
    return acc


# -- window_attn ----------------------------------------------------------------

def window_attn(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                window: int) -> jnp.ndarray:
    """Sliding-window causal attention.

    q, k, v: (B, T, H, hd) (same head count — GQA expansion happens in the
    caller).  Position i attends to j in (i-window, i].  Returns (B,T,H,hd).
    """
    b, t, h, hd = q.shape
    pos = jnp.arange(t)
    mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - window)
    scores = jnp.einsum("bihd,bjhd->bhij", q, k) / jnp.sqrt(hd)
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhij,bjhd->bihd", p, v)
