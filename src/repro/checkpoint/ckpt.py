"""Checkpointing: flat .npz pytree save/restore (orbax is unavailable).

Pytrees are flattened with '/'-joined key paths; dtypes/shapes round-trip
exactly.  Works for params, optimizer state, and RNG-free model state.
"""

from __future__ import annotations

import os
import re
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_key_str(k) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"#{k.idx}"
    return str(k)


def save(path: str, tree, step: Optional[int] = None) -> str:
    """Save pytree; returns the file written."""
    os.makedirs(path, exist_ok=True)
    fname = os.path.join(path, f"ckpt_{step or 0:08d}.npz")
    flat = _flatten(tree)
    np.savez(fname, **flat)
    return fname


def restore(path: str, like, step: Optional[int] = None):
    """Restore into the structure of ``like`` (a template pytree)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    fname = os.path.join(path, f"ckpt_{step:08d}.npz")
    data = np.load(fname)
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for pth, leaf in leaves_p:
        key = "/".join(_key_str(k) for k in pth)
        arr = data[key]
        out.append(jnp.asarray(arr, dtype=leaf.dtype if hasattr(leaf, "dtype")
                               else None))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = []
    for f in os.listdir(path):
        m = re.match(r"ckpt_(\d+)\.npz$", f)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None
