"""Model-level quantization: measured accuracy + QAT (§IV-C).

``cnn_measured_accuracy`` builds the explorer's ``accuracy_fn``: for a cut
vector it executes the *partitioned, fake-quantized* CNN on a validation set
(weights at each platform's bit width, link activations quantized to the
producer's width) and returns top-1 accuracy.

``qat_finetune`` runs quantization-aware training: every forward quantizes
the parameters with straight-through gradients, so the float master weights
adapt to the quantization grid — the paper's accuracy-restoration step.
"""

from __future__ import annotations

from typing import Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import QuantSpec, quantize_pytree
from repro.optim.optimizers import Optimizer, apply_updates, clip_by_global_norm
from repro.serving.pipeline import PartitionedCNNRunner
from repro.training.train_lib import cross_entropy


def quantized_eval(model, params, state, x, y, spec: QuantSpec) -> float:
    """Monolithic fake-quant eval (whole model at one bit width)."""
    qp = quantize_pytree(params, spec)
    logits, _ = model.apply(qp, state, jnp.asarray(x), train=False)
    return float((logits.argmax(-1) == jnp.asarray(y)).mean())


def cnn_measured_accuracy(model, params, state, schedule,
                          val_x: np.ndarray, val_y: np.ndarray,
                          quant_specs: Sequence[QuantSpec],
                          ) -> Callable[[Sequence[int]], float]:
    """accuracy_fn(cuts) for the explorer (2+-platform CNN systems)."""
    model.to_graph()   # populate graph_boundaries
    cache: Dict[Tuple[int, ...], float] = {}
    xj, yj = jnp.asarray(val_x), jnp.asarray(val_y)

    def measure(cuts) -> float:
        key = tuple(int(c) for c in cuts)
        if key in cache:
            return cache[key]
        block_cuts = []
        for c in key:
            if c < 0:
                block_cuts.append(-1)
            else:
                block_cuts.append(model.cut_to_block(schedule, c))
        # drop sentinel/duplicate cuts for the runner, remember platforms
        n_blocks = len(model.blocks)
        seg_specs = []
        bounds = [-1] + block_cuts + [n_blocks - 1]
        for k in range(len(quant_specs)):
            a, b = bounds[k] + 1, bounds[k + 1]
            if b >= a:
                seg_specs.append((a, b, quant_specs[k]))
        runner_cuts = [b for (a, b, _) in seg_specs[:-1]]
        specs = [s for (_, _, s) in seg_specs]
        runner = PartitionedCNNRunner(model, params, state, runner_cuts,
                                      specs, link_quant=True)
        logits, _ = runner.run(xj)
        acc = float((logits.argmax(-1) == yj).mean())
        cache[key] = acc
        return acc

    return measure


def qat_finetune(model, params, state, spec: QuantSpec, optimizer: Optimizer,
                 data_iter, steps: int = 50,
                 classifier: bool = True):
    """QAT loop: fake-quant in the forward, STE gradients to float masters."""

    def loss_fn(p, s, x, y):
        qp = quantize_pytree(p, spec)
        logits, new_s = model.apply(qp, s, x, train=True)
        if classifier:
            loss = cross_entropy(logits, y)
        else:
            loss = cross_entropy(logits, y)
        return loss, new_s

    @jax.jit
    def step_fn(p, opt_s, s, x, y):
        grads, new_s = jax.grad(loss_fn, has_aux=True)(p, s, x, y)
        grads, _ = clip_by_global_norm(grads, 1.0)
        updates, opt_s = optimizer.update(grads, opt_s, p)
        return apply_updates(p, updates), opt_s, new_s

    opt_state = optimizer.init(params)
    for i in range(steps):
        x, y = next(data_iter)
        params, opt_state, state = step_fn(params, opt_state, state,
                                           jnp.asarray(x), jnp.asarray(y))
    return params, state
