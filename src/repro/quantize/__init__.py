from repro.quantize.evaluate import (cnn_measured_accuracy, qat_finetune,
                                     quantized_eval)
