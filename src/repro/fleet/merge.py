"""Deterministic merge of per-cell report shards into one
:class:`~repro.explore.campaign.CampaignReport`.

Entry order is the manifest's cell order — model-major / system-minor,
i.e. exactly the serial :meth:`Campaign.run` iteration order — so a merged
fleet report is *report-identical* to the serial run of the same sweep up
to wall-clock fields (:func:`report_fingerprint` is the canonical
timing-stripped comparison form; the tier-1 suite and the CI fleet-smoke
job assert fingerprint equality).  The merged ``wall_s`` aggregates compute
seconds across every shard (the serial field is end-to-end wall time; with
N workers the two diverge by design).

Shards may also be merged from an explicit iterable (e.g. shard files
rsynced from several hosts): duplicate cell ids with identical payloads
dedupe silently, diverging payloads raise :class:`ReportMergeError` —
a sweep must never silently pick one of two conflicting results.
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Tuple, Union

from repro.explore.campaign import CampaignReport
from repro.fleet.manifest import Manifest


class ReportMergeError(RuntimeError):
    pass


def _normalize(obj: Any) -> Any:
    """JSON-normalize (tuples -> lists, dict key order irrelevant downstream)."""
    return json.loads(json.dumps(obj))


def failed_cell_entry(model: str, system: str, error: str,
                      attempts: int = 0) -> Dict[str, Any]:
    """Placeholder entry for a terminally failed cell: the real entry shape
    (an empty ``ExplorationResult.to_report()``, so the key set can never
    drift from genuine entries) plus the failure record — downstream report
    consumers need no special casing."""
    from repro.explore.result import ExplorationResult
    return {"model": model, "system": system, "wall_s": 0.0,
            "failed": True, "error": error, "attempts": attempts,
            **_normalize(ExplorationResult.empty_report())}


def _strip_timing(entry: Dict[str, Any]) -> Dict[str, Any]:
    return {k: v for k, v in entry.items() if k != "wall_s"}


def report_fingerprint(report: Union[CampaignReport, Dict[str, Any]]
                       ) -> Dict[str, Any]:
    """Canonical timing-stripped form of a campaign report: two runs of the
    same sweep (serial or fleet, any worker count) must produce equal
    fingerprints."""
    d = report.to_dict() if isinstance(report, CampaignReport) else \
        _normalize(report)
    return {"template": d["template"],
            "entries": [_strip_timing(e) for e in d["entries"]]}


def merge_shards(template: Dict[str, Any],
                 cells: Iterable[Tuple[str, str, str]],
                 shards: Iterable[Tuple[str, Dict[str, Any]]],
                 failures: Optional[Dict[str, Tuple[str, int]]] = None,
                 allow_failed: bool = False) -> CampaignReport:
    """Merge ``(cell_id, entry)`` shards for ``cells`` — an ordered iterable
    of ``(cell_id, model, system)`` — into one report.

    * entries come out in ``cells`` order regardless of shard arrival order;
    * a duplicate cell id is a conflict unless the payloads are identical
      (timing-stripped) — identical duplicates dedupe silently;
    * a cell with no shard must have a ``failures`` record *and*
      ``allow_failed=True`` to merge (as a placeholder entry); otherwise
      the merge raises, because a partial merge would masquerade as a
      complete campaign report.
    """
    by_id: Dict[str, Dict[str, Any]] = {}
    for cid, entry in shards:
        entry = _normalize(entry)
        if cid in by_id:
            if _strip_timing(by_id[cid]) != _strip_timing(entry):
                raise ReportMergeError(
                    f"conflicting shards for cell {cid!r}: two workers "
                    f"published different results for the same cell")
            continue
        by_id[cid] = entry

    cells = list(cells)
    known = {cid for cid, _, _ in cells}
    for cid in by_id:
        if cid not in known:
            raise ReportMergeError(f"shard for unknown cell {cid!r} "
                                   f"(not in this sweep's cell list)")

    failures = failures or {}
    entries: List[Dict[str, Any]] = []
    wall = 0.0
    missing: List[str] = []
    for cid, model, system in cells:
        if cid in by_id:
            entries.append(by_id[cid])
            wall += float(by_id[cid].get("wall_s", 0.0))
        elif cid in failures and allow_failed:
            err, attempts = failures[cid]
            entries.append(failed_cell_entry(model, system, err, attempts))
        else:
            missing.append(cid)
    if missing:
        raise ReportMergeError(
            f"{len(missing)} cell(s) without a shard: "
            f"{missing[:5]}{'...' if len(missing) > 5 else ''} — finish the "
            f"sweep (`python -m repro.fleet run`) or pass allow_failed=True "
            f"to merge terminally failed cells as placeholders")
    return CampaignReport(template=_normalize(template), entries=entries,
                          wall_s=round(wall, 4))


def merge_manifest(manifest: Union[Manifest, str],
                   allow_failed: bool = False) -> CampaignReport:
    """Merge a manifest directory's shards (the normal path)."""
    if isinstance(manifest, str):
        manifest = Manifest.load(manifest)
    shards = []
    failures: Dict[str, Tuple[str, int]] = {}
    for c in manifest.cells:
        state = manifest.cell_state(c.id)
        if state == "done":
            shards.append((c.id, manifest.read_shard(c.id)))
        elif state == "failed":
            recs = manifest.failure_records(c.id)
            err = recs[-1]["error"] if recs else "unknown failure"
            failures[c.id] = (err, len(recs))
    return merge_shards(manifest.meta["sweep"]["template"],
                        [(c.id, c.model, c.system) for c in manifest.cells],
                        shards, failures=failures, allow_failed=allow_failed)
