"""Fleet worker: claim cells from a manifest, run the search, publish
shards.

One worker is one process (``python -m repro.fleet worker``); any number of
them may point at the same manifest directory, on one host or many.  The
loop is coordinator-free:

1. list pending cells in serial-run order, try to claim each (atomic
   exclusive create) until one sticks;
2. run the cell through the exact serial-campaign code path
   (:func:`repro.explore.runner.explore_graph` with the template's
   objectives/constraints/strategy — including ``jit_nsga2``), reusing
   per-model graph/schedule/Def.-3-memory caches and the per-arch
   ``cost_cache`` across every cell of the same model this worker executes,
   so cost tables are built once per (worker, model) like the serial
   ``Campaign`` builds them once per model;
3. publish the report entry as an atomic shard and release the claim; on
   an exception, record the failed attempt and release — the cell returns
   to pending until the manifest's bounded retry budget is spent.

A worker exits when the manifest is complete (all cells done or terminally
failed).  While cells are claimed by *other* workers it polls, reclaiming
claims whose owner died on this host, so killing a worker mid-cell never
wedges the sweep.
"""

from __future__ import annotations

import os
import socket
import threading
import time
import traceback
from typing import Any, Dict, Optional

from repro.fleet.manifest import CellInfo, Manifest
from repro.obs.metrics import default_registry


def default_worker_id() -> str:
    return f"{socket.gethostname()}-{os.getpid()}"


class _ModelCache:
    """Per-worker shared state for one model: built graph, schedule, memory
    table and the per-arch cost-table cache (shared across systems, exactly
    like the serial Campaign loop)."""

    def __init__(self, sweep, model_idx: int):
        from repro.core.graph import linearize
        from repro.core.memory import SegmentMemoryTable
        mref = sweep.models[model_idx]
        self.graph, self.shared = mref.build()
        self.schedule = linearize(self.graph, sweep.template.schedule_policy)
        self.memtable = SegmentMemoryTable(self.schedule, self.shared)
        self.cost_cache: Dict = {}


def run_cell(manifest: Manifest, cell: CellInfo,
             model_caches: Optional[Dict[int, _ModelCache]] = None
             ) -> Dict[str, Any]:
    """Execute one claimed cell; returns its report entry dict."""
    from repro.explore.campaign import campaign_entry_dict
    from repro.explore.runner import explore_graph
    sweep = manifest.sweep
    tpl = sweep.template
    caches = model_caches if model_caches is not None else {}
    mc = caches.get(cell.model_idx)
    if mc is None:
        mc = caches[cell.model_idx] = _ModelCache(sweep, cell.model_idx)
    system = sweep.systems[cell.system_idx].build()
    t0 = time.perf_counter()
    res = explore_graph(
        mc.graph, system, objectives=tpl.objectives, weights=tpl.weights,
        constraints=tpl.constraints, search=tpl.search, batch=tpl.batch,
        accuracy=tpl.accuracy, shared_groups=mc.shared,
        schedule=mc.schedule, cost_cache=mc.cost_cache,
        memtable=mc.memtable)
    wall = time.perf_counter() - t0
    return campaign_entry_dict(cell.model, cell.system, res, wall)


def _lease_heartbeat(manifest: Manifest, cell_id: str, lease_s: float,
                     stop: threading.Event) -> None:
    """Refresh the claim's lease every ``lease_s / 3`` until stopped (or
    until the claim disappears — released or reclaimed from under us)."""
    period = max(lease_s / 3.0, 0.05)
    hist = default_registry().histogram("fleet_heartbeat_refresh_s")
    while not stop.wait(period):
        t0 = time.perf_counter()
        ok = manifest.refresh_claim(cell_id)
        hist.observe(time.perf_counter() - t0)
        if not ok:
            return


def run_worker(manifest_dir: str, worker_id: Optional[str] = None,
               poll_s: float = 0.5, verbose: bool = False,
               lease_s: float = 30.0) -> Dict[str, int]:
    """The worker loop; returns ``{"done": n, "failed": n}`` attempt counts
    for this worker's own work.

    While a cell runs, a heartbeat thread refreshes the claim's lease
    every ``lease_s / 3``, and the idle-poll reclaim passes
    ``lease_ttl_s=lease_s`` — so a *hung* worker (process alive, cell
    stuck, lease never refreshed) expires after the TTL just like a dead
    one, on any host."""
    if lease_s <= 0:
        raise ValueError(f"lease_s must be > 0, got {lease_s}")
    manifest = Manifest.load(manifest_dir)
    wid = worker_id or default_worker_id()
    stats = {"done": 0, "failed": 0}
    caches: Dict[int, _ModelCache] = {}
    reg = default_registry()

    def say(msg: str) -> None:
        if verbose:
            print(f"[fleet:{wid}] {msg}", flush=True)

    while True:
        claimed = None
        for cell in manifest.pending_cells():
            if manifest.claim(cell.id, wid):
                claimed = cell
                break
        if claimed is None:
            if manifest.complete():
                say(f"manifest complete; exiting "
                    f"(done={stats['done']} failed={stats['failed']})")
                return stats
            # other workers hold the remaining cells: recover any whose
            # owner died on this host or whose lease expired (hung worker
            # on any host), then wait for live ones
            reclaimed = manifest.reclaim_stale(lease_ttl_s=lease_s)
            if reclaimed:
                reg.counter("fleet_cells_reclaimed").inc(len(reclaimed))
                continue
            time.sleep(poll_s)
            continue
        say(f"claimed {claimed.id}")
        reg.counter("fleet_cells_claimed").inc()
        stop_hb = threading.Event()
        hb = threading.Thread(target=_lease_heartbeat,
                              args=(manifest, claimed.id, lease_s, stop_hb),
                              name=f"lease-{claimed.id}", daemon=True)
        hb.start()
        try:
            entry = run_cell(manifest, claimed, caches)
        except KeyboardInterrupt:
            stop_hb.set()
            hb.join(timeout=5.0)
            manifest.release(claimed.id)
            raise
        except Exception:
            stop_hb.set()
            hb.join(timeout=5.0)
            n = manifest.record_failure(claimed.id, wid,
                                        traceback.format_exc())
            stats["failed"] += 1
            reg.counter("fleet_cells_failed").inc()
            say(f"FAILED {claimed.id} (attempt {n}/"
                f"{manifest.max_retries + 1})")
            continue
        stop_hb.set()
        hb.join(timeout=5.0)
        manifest.write_shard(claimed.id, entry, wid)
        stats["done"] += 1
        reg.counter("fleet_cells_done").inc()
        reg.histogram("fleet_cell_wall_s").observe(entry["wall_s"])
        say(f"done {claimed.id} ({entry['wall_s']:.2f}s)")
