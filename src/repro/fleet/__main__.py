"""``python -m repro.fleet`` — the sweep-service CLI.

  init    build a manifest from a SweepSpec (or single-spec template) JSON
  run     run/resume the sweep with N local workers, then merge
  worker  one worker loop (the per-host unit for multi-host runs)
  merge   merge shards into a CampaignReport JSON
  status  cell-state counts for a manifest
  hosts   print the per-host commands for a multi-host run

A killed run resumes with the same ``run`` command: done cells are never
recomputed, stale claims from dead local workers are reclaimed
automatically.
"""

from __future__ import annotations

import argparse
import json
import sys


def _cmd_init(args) -> int:
    from repro.explore.spec import ExplorationSpec, SweepSpec
    from repro.fleet.manifest import Manifest
    with open(args.sweep or args.spec) as f:
        d = json.load(f)
    if args.sweep:
        sweep = SweepSpec.from_dict(d)
    else:
        # a bare ExplorationSpec template: 1-model x 1-system sweep (extend
        # by writing a SweepSpec JSON or using Campaign.to_manifest)
        sweep = SweepSpec(template=ExplorationSpec.from_dict(d))
    m = Manifest.create(args.manifest, sweep, max_retries=args.max_retries)
    print(f"manifest {m.path}: {len(m.cells)} cell(s), "
          f"spec_hash {m.spec_hash[:12]}")
    return 0


def _cmd_run(args) -> int:
    from repro.fleet.launch import run_fleet
    report = run_fleet(args.manifest, workers=args.workers,
                       reclaim=args.reclaim, allow_failed=args.allow_failed,
                       merge=not args.no_merge, verbose=not args.quiet)
    if report is not None:
        if args.out:
            report.save(args.out)
            print(f"wrote {args.out}")
        print(report.summary())
    return 0


def _cmd_worker(args) -> int:
    from repro.fleet.worker import run_worker
    # failed attempts are recorded in the manifest and retried/merged there;
    # the process itself succeeded if the loop ran to completion
    run_worker(args.manifest, worker_id=args.worker_id,
               verbose=args.verbose, lease_s=args.lease)
    return 0


def _cmd_merge(args) -> int:
    from repro.fleet.merge import merge_manifest
    report = merge_manifest(args.manifest, allow_failed=args.allow_failed)
    report.save(args.out)
    print(f"wrote {args.out} ({len(report.entries)} entries)")
    return 0


def _cmd_status(args) -> int:
    from repro.fleet.manifest import Manifest
    m = Manifest.load(args.manifest)
    st = m.status()
    print(f"{m.path}: {st['cells']} cells — "
          f"{st['done']} done, {st['running']} running, "
          f"{st['pending']} pending, {st['failed']} failed "
          f"[spec {st['spec_hash']}]")
    for c in m.cells:
        print(f"  {m.cell_state(c.id):7s} {c.id}")
    return 0


def _cmd_hosts(args) -> int:
    from repro.fleet.launch import host_commands
    print(host_commands(args.manifest, args.hosts.split(","),
                        workers_per_host=args.workers))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.fleet",
                                 description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("init", help="build a manifest from a sweep JSON")
    g = p.add_mutually_exclusive_group(required=True)
    g.add_argument("--sweep", help="SweepSpec JSON path")
    g.add_argument("--spec", help="single ExplorationSpec JSON path")
    p.add_argument("--manifest", required=True)
    p.add_argument("--max-retries", type=int, default=2)
    p.set_defaults(fn=_cmd_init)

    p = sub.add_parser("run", help="run/resume the sweep locally and merge")
    p.add_argument("--manifest", required=True)
    p.add_argument("--workers", type=int, default=2)
    p.add_argument("--reclaim", choices=("stale", "all", "none"),
                   default="stale")
    p.add_argument("--allow-failed", action="store_true",
                   help="merge terminally failed cells as placeholders")
    p.add_argument("--no-merge", action="store_true",
                   help="run workers only (multi-host: merge separately)")
    p.add_argument("--out", help="write the merged report JSON here")
    p.add_argument("--quiet", action="store_true")
    p.set_defaults(fn=_cmd_run)

    p = sub.add_parser("worker", help="run one worker loop")
    p.add_argument("--manifest", required=True)
    p.add_argument("--worker-id", default=None)
    p.add_argument("--verbose", action="store_true")
    p.add_argument("--lease", type=float, default=30.0,
                   help="claim lease TTL seconds: the heartbeat refreshes "
                        "at lease/3, and claims idle past the TTL are "
                        "reclaimed as hung")
    p.set_defaults(fn=_cmd_worker)

    p = sub.add_parser("merge", help="merge shards into a report JSON")
    p.add_argument("--manifest", required=True)
    p.add_argument("--out", default="campaign_report.json")
    p.add_argument("--allow-failed", action="store_true")
    p.set_defaults(fn=_cmd_merge)

    p = sub.add_parser("status", help="cell-state summary")
    p.add_argument("--manifest", required=True)
    p.set_defaults(fn=_cmd_status)

    p = sub.add_parser("hosts", help="print per-host commands")
    p.add_argument("--manifest", required=True)
    p.add_argument("--hosts", required=True,
                   help="comma-separated host names")
    p.add_argument("--workers", type=int, default=1,
                   help="workers per host")
    p.set_defaults(fn=_cmd_hosts)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
