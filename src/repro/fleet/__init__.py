"""``repro.fleet`` — distributed campaign orchestration for zoo-scale
partitioning sweeps.

The serial :class:`~repro.explore.campaign.Campaign` fans one spec template
across models × systems in-process; this package turns the same fan-out
into a durable, resumable, fault-tolerant sweep service:

* :mod:`repro.fleet.manifest` — a JSON work manifest on a (shared)
  filesystem.  Each (model, system) cell has a stable id and a state
  machine (pending → running → done / failed) driven entirely by atomic
  filesystem operations (``O_CREAT|O_EXCL`` claim files, ``os.replace``
  shard writes), so any number of worker processes — on one host or many
  hosts sharing the directory — can cooperate without a coordinator, and a
  crashed sweep resumes from the manifest without recomputing done cells.
* :mod:`repro.fleet.worker` — the worker loop: claim a cell, run the
  configured search strategy (any of the registered strategies, including
  ``jit_nsga2``) with per-worker shared model/schedule/cost-table caches,
  write the result shard, retry failures within a bounded budget.
* :mod:`repro.fleet.merge` — deterministic merge of per-cell report shards
  into one :class:`~repro.explore.campaign.CampaignReport` that is
  report-identical (modulo wall-clock) to a serial ``Campaign.run`` of the
  same sweep; detects duplicate-cell conflicts and materializes
  placeholders for terminally failed cells.
* :mod:`repro.fleet.launch` — local multi-process launcher plus the
  per-host command printer for multi-host runs; also the ``python -m
  repro.fleet`` CLI (``init`` / ``run`` / ``worker`` / ``merge`` /
  ``status`` / ``hosts``).

Typical use::

    from repro.explore import Campaign
    from repro.fleet import run_fleet

    Campaign(spec, models=zoo_models).to_manifest("sweep.manifest")
    report = run_fleet("sweep.manifest", workers=4)   # == serial .run()

or from a shell (resume after a crash is the same command)::

    python -m repro.fleet init --spec spec.json --manifest sweep.manifest
    python -m repro.fleet run  --manifest sweep.manifest --workers 4
"""

from repro.fleet.manifest import (CellInfo, Manifest, ManifestError,
                                  cell_id_for)
from repro.fleet.merge import (ReportMergeError, failed_cell_entry,
                               merge_manifest, merge_shards,
                               report_fingerprint)
from repro.fleet.launch import host_commands, run_fleet, start_workers
from repro.fleet.worker import run_worker

__all__ = [
    "CellInfo", "Manifest", "ManifestError", "ReportMergeError",
    "cell_id_for", "failed_cell_entry", "host_commands", "merge_manifest",
    "merge_shards", "report_fingerprint", "run_fleet", "run_worker",
    "start_workers",
]
