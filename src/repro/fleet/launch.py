"""Fleet launchers: N local worker processes, or the per-host commands for
a multi-host run over a shared manifest directory.

Local workers are plain subprocesses of ``python -m repro.fleet worker``;
the same command is what a remote host runs (the manifest directory is the
only coordination channel, so "multi-host" just means the directory lives
on a shared filesystem).  :func:`run_fleet` is the one-call path: reclaim
stale claims, start workers, wait, merge — and because every step is
manifest-driven, running it again after a crash (or Ctrl-C) resumes instead
of recomputing.
"""

from __future__ import annotations

import os
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence

from repro.explore.campaign import CampaignReport
from repro.fleet.manifest import Manifest
from repro.fleet.merge import merge_manifest


def _worker_env() -> Dict[str, str]:
    """Child env with ``repro`` importable even when the parent got it via
    ``sys.path`` manipulation rather than an installed package."""
    env = dict(os.environ)
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    parts = [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
    if src not in parts:
        env["PYTHONPATH"] = os.pathsep.join([src] + parts)
    return env


def worker_command(manifest_dir: str, worker_id: Optional[str] = None,
                   verbose: bool = False) -> List[str]:
    cmd = [sys.executable, "-m", "repro.fleet", "worker",
           "--manifest", os.path.abspath(manifest_dir)]
    if worker_id:
        cmd += ["--worker-id", worker_id]
    if verbose:
        cmd.append("--verbose")
    return cmd


def start_workers(manifest_dir: str, n: int, verbose: bool = False
                  ) -> List[subprocess.Popen]:
    """Spawn ``n`` local worker processes against ``manifest_dir``."""
    env = _worker_env()
    return [subprocess.Popen(worker_command(manifest_dir, verbose=verbose),
                             env=env) for _ in range(n)]


def wait_workers(procs: Sequence[subprocess.Popen]) -> List[int]:
    return [p.wait() for p in procs]


def host_commands(manifest_dir: str, hosts: Sequence[str],
                  workers_per_host: int = 1) -> str:
    """The copy-pasteable per-host commands for a multi-host run; the
    manifest directory must be on a filesystem all hosts share."""
    path = os.path.abspath(manifest_dir)
    lines = [f"# manifest: {path} (must be shared across hosts)"]
    for h in hosts:
        if workers_per_host > 1:
            cmd = (f"python -m repro.fleet run --manifest {path} "
                   f"--workers {workers_per_host} --no-merge")
        else:
            cmd = f"python -m repro.fleet worker --manifest {path}"
        lines.append(f"ssh {h} 'cd <repo>; PYTHONPATH=src {cmd}'")
    lines.append(f"# then, anywhere: python -m repro.fleet merge "
                 f"--manifest {path} --out report.json")
    return "\n".join(lines)


def run_fleet(manifest_dir: str, workers: int = 2,
              reclaim: str = "stale", allow_failed: bool = False,
              merge: bool = True,
              verbose: bool = False) -> Optional[CampaignReport]:
    """Run (or resume) a sweep with ``workers`` local processes and merge.

    ``reclaim``: ``'stale'`` (default) clears claims whose owner died on
    this host — the resume-after-crash path; ``'all'`` force-clears every
    claim (only when no worker anywhere is live); ``'none'`` leaves claims
    untouched.  Done cells are never recomputed — resuming an interrupted
    manifest only runs what is still pending.
    """
    manifest = Manifest.load(manifest_dir)
    if reclaim not in ("stale", "all", "none"):
        raise ValueError(f"reclaim must be 'stale', 'all' or 'none', "
                         f"got {reclaim!r}")
    if reclaim != "none":
        got = manifest.reclaim_stale(force=(reclaim == "all"))
        if got and verbose:
            print(f"[fleet] reclaimed {len(got)} stale claim(s)")
    t0 = time.perf_counter()
    if not manifest.complete():
        procs = start_workers(manifest_dir, workers, verbose=verbose)
        try:
            codes = wait_workers(procs)
        except KeyboardInterrupt:
            for p in procs:
                p.terminate()
            raise
        bad = [c for c in codes if c != 0]
        if bad and not manifest.complete():
            raise RuntimeError(
                f"{len(bad)} worker(s) exited non-zero and the manifest is "
                f"incomplete; inspect {manifest_dir}/failed and re-run")
    if not merge:
        return None
    report = merge_manifest(manifest, allow_failed=allow_failed)
    if verbose:
        print(f"[fleet] merged {len(report.entries)} cell(s) in "
              f"{time.perf_counter() - t0:.1f}s wall "
              f"({report.wall_s:.1f}s aggregate compute)")
    return report
