"""Durable JSON work manifest for fleet sweeps.

A manifest is a directory (usually on a filesystem shared by every worker
host) holding the sweep description and the per-cell state machine::

    <manifest_dir>/
      manifest.json              immutable sweep: SweepSpec + cell list
      claims/<cell>.claim        running: atomic O_CREAT|O_EXCL claim marker
      shards/<cell>.json         done: the cell's report entry
      failed/<cell>.attempt<N>.json   one record per failed attempt

Cell ids are stable across runs — ``c<idx>--<model>--<system>`` in
model-major / system-minor (serial ``Campaign.run``) order — and
``manifest.json`` carries the sweep's ``spec_hash`` so a worker pointed at
a manifest built from a different sweep refuses to execute.

State is derived, never stored: a cell is *done* iff its shard exists,
*running* iff a claim exists without a shard, *failed* (terminally) iff its
attempt count reached ``max_retries + 1`` without a shard, else *pending*.
All transitions are single atomic filesystem operations (exclusive create
for claims, ``os.replace`` for shards), so concurrent workers — including
workers on different hosts — never need locks beyond the filesystem's own,
and a crashed run resumes by simply pointing new workers at the directory
(after :meth:`Manifest.reclaim_stale` clears claims whose owners died).
"""

from __future__ import annotations

import dataclasses
import errno
import json
import os
import re
import socket
import time
from typing import Any, Dict, List, Optional

from repro.explore.spec import SweepSpec
from repro.utils.atomicio import atomic_write_json

FLEET_SCHEMA = 1


class ManifestError(RuntimeError):
    pass


def _sanitize(label: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.+-]", "_", label)


def cell_id_for(idx: int, model: str, system: str) -> str:
    """Stable, filesystem-safe cell id; the ``c<idx>`` prefix keeps ids
    unique even when model/system labels collide and preserves the serial
    iteration order under a lexical sort."""
    return f"c{idx:04d}--{_sanitize(model)}--{_sanitize(system)}"


@dataclasses.dataclass(frozen=True)
class CellInfo:
    """One (model, system) cell of the sweep fan-out."""

    id: str
    index: int          # position in serial Campaign.run order
    model_idx: int      # index into sweep.models
    system_idx: int     # index into sweep.systems
    model: str          # labels, for reports and humans
    system: str

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CellInfo":
        return cls(id=d["id"], index=int(d["index"]),
                   model_idx=int(d["model_idx"]),
                   system_idx=int(d["system_idx"]),
                   model=d["model"], system=d["system"])


def _writer_uniq() -> str:
    """Per-process unique suffix for tmp/record file names.  pid alone is
    not enough on a manifest directory shared across hosts (two hosts can
    run the same pid); the sanitized hostname disambiguates."""
    return f"{_sanitize(socket.gethostname())}-{os.getpid()}"


# manifest/shard/failure records publish through the shared write-temp-
# then-replace helper (repro.utils.atomicio); claims are the one artifact
# with a different discipline (content-first O_EXCL link, see claim())
_write_atomic = atomic_write_json


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except OSError as e:
        return e.errno == errno.EPERM   # exists but not ours
    return True


class Manifest:
    """Handle on a manifest directory; see the module docstring for layout
    and state semantics."""

    def __init__(self, path: str, meta: Dict[str, Any]):
        self.path = os.path.abspath(path)
        self.meta = meta
        self.cells: List[CellInfo] = [CellInfo.from_dict(c)
                                      for c in meta["cells"]]
        self._sweep: Optional[SweepSpec] = None

    # -- creation / loading --------------------------------------------------
    @classmethod
    def create(cls, path: str, sweep: SweepSpec,
               max_retries: int = 2) -> "Manifest":
        """Create (or idempotently reopen) a manifest for ``sweep``.

        Reopening an existing directory succeeds only when its
        ``spec_hash`` matches — resuming a crashed run is the common case —
        and raises :class:`ManifestError` otherwise, so two different
        sweeps can never interleave shards in one directory.
        """
        spec_hash = sweep.spec_hash()
        mpath = os.path.join(path, "manifest.json")
        if os.path.exists(mpath):
            m = cls.load(path)
            if m.spec_hash != spec_hash:
                raise ManifestError(
                    f"manifest {path} already exists for a different sweep "
                    f"(spec_hash {m.spec_hash[:12]} != {spec_hash[:12]}); "
                    f"use a fresh directory")
            return m
        cells = [CellInfo(id=cell_id_for(i, ml, sl), index=i,
                          model_idx=mi, system_idx=si, model=ml, system=sl)
                 for i, (mi, ml, si, sl) in enumerate(
                     (mi, m.label, si, s.label)
                     for mi, m in enumerate(sweep.models)
                     for si, s in enumerate(sweep.systems))]
        meta = {
            "fleet_schema": FLEET_SCHEMA,
            "spec_hash": spec_hash,
            "max_retries": int(max_retries),
            "created": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "sweep": sweep.to_dict(),
            "cells": [c.to_dict() for c in cells],
        }
        os.makedirs(path, exist_ok=True)
        for sub in ("claims", "shards", "failed"):
            os.makedirs(os.path.join(path, sub), exist_ok=True)
        _write_atomic(mpath, meta)
        return cls(path, meta)

    @classmethod
    def load(cls, path: str) -> "Manifest":
        mpath = os.path.join(path, "manifest.json")
        try:
            with open(mpath) as f:
                meta = json.load(f)
        except FileNotFoundError:
            raise ManifestError(f"no manifest.json in {path}; create one "
                                f"with Campaign.to_manifest() or "
                                f"`python -m repro.fleet init`")
        except (OSError, json.JSONDecodeError) as e:
            raise ManifestError(f"unreadable manifest {mpath}: {e}")
        if meta.get("fleet_schema") != FLEET_SCHEMA:
            raise ManifestError(
                f"manifest {path} has fleet_schema="
                f"{meta.get('fleet_schema')!r}, this code speaks "
                f"{FLEET_SCHEMA}")
        for sub in ("claims", "shards", "failed"):
            os.makedirs(os.path.join(path, sub), exist_ok=True)
        return cls(path, meta)

    # -- basic accessors -----------------------------------------------------
    @property
    def spec_hash(self) -> str:
        return self.meta["spec_hash"]

    @property
    def max_retries(self) -> int:
        return int(self.meta.get("max_retries", 2))

    @property
    def sweep(self) -> SweepSpec:
        if self._sweep is None:
            self._sweep = SweepSpec.from_dict(self.meta["sweep"])
        return self._sweep

    def _claim_path(self, cell_id: str) -> str:
        return os.path.join(self.path, "claims", f"{cell_id}.claim")

    def _shard_path(self, cell_id: str) -> str:
        return os.path.join(self.path, "shards", f"{cell_id}.json")

    def _failed_path(self, cell_id: str, attempt: int) -> str:
        # writer suffix: two workers racing to record the same attempt
        # number (possible only through reclaim races) append two records
        # instead of silently overwriting one
        return os.path.join(
            self.path, "failed",
            f"{cell_id}.attempt{attempt}-{_writer_uniq()}.json")

    # -- derived state -------------------------------------------------------
    _ATTEMPT_RE = re.compile(r"^(?P<cell>.+)\.attempt\d+-[\w.+-]+\.json$")

    def _failure_counts(self) -> Dict[str, int]:
        """One ``failed/`` listing -> per-cell attempt counts (workers scan
        every cell per loop iteration; per-cell listdir would be
        O(cells × failures) metadata traffic on a shared filesystem)."""
        counts: Dict[str, int] = {}
        for n in os.listdir(os.path.join(self.path, "failed")):
            m = self._ATTEMPT_RE.match(n)
            if m:
                cell = m.group("cell")
                counts[cell] = counts.get(cell, 0) + 1
        return counts

    def attempts(self, cell_id: str) -> int:
        return self._failure_counts().get(cell_id, 0)

    def _state(self, cell_id: str, attempts: int) -> str:
        if os.path.exists(self._shard_path(cell_id)):
            return "done"
        if os.path.exists(self._claim_path(cell_id)):
            return "running"
        if attempts > self.max_retries:
            return "failed"
        return "pending"

    def cell_state(self, cell_id: str) -> str:
        return self._state(cell_id, self.attempts(cell_id))

    def cells_in_state(self, state: str) -> List[CellInfo]:
        counts = self._failure_counts()
        return [c for c in self.cells
                if self._state(c.id, counts.get(c.id, 0)) == state]

    def pending_cells(self) -> List[CellInfo]:
        return self.cells_in_state("pending")

    def complete(self) -> bool:
        """Every cell either done or terminally failed."""
        counts = self._failure_counts()
        return all(self._state(c.id, counts.get(c.id, 0))
                   in ("done", "failed") for c in self.cells)

    def status(self) -> Dict[str, Any]:
        counts: Dict[str, int] = {"pending": 0, "running": 0, "done": 0,
                                  "failed": 0}
        fails = self._failure_counts()
        for c in self.cells:
            counts[self._state(c.id, fails.get(c.id, 0))] += 1
        return {"cells": len(self.cells), **counts,
                "spec_hash": self.spec_hash[:12]}

    # -- transitions (all single atomic fs ops) ------------------------------
    def claim(self, cell_id: str, worker_id: str) -> bool:
        """Atomically claim a cell; False when another worker holds it.

        The claim body is written to a private tmp file and ``os.link``-ed
        into place, so the claim appears *with its content* in one atomic
        step — a half-written claim can never exist for ``reclaim_stale``
        (which treats unreadable claims as crashed) to steal mid-write.
        """
        cpath = self._claim_path(cell_id)
        tmp = f"{cpath}.tmp.{_writer_uniq()}"
        with open(tmp, "w") as f:
            json.dump({"worker": worker_id, "pid": os.getpid(),
                       "host": socket.gethostname(),
                       "time": time.time()}, f)
            f.flush()
            os.fsync(f.fileno())
        try:
            os.link(tmp, cpath)
            return True
        except FileExistsError:
            return False
        finally:
            os.unlink(tmp)

    def refresh_claim(self, cell_id: str) -> bool:
        """Refresh the lease on a held claim (bump its mtime).

        Workers call this periodically while executing a cell so that a
        TTL-based :meth:`reclaim_stale` (``lease_ttl_s``) can distinguish
        a *hung* worker (claim held, lease never refreshed) from a slow
        but live one.  The bumped mtime also makes any in-progress
        reclaimer's identity re-check fail, so a refresh doubles as
        protection against a concurrent steal.  Returns False when the
        claim no longer exists (already released or reclaimed)."""
        try:
            os.utime(self._claim_path(cell_id))
            return True
        except FileNotFoundError:
            return False

    def release(self, cell_id: str) -> None:
        try:
            os.unlink(self._claim_path(cell_id))
        except FileNotFoundError:
            pass

    def write_shard(self, cell_id: str, entry: Dict[str, Any],
                    worker_id: str = "?") -> None:
        """Publish a finished cell (atomic) and drop its claim."""
        _write_atomic(self._shard_path(cell_id),
                      {"fleet_schema": FLEET_SCHEMA, "cell": cell_id,
                       "spec_hash": self.spec_hash, "worker": worker_id,
                       "entry": entry})
        self.release(cell_id)

    def read_shard(self, cell_id: str) -> Dict[str, Any]:
        with open(self._shard_path(cell_id)) as f:
            shard = json.load(f)
        if shard.get("spec_hash") != self.spec_hash:
            raise ManifestError(
                f"shard {cell_id} was produced by a different sweep "
                f"(spec_hash mismatch)")
        return shard["entry"]

    def record_failure(self, cell_id: str, worker_id: str,
                       error: str) -> int:
        """Record one failed attempt and free the cell for retry; returns
        the attempt count so far."""
        n = self.attempts(cell_id) + 1
        _write_atomic(self._failed_path(cell_id, n),
                      {"cell": cell_id, "worker": worker_id, "error": error,
                       "attempt": n, "time": time.time()})
        self.release(cell_id)
        return n

    def failure_records(self, cell_id: str) -> List[Dict[str, Any]]:
        fdir = os.path.join(self.path, "failed")
        prefix = f"{cell_id}.attempt"
        out = []
        for name in sorted(n for n in os.listdir(fdir)
                           if n.startswith(prefix)
                           and self._ATTEMPT_RE.match(n)):
            try:
                with open(os.path.join(fdir, name)) as f:
                    out.append(json.load(f))
            except (OSError, json.JSONDecodeError):
                pass
        out.sort(key=lambda r: (r.get("attempt", 0), r.get("time", 0)))
        return out

    # -- crash recovery ------------------------------------------------------
    # minimum claim age before reclaim may touch it: a decision made from a
    # stale read can then never hit a *freshly re-acquired* claim (new claims
    # have a new mtime), which closes the unlink-a-live-claim race between
    # concurrent reclaimers
    _RECLAIM_GRACE_S = 2.0

    def reclaim_stale(self, force: bool = False,
                      lease_ttl_s: Optional[float] = None) -> List[str]:
        """Remove claims whose owning process is provably gone or whose
        lease expired.

        A claim is stale when its recorded pid is dead *on this host*
        (claims from other hosts can't be probed, so they are only removed
        with ``force=True`` — use after confirming the remote workers are
        down), or — with ``lease_ttl_s`` — when its mtime is older than the
        TTL: live workers refresh their claim's mtime periodically
        (:meth:`refresh_claim`), so an expired lease means the worker is
        dead **or hung**, on any host.  Claims younger than a short grace
        period are never touched, and the claim file's identity
        (inode + mtime) is re-verified immediately before the unlink, so a
        claim re-acquired — or lease-refreshed — by a live worker after
        this reclaimer's read cannot be deleted by mistake.  Returns the
        reclaimed cell ids.
        """
        if lease_ttl_s is not None and lease_ttl_s <= 0:
            raise ValueError(f"lease_ttl_s must be > 0, got {lease_ttl_s}")
        host = socket.gethostname()
        reclaimed = []
        for c in self.cells:
            cpath = self._claim_path(c.id)
            if os.path.exists(self._shard_path(c.id)):
                continue
            try:
                st = os.stat(cpath)
            except FileNotFoundError:
                continue
            age = time.time() - st.st_mtime
            if age < self._RECLAIM_GRACE_S:
                continue
            stale = force or (lease_ttl_s is not None and age > lease_ttl_s)
            if not stale:
                try:
                    with open(cpath) as f:
                        claim = json.load(f)
                    stale = (claim.get("host") == host
                             and not _pid_alive(int(claim.get("pid", -1))))
                except (OSError, json.JSONDecodeError, ValueError):
                    stale = True      # unreadable claim: treat as crashed
            if not stale:
                continue
            try:                      # the claim we judged is still the one
                st2 = os.stat(cpath)  # on disk (claims are never rewritten
            except FileNotFoundError:  # in place, only created/unlinked)
                continue
            if (st2.st_ino, st2.st_mtime_ns) != (st.st_ino, st.st_mtime_ns):
                continue
            self.release(c.id)
            reclaimed.append(c.id)
        return reclaimed
